#!/usr/bin/env bash
# Deprecation-surface check for the SelectionEngine facade (PR 5).
#
# The coordinator's rank-authority plumbing (`with_rank_authority`,
# `last_rank_decision`) is engine-internal wiring: inside `rust/src/`,
# only `engine/` (which owns the wiring) and `coordinator/` (which defines
# it) may touch it.  Everything else — trainer, CLI, cmd, examples-adjacent
# code — must go through `SelectionEngine`, whose `Selection` result and
# `rank_stats()` replace the side channels.  Tests and benches still pin
# the engine AGAINST direct construction, so they are exempt (the grep
# covers `rust/src/` only, matching the PR 5 issue contract).
#
# Usage: scripts/check_facade.sh   (run from the repo root; CI does)
set -u
cd "$(dirname "$0")/.."

hits=$(grep -rn --include='*.rs' -e 'with_rank_authority' -e 'last_rank_decision' rust/src \
  | grep -v '^rust/src/engine/' \
  | grep -v '^rust/src/coordinator/')

if [ -n "$hits" ]; then
  echo "facade violation: rank-authority side channels used outside engine/ and coordinator/:"
  echo "$hits"
  echo
  echo "Route new callers through graft::engine::SelectionEngine instead"
  echo "(Selection.decision / SelectionEngine::rank_stats)."
  exit 1
fi

# CLI subcommands must build selections through EngineBuilder, never by
# hand-wiring selectors (the PR 10 cmd/ audit).  `select_rows` is the one
# carve-out: CrossMaxVol's (rows, cols) cross skeleton has no engine
# expression, and table4 documents why at the call site.
cmd_hits=$(grep -rn --include='*.rs' \
    -e 'selection::by_name' \
    -e 'fast_maxvol(' \
    -e '\.select_into(' \
    -e 'ShardedSelector::new' \
    -e 'PooledSelector::new' \
    -e 'with_grad_pivot' \
    rust/src/cmd || true)

if [ -n "$cmd_hits" ]; then
  echo "facade violation: cmd/ wires selectors directly instead of using EngineBuilder:"
  echo "$cmd_hits"
  echo
  echo "Build the selection through graft::engine::EngineBuilder (method/"
  echo "budget/pivot knobs) so typed EngineErrors surface on the CLI."
  exit 1
fi
echo "facade surface clean: no out-of-facade rank-authority plumbing in rust/src/,"
echo "no hand-wired selectors in rust/src/cmd/"

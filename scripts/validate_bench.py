#!/usr/bin/env python3
"""Validate a graft-bench-v1 or graft-scenario-v1 JSON file.

Usage: scripts/validate_bench.py [--schema bench|scenario] [--allow-empty]
       [--strict] [--require NAME ...] FILE [FILE ...]

With --schema bench (the default; emitted by benches/bench_util.rs),
checks per file:
  * top-level object with "schema": "graft-bench-v1" and a "records" list
  * every record has string "bench"/"op"/"shape" (non-empty) and finite,
    non-negative "mean_ns"/"std_ns"/"min_ns" numbers with min <= mean
  * at least one record, unless --allow-empty (the committed placeholder
    BENCH_pr1.json is empty until scripts/bench.sh runs on a machine with
    a Rust toolchain)
  * every --require NAME (repeatable) appears as the "op" of at least one
    record — how CI pins that a bench family (e.g. the PR 3 "select_pooled"
    pool rows) cannot silently stop emitting

With --schema scenario (emitted by `graft scenarios`), checks per file:
  * top-level object with "schema": "graft-scenario-v1" and a "rows" list
  * every row has string "scenario"/"method"/"shape" (non-empty), finite
    numbers for the metric fields with fraction in (0, 1], budget >= 1,
    and the [0, 1]-bounded metrics (grad_error/coverage/probe_acc) in
    range
  * every --require NAME appears as the "method" of at least one row — how
    the scenario-smoke CI job pins that the roster (e.g. graft+gradpivot,
    hybrid) cannot silently shrink

A file whose top-level "note" marks it as a placeholder (the string
"placeholder", any case) gets a non-fatal WARNING on stderr, so a
committed BENCH_*.json that was never populated with real rows is
visible in CI logs without failing the build.  Under --strict the
warning is promoted to an error: jobs that validate freshly-produced
telemetry (the serve-smoke job) must never accept a placeholder.

Exit status 0 when every file passes, 1 otherwise.  Stdlib only.
"""

import json
import math
import sys

SCHEMA = "graft-bench-v1"
STR_FIELDS = ("bench", "op", "shape")
NUM_FIELDS = ("mean_ns", "std_ns", "min_ns")

SCENARIO_SCHEMA = "graft-scenario-v1"
SCENARIO_STR_FIELDS = ("scenario", "method", "shape")
SCENARIO_NUM_FIELDS = (
    "fraction",
    "budget",
    "grad_error",
    "coverage",
    "mean_loss",
    "probe_acc",
    "mean_rank",
    "degraded",
    "seed",
)
SCENARIO_UNIT_FIELDS = ("grad_error", "coverage", "probe_acc")


def validate(path, allow_empty, require=()):
    errors = []
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        return [f"unreadable or invalid JSON: {exc}"]

    if not isinstance(doc, dict):
        return ["top level is not an object"]
    if doc.get("schema") != SCHEMA:
        errors.append(f'schema is {doc.get("schema")!r}, want {SCHEMA!r}')
    records = doc.get("records")
    if not isinstance(records, list):
        return errors + ["'records' is missing or not a list"]
    if not records and not allow_empty:
        errors.append("no records (pass --allow-empty for placeholder files)")

    for i, rec in enumerate(records):
        where = f"records[{i}]"
        if not isinstance(rec, dict):
            errors.append(f"{where}: not an object")
            continue
        for f in STR_FIELDS:
            v = rec.get(f)
            if not isinstance(v, str) or not v:
                errors.append(f"{where}.{f}: want non-empty string, got {v!r}")
        for f in NUM_FIELDS:
            v = rec.get(f)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errors.append(f"{where}.{f}: want number, got {v!r}")
            elif not math.isfinite(v) or v < 0:
                errors.append(f"{where}.{f}: want finite >= 0, got {v!r}")
        mean, mn = rec.get("mean_ns"), rec.get("min_ns")
        if isinstance(mean, (int, float)) and isinstance(mn, (int, float)):
            # time_it's min is over the same samples the mean is over.
            if mn > mean * 1.000001:
                errors.append(f"{where}: min_ns {mn} > mean_ns {mean}")
        extra = set(rec) - set(STR_FIELDS) - set(NUM_FIELDS)
        if extra:
            errors.append(f"{where}: unknown fields {sorted(extra)}")
    ops = {rec.get("op") for rec in records if isinstance(rec, dict)}
    for op in require:
        if op not in ops:
            errors.append(f"required op {op!r} has no records")
    return errors


def validate_scenario(path, allow_empty, require=()):
    errors = []
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        return [f"unreadable or invalid JSON: {exc}"]

    if not isinstance(doc, dict):
        return ["top level is not an object"]
    if doc.get("schema") != SCENARIO_SCHEMA:
        errors.append(f'schema is {doc.get("schema")!r}, want {SCENARIO_SCHEMA!r}')
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return errors + ["'rows' is missing or not a list"]
    if not rows and not allow_empty:
        errors.append("no rows (pass --allow-empty to accept an empty matrix)")

    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            errors.append(f"{where}: not an object")
            continue
        for f in SCENARIO_STR_FIELDS:
            v = row.get(f)
            if not isinstance(v, str) or not v:
                errors.append(f"{where}.{f}: want non-empty string, got {v!r}")
        for f in SCENARIO_NUM_FIELDS:
            v = row.get(f)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errors.append(f"{where}.{f}: want number, got {v!r}")
            elif not math.isfinite(v) or v < 0:
                errors.append(f"{where}.{f}: want finite >= 0, got {v!r}")
        frac = row.get("fraction")
        if isinstance(frac, (int, float)) and not 0 < frac <= 1:
            errors.append(f"{where}.fraction: want in (0, 1], got {frac!r}")
        budget = row.get("budget")
        if isinstance(budget, (int, float)) and budget < 1:
            errors.append(f"{where}.budget: want >= 1, got {budget!r}")
        for f in SCENARIO_UNIT_FIELDS:
            v = row.get(f)
            if isinstance(v, (int, float)) and v > 1 + 1e-9:
                errors.append(f"{where}.{f}: want <= 1, got {v!r}")
        extra = set(row) - set(SCENARIO_STR_FIELDS) - set(SCENARIO_NUM_FIELDS)
        if extra:
            errors.append(f"{where}: unknown fields {sorted(extra)}")
    methods = {row.get("method") for row in rows if isinstance(row, dict)}
    for m in require:
        if m not in methods:
            errors.append(f"required method {m!r} has no rows")
    return errors


def placeholder_note(path):
    """The top-level "note" when it marks the file as a placeholder, else None."""
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(doc, dict):
        return None
    note = doc.get("note")
    if isinstance(note, str) and "placeholder" in note.lower():
        return note
    return None


def main(argv):
    allow_empty = False
    strict = False
    schema = "bench"
    require = []
    args = []
    it = iter(argv)
    for a in it:
        if a == "--allow-empty":
            allow_empty = True
        elif a == "--strict":
            strict = True
        elif a == "--schema":
            schema = next(it, None)
            if schema not in ("bench", "scenario"):
                print("error: --schema wants 'bench' or 'scenario'", file=sys.stderr)
                return 1
        elif a == "--require":
            op = next(it, None)
            if op is None:
                print("error: --require needs a name", file=sys.stderr)
                return 1
            require.append(op)
        else:
            args.append(a)
    if not args:
        print(__doc__.strip())
        return 1
    rows_key = "records" if schema == "bench" else "rows"
    failed = False
    for path in args:
        if schema == "bench":
            note = placeholder_note(path)
            errs = validate(path, allow_empty, require)
            if note is not None:
                if strict:
                    errs.append(f"placeholder bench file under --strict ({note})")
                else:
                    print(f"WARNING {path}: placeholder bench file ({note})", file=sys.stderr)
        else:
            errs = validate_scenario(path, allow_empty, require)
        if errs:
            failed = True
            print(f"FAIL {path}")
            for e in errs:
                print(f"  - {e}")
        else:
            with open(path, encoding="utf-8") as fh:
                n = len(json.load(fh).get(rows_key, []))
            print(f"OK   {path} ({n} {rows_key})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Compare two graft-bench-v1 JSON documents and fail on perf regressions.

Usage:
  scripts/bench_compare.py [options] BASELINE CURRENT
  scripts/bench_compare.py --self-test

Records are matched by the (bench, op, shape) triple.  For every pair
present in both documents the ratio current/baseline is computed for
both mean_ns and min_ns; a pair only counts as a regression when BOTH
ratios exceed the family threshold — requiring the minimum to move too
filters out one-off scheduler jitter in the mean.  Unmatched rows are
reported (baseline-only rows usually mean a family was renamed or
silently dropped; current-only rows are new families) but never fatal:
the family-coverage gate is scripts/validate_bench.py --require.

Thresholds are per-family: the op name is matched against the keys of
the threshold table by longest prefix, so "matmul_simd" picks the
"matmul" entry unless a more specific "matmul_simd" one exists.
Override or extend with --threshold FAMILY=RATIO (repeatable) and
--default-threshold.  Pairs where both baseline numbers sit under the
noise floor (--min-ns, default 10000) are skipped: smoke-sized runs
bottom out at microseconds where ratios are meaningless.

A BASELINE that is empty or carries a placeholder top-level "note"
(the committed BENCH_pr1.json until scripts/bench.sh runs on a machine
with a Rust toolchain) makes the comparison a no-op: a SKIP notice is
printed and the exit status is 0, so CI stays green until a real
baseline lands — at which point regressions start failing the build.
An empty/placeholder CURRENT is always an error (the smoke run just
produced it; it must have rows).

--self-test runs the comparator against in-memory fixtures — identical
documents must pass, an injected 2x regression must fail, a placeholder
baseline must skip — and exits non-zero if any expectation breaks.

Exit status: 0 = no regression (or baseline skip), 1 = regression or
invalid input.  Stdlib only.
"""

import json
import sys

SCHEMA = "graft-bench-v1"

# Per-family regression thresholds (current/baseline ratio on BOTH
# mean_ns and min_ns).  Keys are op-name prefixes; longest prefix wins.
# Microkernels get a tight leash; end-to-end families that cross thread
# pools and channels breathe harder between runners.
DEFAULT_THRESHOLDS = {
    "matmul": 1.25,
    "gram": 1.25,
    "mgs": 1.25,
    "fast_maxvol": 1.25,
    "select_single": 1.30,
    "select_strict_nocarry": 1.30,
    "select_sharded": 1.40,
    "select_pooled": 1.40,
    "select_engine": 1.40,
    "select_faultpath": 1.40,
    "select_streaming": 1.40,
    "serve": 1.50,
}
DEFAULT_FALLBACK = 1.25
NOISE_FLOOR_NS = 10_000.0


def load(path):
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        return None, f"{path}: unreadable or invalid JSON: {exc}"
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        return None, f"{path}: not a {SCHEMA} document"
    if not isinstance(doc.get("records"), list):
        return None, f"{path}: 'records' is missing or not a list"
    return doc, None


def is_placeholder(doc):
    note = doc.get("note")
    return isinstance(note, str) and "placeholder" in note.lower()


def index(doc):
    out = {}
    for rec in doc["records"]:
        if not isinstance(rec, dict):
            continue
        key = (rec.get("bench"), rec.get("op"), rec.get("shape"))
        if all(isinstance(k, str) and k for k in key):
            out[key] = rec
    return out


def threshold_for(op, thresholds, fallback):
    best = None
    for prefix, ratio in thresholds.items():
        if op.startswith(prefix) and (best is None or len(prefix) > len(best[0])):
            best = (prefix, ratio)
    return best[1] if best else fallback


def compare(baseline, current, thresholds, fallback, floor, out=sys.stdout):
    """Diff two parsed documents; returns the list of regression strings."""
    base, cur = index(baseline), index(current)
    regressions = []
    skipped = 0
    for key in sorted(base.keys() & cur.keys()):
        b, c = base[key], cur[key]
        try:
            bm, bn = float(b["mean_ns"]), float(b["min_ns"])
            cm, cn = float(c["mean_ns"]), float(c["min_ns"])
        except (KeyError, TypeError, ValueError):
            regressions.append(f"{key}: malformed timing fields")
            continue
        if bm < floor and bn < floor:
            skipped += 1
            continue
        limit = threshold_for(key[1], thresholds, fallback)
        mean_ratio = cm / bm if bm > 0 else float("inf")
        min_ratio = cn / bn if bn > 0 else float("inf")
        tag = f"{key[1]} [{key[2]}]"
        if mean_ratio > limit and min_ratio > limit:
            regressions.append(
                f"{tag}: mean {bm:.0f} -> {cm:.0f} ns ({mean_ratio:.2f}x), "
                f"min {bn:.0f} -> {cn:.0f} ns ({min_ratio:.2f}x), limit {limit:.2f}x"
            )
            print(f"REGRESS {regressions[-1]}", file=out)
        else:
            print(f"ok      {tag}: mean {mean_ratio:.2f}x, min {min_ratio:.2f}x", file=out)
    for key in sorted(base.keys() - cur.keys()):
        print(f"note    baseline-only row (dropped or renamed?): {key}", file=out)
    for key in sorted(cur.keys() - base.keys()):
        print(f"note    new row with no baseline: {key}", file=out)
    if skipped:
        print(f"note    {skipped} pair(s) under the {floor:.0f} ns noise floor skipped", file=out)
    return regressions


def run(baseline_path, current_path, thresholds, fallback, floor):
    baseline, err = load(baseline_path)
    if err:
        print(f"error: {err}", file=sys.stderr)
        return 1
    current, err = load(current_path)
    if err:
        print(f"error: {err}", file=sys.stderr)
        return 1
    if not current["records"] or is_placeholder(current):
        print(f"error: {current_path}: current document is empty or a placeholder", file=sys.stderr)
        return 1
    if not baseline["records"] or is_placeholder(baseline):
        print(
            f"SKIP: baseline {baseline_path} is empty or a placeholder — nothing to compare "
            "against yet (run scripts/bench.sh on a real machine to populate it)"
        )
        return 0
    regressions = compare(baseline, current, thresholds, fallback, floor)
    if regressions:
        print(f"FAIL: {len(regressions)} regression(s) vs {baseline_path}")
        return 1
    print(f"PASS: no regressions vs {baseline_path}")
    return 0


def fixture(scale=1.0, note=None, empty=False):
    rows = []
    if not empty:
        for op, shape, mean in [
            ("matmul_simd", "M=256,K=256,N=256", 4.0e6),
            ("gram_simd", "M=4096,N=64", 2.0e6),
            ("select_sharded", "K=4096,R=64,shards=4", 9.0e6),
        ]:
            rows.append(
                {
                    "bench": "fixture",
                    "op": op,
                    "shape": shape,
                    "mean_ns": mean * scale,
                    "std_ns": mean * 0.02,
                    "min_ns": mean * 0.95 * scale,
                }
            )
    doc = {"schema": SCHEMA, "records": rows}
    if note is not None:
        doc["note"] = note
    return doc


def self_test():
    import io

    failures = []

    def expect(label, got_regressions, want_any):
        if bool(got_regressions) != want_any:
            failures.append(f"{label}: want regressions={want_any}, got {got_regressions}")

    sink = io.StringIO()
    base = fixture()
    expect(
        "identical documents",
        compare(base, fixture(), DEFAULT_THRESHOLDS, DEFAULT_FALLBACK, NOISE_FLOOR_NS, sink),
        False,
    )
    expect(
        "injected 2x regression",
        compare(base, fixture(2.0), DEFAULT_THRESHOLDS, DEFAULT_FALLBACK, NOISE_FLOOR_NS, sink),
        True,
    )
    expect(
        "improvement",
        compare(base, fixture(0.5), DEFAULT_THRESHOLDS, DEFAULT_FALLBACK, NOISE_FLOOR_NS, sink),
        False,
    )
    # Mean spikes but min holds: jitter, not a regression.
    spiky = fixture()
    for rec in spiky["records"]:
        rec["mean_ns"] *= 2.0
    expect(
        "mean-only spike",
        compare(base, spiky, DEFAULT_THRESHOLDS, DEFAULT_FALLBACK, NOISE_FLOOR_NS, sink),
        False,
    )
    # Placeholder / empty baselines must skip (exit 0) end to end.
    import os
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        b, c = os.path.join(td, "b.json"), os.path.join(td, "c.json")
        with open(c, "w", encoding="utf-8") as fh:
            json.dump(fixture(), fh)
        for label, doc in [
            ("placeholder baseline", fixture(note="placeholder until bench.sh runs")),
            ("empty baseline", fixture(empty=True)),
        ]:
            with open(b, "w", encoding="utf-8") as fh:
                json.dump(doc, fh)
            rc = run(b, c, DEFAULT_THRESHOLDS, DEFAULT_FALLBACK, NOISE_FLOOR_NS)
            if rc != 0:
                failures.append(f"{label}: want skip (exit 0), got {rc}")
        # And a real baseline against a regressed current must exit 1.
        with open(b, "w", encoding="utf-8") as fh:
            json.dump(fixture(), fh)
        with open(c, "w", encoding="utf-8") as fh:
            json.dump(fixture(2.0), fh)
        rc = run(b, c, DEFAULT_THRESHOLDS, DEFAULT_FALLBACK, NOISE_FLOOR_NS)
        if rc != 1:
            failures.append(f"regressed current: want exit 1, got {rc}")

    if failures:
        for f in failures:
            print(f"SELF-TEST FAIL {f}", file=sys.stderr)
        return 1
    print("SELF-TEST PASS (6 scenarios)")
    return 0


def main(argv):
    thresholds = dict(DEFAULT_THRESHOLDS)
    fallback = DEFAULT_FALLBACK
    floor = NOISE_FLOOR_NS
    paths = []
    it = iter(argv)
    for a in it:
        if a == "--self-test":
            return self_test()
        if a == "--threshold":
            spec = next(it, None)
            if spec is None or "=" not in spec:
                print("error: --threshold needs FAMILY=RATIO", file=sys.stderr)
                return 1
            family, _, ratio = spec.partition("=")
            try:
                thresholds[family] = float(ratio)
            except ValueError:
                print(f"error: bad ratio in {spec!r}", file=sys.stderr)
                return 1
        elif a == "--default-threshold":
            v = next(it, None)
            try:
                fallback = float(v)
            except (TypeError, ValueError):
                print("error: --default-threshold needs a number", file=sys.stderr)
                return 1
        elif a == "--min-ns":
            v = next(it, None)
            try:
                floor = float(v)
            except (TypeError, ValueError):
                print("error: --min-ns needs a number", file=sys.stderr)
                return 1
        else:
            paths.append(a)
    if len(paths) != 2:
        print(__doc__.strip())
        return 1
    return run(paths[0], paths[1], thresholds, fallback, floor)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env bash
# End-to-end smoke of the selection-as-a-service daemon (CI's serve-smoke
# job): start `graft serve` on an OS-assigned port, drive a mixed
# multi-tenant client fleet against it with `graft serve-smoke` (which
# fails unless every served selection is bit-identical to an in-process
# engine), then validate the daemon's Stats telemetry as strict
# graft-bench-v1 — a placeholder or malformed stats file fails the job.
#
# Usage: scripts/serve_smoke.sh
set -euo pipefail

cd "$(dirname "$0")/.."

BIN="${GRAFT_BIN:-target/release/graft}"
if [[ ! -x "$BIN" ]]; then
  echo "== building release binary =="
  cargo build --release
fi

WORK="$(mktemp -d)"
ADDR_FILE="$WORK/addr"
STATS="$WORK/serve_stats.json"
SERVER_PID=""

cleanup() {
  if [[ -n "$SERVER_PID" ]] && kill -0 "$SERVER_PID" 2>/dev/null; then
    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
  fi
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== starting graft serve (port 0, addr via $ADDR_FILE) =="
"$BIN" serve --addr 127.0.0.1:0 --addr-file "$ADDR_FILE" &
SERVER_PID=$!

# The daemon writes the bound address (newline-terminated) once it is
# accepting — poll for it rather than sleeping a fixed amount.
for _ in $(seq 1 100); do
  [[ -s "$ADDR_FILE" ]] && break
  if ! kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "FAIL: daemon exited before publishing its address" >&2
    exit 1
  fi
  sleep 0.1
done
ADDR="$(head -n1 "$ADDR_FILE")"
if [[ -z "$ADDR" ]]; then
  echo "FAIL: daemon never published its address" >&2
  exit 1
fi
echo "daemon listening on $ADDR (pid $SERVER_PID)"

echo "== driving the multi-tenant smoke fleet =="
"$BIN" serve-smoke --addr "$ADDR" --tenants 4 --windows 3 --stats-out "$STATS"

echo "== validating served telemetry (strict graft-bench-v1) =="
python3 scripts/validate_bench.py --strict \
  --require serve_select --require serve_push --require serve_snapshot \
  "$STATS"

echo "serve smoke passed"

#!/usr/bin/env bash
# Run the two hot-path benches and collect their rows into BENCH_pr1.json
# at the repo root (schema graft-bench-v1; see benches/bench_util.rs).
#
# Usage: scripts/bench.sh
# Override the output path with GRAFT_BENCH_JSON=/path/to/file.json.
set -euo pipefail

cd "$(dirname "$0")/.."
export GRAFT_BENCH_JSON="${GRAFT_BENCH_JSON:-$PWD/BENCH_pr1.json}"

echo "== building release benches =="
cargo bench --bench table4_maxvol
cargo bench --bench runtime_hotpath

echo
echo "== bench JSON ($GRAFT_BENCH_JSON) =="
cat "$GRAFT_BENCH_JSON"

#!/usr/bin/env bash
# Run the hot-path benches and collect their rows into BENCH_pr1.json
# at the repo root (schema graft-bench-v1; see benches/bench_util.rs).
#
# Usage: scripts/bench.sh
# Override the output path with GRAFT_BENCH_JSON=/path/to/file.json.
# GRAFT_BENCH_SMOKE=1 shrinks shapes/reps (the CI smoke job uses this).
set -euo pipefail

cd "$(dirname "$0")/.."
export GRAFT_BENCH_JSON="${GRAFT_BENCH_JSON:-$PWD/BENCH_pr1.json}"

echo "== building release benches =="
cargo bench --bench table4_maxvol
cargo bench --bench runtime_hotpath
cargo bench --bench sharded_selection

echo
echo "== bench JSON ($GRAFT_BENCH_JSON) =="
cat "$GRAFT_BENCH_JSON"
python3 scripts/validate_bench.py "$GRAFT_BENCH_JSON"

//! Minimal in-tree shim of the `anyhow` API surface this workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Behaviourally faithful where it matters:
//! - `Error` wraps any `std::error::Error + Send + Sync + 'static` (or a
//!   plain message) plus a stack of context strings;
//! - `{e}` displays the outermost context, `{e:#}` the whole chain joined
//!   with `: ` — matching anyhow's Display/alternate formatting;
//! - the blanket `From<E>` impl makes `?` work on any std error (which is
//!   why `Error` itself deliberately does NOT implement `std::error::Error`).

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: message / source error + context frames (innermost
/// first in `frames`, displayed outermost first).
pub struct Error {
    /// Context frames, most recently attached last.
    frames: Vec<String>,
    /// The root cause, if this error wraps a typed one.
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()], source: None }
    }

    /// Create an error wrapping a typed error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error { frames: Vec::new(), source: Some(Box::new(error)) }
    }

    /// Attach a context frame (becomes the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.push(context.to_string());
        self
    }

    /// The innermost message in the chain.
    pub fn root_cause_message(&self) -> String {
        if let Some(src) = &self.source {
            src.to_string()
        } else {
            self.frames.first().cloned().unwrap_or_default()
        }
    }

    /// The chain outermost-first, as anyhow's `chain()` would yield it.
    fn chain_strings(&self) -> Vec<String> {
        let mut out: Vec<String> = self.frames.iter().rev().cloned().collect();
        if let Some(src) = &self.source {
            let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(src.as_ref());
            while let Some(e) = cur {
                out.push(e.to_string());
                cur = e.source();
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        if f.alternate() {
            // `{:#}` — full chain, colon-joined.
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        match chain.split_first() {
            Some((head, rest)) if !rest.is_empty() => {
                writeln!(f, "{head}")?;
                writeln!(f, "\nCaused by:")?;
                for (i, c) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {c}")?;
                }
                Ok(())
            }
            Some((head, _)) => write!(f, "{head}"),
            None => Ok(()),
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

/// Marker so `Context` can also be implemented for `Option` without
/// overlapping the `Result` impl.
impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("reading manifest")
            .unwrap_err()
            .context("loading engine");
        assert_eq!(format!("{e}"), "loading engine");
        assert_eq!(format!("{e:#}"), "loading engine: reading manifest: file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing value");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).is_err());
        assert!(f(11).is_err());
        let e = anyhow!("plain {}", 3);
        assert_eq!(format!("{e}"), "plain 3");
    }
}

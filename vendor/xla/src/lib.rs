//! Offline stub of the PJRT/XLA binding surface `graft::runtime` consumes.
//!
//! The real bindings (PJRT C API over a CPU plugin) are not available in
//! this build environment, so this crate provides the exact types and
//! signatures the runtime layer compiles against.  [`PjRtClient::cpu`]
//! returns an error, which every caller in the workspace already handles
//! by skipping runtime-dependent work (benches, integration tests, and
//! `Engine::new` callers all degrade gracefully with a "run `make
//! artifacts`"-style message).
//!
//! Host-side [`Literal`] construction and conversion are implemented for
//! real (they are pure data plumbing and unit-tested in `runtime::exec`);
//! only device compilation/execution is unavailable.

use std::fmt;

/// Error type for every fallible stub operation.
#[derive(Debug)]
pub struct XlaError {
    message: String,
}

impl XlaError {
    fn new(message: impl Into<String>) -> Self {
        XlaError { message: message.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.message)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types literals can carry. Sealed to the two the runtime uses.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Elements {
    fn len(&self) -> usize {
        match self {
            Elements::F32(v) => v.len(),
            Elements::I32(v) => v.len(),
        }
    }
}

/// Conversion trait tying native Rust types to literal payloads.
pub trait NativeType: Sized {
    fn wrap(data: &[Self]) -> Elements;
    fn unwrap(e: &Elements) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Elements {
        Elements::F32(data.to_vec())
    }
    fn unwrap(e: &Elements) -> Result<Vec<Self>> {
        match e {
            Elements::F32(v) => Ok(v.clone()),
            Elements::I32(_) => Err(XlaError::new("literal holds i32, requested f32")),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Elements {
        Elements::I32(data.to_vec())
    }
    fn unwrap(e: &Elements) -> Result<Vec<Self>> {
        match e {
            Elements::I32(v) => Ok(v.clone()),
            Elements::F32(_) => Err(XlaError::new("literal holds f32, requested i32")),
        }
    }
}

/// A host literal: flat payload + logical dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Elements,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let wrapped = T::wrap(data);
        let n = wrapped.len() as i64;
        Literal { data: wrapped, dims: vec![n] }
    }

    /// Rank-0 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: Elements::F32(vec![v]), dims: vec![] }
    }

    /// Reinterpret with new dimensions; errors if the element count differs.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(XlaError::new(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a native vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    /// Destructure a tuple literal. The stub never produces tuples (no
    /// execution path), so this only errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::new("stub literals are never tuples"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: retains only the source path).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// Parse an HLO-text file. The stub verifies the file exists so error
    /// messages stay actionable, then defers failure to compile time.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(XlaError::new(format!("no such HLO file: {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// Computation wrapper around a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// Device buffer handle returned by execution (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new("PJRT runtime not available in this build"))
    }
}

/// Compiled executable handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new("PJRT runtime not available in this build"))
    }
}

/// PJRT client. The stub cannot create one: callers see a clean error and
/// skip runtime-dependent paths.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(
            "PJRT runtime not available: this workspace was built against the \
             offline xla stub (vendor/xla); install the real PJRT bindings and \
             point the `xla` path dependency at them to enable execution",
        ))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new("PJRT runtime not available in this build"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let l = Literal::vec1(&[0.0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[2, 2]).is_err());
        assert_eq!(l.reshape(&[3, 2]).unwrap().dims(), &[3, 2]);
    }

    #[test]
    fn client_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }

    #[test]
    fn scalar_shape() {
        let s = Literal::scalar(2.5);
        assert!(s.dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
    }
}

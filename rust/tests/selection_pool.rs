//! Deterministic concurrency-test suite for the persistent selection
//! worker pool (`coordinator::pool`) — the PR 3 acceptance criteria:
//!
//! 1. **Bit-identity**: pooled execution at workers ∈ {1, 2, 4, 8}
//!    produces exactly the subset of the scoped-thread and serial
//!    `ShardedSelector` paths, for the MaxVol family and GRAFT, at every
//!    shard count — worker count and scheduling are structurally
//!    invisible.
//! 2. **Interleaving independence**: seeded permutations of the shard
//!    result arrival order, replayed through the slot + merge protocol the
//!    pool uses, give identical subsets; repeated live pooled runs (real
//!    scheduler interleavings) agree with each other.
//! 3. **Lifecycle regressions**: drop-mid-epoch drains cleanly and leaves
//!    the pool usable, shutdown is idempotent (double shutdown + drop),
//!    a select after shutdown degrades to the coordinator-side fallback
//!    instead of deadlocking or panicking, and a panicking selector is
//!    contained — the worker, the pool, and subsequent selections all
//!    survive.
//! 4. **No-deadlock smoke**: a sustained epoch stream with interleaved
//!    abandoned epochs and varying batch shapes completes (bounded by the
//!    test runner's own timeout, it must simply never wedge).
//! 5. **Overlap equivalence**: `run_windows` with `overlap` on and off
//!    yields identical per-window selections — the trainer's pipelined
//!    refresh cannot change the training trajectory.
//!
//! `GRAFT_POOL_STRESS=1` (the CI `pool-stress` job, with
//! `--test-threads=1`) raises the iteration counts by ~20×.
//!
//! 6. **Fault-tolerance regressions** (fault-tolerance PR): a worker that
//!    panics twice in a row is respawned twice and the retried epoch is
//!    bit-identical; a panic arriving while an errored epoch drains is
//!    absorbed; killing every worker surfaces a typed [`SelectError`]
//!    instead of deadlocking.  `GRAFT_FAULT_STRESS=1` (the CI
//!    `fault-stress` job, `--test-threads=1`) raises these counts ~20×.

use std::time::Duration;

use graft::coordinator::{
    merge_winners, run_windows, FaultPolicy, MergePolicy, PooledSelector, SelectError,
    SelectWindow, ShardedSelector, WindowsError,
};
use graft::faults::FaultPlan;
use graft::graft::{BudgetedRankPolicy, GraftSelector};
use graft::linalg::{Mat, Workspace};
use graft::rng::Rng;
use graft::selection::maxvol::FastMaxVol;
use graft::selection::{by_name, BatchView, Selector};

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

/// Iteration count: `base` normally, `stress` under `GRAFT_POOL_STRESS=1`.
fn iters(base: usize, stress: usize) -> usize {
    let on = std::env::var("GRAFT_POOL_STRESS").map(|v| v != "0").unwrap_or(false);
    if on {
        stress
    } else {
        base
    }
}

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

fn scoped(shards: usize) -> ShardedSelector {
    ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, |_| Box::new(FastMaxVol))
}

fn pooled(shards: usize, workers: usize) -> PooledSelector {
    PooledSelector::from_factory(shards, workers, MergePolicy::Hierarchical, |_| {
        Box::new(FastMaxVol)
    })
}

fn assert_valid(sel: &[usize], k: usize, want: usize, ctx: &str) {
    assert_eq!(sel.len(), want, "size: {ctx}");
    let mut s = sel.to_vec();
    s.sort_unstable();
    s.dedup();
    assert_eq!(s.len(), want, "uniqueness: {ctx}");
    assert!(s.iter().all(|&i| i < k), "range: {ctx}");
}

// ---------------------------------------------------------------------------
// 1. Bit-identity: pool ≡ scoped ≡ serial
// ---------------------------------------------------------------------------

#[test]
fn pool_bit_identical_to_scoped_and_serial_fast_maxvol() {
    // k clears SHARD_PAR_MIN_K so the scoped reference really runs on
    // threads; the serial twin pins that scheduling is irrelevant there,
    // and every (shards, workers) pool shape must reproduce both exactly.
    let owned = random_owned(1024, 16, 8, 4, 31);
    let r = 48;
    for &shards in &[1usize, 2, 4, 8] {
        let serial = scoped(shards).with_parallel(false).select(&owned.view(), r);
        let threads = scoped(shards).select(&owned.view(), r);
        assert_eq!(serial, threads, "scoped serial ≡ parallel, shards={shards}");
        for &workers in &[1usize, 2, 4, 8] {
            let pool = pooled(shards, workers).select(&owned.view(), r);
            assert_eq!(
                pool, serial,
                "pool ≡ scoped ≡ serial broken at shards={shards} workers={workers}"
            );
        }
    }
}

#[test]
fn pool_bit_identical_for_graft_selector() {
    let owned = random_owned(256, 12, 16, 4, 37);
    let mk = || -> Box<dyn Selector> {
        Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
    };
    for &(shards, workers) in &[(1usize, 1usize), (4, 1), (4, 3), (8, 8)] {
        let reference =
            ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, move |_| mk())
                .with_parallel(false)
                .select(&owned.view(), 32);
        let pool =
            PooledSelector::from_factory(shards, workers, MergePolicy::Hierarchical, move |_| {
                mk()
            })
            .select(&owned.view(), 32);
        assert_eq!(pool, reference, "graft shards={shards} workers={workers}");
    }
}

#[test]
fn pool_single_shard_hosts_any_selector_bit_identically() {
    // One shard involves no merge, so the pool may host non-shardable
    // selectors (how the trainer gives every method off-thread selection
    // and the overlap path).  Results must match the plain single-shot
    // object, including across repeated calls on stateless methods.
    let owned = random_owned(96, 12, 8, 4, 41);
    for method in ["el2n", "moderate", "craig", "random"] {
        // A stateful twin (random advances its RNG per call) driven with
        // the identical call sequence: the pool-hosted instance must track
        // it draw for draw.
        let mut twin = by_name(method, 7).unwrap();
        let mut p = PooledSelector::from_factory(1, 1, MergePolicy::Hierarchical, move |_| {
            by_name(method, 7).unwrap()
        });
        for rep in 0..3 {
            assert_eq!(
                p.select(&owned.view(), 24),
                twin.select(&owned.view(), 24),
                "method={method} rep={rep}"
            );
        }
    }
}

#[test]
fn pool_more_shards_than_rows_degrades_like_scoped() {
    let owned = random_owned(5, 4, 4, 2, 43);
    let reference = scoped(8).with_parallel(false).select(&owned.view(), 3);
    assert_valid(&reference, 5, 3, "scoped shards=8 k=5 r=3");
    for &workers in &[1usize, 3, 8] {
        assert_eq!(pooled(8, workers).select(&owned.view(), 3), reference, "workers={workers}");
    }
}

#[test]
fn pool_reuse_across_shapes_and_instances_is_deterministic() {
    // One pool instance must give identical answers across repeated calls
    // (recycled buffers cannot leak state) and across differently-shaped
    // batches, matching a fresh instance each time.
    let mut p = pooled(4, 2);
    for (k, rc, seed) in [(64usize, 8usize, 3u64), (33, 4, 4), (128, 12, 5), (64, 8, 3)] {
        let owned = random_owned(k, rc, 8, 2, seed);
        let fresh = pooled(4, 2).select(&owned.view(), rc);
        assert_eq!(p.select(&owned.view(), rc), fresh, "K={k} R={rc}");
    }
}

// ---------------------------------------------------------------------------
// 2. Interleaving independence
// ---------------------------------------------------------------------------

#[test]
fn seeded_arrival_order_permutations_replay_identically() {
    // The pool writes each shard's winners into its own slot and merges in
    // shard order, so the *arrival* order of results is structurally
    // irrelevant.  Replay that protocol: deliver the winner lists in many
    // seeded permuted orders, slot them, merge — every schedule must give
    // the bit-identical subset.
    let owned = random_owned(512, 16, 8, 4, 47);
    let shards = 8;
    let r = 40;

    // Reference winner lists via the serial scoped path at the same
    // partition (shard s covers shard_ranges(k, shards)[s]).
    let ranges = graft::coordinator::shard_ranges(512, shards);
    let mut ws = Workspace::new();
    let mut lists: Vec<Vec<usize>> = Vec::new();
    for range in &ranges {
        // Gather the shard rows and select, mirroring the worker kernel.
        let len = range.len();
        let rc = owned.features.cols();
        let ec = owned.grads.cols();
        let feat = Mat::from_fn(len, rc, |i, j| owned.features[(range.start + i, j)]);
        let grad = Mat::from_fn(len, ec, |i, j| owned.grads[(range.start + i, j)]);
        let shard_view = BatchView {
            features: &feat,
            grads: &grad,
            losses: &owned.losses[range.clone()],
            labels: &owned.labels[range.clone()],
            preds: &owned.preds[range.clone()],
            classes: owned.classes,
            row_ids: &owned.row_ids[range.clone()],
        };
        let mut local = Vec::new();
        FastMaxVol.select_into(&shard_view, r.min(len), &mut ws, &mut local);
        lists.push(local.iter().map(|&i| range.start + i).collect());
    }

    let merge = |slots: &[Vec<usize>]| -> Vec<usize> {
        let mut ws = Workspace::new();
        let mut scratch = graft::coordinator::merge::MergeScratch::default();
        let mut out = Vec::new();
        merge_winners(
            &owned.view(),
            slots.iter().map(|l| l.as_slice()),
            r,
            MergePolicy::Hierarchical,
            &mut ws,
            &mut scratch,
            &mut out,
        );
        out
    };
    let reference = merge(&lists);
    assert_valid(&reference, 512, r, "reference merge");
    // The replay harness must model the live pool exactly: same winner
    // lists, same slots, same merge.
    assert_eq!(
        pooled(shards, 4).select(&owned.view(), r),
        reference,
        "replay harness diverges from the live pool"
    );

    let mut rng = Rng::new(0xA11);
    for schedule in 0..iters(50, 1000) {
        // A permuted arrival order: results land in their slots as they
        // "arrive", then the merge reads slots in shard order.
        let mut arrival: Vec<usize> = (0..shards).collect();
        rng.shuffle(&mut arrival);
        let mut slots: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for &s in &arrival {
            slots[s] = lists[s].clone();
        }
        assert_eq!(merge(&slots), reference, "schedule {schedule} ({arrival:?})");
    }
}

#[test]
fn repeated_live_runs_agree_under_real_interleaving() {
    // Real scheduler nondeterminism: many live pooled runs, workers
    // genuinely racing, must all produce the same subset.
    let owned = random_owned(768, 16, 8, 4, 53);
    let reference = scoped(8).with_parallel(false).select(&owned.view(), 40);
    let mut p = pooled(8, 4);
    for rep in 0..iters(20, 400) {
        assert_eq!(p.select(&owned.view(), 40), reference, "rep={rep}");
    }
}

// ---------------------------------------------------------------------------
// 3. Lifecycle: drop-mid-epoch, double shutdown, panic containment
// ---------------------------------------------------------------------------

#[test]
fn drop_mid_epoch_drains_and_pool_stays_usable() {
    let owned = random_owned(256, 12, 8, 4, 59);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 24);
    let mut p = pooled(4, 2);
    for rep in 0..iters(10, 200) {
        {
            let view = owned.view();
            let pending = p.begin(&view, 24);
            // Abandon the epoch with jobs in flight: the guard's drop must
            // drain every outstanding result before the view borrow ends.
            drop(pending);
        }
        let sel = p.select(&owned.view(), 24);
        assert_eq!(sel, reference, "pool unusable after abandoned epoch (rep={rep})");
    }
}

#[test]
fn double_shutdown_is_idempotent_and_post_shutdown_select_degrades() {
    let owned = random_owned(128, 8, 8, 2, 61);
    let mut p = pooled(4, 2);
    let before = p.select(&owned.view(), 16);
    assert_valid(&before, 128, 16, "pre-shutdown");
    p.shutdown();
    p.shutdown(); // second call must be a no-op, not a double-join
    // The typed surface fails fast: `begin`/`finish` on a torn-down pool
    // reports `PoolUnavailable` instead of deadlocking.
    let err = typed_select(&mut p, &owned, 16).expect_err("shut-down pool must fail typed");
    assert!(matches!(err, SelectError::PoolUnavailable), "got {err}");
    // The legacy `Selector::select_into` wrapper has no error channel; it
    // must degrade to the deterministic coordinator-side feature-only
    // selection — never panic, never hang (the pre-fix wrapper panicked).
    let got = p.select(&owned.view(), 16);
    let fallback = FastMaxVol.select(&owned.view(), 16);
    assert_eq!(got, fallback, "post-shutdown select must be the feature-only fallback");
    drop(p); // third teardown path: Drop after explicit shutdowns
}

/// Selector that panics when the batch carries the poison marker (a loss
/// above 1e8) — only the shard holding the poisoned row blows up.
struct PanicOnPoison;

impl Selector for PanicOnPoison {
    fn name(&self) -> &'static str {
        "panic-on-poison"
    }

    fn shardable(&self) -> bool {
        true
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        assert!(
            !view.losses.iter().any(|&l| l > 1e8),
            "injected selector panic (poisoned batch)"
        );
        FastMaxVol.select_into(view, r, ws, out);
    }
}

#[test]
fn worker_panic_is_contained_and_pool_recovers() {
    let clean = random_owned(256, 12, 8, 4, 67);
    let mut poisoned = random_owned(256, 12, 8, 4, 67);
    poisoned.losses[5] = 1e9; // lands in shard 0 only

    let reference = ShardedSelector::from_factory(4, MergePolicy::Hierarchical, |_| {
        Box::new(PanicOnPoison)
    })
    .with_parallel(false)
    .select(&clean.view(), 24);

    let mut p = PooledSelector::from_factory(4, 2, MergePolicy::Hierarchical, |_| {
        Box::new(PanicOnPoison)
    });
    assert_eq!(p.select(&clean.view(), 24), reference, "healthy before injection");
    // What the legacy wrapper's log-and-degrade fallback computes for the
    // poisoned batch: coordinator-side feature-only MaxVol + loss top-up.
    let fallback = FastMaxVol.select(&poisoned.view(), 24);
    for rep in 0..iters(3, 50) {
        // The worker catches the selector panic, reports it, and survives.
        // The typed surface sees the shard failure after the epoch fully
        // drains; the legacy wrapper degrades to the deterministic
        // coordinator-side fallback instead of panicking the caller.
        let err = typed_select(&mut p, &poisoned, 24)
            .expect_err("poisoned select must surface the typed shard failure");
        assert!(matches!(err, SelectError::ShardFailure { .. }), "got {err} (rep={rep})");
        assert_eq!(
            p.select(&poisoned.view(), 24),
            fallback,
            "legacy wrapper must degrade deterministically, not panic (rep={rep})"
        );
        // Containment: the same pool keeps answering correctly.
        assert_eq!(p.select(&clean.view(), 24), reference, "pool lost after panic (rep={rep})");
    }
}

// ---------------------------------------------------------------------------
// 4. No-deadlock smoke under sustained load
// ---------------------------------------------------------------------------

#[test]
fn sustained_epoch_stream_never_wedges() {
    // GRAFT_BENCH_SMOKE-sized shapes, many epochs, abandoned epochs mixed
    // in, batch shape changing mid-stream: completing at all is the
    // assertion (a lost result, stale-epoch confusion, or a full channel
    // would deadlock the loop, which the harness timeout surfaces).
    let shapes = [(512usize, 16usize, 48usize), (256, 8, 24), (320, 12, 64)];
    let owned: Vec<Owned> =
        shapes.iter().enumerate().map(|(i, &(k, rc, _))| random_owned(k, rc, 8, 4, 71 + i as u64)).collect();
    let refs: Vec<Vec<usize>> = shapes
        .iter()
        .zip(&owned)
        .map(|(&(_, _, r), o)| scoped(8).with_parallel(false).select(&o.view(), r))
        .collect();
    let mut p = pooled(8, 4);
    for i in 0..iters(150, 3000) {
        let which = i % shapes.len();
        if i % 7 == 3 {
            // Periodically abandon an epoch mid-flight.
            let view = owned[which].view();
            drop(p.begin(&view, shapes[which].2));
            continue;
        }
        let sel = p.select(&owned[which].view(), shapes[which].2);
        assert_eq!(sel, refs[which], "iteration {i} shape {which}");
    }
}

// ---------------------------------------------------------------------------
// 5. Overlap equivalence (run_windows)
// ---------------------------------------------------------------------------

fn make_window(wi: usize, k: usize, rc: usize, seed: u64) -> SelectWindow {
    let o = random_owned(k, rc, 8, 4, seed ^ (wi as u64).wrapping_mul(0x9E37));
    SelectWindow {
        features: o.features,
        grads: o.grads,
        losses: o.losses,
        labels: o.labels,
        preds: o.preds,
        classes: o.classes,
        // Global ids offset per window, as the trainer's shuffled order
        // slices would be.
        row_ids: (0..k).map(|i| wi * k + i).collect(),
    }
}

fn collect_windows(overlap: bool, count: usize) -> Vec<(usize, Vec<usize>)> {
    let mut p = pooled(4, 2);
    let mut ws = Workspace::new();
    let mut selbuf = Vec::new();
    let mut got: Vec<(usize, Vec<usize>)> = Vec::new();
    run_windows(
        &mut p,
        24,
        overlap,
        count,
        &mut ws,
        &mut selbuf,
        |wi| Ok::<_, ()>(make_window(wi, 192, 12, 0xBEE5)),
        |wi, win, winners| {
            got.push((wi, winners.iter().map(|&bi| win.row_ids[bi]).collect()));
        },
    )
    .unwrap();
    got
}

#[test]
fn overlap_and_serial_paths_agree() {
    let serial = collect_windows(false, 9);
    let pipelined = collect_windows(true, 9);
    assert_eq!(serial.len(), 9);
    assert_eq!(serial, pipelined, "overlap must not change any window's selection");
    // And both match the scoped reference applied window-by-window.
    let mut reference = scoped(4).with_parallel(false);
    for (wi, got) in &serial {
        let win = make_window(*wi, 192, 12, 0xBEE5);
        let want: Vec<usize> =
            reference.select(&win.view(), 24).iter().map(|&bi| win.row_ids[bi]).collect();
        assert_eq!(got, &want, "window {wi}");
    }
}

#[test]
fn overlap_zero_and_single_window_edges() {
    assert!(collect_windows(true, 0).is_empty());
    assert!(collect_windows(false, 0).is_empty());
    assert_eq!(collect_windows(true, 1), collect_windows(false, 1));
}

#[test]
fn assemble_error_mid_overlap_drains_and_propagates() {
    let mut p = pooled(4, 2);
    let mut ws = Workspace::new();
    let mut selbuf = Vec::new();
    let mut consumed = 0usize;
    let err = run_windows(
        &mut p,
        24,
        true,
        10,
        &mut ws,
        &mut selbuf,
        |wi| {
            if wi == 3 {
                Err("assembly failed")
            } else {
                Ok(make_window(wi, 192, 12, 77))
            }
        },
        |_, _, _| consumed += 1,
    );
    assert_eq!(err, Err(WindowsError::Assemble("assembly failed")));
    // Windows 0..=1 finished before the wi=3 assembly ran (wi=2 was
    // in flight and is drained, not consumed).
    assert_eq!(consumed, 2, "exactly the pre-error windows consume");
    // The in-flight epoch for window 2 was drained by the guard: the pool
    // must still be fully usable.
    let owned = random_owned(128, 8, 8, 2, 79);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 16);
    assert_eq!(p.select(&owned.view(), 16), reference, "pool unusable after aborted overlap");
}

// ---------------------------------------------------------------------------
// 6. Fault-tolerance regressions (fault-tolerance PR)
// ---------------------------------------------------------------------------

/// Iteration count for the fault regressions: `GRAFT_FAULT_STRESS=1`
/// (the CI `fault-stress` job, with `--test-threads=1`) raises it ~20×.
fn fault_iters(base: usize, stress: usize) -> usize {
    let on = std::env::var("GRAFT_FAULT_STRESS").map(|v| v != "0").unwrap_or(false);
    if on {
        stress
    } else {
        base
    }
}

/// The typed epoch API the engine uses (`select_into` is the legacy
/// log-and-degrade wrapper over it; these suites pin the `Result`
/// surface).
fn typed_select(
    p: &mut PooledSelector,
    owned: &Owned,
    r: usize,
) -> Result<Vec<usize>, SelectError> {
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    let view = owned.view();
    p.begin(&view, r).finish(&mut ws, &mut out)?;
    Ok(out)
}

#[test]
fn worker_panicking_twice_in_a_row_is_respawned_and_retried_bit_identically() {
    let owned = random_owned(256, 12, 8, 4, 83);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 24);
    for rep in 0..fault_iters(3, 60) {
        let mut p = pooled(4, 2);
        p.set_fault_policy(FaultPolicy::Retry { max: 3, backoff: Duration::ZERO });
        // Shard 1's job panics on its next two runs: the hosting worker is
        // respawned after each, and the third attempt must land the exact
        // fault-free subset.
        p.set_fault_injector(Some(FaultPlan::new().panic_shard_times(1, 2).arc()));
        let got = typed_select(&mut p, &owned, 24).expect("retry budget absorbs both panics");
        assert_eq!(got, reference, "retried epoch must be bit-identical (rep={rep})");
        let st = p.stats();
        assert!(st.respawns >= 2, "two panics → two respawns, got {st:?} (rep={rep})");
        assert!(st.retries >= 2, "two panics → two retries, got {st:?} (rep={rep})");
        // Injector spent: the next epoch on the same pool is healthy.
        assert_eq!(typed_select(&mut p, &owned, 24).unwrap(), reference, "rep={rep}");
    }
}

#[test]
fn panic_during_drain_of_errored_epoch_is_absorbed() {
    // Two shards panic in one epoch under `Fail`: the first panicked
    // result types the error, the second arrives while the epoch drains
    // and must be absorbed (respawn, no double count) — the pool stays
    // fully usable.
    let owned = random_owned(256, 12, 8, 4, 89);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 24);
    for rep in 0..fault_iters(3, 60) {
        let mut p = pooled(4, 2);
        p.set_fault_injector(Some(FaultPlan::new().panic_shard(0, 1).panic_shard(3, 1).arc()));
        let err = typed_select(&mut p, &owned, 24).expect_err("Fail surfaces the panic");
        assert!(
            matches!(err, SelectError::ShardFailure { .. }),
            "typed shard failure, got {err} (rep={rep})"
        );
        assert_eq!(
            typed_select(&mut p, &owned, 24).unwrap(),
            reference,
            "pool unusable after drained panic (rep={rep})"
        );
    }
}

#[test]
fn all_workers_dead_surfaces_typed_error_not_deadlock() {
    let owned = random_owned(256, 12, 8, 4, 97);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 24);
    for rep in 0..fault_iters(2, 40) {
        let mut p = pooled(4, 2);
        p.set_job_deadline(Duration::from_millis(50));
        p.set_fault_injector(Some(FaultPlan::new().kill_all_workers(2).arc()));
        // Every worker dies mid-epoch.  The deadline probe proves the
        // threads dead, writes their jobs off, and `finish` returns a
        // typed error instead of waiting forever on answers that cannot
        // come (the pre-PR code would hang here).
        let err = typed_select(&mut p, &owned, 24).expect_err("dead pool must fail typed");
        assert!(
            matches!(err, SelectError::ShardFailure { .. } | SelectError::PoolUnavailable),
            "typed death, got {err} (rep={rep})"
        );
        // The probe respawned the dead slots: the same pool heals.
        assert!(p.stats().respawns >= 2, "dead workers must be respawned (rep={rep})");
        assert_eq!(
            typed_select(&mut p, &owned, 24).unwrap(),
            reference,
            "pool must heal after total worker death (rep={rep})"
        );
    }
}

#[test]
fn legacy_select_into_never_panics_on_fault() {
    // Regression (bugfix PR): the `Selector::select_into` compatibility
    // wrapper used to `panic!` whenever `begin`/`finish` surfaced a typed
    // `SelectError`, making it the one public entry point that could blow
    // up a caller on fault input.  It must now log-and-degrade: return the
    // deterministic coordinator-side feature-only selection and leave the
    // pool consistent and reusable.
    let owned = random_owned(256, 12, 8, 4, 103);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 24);
    let fallback = FastMaxVol.select(&owned.view(), 24);
    for rep in 0..fault_iters(2, 40) {
        let mut p = pooled(4, 2);
        // Default `Fail` policy + a shard that panics more times than any
        // retry budget: the typed error is guaranteed to reach the wrapper.
        p.set_fault_injector(Some(FaultPlan::new().panic_shard_times(2, 8).arc()));
        let got = p.select(&owned.view(), 24);
        assert_eq!(
            got, fallback,
            "wrapper must return the deterministic degraded selection (rep={rep})"
        );
        assert_valid(&got, 256, 24, "degraded selection contract");
        // The drain ran before the fallback: the pool stays reusable, and
        // once the injected faults are spent it answers exactly again.
        p.set_fault_injector(None);
        assert_eq!(
            p.select(&owned.view(), 24),
            reference,
            "pool must stay consistent after a degraded legacy call (rep={rep})"
        );
    }
}

#[test]
fn all_workers_dead_under_retry_recovers_bit_identically() {
    let owned = random_owned(256, 12, 8, 4, 101);
    let reference = scoped(4).with_parallel(false).select(&owned.view(), 24);
    for rep in 0..fault_iters(2, 40) {
        let mut p = pooled(4, 2);
        p.set_job_deadline(Duration::from_millis(50));
        p.set_fault_policy(FaultPolicy::Retry { max: 2, backoff: Duration::ZERO });
        p.set_fault_injector(Some(FaultPlan::new().kill_all_workers(2).arc()));
        let got = typed_select(&mut p, &owned, 24).expect("retry heals total worker death");
        assert_eq!(got, reference, "healed epoch must be bit-identical (rep={rep})");
    }
}

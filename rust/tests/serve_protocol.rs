//! Wire-protocol robustness against a LIVE daemon (PR 8, satellite 3).
//! The in-module protocol tests pin the codec on byte slices; these pin
//! the server loop: malformed frames, oversized length prefixes, unknown
//! versions, and mid-frame disconnects must produce typed `Fault`
//! replies (or a clean close) — never a panic, a hang, or interference
//! with another tenant's session.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use graft::coordinator::SelectWindow;
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::serve::protocol::{FaultKind, Msg, TenantConfig, PROTOCOL_VERSION};
use graft::serve::{engine_builder, Client, Server, ServerBuilder};

// ---------------------------------------------------------------------------
// Raw-socket helpers (deliberately NOT the Client, so we can speak wrong)
// ---------------------------------------------------------------------------

fn raw_connect(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).expect("raw connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    s.set_nodelay(true).ok();
    s
}

/// Read one length-prefixed frame payload, or None on clean EOF.
fn read_frame_raw(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match s.read(&mut len[got..]) {
            Ok(0) if got == 0 => return None,
            Ok(0) => panic!("EOF inside a length prefix"),
            Ok(n) => got += n,
            Err(e) => panic!("reading reply prefix: {e}"),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).expect("reply body");
    Some(buf)
}

/// The reply a hostile frame must earn: a decodable `Fault { Protocol }`
/// followed by EOF (the server hangs up on protocol violations).
fn expect_protocol_fault_then_close(s: &mut TcpStream, what: &str) {
    let payload = read_frame_raw(s).unwrap_or_else(|| panic!("{what}: no Fault before close"));
    match Msg::decode(&payload) {
        Ok(Msg::Fault { kind: FaultKind::Protocol, detail }) => {
            assert!(!detail.is_empty(), "{what}: fault detail is populated");
        }
        other => panic!("{what}: expected Fault(Protocol), got {other:?}"),
    }
    assert!(read_frame_raw(s).is_none(), "{what}: connection closes after the fault");
}

fn window(k: usize, seed: u64) -> SelectWindow {
    let (rc, e, classes) = (6usize, 8usize, 4usize);
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    SelectWindow {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

fn addr_of(server: &Server) -> String {
    server.local_addr().expect("tcp addr").to_string()
}

/// Wait for the server to reap dead sessions (read-tick granularity).
fn wait_for_sessions(server: &Server, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.active_sessions() != want {
        assert!(Instant::now() < deadline, "sessions never settled to {want}");
        thread::sleep(Duration::from_millis(20));
    }
}

// ---------------------------------------------------------------------------
// Hostile frames get typed faults
// ---------------------------------------------------------------------------

#[test]
fn oversized_length_prefix_is_refused_before_the_body() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let mut s = raw_connect(&addr_of(&server));
    // Claim a frame far over the cap; send no body at all.  The refusal
    // must come from the prefix check, not from buffering 64 MiB.
    s.write_all(&(64u32 << 20).to_le_bytes()).expect("write prefix");
    expect_protocol_fault_then_close(&mut s, "oversized prefix");
    server.shutdown();
}

#[test]
fn garbage_unknown_version_and_empty_frames_get_typed_faults() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);

    // (a) Unknown protocol version.
    let mut s = raw_connect(&addr);
    s.write_all(&2u32.to_le_bytes()).expect("prefix");
    s.write_all(&[PROTOCOL_VERSION + 1, 1]).expect("body");
    expect_protocol_fault_then_close(&mut s, "unknown version");

    // (b) Unknown message type on a valid version.
    let mut s = raw_connect(&addr);
    s.write_all(&2u32.to_le_bytes()).expect("prefix");
    s.write_all(&[PROTOCOL_VERSION, 250]).expect("body");
    expect_protocol_fault_then_close(&mut s, "unknown type");

    // (c) Zero-length frame: no version byte to trust.
    let mut s = raw_connect(&addr);
    s.write_all(&0u32.to_le_bytes()).expect("prefix");
    expect_protocol_fault_then_close(&mut s, "empty frame");

    // (d) Declared counts that overrun the payload (a Hello whose tenant
    // length claims bytes that never arrive).
    let mut s = raw_connect(&addr);
    let body = [PROTOCOL_VERSION, 1, 255, 255, 255, 255];
    s.write_all(&(body.len() as u32).to_le_bytes()).expect("prefix");
    s.write_all(&body).expect("body");
    expect_protocol_fault_then_close(&mut s, "hostile count");

    server.shutdown();
}

// ---------------------------------------------------------------------------
// Mid-frame disconnects and hostile peers never corrupt other tenants
// ---------------------------------------------------------------------------

#[test]
fn mid_frame_disconnect_leaves_the_server_healthy() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);

    // Die halfway through a declared frame.
    {
        let mut s = raw_connect(&addr);
        s.write_all(&1000u32.to_le_bytes()).expect("prefix");
        s.write_all(&[PROTOCOL_VERSION; 10]).expect("partial body");
    }
    wait_for_sessions(&server, 0);

    // The daemon is intact: a well-behaved tenant gets a bit-identical
    // selection afterwards.
    let cfg = TenantConfig { budget: 8, seed: 31, ..TenantConfig::default() };
    let win = window(48, 0xBEEF);
    let mut client = Client::connect_tcp(&addr).expect("connect");
    client.hello("healthy", &cfg).expect("hello");
    let served = client.select(&win.view()).expect("select").indices;
    client.bye().expect("bye");

    let mut reference = engine_builder(&cfg).build().expect("reference engine");
    let sel = reference.select(&win.view()).expect("reference select");
    let want: Vec<u64> = sel.indices.iter().map(|&i| i as u64).collect();
    assert_eq!(served, want, "post-disconnect selections are bit-identical");
    server.shutdown();
}

#[test]
fn hostile_peer_mid_stream_does_not_perturb_a_live_tenant() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);
    let cfg = TenantConfig { budget: 8, seed: 77, ..TenantConfig::default() };
    let wins = [window(48, 1), window(48, 2)];

    let mut client = Client::connect_tcp(&addr).expect("connect");
    client.hello("steady", &cfg).expect("hello");
    let first = client.select(&wins[0].view()).expect("first select").indices;

    // Between the tenant's windows: a hostile connection sprays garbage
    // and a second one dies mid-frame.
    let mut hostile = raw_connect(&addr);
    hostile.write_all(&3u32.to_le_bytes()).expect("prefix");
    hostile.write_all(&[0xFF, 0xFF, 0xFF]).expect("garbage");
    expect_protocol_fault_then_close(&mut hostile, "garbage spray");
    {
        let mut dying = raw_connect(&addr);
        dying.write_all(&500u32.to_le_bytes()).expect("prefix");
    }

    let second = client.select(&wins[1].view()).expect("second select").indices;
    client.bye().expect("bye");
    server.shutdown();

    // Reference: both windows through ONE engine — the tenant's state
    // must have advanced exactly as if the hostiles never existed.
    let mut eng = engine_builder(&cfg).build().expect("reference engine");
    for (served, win) in [(&first, &wins[0]), (&second, &wins[1])] {
        let want: Vec<u64> =
            eng.select(&win.view()).expect("reference").indices.iter().map(|&i| i as u64).collect();
        assert_eq!(served, &want, "tenant unaffected by hostile peers");
    }
}

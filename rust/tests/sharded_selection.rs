//! Property tests for the sharded selection coordinator
//! (`coordinator::shard` + `coordinator::merge`), the suite the PR 2
//! acceptance criteria pin:
//!
//! 1. `shards == 1` is **bit-identical** to single-shot selection (the
//!    wrapper delegates with the caller's workspace — same arithmetic,
//!    same order).
//! 2. For `shards ∈ {2, 4, 8}` the merged subset keeps the selector
//!    contract (unique, in-range, `|out| == min(r, K)`), is deterministic
//!    across runs and selector instances, and is independent of worker
//!    interleaving (serial == parallel, repeated threaded runs agree).
//! 3. The merged subset's final `prefix_projection_errors` value is within
//!    a fixed tolerance of the single-shot selection on seeded synthetic
//!    batches with planted low-rank gradient structure — the
//!    subspace-preservation guarantee of the select-then-merge design.

use graft::coordinator::{shard_ranges, MergePolicy, ShardedSelector, SHARD_PAR_MIN_K};
use graft::graft::{prefix_projection_errors, BudgetedRankPolicy, GraftSelector};
use graft::linalg::{Mat, Workspace};
use graft::rng::Rng;
use graft::selection::maxvol::FastMaxVol;
use graft::selection::{BatchView, Selector};

// ---------------------------------------------------------------------------
// Synthetic batch builders
// ---------------------------------------------------------------------------

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

/// Fully random batch: gaussian features/gradients, uniform losses.
fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

/// Batch with planted rank-`p` structure: features and gradients share the
/// same k×p loadings, so the gradient geometry a good selection must
/// capture is visible to the feature-space MaxVol, up to `noise`.
fn planted_owned(k: usize, rc: usize, e: usize, p: usize, noise: f64, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let loadings = Mat::from_fn(k, p, |_, _| rng.normal());
    let basis_f = Mat::from_fn(p, rc, |_, _| rng.normal());
    let basis_g = Mat::from_fn(p, e, |_, _| rng.normal());
    let mut features = loadings.matmul(&basis_f);
    let mut grads = loadings.matmul(&basis_g);
    for v in features.data_mut() {
        *v += noise * rng.normal();
    }
    for v in grads.data_mut() {
        *v += noise * rng.normal();
    }
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % 4) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes: 4,
        row_ids: (0..k).collect(),
    }
}

/// Final prefix projection error of the batch-mean gradient against the
/// gradient rows of `sel` — the Lemma-1 metric GRAFT's rank policy reads.
fn final_proj_err(grads: &Mat, sel: &[usize]) -> f64 {
    let (k, e) = (grads.rows(), grads.cols());
    let mut gbar = vec![0.0; e];
    for i in 0..k {
        for (t, &v) in grads.row(i).iter().enumerate() {
            gbar[t] += v;
        }
    }
    for v in gbar.iter_mut() {
        *v /= k as f64;
    }
    let gsel = Mat::from_fn(e, sel.len(), |i, j| grads[(sel[j], i)]);
    *prefix_projection_errors(&gsel, &gbar).last().expect("non-empty selection")
}

fn sharded(shards: usize, merge: MergePolicy) -> ShardedSelector {
    ShardedSelector::from_factory(shards, merge, |_| Box::new(FastMaxVol))
}

fn assert_valid(sel: &[usize], k: usize, want: usize, ctx: &str) {
    assert_eq!(sel.len(), want, "size: {ctx}");
    let mut s = sel.to_vec();
    s.sort_unstable();
    s.dedup();
    assert_eq!(s.len(), want, "uniqueness: {ctx}");
    assert!(s.iter().all(|&i| i < k), "range: {ctx}");
}

// ---------------------------------------------------------------------------
// 1. shards == 1 is bit-identical to single-shot
// ---------------------------------------------------------------------------

#[test]
fn one_shard_bit_identical_to_fast_maxvol() {
    for seed in [1u64, 2, 3, 4, 5] {
        let owned = random_owned(96, 12, 8, 4, seed);
        for r in [1usize, 4, 12, 40] {
            let single = FastMaxVol.select(&owned.view(), r);
            let wrapped = sharded(1, MergePolicy::Hierarchical).select(&owned.view(), r);
            assert_eq!(single, wrapped, "seed={seed} r={r}");
        }
    }
}

#[test]
fn one_shard_bit_identical_to_graft_selector() {
    for seed in [7u64, 8, 9] {
        let owned = random_owned(64, 8, 16, 4, seed);
        let single = GraftSelector::new(BudgetedRankPolicy::strict(0.05)).select(&owned.view(), 16);
        let wrapped = ShardedSelector::from_factory(1, MergePolicy::Hierarchical, |_| {
            Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
        })
        .select(&owned.view(), 16);
        assert_eq!(single, wrapped, "seed={seed}");
    }
}

#[test]
fn one_shard_shares_caller_workspace_across_shapes() {
    // The delegation path must tolerate workspace reuse across
    // differently-shaped batches, exactly like the inner selector does.
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    let mut wrapped = sharded(1, MergePolicy::Hierarchical);
    for (k, rc, seed) in [(32usize, 8usize, 3u64), (16, 4, 4), (64, 12, 5)] {
        let owned = random_owned(k, rc, 8, 2, seed);
        wrapped.select_into(&owned.view(), rc, &mut ws, &mut out);
        assert_eq!(out, FastMaxVol.select(&owned.view(), rc), "K={k} R={rc}");
    }
}

// ---------------------------------------------------------------------------
// 2. Multi-shard contract, determinism, interleaving-independence
// ---------------------------------------------------------------------------

#[test]
fn multi_shard_contract_no_dups_in_range() {
    for &shards in &[2usize, 4, 8] {
        for &r in &[8usize, 16, 60] {
            for seed in [1u64, 2, 3] {
                let k = 64;
                let owned = random_owned(k, 16, 8, 4, seed);
                for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
                    let sel = sharded(shards, policy).select(&owned.view(), r);
                    assert_valid(
                        &sel,
                        k,
                        r.min(k),
                        &format!("shards={shards} r={r} seed={seed} {policy:?}"),
                    );
                }
            }
        }
    }
}

#[test]
fn more_shards_than_rows_degrades_gracefully() {
    let owned = random_owned(5, 4, 4, 2, 6);
    let sel = sharded(8, MergePolicy::Hierarchical).select(&owned.view(), 3);
    assert_valid(&sel, 5, 3, "shards=8 k=5 r=3");
}

#[test]
fn deterministic_across_runs_and_instances() {
    let owned = random_owned(128, 16, 8, 4, 11);
    for &shards in &[2usize, 4, 8] {
        let mut a = sharded(shards, MergePolicy::Hierarchical);
        let first = a.select(&owned.view(), 24);
        let second = a.select(&owned.view(), 24); // same instance, reused scratch
        let fresh = sharded(shards, MergePolicy::Hierarchical).select(&owned.view(), 24);
        assert_eq!(first, second, "instance reuse, shards={shards}");
        assert_eq!(first, fresh, "fresh instance, shards={shards}");
    }
}

#[test]
fn parallel_matches_serial_above_threshold() {
    // k clears SHARD_PAR_MIN_K so the default path really runs on scoped
    // threads; a serial twin must agree bit-for-bit, and repeated threaded
    // runs must agree with each other (scheduling cannot leak in).
    let k = SHARD_PAR_MIN_K.max(512) * 2;
    let owned = random_owned(k, 16, 8, 4, 13);
    for &shards in &[2usize, 4, 8] {
        let serial = sharded(shards, MergePolicy::Hierarchical)
            .with_parallel(false)
            .select(&owned.view(), 48);
        let mut par = sharded(shards, MergePolicy::Hierarchical);
        for rep in 0..3 {
            let sel = par.select(&owned.view(), 48);
            assert_eq!(sel, serial, "shards={shards} rep={rep}");
        }
    }
}

#[test]
fn workspace_reuse_does_not_cross_talk() {
    // One caller workspace alternating between single-shot and sharded
    // selection must leave both unchanged vs fresh-workspace runs.
    let owned = random_owned(96, 12, 8, 4, 17);
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    let mut plain = FastMaxVol;
    let mut shard4 = sharded(4, MergePolicy::Hierarchical);
    for _ in 0..3 {
        plain.select_into(&owned.view(), 12, &mut ws, &mut out);
        assert_eq!(out, FastMaxVol.select(&owned.view(), 12));
        shard4.select_into(&owned.view(), 12, &mut ws, &mut out);
        assert_eq!(out, sharded(4, MergePolicy::Hierarchical).select(&owned.view(), 12));
    }
}

// ---------------------------------------------------------------------------
// 3. Projection-error tolerance vs single-shot
// ---------------------------------------------------------------------------

/// Fixed tolerance for the |merged − single| projection-error gap on the
/// planted-structure batches (the observed gap is ~1e-3; the bound leaves
/// a ~50× margin so the test pins the guarantee, not the noise).
const PROJ_TOL: f64 = 0.05;

#[test]
fn merged_projection_error_close_to_single_shot_planted() {
    for seed in [1u64, 2, 3] {
        let owned = planted_owned(256, 16, 24, 4, 0.02, seed);
        let single = FastMaxVol.select(&owned.view(), 16);
        let d_single = final_proj_err(&owned.grads, &single);
        assert!(d_single <= PROJ_TOL, "single-shot d={d_single} seed={seed}");
        for &shards in &[2usize, 4, 8] {
            let merged = sharded(shards, MergePolicy::Hierarchical).select(&owned.view(), 16);
            assert_valid(&merged, 256, 16, &format!("planted shards={shards}"));
            let d_merged = final_proj_err(&owned.grads, &merged);
            assert!(
                d_merged <= PROJ_TOL && (d_merged - d_single).abs() <= PROJ_TOL,
                "shards={shards} seed={seed}: merged d={d_merged} vs single d={d_single}"
            );
        }
    }
}

#[test]
fn merged_projection_error_zero_when_budget_covers_grad_dim() {
    // With r > E any generic selection spans the whole gradient space, so
    // both paths must drive the residual to (numerical) zero.
    let owned = random_owned(128, 12, 8, 4, 19);
    let single = FastMaxVol.select(&owned.view(), 16);
    assert!(final_proj_err(&owned.grads, &single) <= 1e-8);
    for &shards in &[2usize, 4, 8] {
        let merged = sharded(shards, MergePolicy::Hierarchical).select(&owned.view(), 16);
        let d = final_proj_err(&owned.grads, &merged);
        assert!(d <= 1e-8, "shards={shards}: d={d}");
    }
}

#[test]
fn flat_and_hierarchical_merges_agree_on_quality() {
    for seed in [4u64, 5] {
        let owned = planted_owned(256, 16, 24, 4, 0.02, seed);
        for &shards in &[4usize, 8] {
            let hier = sharded(shards, MergePolicy::Hierarchical).select(&owned.view(), 16);
            let flat = sharded(shards, MergePolicy::Flat).select(&owned.view(), 16);
            let (dh, df) =
                (final_proj_err(&owned.grads, &hier), final_proj_err(&owned.grads, &flat));
            assert!(
                dh <= PROJ_TOL && df <= PROJ_TOL && (dh - df).abs() <= PROJ_TOL,
                "shards={shards} seed={seed}: hier d={dh} flat d={df}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Partition helper
// ---------------------------------------------------------------------------

#[test]
fn shard_ranges_empty_input_yields_no_ranges() {
    assert!(shard_ranges(0, 1).is_empty());
    assert!(shard_ranges(0, 4).is_empty());
}

#[test]
fn shard_ranges_partition_properties() {
    for &(k, s) in &[(1usize, 1usize), (5, 2), (64, 8), (65, 8), (1000, 7), (5, 8), (3, 200)] {
        let ranges = shard_ranges(k, s);
        assert_eq!(ranges.len(), s.min(k), "count for k={k} s={s}");
        let mut cursor = 0;
        let (mut min_len, mut max_len) = (usize::MAX, 0);
        for r in &ranges {
            assert_eq!(r.start, cursor, "contiguous for k={k} s={s}");
            assert!(!r.is_empty(), "non-empty for k={k} s={s}");
            min_len = min_len.min(r.len());
            max_len = max_len.max(r.len());
            cursor = r.end;
        }
        assert_eq!(cursor, k, "covers 0..{k} for s={s}");
        assert!(max_len - min_len <= 1, "balanced for k={k} s={s}");
    }
}

//! f32 gradient-sketch property pins (PR 9): the
//! [`EngineBuilder::sketch_f32`] knob halves merge bandwidth and
//! pool-message memory by narrowing carried sketch columns to f32.
//! Pivot ordering is computed on the f64 feature matrices — narrowing
//! can only move the adaptive rank cut — so:
//!
//! 1. On planted low-rank batches (gradients in an exact 2-D subspace:
//!    prefix errors sit at ~1e-14 and ~1, far from ε on both sides) the
//!    f32 engine's subsets and rank decisions are **identical** to the
//!    f64 reference across Sharded/Pooled/Streaming shapes.
//! 2. On generic random batches the decided rank differs by at most one
//!    and the common winner prefix is identical (the merged order is
//!    width-independent).
//! 3. The knob is inert where no sketches are carried: serial engines
//!    (no merge boundary) and strict engines (the adaptive-only carry)
//!    stay bit-identical with it on or off, at zero carried bytes.

use graft::engine::{EngineBuilder, ExecShape, RankMode, SelectionEngine, StreamingEngine};
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::selection::BatchView;

const EPS: f64 = 0.05;
const BUDGET: usize = 16;

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: 4,
            row_ids: &self.row_ids,
        }
    }
}

fn random_owned(k: usize, rc: usize, e: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    Owned {
        features: Mat::from_fn(k, rc, |_, _| rng.normal()),
        grads: Mat::from_fn(k, e, |_, _| rng.normal()),
        losses: (0..k).map(|_| rng.uniform() * 2.0).collect(),
        labels: (0..k).map(|i| (i % 4) as i32).collect(),
        preds: (0..k).map(|i| (i % 4) as i32).collect(),
        row_ids: (0..k).collect(),
    }
}

/// Gradients planted in an exact 2-D subspace: the prefix-error curve
/// collapses at rank 2 (residual ~1e-14 after f32 rounding, « ε) while
/// rank 1 stays generic (» ε), so the adaptive decision is pinned to the
/// same rank at either sketch width.
fn planted_owned(k: usize, rc: usize, e: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let u: Vec<f64> = (0..e).map(|_| rng.normal()).collect();
    let v: Vec<f64> = (0..e).map(|_| rng.normal()).collect();
    let coeffs: Vec<(f64, f64)> =
        (0..k).map(|_| (2.0 * rng.normal(), 2.0 * rng.normal())).collect();
    let grads = Mat::from_fn(k, e, |i, j| coeffs[i].0 * u[j] + coeffs[i].1 * v[j]);
    let mut o = random_owned(k, rc, e, seed ^ 0xABCD);
    o.grads = grads;
    o
}

fn engine(shape: ExecShape, f32s: bool) -> SelectionEngine {
    EngineBuilder::new()
        .method("graft")
        .budget(BUDGET)
        .epsilon(EPS)
        .rank(RankMode::Adaptive { epsilon: EPS })
        .sketch_f32(f32s)
        .exec(shape)
        .build()
        .expect("valid adaptive configuration")
}

fn stream_engine(f32s: bool) -> StreamingEngine {
    EngineBuilder::new()
        .method("graft")
        .budget(BUDGET)
        .epsilon(EPS)
        .rank(RankMode::Adaptive { epsilon: EPS })
        .sketch_f32(f32s)
        .build_streaming()
        .expect("valid adaptive streaming configuration")
}

const SHAPES: [ExecShape; 3] = [
    ExecShape::Sharded { shards: 2 },
    ExecShape::Sharded { shards: 4 },
    ExecShape::Pooled { shards: 4, workers: 2, overlap: false },
];

#[test]
fn planted_low_rank_subsets_are_identical_across_widths() {
    // Three windows per engine so pooled buffer recycling (spare grads
    // re-entering circulation) runs under the narrowed width too.
    let batches: Vec<Owned> = (0..3).map(|i| planted_owned(64, 8, 10, 919 + i)).collect();
    for shape in SHAPES {
        let mut wide = engine(shape, false);
        let mut narrow = engine(shape, true);
        assert_eq!(narrow.carried_sketch_bytes(), 0, "nothing carried before a select");
        for (bi, b) in batches.iter().enumerate() {
            let (wi_idx, wi_dec) = {
                let s = wide.select(&b.view()).expect("healthy");
                (s.indices.to_vec(), s.decision)
            };
            let (na_idx, na_dec) = {
                let s = narrow.select(&b.view()).expect("healthy");
                (s.indices.to_vec(), s.decision)
            };
            assert_eq!(na_idx, wi_idx, "subset diverged ({shape:?}, window {bi})");
            let (w, n) = (wi_dec.expect("adaptive decides"), na_dec.expect("adaptive decides"));
            assert_eq!(n.rank, w.rank, "rank diverged ({shape:?}, window {bi})");
            assert!(
                (n.error - w.error).abs() < 1e-6,
                "error beyond f32 tolerance ({shape:?}, window {bi}): {} vs {}",
                n.error,
                w.error
            );
        }
        // The narrowed carry really is narrower: same column count, half
        // the payload bytes.
        let (wb, nb) = (wide.carried_sketch_bytes(), narrow.carried_sketch_bytes());
        assert!(wb > 0, "adaptive {shape:?} carries sketches");
        assert_eq!(nb * 2, wb, "f32 carry is half the f64 payload ({shape:?})");
    }

    // Streaming: reservoir cap = 2·budget = 32 ≥ k, so the stream is the
    // batch input and the two widths must agree exactly as above.
    for seed in [919u64, 920] {
        let owned = planted_owned(32, 8, 10, seed);
        let mut wide = stream_engine(false);
        let mut narrow = stream_engine(true);
        wide.push(&owned.view()).expect("clean push");
        narrow.push(&owned.view()).expect("clean push");
        let w = wide.snapshot().expect("healthy");
        let n = narrow.snapshot().expect("healthy");
        assert_eq!(n.indices, w.indices, "stream subset diverged (seed {seed})");
        let (wd, nd) = (w.decision.expect("adaptive"), n.decision.expect("adaptive"));
        assert_eq!(nd.rank, wd.rank, "stream rank diverged (seed {seed})");
        assert!((nd.error - wd.error).abs() < 1e-6, "stream error beyond f32 tolerance");
        assert!(wide.carried_sketch_bytes() > 0, "adaptive stream carries sketches");
        assert_eq!(
            narrow.carried_sketch_bytes() * 2,
            wide.carried_sketch_bytes(),
            "f32 stream carry is half the f64 payload"
        );
    }
}

#[test]
fn random_batches_stay_within_one_rank_and_share_the_winner_prefix() {
    let shapes = [
        ExecShape::Sharded { shards: 2 },
        ExecShape::Pooled { shards: 2, workers: 2, overlap: false },
    ];
    for shape in shapes {
        for seed in [101u64, 202, 303] {
            let owned = random_owned(96, 12, 16, seed);
            let mut wide = engine(shape, false);
            let mut narrow = engine(shape, true);
            let w = wide.select(&owned.view()).expect("healthy").indices.to_vec();
            let n = narrow.select(&owned.view()).expect("healthy").indices.to_vec();
            let (wr, nr) = (
                wide.last_decision().expect("adaptive decides").rank,
                narrow.last_decision().expect("adaptive decides").rank,
            );
            assert!(
                wr.abs_diff(nr) <= 1,
                "rank drifted past tolerance ({shape:?}, seed {seed}): {wr} vs {nr}"
            );
            // The merged pivot order is computed on f64 features, so the
            // two subsets are prefixes of the same winner sequence.
            let common = w.len().min(n.len());
            assert_eq!(
                &w[..common],
                &n[..common],
                "winner prefix diverged ({shape:?}, seed {seed})"
            );
            assert!(w.len().abs_diff(n.len()) <= 1, "subset length tracks the rank cut");
        }
    }
}

#[test]
fn knob_is_inert_where_no_sketches_are_carried() {
    let owned = random_owned(64, 8, 12, 515);
    // Serial adaptive: no merge boundary, nothing to narrow.
    let mut a = engine(ExecShape::Serial, false);
    let mut b = engine(ExecShape::Serial, true);
    assert_eq!(
        a.select(&owned.view()).expect("healthy").indices,
        b.select(&owned.view()).expect("healthy").indices,
        "serial adaptive must ignore sketch_f32"
    );
    assert_eq!(a.rank_stats(), b.rank_stats());
    assert_eq!(b.carried_sketch_bytes(), 0);

    // Strict sharded: the adaptive-only carry ships no sketches at all,
    // so the width knob cannot matter.
    let strict = |f32s: bool| {
        EngineBuilder::new()
            .method("graft")
            .budget(BUDGET)
            .epsilon(EPS)
            .sketch_f32(f32s)
            .exec(ExecShape::Sharded { shards: 4 })
            .build()
            .expect("valid strict configuration")
    };
    let mut a = strict(false);
    let mut b = strict(true);
    assert_eq!(
        a.select(&owned.view()).expect("healthy").indices,
        b.select(&owned.view()).expect("healthy").indices,
        "strict sharded must ignore sketch_f32"
    );
    assert_eq!(a.carried_sketch_bytes(), 0);
    assert_eq!(b.carried_sketch_bytes(), 0);

    // Strict streaming: carry is off, the reservoir holds no sketches.
    let strict_stream = |f32s: bool| {
        EngineBuilder::new()
            .method("graft")
            .budget(BUDGET)
            .epsilon(EPS)
            .sketch_f32(f32s)
            .build_streaming()
            .expect("valid strict streaming configuration")
    };
    let mut a = strict_stream(false);
    let mut b = strict_stream(true);
    a.push(&owned.view()).expect("clean push");
    b.push(&owned.view()).expect("clean push");
    assert_eq!(
        a.snapshot().expect("healthy").indices,
        b.snapshot().expect("healthy").indices,
        "strict stream must ignore sketch_f32"
    );
    assert_eq!(a.carried_sketch_bytes(), 0);
    assert_eq!(b.carried_sketch_bytes(), 0);
}

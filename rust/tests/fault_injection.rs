//! Fault-injection suite (PR 6) — the headline robustness property:
//!
//! **Under any injected fault schedule, selection either returns the
//! bit-identical fault-free subset after retries, or a recorded
//! degradation — never a panic, a hang, or a silently different
//! subset.**
//!
//! Pinned here as shapes × faults × policies:
//!
//! * shapes — `Serial`, `Sharded{2,4}`, `Pooled{2×2, 4×2}`;
//! * faults — injected shard panic, worker death, worker delay past the
//!   per-job deadline (via [`graft::faults::FaultPlan`]), poisoned
//!   (non-finite) input rows, numerical breakdown (identical rows
//!   tripping the MaxVol pivot clamp);
//! * policies — `Fail` (typed error, engine stays usable), `Retry`
//!   (bit-identical recovery), `Degrade` (quarantine + ladder, every
//!   rung recorded in `Selection::degradations`).
//!
//! Zero-fault runs must be bit-identical under every policy, so opting
//! into fault tolerance can never change healthy results.
//!
//! `GRAFT_FAULT_STRESS=1` (the CI `fault-stress` job) multiplies the
//! iteration counts ~20× and should run serialized
//! (`--test-threads=1`).

use std::time::Duration;

use graft::coordinator::SelectWindow;
use graft::engine::{
    Degradation, EngineBuilder, ExecShape, FaultPolicy, RankMode, SelectError, SelectionEngine,
    WindowsError,
};
use graft::faults::FaultPlan;
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::selection::BatchView;

const EPS: f64 = 0.05;
const K: usize = 32;

// ---------------------------------------------------------------------------
// Synthetic batch builders (mirrors tests/engine_api.rs)
// ---------------------------------------------------------------------------

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }

    fn into_window(self) -> SelectWindow {
        SelectWindow {
            features: self.features,
            grads: self.grads,
            losses: self.losses,
            labels: self.labels,
            preds: self.preds,
            classes: self.classes,
            row_ids: self.row_ids,
        }
    }

    /// Copy without the given rows (ascending) — the expected-value twin
    /// of the engine's quarantine filter.
    fn without_rows(&self, drop: &[usize]) -> Owned {
        let (rc, ec) = (self.features.cols(), self.grads.cols());
        let kept: Vec<usize> =
            (0..self.features.rows()).filter(|i| !drop.contains(i)).collect();
        let mut feat = Vec::new();
        let mut grad = Vec::new();
        let mut out = Owned {
            features: Mat::from_vec(0, rc, Vec::new()),
            grads: Mat::from_vec(0, ec, Vec::new()),
            losses: Vec::new(),
            labels: Vec::new(),
            preds: Vec::new(),
            classes: self.classes,
            row_ids: Vec::new(),
        };
        for &i in &kept {
            feat.extend_from_slice(&self.features.data()[i * rc..(i + 1) * rc]);
            grad.extend_from_slice(&self.grads.data()[i * ec..(i + 1) * ec]);
            out.losses.push(self.losses[i]);
            out.labels.push(self.labels[i]);
            out.preds.push(self.preds[i]);
            out.row_ids.push(self.row_ids[i]);
        }
        out.features = Mat::from_vec(kept.len(), rc, feat);
        out.grads = Mat::from_vec(kept.len(), ec, grad);
        out
    }
}

fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

fn healthy_batch() -> Owned {
    random_owned(K, 4, 6, 2, 42)
}

/// Healthy batch with NaN planted in rows 5 and 17.
fn poisoned_batch() -> Owned {
    let mut b = healthy_batch();
    b.features[(5, 0)] = f64::NAN;
    b.grads[(17, 2)] = f64::INFINITY;
    b
}

/// Identical feature rows: rank 1, so MaxVol past the first pivot trips
/// the degenerate-pivot clamp — deterministic numerical breakdown.
fn degenerate_batch() -> Owned {
    let mut b = healthy_batch();
    b.features = Mat::from_fn(K, 4, |_, j| (j + 1) as f64);
    b
}

// ---------------------------------------------------------------------------
// Engine builders over the shape × policy grid
// ---------------------------------------------------------------------------

/// Every execution shape the headline property quantifies over:
/// (label, shape, shards, workers).
fn shapes() -> Vec<(&'static str, ExecShape, usize, usize)> {
    vec![
        ("serial", ExecShape::Serial, 1, 1),
        ("sharded2", ExecShape::Sharded { shards: 2 }, 2, 1),
        ("sharded4", ExecShape::Sharded { shards: 4 }, 4, 1),
        ("pooled2x2", ExecShape::Pooled { shards: 2, workers: 2, overlap: false }, 2, 2),
        ("pooled4x2", ExecShape::Pooled { shards: 4, workers: 2, overlap: false }, 4, 2),
    ]
}

fn retry(max: u32) -> FaultPolicy {
    FaultPolicy::Retry { max, backoff: Duration::ZERO }
}

fn build(shape: ExecShape, policy: FaultPolicy) -> SelectionEngine {
    EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .rank(RankMode::Adaptive { epsilon: EPS })
        .seed(11)
        .exec(shape)
        .fault_policy(policy)
        .build()
        .expect("valid configuration")
}

/// Same, with a short per-job deadline so dead/wedged pool workers are
/// probed quickly instead of after the generous production default.
fn build_deadline(shape: ExecShape, policy: FaultPolicy) -> SelectionEngine {
    EngineBuilder::new()
        .method("graft")
        .fraction(0.25)
        .rank(RankMode::Adaptive { epsilon: EPS })
        .seed(11)
        .exec(shape)
        .fault_policy(policy)
        .job_deadline(Duration::from_millis(50))
        .build()
        .expect("valid configuration")
}

/// Fault-free reference subset for one shape (fresh `Fail` engine).
fn reference(shape: ExecShape, batch: &Owned) -> Vec<usize> {
    build(shape, FaultPolicy::Fail).select(&batch.view()).expect("healthy").indices.to_vec()
}

/// Fault-free reference stream: `count` consecutive selects on the same
/// batch (the adaptive rank authority accumulates across them, so the
/// stream — not just the first subset — is the bit-identity target).
fn reference_stream(shape: ExecShape, batch: &Owned, count: usize) -> Vec<Vec<usize>> {
    let mut eng = build(shape, FaultPolicy::Fail);
    (0..count).map(|_| eng.select(&batch.view()).expect("healthy").indices.to_vec()).collect()
}

fn fault_iters(base: usize, stress: usize) -> usize {
    if std::env::var("GRAFT_FAULT_STRESS").ok().as_deref() == Some("1") {
        stress
    } else {
        base
    }
}

// ---------------------------------------------------------------------------
// Zero faults: policy invariance
// ---------------------------------------------------------------------------

#[test]
fn zero_fault_runs_are_policy_invariant_across_shapes() {
    let batch = healthy_batch();
    let serial_ref = reference(ExecShape::Serial, &batch);
    for (name, shape, _, _) in shapes() {
        for policy in [FaultPolicy::Fail, retry(2), FaultPolicy::Degrade] {
            let mut eng = build(shape, policy);
            let sel = eng.select(&batch.view()).expect("zero-fault select must succeed");
            assert_eq!(
                sel.indices, &serial_ref[..],
                "{name}/{policy:?}: zero-fault subset must be policy- and shape-invariant"
            );
            assert!(sel.degradations.is_empty(), "{name}/{policy:?}: nothing degraded");
            let stats = eng.fault_stats();
            assert_eq!(stats.retries, 0, "{name}/{policy:?}");
            assert_eq!(stats.respawns, 0, "{name}/{policy:?}");
            assert_eq!(stats.quarantined_rows, 0, "{name}/{policy:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Injected shard panics
// ---------------------------------------------------------------------------

#[test]
fn injected_shard_panic_retries_bit_identically_across_shapes() {
    let batch = healthy_batch();
    for (name, shape, shards, _) in shapes() {
        let want = reference(shape, &batch);
        let faulted_shard = if shards > 1 { 1 } else { 0 };
        for _ in 0..fault_iters(2, 40) {
            let mut eng = build(shape, retry(2));
            eng.set_fault_injector(Some(FaultPlan::new().panic_shard(faulted_shard, 1).arc()));
            let got = eng
                .select(&batch.view())
                .unwrap_or_else(|e| panic!("{name}: retry must absorb a one-shot panic: {e}"))
                .indices
                .to_vec();
            assert_eq!(got, want, "{name}: successful retry must be bit-identical");
            assert!(eng.fault_stats().retries >= 1, "{name}: the retry must be counted");
            assert!(eng.last_degradations().is_empty(), "{name}: recovery is not degradation");
        }
    }
}

#[test]
fn injected_shard_panic_under_fail_is_typed_and_engine_stays_usable() {
    let batch = healthy_batch();
    for (name, shape, _, _) in shapes() {
        let want = reference(shape, &batch);
        let mut eng = build(shape, FaultPolicy::Fail);
        eng.set_fault_injector(Some(FaultPlan::new().panic_shard(0, 1).arc()));
        let err = eng.select(&batch.view()).expect_err("Fail must surface the panic");
        assert!(
            matches!(err, SelectError::ShardFailure { .. }),
            "{name}: expected ShardFailure, got {err:?}"
        );
        // The fault was one-shot and the failure drained cleanly: the
        // same engine's next select is healthy and bit-identical.
        let got = eng.select(&batch.view()).expect("engine must stay usable").indices.to_vec();
        assert_eq!(got, want, "{name}: post-error select must be bit-identical");
    }
}

#[test]
fn exhausted_retries_under_degrade_walk_the_ladder() {
    let batch = healthy_batch();
    let mut outputs: Vec<Vec<usize>> = Vec::new();
    for (name, shape, _, _) in shapes() {
        let mut eng = build(shape, FaultPolicy::Degrade);
        eng.set_fault_injector(Some(FaultPlan::new().panic_shard_always(0).arc()));
        let sel = eng.select(&batch.view()).expect("Degrade never fails on a healthy batch");
        assert!(
            matches!(sel.degradations, [Degradation::FeatureOnlyMaxVol { .. }]),
            "{name}: expected the feature-only rung, got {:?}",
            sel.degradations
        );
        assert!(sel.decision.is_none(), "{name}: a degraded subset has no rank decision");
        outputs.push(sel.indices.to_vec());
    }
    // The ladder's feature-only MaxVol runs serially on the engine
    // thread, so every shape degrades to the same subset.
    for (i, out) in outputs.iter().enumerate() {
        assert_eq!(out, &outputs[0], "ladder output must not depend on the shape (#{i})");
    }
}

// ---------------------------------------------------------------------------
// Poisoned input rows
// ---------------------------------------------------------------------------

#[test]
fn poisoned_rows_surface_typed_error_under_fail_and_retry() {
    let batch = poisoned_batch();
    for (name, shape, _, _) in shapes() {
        for policy in [FaultPolicy::Fail, retry(3)] {
            let mut eng = build(shape, policy);
            let err = eng.select(&batch.view()).expect_err("poisoned input must error");
            assert_eq!(
                err,
                SelectError::PoisonedInput { rows: vec![5, 17] },
                "{name}/{policy:?}"
            );
            // Not retryable: the same rows would poison every attempt.
            assert_eq!(eng.fault_stats().retries, 0, "{name}/{policy:?}");
        }
    }
}

#[test]
fn poisoned_rows_under_degrade_are_quarantined_and_winners_remapped() {
    let batch = poisoned_batch();
    let clean = batch.without_rows(&[5, 17]);
    let kept: Vec<usize> = (0..K).filter(|i| *i != 5 && *i != 17).collect();
    for (name, shape, _, _) in shapes() {
        // Expected: exactly the subset the same shape picks on the
        // filtered batch, mapped back to original batch-local indices.
        let expect: Vec<usize> =
            reference(shape, &clean).into_iter().map(|i| kept[i]).collect();
        let mut eng = build(shape, FaultPolicy::Degrade);
        let sel = eng.select(&batch.view()).expect("Degrade quarantines instead of failing");
        assert_eq!(
            sel.degradations,
            &[Degradation::Quarantined { rows: vec![5, 17] }],
            "{name}"
        );
        assert_eq!(sel.indices, &expect[..], "{name}: winners must map back to the original batch");
        assert!(!sel.indices.contains(&5) && !sel.indices.contains(&17), "{name}");
        assert_eq!(eng.fault_stats().quarantined_rows, 2, "{name}");
    }
}

// ---------------------------------------------------------------------------
// Numerical breakdown
// ---------------------------------------------------------------------------

#[test]
fn numerical_breakdown_is_typed_and_never_retried() {
    let batch = degenerate_batch();
    for (name, shape, _, _) in shapes() {
        for policy in [FaultPolicy::Fail, retry(3)] {
            let mut eng = build(shape, policy);
            let err = eng.select(&batch.view()).expect_err("degenerate pivots must error");
            assert!(
                matches!(err, SelectError::NumericalBreakdown { .. }),
                "{name}/{policy:?}: expected NumericalBreakdown, got {err:?}"
            );
            // Deterministic breakdown: retrying would be useless, so the
            // retry counter must stay at zero even under Retry.
            assert_eq!(eng.fault_stats().retries, 0, "{name}/{policy:?}");
        }
    }
}

#[test]
fn numerical_breakdown_under_degrade_takes_the_seeded_random_rung() {
    let batch = degenerate_batch();
    let r = build(ExecShape::Serial, FaultPolicy::Fail).budget_for(K);
    for (name, shape, _, _) in shapes() {
        let run = |mut eng: SelectionEngine| {
            let sel = eng.select(&batch.view()).expect("Degrade never fails");
            assert!(
                matches!(sel.degradations, [Degradation::SeededRandom { .. }]),
                "{name}: feature-only MaxVol breaks the same way, so the ladder must \
                 skip straight to seeded random; got {:?}",
                sel.degradations
            );
            sel.indices.to_vec()
        };
        let a = run(build(shape, FaultPolicy::Degrade));
        let b = run(build(shape, FaultPolicy::Degrade));
        assert_eq!(a, b, "{name}: the random rung is deterministic in (seed, window)");
        assert_eq!(a.len(), r, "{name}: the fallback still honours the budget");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), a.len(), "{name}: unique rows");
        assert!(sorted.iter().all(|&i| i < K), "{name}: in range");
    }
}

// ---------------------------------------------------------------------------
// Worker death and deadline delays (pooled shapes)
// ---------------------------------------------------------------------------

fn pooled_shapes() -> Vec<(&'static str, ExecShape)> {
    shapes()
        .into_iter()
        .filter(|(_, s, _, _)| matches!(s, ExecShape::Pooled { .. }))
        .map(|(n, s, _, _)| (n, s))
        .collect()
}

#[test]
fn worker_death_is_respawned_and_retried_bit_identically() {
    let batch = healthy_batch();
    for (name, shape) in pooled_shapes() {
        let want = reference(shape, &batch);
        for _ in 0..fault_iters(2, 40) {
            let mut eng = build_deadline(shape, retry(2));
            eng.set_fault_injector(Some(FaultPlan::new().kill_worker(0).arc()));
            let got = eng
                .select(&batch.view())
                .unwrap_or_else(|e| panic!("{name}: death must be recovered: {e}"))
                .indices
                .to_vec();
            assert_eq!(got, want, "{name}: recovery after a worker death is bit-identical");
            let stats = eng.fault_stats();
            assert!(stats.respawns >= 1, "{name}: the dead worker must be respawned");
            assert!(stats.retries >= 1, "{name}: its lost job must be retried");
            // The respawned worker serves the next epoch normally.
            let again = eng.select(&batch.view()).expect("healed pool").indices.to_vec();
            assert_eq!(again, want, "{name}");
        }
    }
}

#[test]
fn worker_delay_past_deadline_is_requeued_and_stays_bit_identical() {
    let batch = healthy_batch();
    for (name, shape) in pooled_shapes() {
        let want = reference(shape, &batch);
        for _ in 0..fault_iters(2, 20) {
            let mut eng = build_deadline(shape, retry(2));
            eng.set_fault_injector(Some(
                FaultPlan::new().delay_worker(0, Duration::from_millis(250)).arc(),
            ));
            let got = eng
                .select(&batch.view())
                .unwrap_or_else(|e| panic!("{name}: a wedged worker must not fail: {e}"))
                .indices
                .to_vec();
            assert_eq!(got, want, "{name}: requeued shard must produce the same subset");
            assert!(
                eng.fault_stats().deadline_requeues >= 1,
                "{name}: the deadline requeue must be counted"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Seeded schedule sweeps — the headline property, quantified
// ---------------------------------------------------------------------------

#[test]
fn seeded_fault_schedules_converge_bit_identically_under_retry() {
    let batch = healthy_batch();
    let windows = 3usize;
    for (name, shape, shards, workers) in shapes() {
        let refs = reference_stream(shape, &batch, windows);
        for seed in 0..fault_iters(4, 24) as u64 {
            let plan = FaultPlan::seeded(seed, shards, workers, windows as u64);
            // Budget ≥ the worst case: every event of the plan hitting
            // the same shard in the same window.
            let mut eng = build_deadline(shape, retry(3));
            eng.set_fault_injector(Some(plan.arc()));
            for (w, want) in refs.iter().enumerate() {
                let got = eng
                    .select(&batch.view())
                    .unwrap_or_else(|e| {
                        panic!("{name}/seed {seed}/window {w}: retry must converge: {e}")
                    })
                    .indices
                    .to_vec();
                assert_eq!(
                    &got, want,
                    "{name}/seed {seed}/window {w}: one-shot schedules must end bit-identical"
                );
            }
        }
    }
}

#[test]
fn seeded_fault_schedules_under_degrade_never_fail_and_record_any_drift() {
    let batch = healthy_batch();
    let windows = 3usize;
    for (name, shape, shards, workers) in shapes() {
        let refs = reference_stream(shape, &batch, windows);
        for seed in 0..fault_iters(4, 24) as u64 {
            let mut eng = build_deadline(shape, FaultPolicy::Degrade);
            eng.set_fault_injector(Some(
                FaultPlan::seeded(seed, shards, workers, windows as u64).arc(),
            ));
            for w in 0..windows {
                let sel = eng.select(&batch.view()).unwrap_or_else(|e| {
                    panic!("{name}/seed {seed}/window {w}: Degrade must never fail: {e}")
                });
                // The headline property: either the fault-free subset, or
                // the drift is recorded — never silent.
                assert!(
                    sel.indices == &refs[w][..] || !sel.degradations.is_empty(),
                    "{name}/seed {seed}/window {w}: subset drifted without a recorded \
                     degradation: got {:?}, want {:?}",
                    sel.indices,
                    refs[w]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Streaming windows under faults (pooled assembly-time quarantine)
// ---------------------------------------------------------------------------

#[test]
fn pooled_windows_quarantine_poisoned_window_under_degrade() {
    let shape = ExecShape::Pooled { shards: 2, workers: 2, overlap: false };
    let mut eng = build(shape, FaultPolicy::Degrade);
    let mut consumed: Vec<(usize, usize, Vec<usize>)> = Vec::new();
    eng.windows::<String, _, _>(
        3,
        |wi, _ext| {
            let mut b = random_owned(K, 4, 6, 2, 100 + wi as u64);
            if wi == 1 {
                b.features[(5, 0)] = f64::NAN;
            }
            Ok(b.into_window())
        },
        |wi, win, winners| consumed.push((wi, win.features.rows(), winners.to_vec())),
    )
    .expect("Degrade quarantines the poisoned window instead of failing");
    assert_eq!(consumed.len(), 3, "every window must be consumed");
    assert_eq!(consumed[0].1, K);
    assert_eq!(consumed[1].1, K - 1, "the quarantined row is compacted out of window 1");
    assert_eq!(consumed[2].1, K);
    assert!(
        eng.last_degradations()
            .iter()
            .any(|d| matches!(d, Degradation::Quarantined { rows } if rows == &[5])),
        "the quarantine must be recorded: {:?}",
        eng.last_degradations()
    );
    assert_eq!(eng.fault_stats().quarantined_rows, 1);
}

#[test]
fn pooled_windows_poisoned_window_fails_typed_under_fail() {
    let shape = ExecShape::Pooled { shards: 2, workers: 2, overlap: false };
    let mut eng = build(shape, FaultPolicy::Fail);
    let mut consumed: Vec<usize> = Vec::new();
    let err = eng
        .windows::<String, _, _>(
            3,
            |wi, _ext| {
                let mut b = random_owned(K, 4, 6, 2, 100 + wi as u64);
                if wi == 1 {
                    b.features[(5, 0)] = f64::NAN;
                }
                Ok(b.into_window())
            },
            |wi, _win, _winners| consumed.push(wi),
        )
        .expect_err("a poisoned window under Fail aborts the session");
    assert_eq!(
        err,
        WindowsError::Select(SelectError::PoisonedInput { rows: vec![5] })
    );
    assert_eq!(consumed, vec![0], "only the healthy window before the poison lands");
}

//! Counting-allocator proof of the PR 1 zero-allocation claim: once the
//! [`Workspace`] and output buffers have warmed up on one batch, the
//! steady-state selection loop (`fast_maxvol_with`, `FastMaxVol` and
//! strict-budget `GraftSelector` via `select_into`) performs no heap
//! allocations at all.
//!
//! A single #[test] keeps the global counter single-writer; the measured
//! region is retried a few times so an unrelated harness-thread allocation
//! cannot flake the assertion (a genuine per-call allocation fires on
//! every attempt and still fails).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use graft::coordinator::{MergePolicy, PooledSelector, ShardedSelector};
use graft::engine::{EngineBuilder, ExecShape};
use graft::graft::{BudgetedRankPolicy, GraftSelector};
use graft::linalg::{transpose_into, Mat, Workspace};
use graft::rng::Rng;
use graft::selection::maxvol::{fast_maxvol_with, FastMaxVol};
use graft::selection::{BatchView, Selector};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

/// Run `f` and return the number of allocator calls it triggered,
/// retrying to shrug off unrelated background allocations.
fn measured<F: FnMut()>(mut f: F) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..3 {
        let before = allocs();
        f();
        let delta = allocs() - before;
        best = best.min(delta);
        if best == 0 {
            break;
        }
    }
    best
}

struct OwnedView {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    row_ids: Vec<usize>,
}

impl OwnedView {
    fn random(k: usize, r: usize, e: usize, seed: u64) -> OwnedView {
        let mut rng = Rng::new(seed);
        OwnedView {
            features: Mat::from_fn(k, r, |_, _| rng.normal()),
            grads: Mat::from_fn(k, e, |_, _| rng.normal()),
            losses: (0..k).map(|_| rng.uniform() * 2.0).collect(),
            labels: (0..k).map(|i| (i % 4) as i32).collect(),
            preds: (0..k).map(|i| (i % 4) as i32).collect(),
            row_ids: (0..k).collect(),
        }
    }

    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: 4,
            row_ids: &self.row_ids,
        }
    }
}

#[test]
fn steady_state_selection_is_allocation_free() {
    let owned = OwnedView::random(256, 16, 24, 7);
    let mut ws = Workspace::new();
    let mut out: Vec<usize> = Vec::new();

    // ---- fast_maxvol_with ------------------------------------------------
    for _ in 0..2 {
        fast_maxvol_with(&owned.features, 16, &mut ws, &mut out); // warm-up
    }
    let d = measured(|| {
        for _ in 0..10 {
            fast_maxvol_with(&owned.features, 16, &mut ws, &mut out);
        }
    });
    assert_eq!(d, 0, "fast_maxvol_with allocated {d} times at steady state");

    // ---- FastMaxVol selector with loss top-up ----------------------------
    let mut sel = FastMaxVol;
    for _ in 0..2 {
        sel.select_into(&owned.view(), 32, &mut ws, &mut out); // warm-up (forces top-up)
    }
    assert_eq!(out.len(), 32);
    let d = measured(|| {
        for _ in 0..10 {
            sel.select_into(&owned.view(), 32, &mut ws, &mut out);
        }
    });
    assert_eq!(d, 0, "FastMaxVol::select_into allocated {d} times at steady state");

    // ---- strict-budget GraftSelector (full Stage 1 + Stage 2 path) -------
    let mut g = GraftSelector::new(BudgetedRankPolicy::strict(0.05));
    for _ in 0..2 {
        g.select_into(&owned.view(), 48, &mut ws, &mut out); // warm-up
    }
    assert_eq!(out.len(), 48);
    let d = measured(|| {
        for _ in 0..10 {
            g.select_into(&owned.view(), 48, &mut ws, &mut out);
        }
    });
    assert_eq!(d, 0, "GraftSelector::select_into allocated {d} times at steady state");

    // ---- persistent selection pool (PR 3) --------------------------------
    // The counting allocator is global, so worker-thread allocations count
    // too: once each worker's workspace/gather buffers and every winner
    // buffer have warmed up, a pooled refresh must allocate nowhere — the
    // job/result messages move recycled Vecs through preallocated
    // `sync_channel` slots, and the merge runs on retained scratch.
    let mut pooled = PooledSelector::from_factory(4, 2, MergePolicy::Hierarchical, |_| {
        Box::new(FastMaxVol)
    });
    for _ in 0..3 {
        pooled.select_into(&owned.view(), 32, &mut ws, &mut out); // warm-up (incl. merge top-up)
    }
    assert_eq!(out.len(), 32);
    let d = measured(|| {
        for _ in 0..10 {
            pooled.select_into(&owned.view(), 32, &mut ws, &mut out);
        }
    });
    assert_eq!(d, 0, "PooledSelector::select_into allocated {d} times at steady state");

    // ---- gradient-aware merge (PR 4) --------------------------------------
    // The grad merge adds per-shard ShardGrads (winner sketch columns +
    // partial ḡ sums), the id→shard map, the global ḡ, and the merged
    // error curve — all retained scratch.  Once warmed, a sharded
    // grad-merge refresh with an adaptive rank authority must allocate
    // nothing, scoped or pooled.
    let mut graded = ShardedSelector::from_factory(4, MergePolicy::Grad, |_| {
        Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
    })
    .with_parallel(false)
    .with_rank_authority(Box::new(GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0))));
    for _ in 0..3 {
        graded.select_into(&owned.view(), 32, &mut ws, &mut out); // warm-up
    }
    let d = measured(|| {
        for _ in 0..10 {
            graded.select_into(&owned.view(), 32, &mut ws, &mut out);
        }
    });
    assert_eq!(d, 0, "grad-merge ShardedSelector allocated {d} times at steady state");

    let mut graded_pool = PooledSelector::from_factory(4, 2, MergePolicy::Grad, |_| {
        Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
    })
    .with_rank_authority(Box::new(GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0))));
    for _ in 0..3 {
        graded_pool.select_into(&owned.view(), 32, &mut ws, &mut out); // warm-up
    }
    let d = measured(|| {
        for _ in 0..10 {
            graded_pool.select_into(&owned.view(), 32, &mut ws, &mut out);
        }
    });
    assert_eq!(d, 0, "grad-merge PooledSelector allocated {d} times at steady state");

    // ---- streaming push (PR 7) -------------------------------------------
    // The streaming engine's bounded-memory claim, allocation edition:
    // once the reservoir has saturated and the elimination cache has
    // warmed (first full pass over the stream), every further push —
    // including admissions, which rebuild the cache, and evictions,
    // which overwrite slots in place — reuses retained buffers.  The
    // 512-row stream is 16× the 32-slot reservoir, so the measured
    // region exercises the admit, reject, and loss-replace arms.
    let big = OwnedView::random(512, 8, 12, 19);
    let mut se = EngineBuilder::new()
        .method("graft")
        .budget(16)
        .epsilon(0.05)
        .build_streaming()
        .expect("stream engine");
    se.push(&big.view()).expect("warm-up stream");
    let d = measured(|| {
        se.push(&big.view()).expect("steady-state push");
    });
    assert_eq!(d, 0, "StreamingEngine::push allocated {d} times at steady state");
    assert_eq!(se.carried_sketch_bytes(), 0, "strict stream carries no gradient sketches");

    // ---- transpose_into (PR 9) -------------------------------------------
    // The allocation-free twin of `Mat::transpose`: callers holding
    // scratch write straight into it, so the steady-state call must not
    // touch the allocator at all.
    let src = OwnedView::random(96, 24, 4, 23).features;
    let mut dst = vec![0.0f64; 96 * 24];
    transpose_into(96, 24, src.data(), &mut dst); // warm-up (paging, not allocs)
    let d = measured(|| {
        for _ in 0..10 {
            transpose_into(96, 24, src.data(), &mut dst);
        }
    });
    assert_eq!(d, 0, "transpose_into allocated {d} times at steady state");

    // ---- adaptive-only gradient carry (PR 9) ------------------------------
    // Strict sharded/pooled engines install no rank authority, so zero
    // gradient-sketch bytes ever cross the shard→merge boundary — while
    // the subset stays bit-identical to the old strict wiring (per-shard
    // strict instances + a strict authority on the coordinator).
    let mut legacy = ShardedSelector::from_factory(4, MergePolicy::Grad, |_| {
        Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05)))
    })
    .with_parallel(false)
    .with_rank_authority(Box::new(GraftSelector::new(BudgetedRankPolicy::strict(0.05))));
    let mut want = Vec::new();
    legacy.select_into(&owned.view(), 32, &mut ws, &mut want);
    assert!(legacy.carried_sketch_bytes() > 0, "legacy strict wiring ships sketches");

    for shape in [
        ExecShape::Sharded { shards: 4 },
        ExecShape::Pooled { shards: 4, workers: 2, overlap: false },
    ] {
        let mut eng = EngineBuilder::new()
            .method("graft")
            .budget(32)
            .epsilon(0.05)
            .exec(shape)
            .build()
            .expect("strict engine");
        let got = eng.select(&owned.view()).expect("healthy").indices.to_vec();
        assert_eq!(got, want, "strict no-carry subset diverged at {shape:?}");
        assert_eq!(
            eng.carried_sketch_bytes(),
            0,
            "strict {shape:?} must carry zero gradient-sketch bytes"
        );
    }
}

//! Property sweeps over ALL selection methods (a dependency-free stand-in
//! for proptest): many seeded random batch shapes × every selector,
//! asserting the selection contract (size, uniqueness, range, determinism
//! under fixed state) plus method-specific invariants.

use graft::graft::{BudgetedRankPolicy, GraftSelector};
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::selection::{by_name, BatchView, Selector};

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

/// Random batch with occasional adversarial structure (duplicates, zero
/// rows, constant gradients) controlled by the seed.
fn random_batch(seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let k = 8 + rng.below(120);
    let r = 1 + rng.below(12.min(k));
    let e = 2 + rng.below(24);
    let classes = 2 + rng.below(6);
    let mut features = Mat::from_fn(k, r, |_, _| rng.normal());
    let mut grads = Mat::from_fn(k, e, |_, _| rng.normal());
    // Adversarial decorations.
    match seed % 5 {
        1 => {
            // Duplicate half the rows.
            for i in 0..k / 2 {
                let src = i;
                let dst = k / 2 + i;
                for j in 0..r {
                    features[(dst, j)] = features[(src, j)];
                }
                for j in 0..e {
                    grads[(dst, j)] = grads[(src, j)];
                }
            }
        }
        2 => {
            // Zero out a block of rows.
            for i in 0..k / 3 {
                for j in 0..r {
                    features[(i, j)] = 0.0;
                }
            }
        }
        3 => {
            // Constant gradients (zero variance).
            for i in 0..k {
                for j in 0..e {
                    grads[(i, j)] = 1.0;
                }
            }
        }
        _ => {}
    }
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 3.0).collect();
    let labels: Vec<i32> = (0..k).map(|_| rng.below(classes) as i32).collect();
    let preds: Vec<i32> = labels
        .iter()
        .map(|&y| if rng.uniform() < 0.75 { y } else { rng.below(classes) as i32 })
        .collect();
    Owned { features, grads, losses, labels, preds, classes, row_ids: (0..k).collect() }
}

const METHODS: &[&str] = &[
    "maxvol", "cross-maxvol", "random", "craig", "gradmatch", "glister", "drop", "el2n", "forget",
];

fn check_contract(name: &str, sel: &mut dyn Selector, owned: &Owned, r: usize, seed: u64) {
    let k = owned.features.rows();
    let out = sel.select(&owned.view(), r);
    let want = r.min(k);
    assert_eq!(out.len(), want, "{name} seed {seed}: size (k={k}, r={r})");
    let mut s = out.clone();
    s.sort_unstable();
    s.dedup();
    assert_eq!(s.len(), want, "{name} seed {seed}: uniqueness");
    assert!(s.iter().all(|&i| i < k), "{name} seed {seed}: range");
}

#[test]
fn all_selectors_satisfy_contract_on_100_random_batches() {
    for seed in 0..100u64 {
        let owned = random_batch(seed);
        let k = owned.features.rows();
        let mut rng = Rng::new(seed ^ 0xABC);
        let r = 1 + rng.below(k);
        for m in METHODS {
            let mut sel = by_name(m, seed).unwrap();
            check_contract(m, sel.as_mut(), &owned, r, seed);
        }
    }
}

#[test]
fn graft_selector_contract_on_random_batches() {
    for seed in 0..60u64 {
        let owned = random_batch(seed);
        let k = owned.features.rows();
        let mut rng = Rng::new(seed ^ 0xDEF);
        let r = 1 + rng.below(k);
        let mut g = GraftSelector::new(BudgetedRankPolicy::strict(0.1));
        check_contract("graft", &mut g, &owned, r, seed);
        // Adaptive never exceeds the feature width.
        let mut ga = GraftSelector::new(BudgetedRankPolicy::adaptive(0.1, 0.5));
        let out = ga.select(&owned.view(), r);
        assert!(out.len() <= owned.features.cols().max(r));
        let mut s = out;
        s.sort_unstable();
        s.dedup();
        assert!(s.iter().all(|&i| i < k));
    }
}

#[test]
fn deterministic_methods_are_deterministic() {
    for seed in [3u64, 17, 41] {
        let owned = random_batch(seed);
        for m in METHODS.iter().filter(|&&m| m != "random") {
            let a = by_name(m, 9).unwrap().select(&owned.view(), 6);
            let b = by_name(m, 9).unwrap().select(&owned.view(), 6);
            assert_eq!(a, b, "{m} seed {seed}");
        }
    }
}

#[test]
fn maxvol_volume_dominates_random_across_seeds() {
    // Statistical invariant: over many seeds, MaxVol's selected volume
    // beats the random median in at least 90% of cases.
    let mut wins = 0;
    let total = 40;
    for seed in 0..total as u64 {
        let mut rng = Rng::new(seed ^ 0x70_1d);
        let k = 32 + rng.below(64);
        let r = 4 + rng.below(4);
        let v = Mat::from_fn(k, r, |_, _| rng.normal());
        let p = graft::selection::maxvol::fast_maxvol(&v, r);
        let vol = graft::linalg::det(&v.take_rows(&p)).abs();
        let mut rand_vols: Vec<f64> = (0..9)
            .map(|_| graft::linalg::det(&v.take_rows(&rng.choose(k, r))).abs())
            .collect();
        rand_vols.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if vol >= rand_vols[4] {
            wins += 1;
        }
    }
    assert!(wins * 10 >= total * 9, "maxvol won only {wins}/{total}");
}

#[test]
fn class_coverage_of_stratified_methods() {
    // DRoP must touch every class present when the budget allows.
    for seed in 0..20u64 {
        let owned = random_batch(seed * 7 + 4);
        let k = owned.features.rows();
        let classes = owned.classes;
        if k < classes * 3 {
            continue;
        }
        let mut sel = by_name("drop", seed).unwrap();
        let out = sel.select(&owned.view(), classes * 2);
        let mut seen = vec![false; classes];
        for &i in &out {
            seen[owned.labels[i] as usize] = true;
        }
        let present: Vec<usize> = (0..classes)
            .filter(|&c| owned.labels.iter().any(|&y| y as usize == c))
            .collect();
        let covered = present.iter().filter(|&&c| seen[c]).count();
        assert!(
            covered * 3 >= present.len() * 2,
            "drop seed {seed}: covered {covered}/{} classes",
            present.len()
        );
    }
}

//! `SelectionEngine` facade pins (PR 5):
//!
//! 1. **Builder validation** — every rejected knob combination returns a
//!    typed [`EngineError`] naming the offending field (table-driven).
//! 2. **Bit-identity through the facade** — for FastMaxVol and GRAFT
//!    (strict + adaptive), engine output equals the pre-engine
//!    trainer/coordinator wiring at `ExecShape` ∈ {Serial, Sharded{2,4},
//!    Pooled{2 workers, overlap on/off}} on seeded batches, including the
//!    rank authority's accounting.
//! 3. **Fallback semantics** — non-shardable methods downgrade to serial
//!    (or a one-shard pool) with a note, and behave exactly like the
//!    serial construction.
//! 4. **Streaming session** — `windows()` produces the same consume
//!    stream with overlap on and off, and drains cleanly when assembly
//!    fails mid-overlap.

use graft::coordinator::{MergePolicy, PooledSelector, SelectWindow, ShardedSelector};
use graft::engine::{
    EngineBuilder, EngineError, ExecShape, RankMode, SelectionEngine, WindowsError,
};
use graft::graft::{BudgetedRankPolicy, GraftSelector, RankStats};
use graft::linalg::{Mat, Workspace};
use graft::rng::Rng;
use graft::selection::{el2n::El2n, maxvol::FastMaxVol, BatchView, Selector};

const EPS: f64 = 0.05;

// ---------------------------------------------------------------------------
// Synthetic batch builders (mirrors tests/gradient_merge.rs)
// ---------------------------------------------------------------------------

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }

    fn into_window(self) -> SelectWindow {
        SelectWindow {
            features: self.features,
            grads: self.grads,
            losses: self.losses,
            labels: self.labels,
            preds: self.preds,
            classes: self.classes,
            row_ids: self.row_ids,
        }
    }
}

fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

// ---------------------------------------------------------------------------
// 1. Builder validation: typed errors naming the offending field
// ---------------------------------------------------------------------------

#[test]
fn builder_rejections_name_the_offending_field() {
    type Build = Box<dyn Fn() -> Result<SelectionEngine, EngineError>>;
    let cases: Vec<(&str, Build, &str)> = vec![
        (
            "overlap without pool",
            Box::new(|| EngineBuilder::new().overlap(true).build()),
            "overlap",
        ),
        ("shards = 0 (knob)", Box::new(|| EngineBuilder::new().shards(0).build()), "shards"),
        (
            "shards = 0 (typed)",
            Box::new(|| EngineBuilder::new().exec(ExecShape::Sharded { shards: 0 }).build()),
            "shards",
        ),
        (
            "pooled shards = 0 (typed)",
            Box::new(|| {
                EngineBuilder::new()
                    .exec(ExecShape::Pooled { shards: 0, workers: 2, overlap: false })
                    .build()
            }),
            "shards",
        ),
        (
            "pooled workers = 0 (typed)",
            Box::new(|| {
                EngineBuilder::new()
                    .exec(ExecShape::Pooled { shards: 2, workers: 0, overlap: false })
                    .build()
            }),
            "workers",
        ),
        (
            "unknown method",
            Box::new(|| EngineBuilder::new().method("nope").build()),
            "method",
        ),
        (
            "misspelled graft variant",
            Box::new(|| EngineBuilder::new().method("graftx").build()),
            "method",
        ),
        (
            "unknown extractor",
            Box::new(|| EngineBuilder::new().extractor("nope").build()),
            "extractor",
        ),
        (
            "unknown merge spelling",
            Box::new(|| EngineBuilder::new().merge_name("nope").build()),
            "merge",
        ),
        ("epsilon = 0", Box::new(|| EngineBuilder::new().epsilon(0.0).build()), "epsilon"),
        (
            "epsilon > 1 (adaptive)",
            Box::new(|| EngineBuilder::new().rank(RankMode::Adaptive { epsilon: 1.5 }).build()),
            "epsilon",
        ),
        (
            "epsilon NaN",
            Box::new(|| EngineBuilder::new().epsilon(f64::NAN).build()),
            "epsilon",
        ),
        ("fraction = 0", Box::new(|| EngineBuilder::new().fraction(0.0).build()), "fraction"),
        (
            "fraction > 1",
            Box::new(|| EngineBuilder::new().fraction(1.5).build()),
            "fraction",
        ),
        (
            "fraction NaN",
            Box::new(|| EngineBuilder::new().fraction(f64::NAN).build()),
            "fraction",
        ),
        ("budget = 0", Box::new(|| EngineBuilder::new().budget(0).build()), "budget"),
    ];
    for (label, build, field) in cases {
        let err = build().err().unwrap_or_else(|| panic!("{label}: must be rejected"));
        assert_eq!(err.field(), field, "{label}: typed field");
        let msg = err.to_string();
        assert!(msg.contains(field), "{label}: message must name the field, got '{msg}'");
    }
}

#[test]
fn valid_configurations_build() {
    // The happy paths the rejection table brackets.
    for shape in [
        ExecShape::Serial,
        ExecShape::Sharded { shards: 4 },
        ExecShape::Pooled { shards: 4, workers: 2, overlap: true },
    ] {
        let eng = EngineBuilder::new()
            .method("graft")
            .fraction(0.5)
            .rank(RankMode::Adaptive { epsilon: EPS })
            .exec(shape)
            .build()
            .unwrap_or_else(|e| panic!("{shape:?}: {e}"));
        assert_eq!(eng.shape(), shape);
        assert!(eng.notes().is_empty(), "{shape:?}: no fallback for a shardable method");
    }
    // Knob path resolves to the same typed shapes.
    let eng = EngineBuilder::new().shards(4).pool_workers(2).overlap(true).build().unwrap();
    assert_eq!(eng.shape(), ExecShape::Pooled { shards: 4, workers: 2, overlap: true });
    let eng = EngineBuilder::new().shards(4).build().unwrap();
    assert_eq!(eng.shape(), ExecShape::Sharded { shards: 4 });
    let eng = EngineBuilder::new().shards(1).build().unwrap();
    assert_eq!(eng.shape(), ExecShape::Serial);
    // Method-aware merge default, in one place.
    assert_eq!(EngineBuilder::new().method("graft").build().unwrap().merge(), MergePolicy::Grad);
    assert_eq!(
        EngineBuilder::new().method("maxvol").build().unwrap().merge(),
        MergePolicy::Hierarchical
    );
    assert_eq!(
        EngineBuilder::new().method("graft").merge_name("flat").build().unwrap().merge(),
        MergePolicy::Flat,
        "explicit spelling beats the method-aware default"
    );
}

// ---------------------------------------------------------------------------
// 2. Bit-identity through the facade
// ---------------------------------------------------------------------------

/// The pre-engine trainer wiring for GRAFT (mirrors
/// tests/gradient_merge.rs): per-shard strict instances above one shard,
/// run policy inline at one shard, authority on the coordinator.
fn direct_scoped(shards: usize, policy: &BudgetedRankPolicy) -> ShardedSelector {
    let inner = policy.clone();
    let sel = ShardedSelector::from_factory(shards, MergePolicy::Grad, move |_| {
        Box::new(GraftSelector::new(if shards > 1 {
            BudgetedRankPolicy::strict(EPS)
        } else {
            inner.clone()
        }))
    });
    if shards > 1 {
        sel.with_rank_authority(Box::new(GraftSelector::new(policy.clone())))
    } else {
        sel
    }
}

fn direct_pooled(shards: usize, workers: usize, policy: &BudgetedRankPolicy) -> PooledSelector {
    let inner = policy.clone();
    let sel = PooledSelector::from_factory(shards, workers, MergePolicy::Grad, move |_| {
        Box::new(GraftSelector::new(if shards > 1 {
            BudgetedRankPolicy::strict(EPS)
        } else {
            inner.clone()
        }))
    });
    if shards > 1 {
        sel.with_rank_authority(Box::new(GraftSelector::new(policy.clone())))
    } else {
        sel
    }
}

fn graft_engine(shape: ExecShape, adaptive: bool) -> SelectionEngine {
    let mut b = EngineBuilder::new()
        .method("graft")
        .fraction(0.5)
        .epsilon(EPS)
        .budget(16)
        .exec(shape);
    if adaptive {
        b = b.rank(RankMode::Adaptive { epsilon: EPS });
    }
    b.build().expect("valid GRAFT configuration")
}

fn run_policy(adaptive: bool) -> BudgetedRankPolicy {
    if adaptive {
        BudgetedRankPolicy::adaptive(EPS, 0.5)
    } else {
        BudgetedRankPolicy::strict(EPS)
    }
}

/// Accounting comparison against the pre-engine wiring.  Adaptive shapes
/// must match the direct authority's `RankStats` exactly.  Strict
/// sharded/pooled shapes no longer install an authority (the
/// adaptive-only carry): the engine's strict tally reproduces the same
/// rank sequence and batch count, but reports the identity cut's zero
/// residual instead of re-running fused MGS to price a cut that cannot
/// happen.
fn assert_accounting_matches(
    eng: Option<RankStats>,
    direct: Option<RankStats>,
    adaptive: bool,
    ctx: &str,
) {
    if adaptive {
        assert_eq!(eng, direct, "{ctx}: adaptive accounting");
        return;
    }
    match (eng, direct) {
        (None, None) => {}
        (Some(e), Some(d)) => {
            assert_eq!(e.mean_rank, d.mean_rank, "{ctx}: strict mean rank");
            assert_eq!(e.batches, d.batches, "{ctx}: strict batch count");
            assert_eq!(
                e.last.map(|l| l.rank),
                d.last.map(|l| l.rank),
                "{ctx}: strict last rank"
            );
            let last = e.last.expect("strict tally records every healthy window");
            assert_eq!(last.error, 0.0, "{ctx}: identity cut has zero residual");
            assert!(last.satisfied, "{ctx}: identity cut is satisfied");
        }
        (e, d) => panic!("{ctx}: accounting presence mismatch (engine {e:?}, direct {d:?})"),
    }
}

#[test]
fn graft_facade_matches_pre_engine_wiring_at_every_shape() {
    // Three batches per shape so the adaptive accumulator state evolves;
    // the engine must match the direct wiring batch-for-batch AND end in
    // the same accounting state.
    let batches: Vec<Owned> = (0..3).map(|i| random_owned(96, 12, 16, 4, 301 + i)).collect();
    for adaptive in [false, true] {
        let ctx = if adaptive { "adaptive" } else { "strict" };
        // Serial ≡ single-shot GraftSelector.
        let mut eng = graft_engine(ExecShape::Serial, adaptive);
        let mut direct = GraftSelector::new(run_policy(adaptive));
        for b in &batches {
            let want = direct.select(&b.view(), 16);
            assert_eq!(eng.select(&b.view()).expect("healthy").indices, &want[..], "{ctx} serial");
        }
        assert_eq!(eng.rank_stats(), direct.rank_stats(), "{ctx} serial accounting");

        // Sharded{2,4} ≡ scoped ShardedSelector with trainer wiring.
        for shards in [2usize, 4] {
            let mut eng = graft_engine(ExecShape::Sharded { shards }, adaptive);
            let mut direct = direct_scoped(shards, &run_policy(adaptive));
            let mut ws = Workspace::new();
            let mut out = Vec::new();
            for b in &batches {
                direct.select_into(&b.view(), 16, &mut ws, &mut out);
                assert_eq!(
                    eng.select(&b.view()).expect("healthy").indices,
                    &out[..],
                    "{ctx} sharded{shards}"
                );
            }
            assert_accounting_matches(
                eng.rank_stats(),
                direct.rank_stats(),
                adaptive,
                &format!("{ctx} sharded{shards}"),
            );
        }

        // Pooled{2 workers} ≡ PooledSelector with trainer wiring.
        for shards in [1usize, 2, 4] {
            let mut eng = graft_engine(
                ExecShape::Pooled { shards, workers: 2, overlap: false },
                adaptive,
            );
            let mut direct = direct_pooled(shards, 2, &run_policy(adaptive));
            let mut ws = Workspace::new();
            let mut out = Vec::new();
            for b in &batches {
                direct.select_into(&b.view(), 16, &mut ws, &mut out);
                assert_eq!(
                    eng.select(&b.view()).expect("healthy").indices,
                    &out[..],
                    "{ctx} pooled shards={shards}"
                );
            }
            assert_accounting_matches(
                eng.rank_stats(),
                direct.rank_stats(),
                adaptive,
                &format!("{ctx} pooled shards={shards}"),
            );
        }
    }
}

#[test]
fn maxvol_facade_matches_direct_construction() {
    let owned = random_owned(128, 16, 8, 4, 401);
    let mut ws = Workspace::new();
    let mut want = Vec::new();

    let mut eng = EngineBuilder::new().method("maxvol").budget(24).build().unwrap();
    FastMaxVol.select_into(&owned.view(), 24, &mut ws, &mut want);
    assert_eq!(eng.select(&owned.view()).expect("healthy").indices, &want[..], "serial");

    for shards in [2usize, 4] {
        let mut eng = EngineBuilder::new()
            .method("maxvol")
            .budget(24)
            .exec(ExecShape::Sharded { shards })
            .build()
            .unwrap();
        let mut direct = ShardedSelector::from_factory(shards, MergePolicy::Hierarchical, |_| {
            Box::new(FastMaxVol)
        });
        direct.select_into(&owned.view(), 24, &mut ws, &mut want);
        assert_eq!(
            eng.select(&owned.view()).expect("healthy").indices,
            &want[..],
            "sharded{shards}"
        );
    }

    let mut eng = EngineBuilder::new()
        .method("maxvol")
        .budget(24)
        .exec(ExecShape::Pooled { shards: 4, workers: 2, overlap: false })
        .build()
        .unwrap();
    let mut direct =
        PooledSelector::from_factory(4, 2, MergePolicy::Hierarchical, |_| Box::new(FastMaxVol));
    direct.select_into(&owned.view(), 24, &mut ws, &mut want);
    assert_eq!(eng.select(&owned.view()).expect("healthy").indices, &want[..], "pooled");
}

#[test]
fn seeded_baselines_match_direct_construction_per_shape() {
    // `random` exercises the seed plumbing: the facade must hand the base
    // seed to shard 0 so every shape matches the serial construction.
    let owned = random_owned(64, 8, 8, 4, 403);
    let seed = 0xC0FFEE;
    let want = graft::selection::by_name("random", seed).unwrap().select(&owned.view(), 16);
    let mut eng = EngineBuilder::new().method("random").seed(seed).budget(16).build().unwrap();
    assert_eq!(eng.select(&owned.view()).expect("healthy").indices, &want[..], "serial random");
    // Non-shardable → a pool hosts it at ONE shard: same instance, same
    // seed, same subset.
    let mut eng = EngineBuilder::new()
        .method("random")
        .seed(seed)
        .budget(16)
        .exec(ExecShape::Pooled { shards: 4, workers: 2, overlap: false })
        .build()
        .unwrap();
    assert!(!eng.notes().is_empty(), "downgrade must be noted");
    assert_eq!(eng.shape(), ExecShape::Pooled { shards: 1, workers: 2, overlap: false });
    assert_eq!(
        eng.select(&owned.view()).expect("healthy").indices,
        &want[..],
        "pool-hosted random"
    );
}

// ---------------------------------------------------------------------------
// 3. Fallbacks and selection metadata
// ---------------------------------------------------------------------------

#[test]
fn non_shardable_method_downgrades_to_serial_with_note() {
    let owned = random_owned(64, 8, 8, 4, 405);
    let mut eng = EngineBuilder::new()
        .method("el2n")
        .budget(16)
        .exec(ExecShape::Sharded { shards: 4 })
        .build()
        .unwrap();
    assert_eq!(eng.shape(), ExecShape::Serial, "non-shardable falls back to serial");
    let note = eng.notes().join("\n");
    assert!(note.contains("not shardable"), "note explains the downgrade: {note}");
    let want = El2n.select(&owned.view(), 16);
    assert_eq!(
        eng.select(&owned.view()).expect("healthy").indices,
        &want[..],
        "downgraded ≡ serial el2n"
    );
}

#[test]
fn selection_reports_budget_window_and_decision() {
    let owned = random_owned(64, 8, 16, 4, 407);
    // Fraction-derived budget: 0.25 · 64 = 16.
    let mut eng = EngineBuilder::new().method("graft").fraction(0.25).build().unwrap();
    assert_eq!(eng.budget_for(64), 16);
    {
        let sel = eng.select(&owned.view()).expect("healthy");
        assert_eq!(sel.budget, 16);
        assert_eq!(sel.indices.len(), 16, "strict GRAFT honours the budget");
        assert_eq!(sel.window, 0);
        let d = sel.decision.expect("serial GRAFT reports its decision");
        assert!(d.rank >= 1);
    }
    assert_eq!(
        eng.select(&owned.view()).expect("healthy").window,
        1,
        "window counter advances"
    );

    // Sharded gradient-aware strict path: no authority runs (the
    // adaptive-only carry), but the engine still surfaces the synthesised
    // strict decision — and zero gradient-sketch bytes are resident.
    let mut eng = EngineBuilder::new()
        .method("graft")
        .budget(16)
        .exec(ExecShape::Sharded { shards: 2 })
        .build()
        .unwrap();
    let sel = eng.select(&owned.view()).expect("healthy");
    let d = sel.decision.expect("strict tally decides");
    assert_eq!(d.rank, 16, "strict cut keeps the budget");
    assert_eq!(sel.indices.len(), 16);
    assert_eq!(eng.carried_sketch_bytes(), 0, "strict sharded carries no sketches");
}

// ---------------------------------------------------------------------------
// 4. Streaming session: overlap ≡ serial, error drains
// ---------------------------------------------------------------------------

fn window_stream(count: usize, base: u64) -> Vec<SelectWindow> {
    (0..count).map(|i| random_owned(96, 12, 16, 4, base + i as u64).into_window()).collect()
}

#[test]
fn windows_overlap_and_serial_consume_streams_agree() {
    let count = 5;
    let shapes = [
        ExecShape::Pooled { shards: 2, workers: 2, overlap: true },
        ExecShape::Pooled { shards: 2, workers: 2, overlap: false },
        ExecShape::Sharded { shards: 2 },
    ];
    let mut streams: Vec<Vec<(usize, Vec<usize>)>> = Vec::new();
    for shape in shapes {
        let mut eng = graft_engine(shape, false);
        let mut got: Vec<(usize, Vec<usize>)> = Vec::new();
        let windows = window_stream(count, 501);
        eng.windows::<std::convert::Infallible, _, _>(
            count,
            |wi, _ext| Ok(windows[wi].clone_window()),
            |wi, _win, winners| got.push((wi, winners.to_vec())),
        )
        .unwrap();
        assert_eq!(got.len(), count, "{shape:?}: every window consumed");
        streams.push(got);
    }
    assert_eq!(streams[0], streams[1], "overlap on ≡ overlap off");
    assert_eq!(streams[0], streams[2], "pooled ≡ scoped at equal shard count");
}

/// `SelectWindow` is consumed by value per call in this test's assemble
/// closures; clone the backing data so the fixture can be replayed across
/// engines.
trait CloneWindow {
    fn clone_window(&self) -> SelectWindow;
}

impl CloneWindow for SelectWindow {
    fn clone_window(&self) -> SelectWindow {
        SelectWindow {
            features: self.features.clone(),
            grads: self.grads.clone(),
            losses: self.losses.clone(),
            labels: self.labels.clone(),
            preds: self.preds.clone(),
            classes: self.classes,
            row_ids: self.row_ids.clone(),
        }
    }
}

#[test]
fn windows_assemble_error_mid_overlap_drains_and_propagates() {
    let mut eng = graft_engine(ExecShape::Pooled { shards: 2, workers: 2, overlap: true }, false);
    let windows = window_stream(2, 601);
    let mut consumed = 0usize;
    let res = eng.windows::<String, _, _>(
        4,
        |wi, _ext| {
            if wi >= 2 {
                Err(format!("assembly failed at window {wi}"))
            } else {
                Ok(windows[wi].clone_window())
            }
        },
        |_wi, _win, _winners| consumed += 1,
    );
    let err = res.expect_err("assembly error must propagate");
    let WindowsError::Assemble(msg) = err else {
        panic!("assembly failure must surface as WindowsError::Assemble, got {err:?}");
    };
    assert!(msg.contains("window 2"), "{msg}");
    // The in-flight epoch was drained by the pending guard: the engine
    // stays usable for the next refresh.
    let owned = random_owned(96, 12, 16, 4, 603);
    assert_eq!(
        eng.select(&owned.view()).expect("healthy").indices.len(),
        16,
        "engine usable after error"
    );
}

#[test]
fn one_shot_select_thread_local_workspace_is_consistent() {
    // Satellite pin: `Selector::select` now draws scratch from a
    // per-thread cached workspace — repeated and interleaved one-shot
    // calls must stay identical to `select_into` with fresh scratch.
    let a = random_owned(64, 8, 16, 4, 701);
    let b = random_owned(64, 8, 16, 4, 702);
    for _ in 0..3 {
        let via_select = FastMaxVol.select(&a.view(), 12);
        let mut ws = Workspace::new();
        let mut out = Vec::new();
        FastMaxVol.select_into(&a.view(), 12, &mut ws, &mut out);
        assert_eq!(via_select, out, "cached workspace must not change results");
        // Interleave another batch through the same thread-local cache.
        let _ = FastMaxVol.select(&b.view(), 20);
    }
}

//! Streaming selection engine pins (PR 7):
//!
//! 1. **Stream ≡ batch** — when the whole stream fits the reservoir
//!    (K ≤ cap = max(2·budget, feature width)), a `StreamingEngine`
//!    snapshot is bit-identical to the batch `SelectionEngine` on the
//!    same rows — indices AND rank decision — for strict and adaptive
//!    rank, at every chunking (one row, budget-sized, whole-window, and
//!    irregular splits).
//! 2. **Determinism** — chunk boundaries never change the result (long
//!    streams included), repeated snapshots of the same state agree, and
//!    under a permuted arrival order (strict mode, tie-free data) the
//!    selected global id set is unchanged.
//! 3. **Bounded memory** — the reservoir never grows past its capacity
//!    no matter how long the stream runs (the alloc-free suite pins the
//!    steady-state allocation count separately).
//! 4. **Typed faults** — the PR 6 policy semantics carry over: poisoned
//!    chunks reject atomically (`Fail`/`Retry`) or quarantine and
//!    continue (`Degrade`); numerical breakdown surfaces at the snapshot
//!    as a typed error or the deterministic seeded-random rung.
//! 5. **Builder validation** — streaming-specific rejections are typed
//!    and name the offending field.

use graft::engine::{
    Degradation, EngineBuilder, EngineError, ExecShape, FaultPolicy, RankMode, SelectError,
    StreamingEngine,
};
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::selection::BatchView;

// ---------------------------------------------------------------------------
// Synthetic batch builders (mirrors tests/engine_api.rs)
// ---------------------------------------------------------------------------

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }

    /// The same rows in a permuted arrival order, keeping each row's
    /// original global id.
    fn permuted(&self, perm: &[usize]) -> Owned {
        let k = perm.len();
        Owned {
            features: Mat::from_fn(k, self.features.cols(), |i, j| self.features.row(perm[i])[j]),
            grads: Mat::from_fn(k, self.grads.cols(), |i, j| self.grads.row(perm[i])[j]),
            losses: perm.iter().map(|&p| self.losses[p]).collect(),
            labels: perm.iter().map(|&p| self.labels[p]).collect(),
            preds: perm.iter().map(|&p| self.preds[p]).collect(),
            classes: self.classes,
            row_ids: perm.iter().map(|&p| self.row_ids[p]).collect(),
        }
    }
}

fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

fn builder(method: &str, budget: usize, adaptive: bool) -> EngineBuilder {
    let mut b = EngineBuilder::new().method(method).budget(budget).seed(11).epsilon(0.05);
    if adaptive {
        b = b.rank(RankMode::Adaptive { epsilon: 0.05 });
    }
    b
}

/// Push `view` through `se` in chunks of the given sizes (cycled until
/// the view is exhausted), then snapshot.
fn stream_chunked(se: &mut StreamingEngine, view: &BatchView<'_>, chunks: &[usize]) -> Vec<usize> {
    let mut lo = 0usize;
    let mut ci = 0usize;
    while lo < view.k() {
        let step = chunks[ci % chunks.len()].max(1);
        let hi = (lo + step).min(view.k());
        se.push_range(view, lo..hi).expect("clean chunk");
        lo = hi;
        ci += 1;
    }
    se.snapshot().expect("clean snapshot").indices
}

// ---------------------------------------------------------------------------
// 1. Stream ≡ batch, bit-identical, at every chunking (K ≤ cap)
// ---------------------------------------------------------------------------

#[test]
fn stream_matches_batch_bitwise_at_every_chunking() {
    // cap = max(2·budget, rcols) ≥ k in every tuple, so the stream is
    // structurally the batch input and equality must be exact.
    for &(k, rc, e, budget, seed) in &[(32usize, 6usize, 10usize, 16usize, 1u64), (24, 8, 12, 12, 2)] {
        for &adaptive in &[false, true] {
            let owned = random_owned(k, rc, e, 2, seed);

            let mut batch = builder("graft", budget, adaptive).build().expect("batch engine");
            let reference = {
                let sel = batch.select(&owned.view()).expect("batch select");
                (sel.indices.to_vec(), sel.decision)
            };
            assert!(!reference.0.is_empty(), "batch reference selected nothing");
            if !adaptive {
                assert_eq!(reference.0.len(), budget, "strict mode fills the whole budget");
            }

            let chunkings: &[&[usize]] = &[&[1], &[budget], &[k], &[5, 11, 3], &[7, 25]];
            for chunks in chunkings {
                let mut se =
                    builder("graft", budget, adaptive).build_streaming().expect("stream engine");
                let got = stream_chunked(&mut se, &owned.view(), chunks);
                assert_eq!(
                    got, reference.0,
                    "indices diverged (adaptive={adaptive}, chunks={chunks:?}, seed={seed})"
                );
                let snap_decision = {
                    // Fresh engine, same stream: decision must also match
                    // the batch engine's, so re-run and compare.
                    let mut se2 = builder("graft", budget, adaptive)
                        .build_streaming()
                        .expect("stream engine");
                    for i in 0..k {
                        se2.push_range(&owned.view(), i..i + 1).unwrap();
                    }
                    se2.snapshot().unwrap().decision
                };
                if adaptive {
                    assert_eq!(
                        snap_decision, reference.1,
                        "decision diverged (adaptive={adaptive}, seed={seed})"
                    );
                } else {
                    // Strict streams synthesise their decision from the
                    // engine's strict tally (the adaptive-only carry): the
                    // rank must match the batch decision's, while the
                    // residual reports the identity cut's zero instead of
                    // being re-priced by a fused-MGS pass.
                    assert_eq!(
                        snap_decision.map(|d| d.rank),
                        reference.1.map(|d| d.rank),
                        "strict rank diverged (seed={seed})"
                    );
                    let d = snap_decision.expect("strict stream reports a decision");
                    assert_eq!(d.error, 0.0, "identity cut has zero residual");
                    assert!(d.satisfied, "identity cut is satisfied");
                }
            }
        }
    }
}

#[test]
fn feature_only_maxvol_stream_matches_batch() {
    let owned = random_owned(24, 6, 8, 2, 5);
    let mut batch = builder("maxvol", 12, false).build().expect("batch engine");
    let want = batch.select(&owned.view()).expect("batch select").indices.to_vec();
    let mut se = builder("maxvol", 12, false).build_streaming().expect("stream engine");
    let got = stream_chunked(&mut se, &owned.view(), &[5]);
    assert_eq!(got, want, "feature-only stream must equal FastMaxVol batch selection");
    assert!(se.rank_stats().is_none(), "maxvol streams have no rank authority");
}

// ---------------------------------------------------------------------------
// 2. Determinism: long streams, chunking invariance, arrival permutations
// ---------------------------------------------------------------------------

#[test]
fn long_stream_is_chunking_invariant_and_repeatable() {
    // K = 240 blows well past cap = 16: admissions and evictions run
    // constantly, and the result must still be a pure function of the
    // arrival order.
    let owned = random_owned(240, 6, 8, 2, 9);
    let mut first: Option<Vec<usize>> = None;
    for chunks in [&[1usize][..], &[8], &[240], &[13, 7, 64]] {
        let mut se = builder("graft", 8, false).build_streaming().expect("stream engine");
        let got = stream_chunked(&mut se, &owned.view(), chunks);
        assert_eq!(se.rows_seen(), 240);
        assert_eq!(got.len(), 8);
        match &first {
            None => first = Some(got),
            Some(want) => assert_eq!(&got, want, "chunking {chunks:?} changed the selection"),
        }
        // A second snapshot of the same state agrees with the first
        // (snapshots are pure reads of the reservoir).
        let again = se.snapshot().expect("repeat snapshot").indices;
        assert_eq!(&again, first.as_ref().unwrap(), "snapshot is not repeatable");
    }
}

#[test]
fn strict_arrival_permutation_keeps_the_selected_id_set() {
    // Strict mode, tie-free data, K ≤ cap: a permuted arrival order may
    // reorder the pivot tournament's scan, but the selected global id
    // set is pinned (floating-point magnitudes are tie-free with
    // probability 1 on this data).
    let owned = random_owned(28, 6, 8, 2, 21);
    let perms: Vec<Vec<usize>> = vec![
        (0..28).rev().collect(),
        {
            let mut p: Vec<usize> = (0..28).collect();
            let mut rng = Rng::new(77);
            rng.shuffle(&mut p);
            p
        },
    ];
    let mut se = builder("graft", 14, false).build_streaming().expect("stream engine");
    let mut want = stream_chunked(&mut se, &owned.view(), &[28]);
    want.sort_unstable();
    for perm in &perms {
        let shuffled = owned.permuted(perm);
        let mut se = builder("graft", 14, false).build_streaming().expect("stream engine");
        let mut got = stream_chunked(&mut se, &shuffled.view(), &[3, 9]);
        got.sort_unstable();
        assert_eq!(got, want, "arrival order changed the strict selection set");
    }
}

#[test]
fn reset_isolates_streams_while_the_rank_authority_accumulates() {
    let a = random_owned(24, 6, 8, 2, 31);
    let b = random_owned(24, 6, 8, 2, 32);
    let mut se = builder("graft", 12, false).build_streaming().expect("stream engine");
    let first = stream_chunked(&mut se, &a.view(), &[6]);
    se.reset();
    assert_eq!(se.rows_seen(), 0, "reset forgets the stream");
    let second = stream_chunked(&mut se, &b.view(), &[6]);
    // Window 2 must behave exactly like a fresh engine fed only stream b.
    let mut fresh = builder("graft", 12, false).build_streaming().expect("stream engine");
    assert_eq!(second, stream_chunked(&mut fresh, &b.view(), &[24]));
    assert_ne!(first, second, "different streams select differently");
    let stats = se.rank_stats().expect("graft stream has a rank authority");
    assert_eq!(stats.batches, 2.0, "one decision per snapshot, accumulated across resets");
}

// ---------------------------------------------------------------------------
// 3. Bounded memory
// ---------------------------------------------------------------------------

#[test]
fn reservoir_stays_bounded_on_long_streams() {
    let owned = random_owned(400, 6, 8, 2, 41);
    let mut se = builder("graft", 10, false).build_streaming().expect("stream engine");
    se.push(&owned.view()).expect("clean push");
    assert_eq!(se.reservoir_capacity(), 20, "cap = max(2·budget, feature width)");
    assert_eq!(se.reservoir_len(), 20, "reservoir saturates at cap, never beyond");
    assert_eq!(se.rows_seen(), 400);
    let snap = se.snapshot().expect("clean snapshot");
    assert_eq!(snap.reservoir_len, 20);
    assert_eq!(snap.indices.len(), 10);
}

// ---------------------------------------------------------------------------
// 4. Fault-policy semantics carried over from the batch engine
// ---------------------------------------------------------------------------

fn poison(owned: &mut Owned, row: usize) {
    let rc = owned.features.cols();
    owned.features.row_mut(row)[rc - 1] = f64::NAN;
}

#[test]
fn poisoned_chunk_rejects_atomically_under_fail_and_retry() {
    for fault in [FaultPolicy::Fail, FaultPolicy::Retry { max: 2, backoff: std::time::Duration::ZERO }] {
        let mut owned = random_owned(24, 6, 8, 2, 51);
        poison(&mut owned, 13);
        let mut se = builder("graft", 8, false)
            .fault_policy(fault)
            .build_streaming()
            .expect("stream engine");
        se.push_range(&owned.view(), 0..12).expect("clean prefix streams");
        let err = se.push_range(&owned.view(), 12..24).expect_err("poisoned chunk must fault");
        match err {
            SelectError::PoisonedInput { rows } => {
                assert_eq!(rows, vec![13], "view-local row indices")
            }
            other => panic!("expected PoisonedInput, got {other:?}"),
        }
        // Atomic rejection: nothing from the bad chunk was ingested, and
        // the stream remains usable with clean input.
        assert_eq!(se.rows_seen(), 12);
        let snap = se.snapshot().expect("clean rows still snapshot");
        assert_eq!(snap.indices.len(), 8);
        assert!(snap.degradations.is_empty());
    }
}

#[test]
fn poisoned_rows_quarantine_and_stream_continues_under_degrade() {
    let mut owned = random_owned(24, 6, 8, 2, 51);
    poison(&mut owned, 13);
    poison(&mut owned, 17);
    let mut se = builder("graft", 8, false)
        .fault_policy(FaultPolicy::Degrade)
        .build_streaming()
        .expect("stream engine");
    se.push(&owned.view()).expect("degrade mode never faults on poison");
    assert_eq!(se.rows_seen(), 22, "poisoned rows skipped, clean rows ingested");
    assert_eq!(se.quarantined_rows(), 2);
    let snap = se.snapshot().expect("clean snapshot");
    assert!(!snap.indices.contains(&13) && !snap.indices.contains(&17));
    assert!(
        snap.degradations.iter().any(|d| matches!(d, Degradation::Quarantined { rows } if rows == &vec![13, 17])),
        "quarantine recorded: {:?}",
        snap.degradations
    );
    // Degradations drain with the snapshot that reports them.
    let again = se.snapshot().expect("second snapshot");
    assert!(again.degradations.is_empty());
}

#[test]
fn numerical_breakdown_surfaces_at_snapshot_or_degrades_to_seeded_random() {
    // All-zero features degenerate every MaxVol pivot; losses/grads stay
    // finite so the poison scan passes and the breakdown is caught by
    // the snapshot health check, exactly like the batch ladder's.
    let mut owned = random_owned(20, 6, 8, 2, 61);
    owned.features = Mat::from_fn(20, 6, |_, _| 0.0);

    let mut fail = builder("graft", 8, false).build_streaming().expect("stream engine");
    fail.push(&owned.view()).expect("zeros are finite; push is clean");
    match fail.snapshot() {
        Err(SelectError::NumericalBreakdown { stage, .. }) => assert_eq!(stage, "stream-maxvol"),
        other => panic!("expected NumericalBreakdown, got {other:?}"),
    }

    let degraded = |seed: u64| {
        let mut se = builder("graft", 8, false)
            .seed(seed)
            .fault_policy(FaultPolicy::Degrade)
            .build_streaming()
            .expect("stream engine");
        se.push(&owned.view()).expect("clean push");
        se.snapshot().expect("degrade mode snapshots")
    };
    let a = degraded(7);
    assert_eq!(a.indices.len(), 8, "seeded-random fallback honours the budget");
    assert!(a.decision.is_none(), "degraded snapshots report no rank decision");
    assert!(
        a.degradations.iter().any(|d| matches!(d, Degradation::SeededRandom { .. })),
        "fallback recorded: {:?}",
        a.degradations
    );
    let b = degraded(7);
    assert_eq!(a.indices, b.indices, "seeded-random fallback is deterministic per seed");
}

// ---------------------------------------------------------------------------
// 5. Builder validation
// ---------------------------------------------------------------------------

#[test]
fn streaming_builder_rejections_name_the_offending_field() {
    type Build = Box<dyn Fn() -> Result<StreamingEngine, EngineError>>;
    let cases: Vec<(&str, Build, &str)> = vec![
        (
            "missing budget",
            Box::new(|| EngineBuilder::new().method("graft").build_streaming()),
            "budget",
        ),
        (
            "zero budget",
            Box::new(|| EngineBuilder::new().method("graft").budget(0).build_streaming()),
            "budget",
        ),
        (
            "unsupported method",
            Box::new(|| EngineBuilder::new().method("el2n").budget(8).build_streaming()),
            "method",
        ),
        (
            "unknown method",
            Box::new(|| EngineBuilder::new().method("bogus").budget(8).build_streaming()),
            "method",
        ),
        (
            "bad epsilon",
            Box::new(|| {
                EngineBuilder::new().method("graft").budget(8).epsilon(2.0).build_streaming()
            }),
            "epsilon",
        ),
        (
            "unknown extractor",
            Box::new(|| {
                EngineBuilder::new().method("graft").budget(8).extractor("nope").build_streaming()
            }),
            "extractor",
        ),
    ];
    for (what, build, field) in cases {
        let err = build().err().unwrap_or_else(|| panic!("{what}: must be rejected"));
        assert_eq!(err.field(), field, "{what}: {err}");
    }
    // A known-but-unstreamable method and an unknown one are DIFFERENT
    // typed errors, even though both name the method field.
    assert!(matches!(
        EngineBuilder::new().method("el2n").budget(8).build_streaming(),
        Err(EngineError::StreamUnsupportedMethod { .. })
    ));
    assert!(matches!(
        EngineBuilder::new().method("bogus").budget(8).build_streaming(),
        Err(EngineError::UnknownMethod { .. })
    ));
}

#[test]
fn non_serial_shapes_fall_back_to_serial_with_a_note() {
    let se = EngineBuilder::new()
        .method("graft")
        .budget(8)
        .exec(ExecShape::Sharded { shards: 4 })
        .build_streaming()
        .expect("shape falls back, not errors");
    assert!(
        se.notes().iter().any(|n| n.contains("serial")),
        "fallback must be noted: {:?}",
        se.notes()
    );
    let quiet = EngineBuilder::new().method("graft").budget(8).build_streaming().unwrap();
    assert!(quiet.notes().is_empty());
}

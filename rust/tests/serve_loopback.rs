//! Selection-as-a-service loopback pins (PR 8):
//!
//! 1. **Served ≡ in-process** — K ≥ 3 concurrent tenants with mixed
//!    configs (serial strict, pooled adaptive, streaming, sharded
//!    FastMaxVol) receive selections bit-identical to in-process engines
//!    built through the same [`graft::serve::engine_builder`] mapping,
//!    under interleaved arrivals.
//! 2. **Disconnect drains** — a client that dies mid-window loses nothing
//!    it didn't ask for: the pending window is dropped whole (no partial
//!    selection, no duplication), the tenant name frees, and a
//!    reconnecting tenant starts bit-identically from scratch.
//! 3. **Faults through the wire** — an injected worker panic under
//!    `FaultPolicy::Retry` converges to the bit-identical selection
//!    through the served path, and the drain telemetry counts the retry.
//! 4. **Backpressure is typed** — over-admission gets `Busy`, a name
//!    collision gets `Rejected(DuplicateTenant)`; neither kills the
//!    daemon or another tenant's session.
//! 5. **Stats speak graft-bench-v1** — the `Stats` reply carries
//!    per-tenant rows the bench validator accepts.

use std::io::Read;
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use graft::coordinator::SelectWindow;
use graft::faults::FaultPlan;
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::serve::protocol::{Msg, RejectCode, TenantConfig, WireFaultPolicy};
use graft::serve::{engine_builder, Client, ClientError, ServeOptions, Server, ServerBuilder};

// ---------------------------------------------------------------------------
// Synthetic windows (mirrors tests/streaming.rs, owned so threads can move
// them)
// ---------------------------------------------------------------------------

fn window(k: usize, seed: u64, base_id: usize) -> SelectWindow {
    let (rc, e, classes) = (6usize, 8usize, 4usize);
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    SelectWindow {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (base_id..base_id + k).collect(),
    }
}

fn windows_for(tenant: usize, count: usize, rows: usize) -> Vec<SelectWindow> {
    (0..count)
        .map(|w| window(rows, 0x7E57 ^ ((tenant as u64) << 16) ^ w as u64, w * rows))
        .collect()
}

fn addr_of(server: &Server) -> String {
    server.local_addr().expect("tcp server has a local addr").to_string()
}

/// Serve one tenant's windows through the wire; returns per-window winner
/// indices (batch-local for batch tenants, global ids for snapshots).
fn drive_served(
    addr: &str,
    name: &str,
    cfg: &TenantConfig,
    windows: &[SelectWindow],
) -> Result<Vec<Vec<u64>>, ClientError> {
    let mut client = Client::connect_tcp(addr)?;
    client.hello(name, cfg)?;
    let mut out = Vec::new();
    for win in windows {
        if cfg.streaming {
            client.push_chunk(&win.view())?;
            out.push(client.snapshot()?.indices);
        } else {
            out.push(client.select(&win.view())?.indices);
        }
    }
    client.bye()?;
    Ok(out)
}

/// The in-process reference for the same config + windows.
fn drive_reference(cfg: &TenantConfig, windows: &[SelectWindow]) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    if cfg.streaming {
        let mut eng = engine_builder(cfg).build_streaming().expect("reference stream engine");
        for win in windows {
            eng.push(&win.view()).expect("reference push");
            let snap = eng.snapshot().expect("reference snapshot");
            out.push(snap.indices.iter().map(|&i| i as u64).collect());
        }
    } else {
        let mut eng = engine_builder(cfg).build().expect("reference batch engine");
        for win in windows {
            let sel = eng.select(&win.view()).expect("reference select");
            out.push(sel.indices.iter().map(|&i| i as u64).collect());
        }
    }
    out
}

/// Hello with retry: after a disconnect the server frees the tenant name
/// on its next read tick, so a racing reconnect may briefly see
/// `DuplicateTenant`.
fn hello_until_free(addr: &str, name: &str, cfg: &TenantConfig) -> Client {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut client = Client::connect_tcp(addr).expect("connect");
        match client.hello(name, cfg) {
            Ok(_) => return client,
            Err(ClientError::Rejected { code: RejectCode::DuplicateTenant, .. })
                if Instant::now() < deadline =>
            {
                thread::sleep(Duration::from_millis(20));
            }
            Err(e) => panic!("reconnect hello failed: {e}"),
        }
    }
}

// ---------------------------------------------------------------------------
// 1. Concurrent mixed tenants, bit-identical
// ---------------------------------------------------------------------------

#[test]
fn concurrent_mixed_tenants_are_bit_identical() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);

    let profiles: Vec<TenantConfig> = vec![
        // Serial, strict rank.
        TenantConfig { budget: 8, seed: 101, ..TenantConfig::default() },
        // Pooled + sharded, adaptive rank.
        TenantConfig {
            budget: 8,
            seed: 202,
            adaptive: true,
            shards: 2,
            workers: 2,
            ..TenantConfig::default()
        },
        // Streaming reservoir.
        TenantConfig { streaming: true, budget: 6, seed: 303, ..TenantConfig::default() },
        // Sharded FastMaxVol (non-GRAFT method through the same wire).
        TenantConfig {
            method: "maxvol".into(),
            budget: 8,
            seed: 404,
            shards: 2,
            ..TenantConfig::default()
        },
    ];

    let mut handles = Vec::new();
    for (i, cfg) in profiles.iter().enumerate() {
        let addr = addr.clone();
        let cfg = cfg.clone();
        let wins = windows_for(i, 3, 48);
        let (tcfg, twins) = (cfg.clone(), wins.clone());
        let handle =
            thread::spawn(move || drive_served(&addr, &format!("tenant-{i}"), &tcfg, &twins));
        handles.push((i, cfg, wins, handle));
    }
    for (i, cfg, wins, handle) in handles {
        let served = handle.join().expect("client thread").expect("served path");
        let reference = drive_reference(&cfg, &wins);
        assert_eq!(served, reference, "tenant-{i}: served selections must be bit-identical");
        assert_eq!(served.len(), 3, "tenant-{i}: one selection per window");
        for sel in &served {
            assert!(!sel.is_empty(), "tenant-{i}: selections are non-empty");
        }
    }
    server.shutdown();
}

// ---------------------------------------------------------------------------
// 2. Disconnect mid-window: drained, name freed, no loss/duplication
// ---------------------------------------------------------------------------

#[test]
fn disconnect_mid_window_drains_and_frees_the_name() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);
    let cfg = TenantConfig { budget: 8, seed: 7, ..TenantConfig::default() };
    let wins = windows_for(0, 2, 48);

    // Die mid-window: the batch is submitted but never selected.
    {
        let mut client = Client::connect_tcp(&addr).expect("connect");
        client.hello("flaky", &cfg).expect("hello");
        let accepted = client.submit_batch(&wins[0].view()).expect("submit");
        assert_eq!(accepted, 48);
        // Drop without Bye — simulates a client crash mid-window.
    }

    // The name frees once the server reaps the dead session; the
    // reconnected tenant gets a FRESH engine: its selections must be
    // bit-identical to a fresh in-process reference, proving the dead
    // session's pending window was dropped whole (no leftover rows, no
    // replays) and nothing was partially selected on its behalf.
    let mut client = hello_until_free(&addr, "flaky", &cfg);
    let mut served = Vec::new();
    for win in &wins {
        served.push(client.select(&win.view()).expect("post-reconnect select").indices);
    }
    let drained = client.drain().expect("drain");
    assert_eq!(drained.windows, 2, "only the reconnected session's selects count");
    assert_eq!(drained.rows, 96, "only the reconnected session's rows count");
    client.bye().expect("bye");

    assert_eq!(served, drive_reference(&cfg, &wins), "reconnect restarts bit-identically");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// 3. Injected worker panic under Retry, served path
// ---------------------------------------------------------------------------

#[test]
fn injected_worker_panic_under_retry_is_bit_identical_through_server() {
    let mut server = ServerBuilder::new()
        .fault_injector(FaultPlan::new().panic_shard(1, 1).arc())
        .bind_tcp("127.0.0.1:0")
        .expect("bind");
    let addr = addr_of(&server);
    let cfg = TenantConfig {
        budget: 8,
        seed: 55,
        shards: 2,
        workers: 2,
        fault: WireFaultPolicy::Retry { max: 2, backoff_ms: 1 },
        ..TenantConfig::default()
    };
    let wins = windows_for(3, 2, 48);

    let mut client = Client::connect_tcp(&addr).expect("connect");
    client.hello("faulted", &cfg).expect("hello");
    let mut served = Vec::new();
    for win in &wins {
        served.push(client.select(&win.view()).expect("retry absorbs the panic").indices);
    }
    let drained = client.drain().expect("drain");
    client.bye().expect("bye");

    // The reference runs with NO injector: a successful retry must erase
    // the fault from the output entirely.
    assert_eq!(served, drive_reference(&cfg, &wins), "retry recovery must be bit-identical");
    assert!(drained.retries >= 1, "the retry must show up in drain telemetry");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// 4. Typed backpressure: Busy and DuplicateTenant
// ---------------------------------------------------------------------------

#[test]
fn admission_overflow_is_busy_and_name_collision_is_rejected() {
    let opts = ServeOptions { max_sessions: 1, ..ServeOptions::default() };
    let mut server = ServerBuilder::new().options(opts).bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);
    let cfg = TenantConfig { budget: 4, seed: 1, ..TenantConfig::default() };

    let mut first = Client::connect_tcp(&addr).expect("connect");
    first.hello("solo", &cfg).expect("hello");

    // Second connection: over the admission bound, refused with an
    // unprompted Busy frame at accept — it never needs to speak (and a
    // raw read avoids racing the server's close against a write).
    let mut second = TcpStream::connect(&addr).expect("tcp connect still succeeds");
    second.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    let mut prefix = [0u8; 4];
    second.read_exact(&mut prefix).expect("busy prefix");
    let mut body = vec![0u8; u32::from_le_bytes(prefix) as usize];
    second.read_exact(&mut body).expect("busy body");
    match Msg::decode(&body) {
        Ok(Msg::Busy { active, max }) => assert_eq!((active, max), (1, 1)),
        other => panic!("expected Busy, got {other:?}"),
    }

    // The admitted tenant is unharmed and still serves bit-identically.
    let wins = windows_for(9, 1, 32);
    let sel = first.select(&wins[0].view()).expect("survivor selects").indices;
    assert_eq!(vec![sel], drive_reference(&cfg, &wins));
    first.bye().expect("bye");

    // Name collisions on a server with room are a typed rejection that
    // leaves the holder's session working.
    let opts = ServeOptions { max_sessions: 4, ..ServeOptions::default() };
    let mut server2 = ServerBuilder::new().options(opts).bind_tcp("127.0.0.1:0").expect("bind");
    let addr2 = addr_of(&server2);
    let mut holder = Client::connect_tcp(&addr2).expect("connect");
    holder.hello("claimed", &cfg).expect("hello");
    let mut rival = Client::connect_tcp(&addr2).expect("connect");
    match rival.hello("claimed", &cfg) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, RejectCode::DuplicateTenant),
        other => panic!("expected Rejected(DuplicateTenant), got {other:?}"),
    }
    let sel = holder.select(&wins[0].view()).expect("holder unaffected").indices;
    assert_eq!(vec![sel], drive_reference(&cfg, &wins));
    holder.bye().expect("bye");

    server.shutdown();
    server2.shutdown();
}

// ---------------------------------------------------------------------------
// 5. Stats rows in graft-bench-v1 shape
// ---------------------------------------------------------------------------

#[test]
fn stats_reply_carries_bench_schema_rows() {
    let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
    let addr = addr_of(&server);

    let batch_cfg = TenantConfig { budget: 8, seed: 21, ..TenantConfig::default() };
    let stream_cfg =
        TenantConfig { streaming: true, budget: 6, seed: 22, ..TenantConfig::default() };
    drive_served(&addr, "bt", &batch_cfg, &windows_for(0, 2, 48)).expect("batch tenant");
    drive_served(&addr, "st", &stream_cfg, &windows_for(1, 2, 48)).expect("stream tenant");

    // Stats needs no Hello: it's the monitoring path.
    let mut monitor = Client::connect_tcp(&addr).expect("connect");
    let json = monitor.stats().expect("stats");
    monitor.bye().expect("bye");
    server.shutdown();

    assert!(json.contains("\"bench\":\"graft-serve\""), "bench tag present: {json}");
    assert!(json.contains("\"op\":\"serve_select\""), "batch rows present: {json}");
    assert!(json.contains("\"op\":\"serve_push\""), "push rows present: {json}");
    assert!(json.contains("\"op\":\"serve_snapshot\""), "snapshot rows present: {json}");
    assert!(json.contains("tenant=bt,mode=batch,windows=2,rows=96"), "batch shape: {json}");
    assert!(json.contains("tenant=st,mode=stream,windows=2,rows=96"), "stream shape: {json}");
    // Every record carries exactly the graft-bench-v1 numeric fields.
    for key in ["\"mean_ns\":", "\"std_ns\":", "\"min_ns\":"] {
        assert!(json.contains(key), "{key} present: {json}");
    }
}

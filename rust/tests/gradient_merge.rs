//! Property tests for the gradient-aware sharded merge
//! (`coordinator::merge::MergePolicy::Grad`) — the PR 4 acceptance pins:
//!
//! 1. `shards == 1` under `--merge grad` (strict *and* adaptive rank) is
//!    **bit-identical** to single-shot `GraftSelector` — the delegation
//!    path never consults the merge, and the inner instance carries the
//!    run policy.
//! 2. Pool ≡ scoped ≡ serial bit-identity extends to the gradient-aware
//!    merge at shards × workers ∈ {1, 2, 4, 8}, including the rank
//!    authority's decision sequence (same `RankStats` after the same
//!    batch stream).
//! 3. On planted low-rank gradient batches the grad-aware merge restores
//!    the paper's dynamic rank across shards: R* collapses to ~the
//!    planted rank with d(R*) ≤ ε, the merged subset's final
//!    `prefix_projection_errors` value is within tolerance of (and the
//!    strict-budget subset bitwise equal to) the feature-only merge, and
//!    within tolerance of single-shot selection.
//! 4. ε/budget accounting is **shard-count-independent**: one authority
//!    decision per refreshed batch at any shard/worker count (the budget
//!    drift regression — per-shard policy clones used to accumulate
//!    independently).

use graft::coordinator::{MergePolicy, PooledSelector, ShardedSelector};
use graft::graft::{prefix_projection_errors, BudgetedRankPolicy, GraftSelector};
use graft::linalg::{Mat, Workspace};
use graft::rng::Rng;
use graft::selection::{BatchView, Selector};

const EPS: f64 = 0.05;

// ---------------------------------------------------------------------------
// Synthetic batch builders (mirrors tests/sharded_selection.rs)
// ---------------------------------------------------------------------------

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

/// Batch whose gradients live in a planted rank-`p` subspace (features
/// share the loadings up to `noise`) — the geometry the dynamic rank must
/// exploit.
fn planted_owned(k: usize, rc: usize, e: usize, p: usize, noise: f64, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let loadings = Mat::from_fn(k, p, |_, _| rng.normal());
    let basis_f = Mat::from_fn(p, rc, |_, _| rng.normal());
    let basis_g = Mat::from_fn(p, e, |_, _| rng.normal());
    let mut features = loadings.matmul(&basis_f);
    let mut grads = loadings.matmul(&basis_g);
    for v in features.data_mut() {
        *v += noise * rng.normal();
    }
    for v in grads.data_mut() {
        *v += noise * rng.normal();
    }
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % 4) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes: 4,
        row_ids: (0..k).collect(),
    }
}

/// Final prefix projection error of ḡ against the gradient rows of `sel`.
fn final_proj_err(grads: &Mat, sel: &[usize]) -> f64 {
    let (k, e) = (grads.rows(), grads.cols());
    let mut gbar = vec![0.0; e];
    for i in 0..k {
        for (t, &v) in grads.row(i).iter().enumerate() {
            gbar[t] += v;
        }
    }
    for v in gbar.iter_mut() {
        *v /= k as f64;
    }
    let gsel = Mat::from_fn(e, sel.len(), |i, j| grads[(sel[j], i)]);
    *prefix_projection_errors(&gsel, &gbar).last().expect("non-empty selection")
}

// ---------------------------------------------------------------------------
// Execution-shape builders (mirrors the trainer's wiring)
// ---------------------------------------------------------------------------

/// Per-shard instances run strict at shards > 1 (full pivot emission);
/// the run policy sits on the single instance at one shard, or on the
/// coordinator's rank authority otherwise — exactly the trainer's wiring.
fn scoped(shards: usize, policy: &BudgetedRankPolicy) -> ShardedSelector {
    let inner = policy.clone();
    let sel = ShardedSelector::from_factory(shards, MergePolicy::Grad, move |_| {
        Box::new(GraftSelector::new(if shards > 1 {
            BudgetedRankPolicy::strict(EPS)
        } else {
            inner.clone()
        }))
    });
    if shards > 1 {
        sel.with_rank_authority(Box::new(GraftSelector::new(policy.clone())))
    } else {
        sel
    }
}

fn pooled(shards: usize, workers: usize, policy: &BudgetedRankPolicy) -> PooledSelector {
    let inner = policy.clone();
    let sel = PooledSelector::from_factory(shards, workers, MergePolicy::Grad, move |_| {
        Box::new(GraftSelector::new(if shards > 1 {
            BudgetedRankPolicy::strict(EPS)
        } else {
            inner.clone()
        }))
    });
    if shards > 1 {
        sel.with_rank_authority(Box::new(GraftSelector::new(policy.clone())))
    } else {
        sel
    }
}

fn assert_valid(sel: &[usize], k: usize, ctx: &str) {
    let mut s = sel.to_vec();
    s.sort_unstable();
    s.dedup();
    assert_eq!(s.len(), sel.len(), "uniqueness: {ctx}");
    assert!(s.iter().all(|&i| i < k), "range: {ctx}");
}

// ---------------------------------------------------------------------------
// 1. shards == 1 is bit-identical to single-shot GRAFT under grad merge
// ---------------------------------------------------------------------------

#[test]
fn one_shard_grad_merge_bit_identical_to_single_shot() {
    for (name, policy) in [
        ("strict", BudgetedRankPolicy::strict(EPS)),
        ("adaptive", BudgetedRankPolicy::adaptive(EPS, 0.5)),
    ] {
        for seed in [31u64, 32, 33] {
            let owned = random_owned(64, 8, 16, 4, seed);
            let single = GraftSelector::new(policy.clone()).select(&owned.view(), 16);
            let wrapped = scoped(1, &policy).select(&owned.view(), 16);
            assert_eq!(single, wrapped, "{name} scoped seed={seed}");
            for workers in [1usize, 2] {
                let via_pool = pooled(1, workers, &policy).select(&owned.view(), 16);
                assert_eq!(single, via_pool, "{name} pooled w={workers} seed={seed}");
            }
        }
    }
}

#[test]
fn one_shard_authority_is_inert_in_both_shapes() {
    // A rank authority installed at one shard must never be consulted:
    // the delegation path's inner selector is the decision maker, so
    // scoped ≡ pooled ≡ single-shot holds even with an authority present
    // (the misconfiguration a future caller could produce), and the
    // unconsulted authority's empty accounting is never reported.
    let policy = BudgetedRankPolicy::adaptive(EPS, 0.5);
    let owned = random_owned(64, 8, 16, 4, 97);
    let single = GraftSelector::new(policy.clone()).select(&owned.view(), 16);
    let mut sc = ShardedSelector::from_factory(1, MergePolicy::Grad, |_| {
        Box::new(GraftSelector::new(BudgetedRankPolicy::adaptive(EPS, 0.5)))
    })
    .with_rank_authority(Box::new(GraftSelector::new(policy.clone())));
    assert_eq!(sc.select(&owned.view(), 16), single, "scoped ≡ single-shot");
    let inner = sc.rank_stats().expect("inner selector accounting");
    assert_eq!(inner.batches, 1.0, "inner decided; authority stayed inert");
    let mut pl = PooledSelector::from_factory(1, 2, MergePolicy::Grad, |_| {
        Box::new(GraftSelector::new(BudgetedRankPolicy::adaptive(EPS, 0.5)))
    })
    .with_rank_authority(Box::new(GraftSelector::new(policy.clone())));
    assert_eq!(pl.select(&owned.view(), 16), single, "pooled ≡ single-shot");
    assert!(pl.rank_stats().is_none(), "unconsulted authority never reported");
}

// ---------------------------------------------------------------------------
// 2. Pool ≡ scoped ≡ serial bit-identity extends to the grad merge
// ---------------------------------------------------------------------------

#[test]
fn pool_scoped_serial_bit_identical_under_grad_merge() {
    // k clears SHARD_PAR_MIN_K so the scoped path really runs threaded;
    // three batches per shape so the authority's accumulator state (and
    // with it the adaptive window) evolves across calls.
    let policy = BudgetedRankPolicy::adaptive(EPS, 0.5);
    let batches: Vec<Owned> =
        (0..3).map(|i| planted_owned(1024, 16, 24, 4, 0.02, 41 + i)).collect();
    let mut ws = Workspace::new();
    for &shards in &[1usize, 2, 4, 8] {
        let mut serial = scoped(shards, &policy).with_parallel(false);
        let mut par = scoped(shards, &policy);
        let mut reference: Vec<Vec<usize>> = Vec::new();
        let mut out = Vec::new();
        for b in &batches {
            serial.select_into(&b.view(), 64, &mut ws, &mut out);
            reference.push(out.clone());
        }
        for (b, want) in batches.iter().zip(&reference) {
            par.select_into(&b.view(), 64, &mut ws, &mut out);
            assert_eq!(&out, want, "scoped parallel, shards={shards}");
        }
        assert_eq!(serial.rank_stats(), par.rank_stats(), "authority state, shards={shards}");
        for &workers in &[1usize, 2, 4, 8] {
            let mut pool = pooled(shards, workers, &policy);
            for (b, want) in batches.iter().zip(&reference) {
                pool.select_into(&b.view(), 64, &mut ws, &mut out);
                assert_eq!(&out, want, "pool, shards={shards} workers={workers}");
                assert_valid(&out, 1024, &format!("shards={shards} workers={workers}"));
            }
            if shards > 1 {
                assert_eq!(
                    pool.rank_stats(),
                    serial.rank_stats(),
                    "pool authority state, shards={shards} workers={workers}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. The grad merge restores the paper's criterion across shards
// ---------------------------------------------------------------------------

/// Same fixed tolerance as tests/sharded_selection.rs: the observed gaps
/// on these planted batches are ~1e-3, the bound leaves ~50× margin.
const PROJ_TOL: f64 = 0.05;

#[test]
fn grad_merge_dynamic_rank_meets_epsilon_across_shards() {
    for seed in [51u64, 52, 53] {
        let owned = planted_owned(256, 16, 24, 3, 0.02, seed);
        // Single-shot adaptive reference: small R*, error within ε.
        let mut single = GraftSelector::new(BudgetedRankPolicy::adaptive(EPS, 1.0));
        let sref = single.select(&owned.view(), 32);
        let dref = single.last.expect("single-shot decides");
        assert!(dref.satisfied && sref.len() <= 8, "reference R*={}", sref.len());
        for &shards in &[2usize, 4, 8] {
            let policy = BudgetedRankPolicy::adaptive(EPS, 1.0);
            let mut sel = scoped(shards, &policy);
            let merged = sel.select(&owned.view(), 32);
            assert_valid(&merged, 256, &format!("planted shards={shards} seed={seed}"));
            let d = sel.last_rank_decision().expect("grad merge decides");
            assert_eq!(merged.len(), d.rank, "subset is the decided rank");
            assert!(d.satisfied, "shards={shards} seed={seed}: ε not met (d={})", d.error);
            assert!(d.error <= EPS + 1e-9, "shards={shards}: decision error {}", d.error);
            // Dynamic rank collapses to ~the planted rank — the defining
            // GRAFT behaviour the feature-only merge lost at shards > 1.
            assert!(
                merged.len() <= 8,
                "shards={shards} seed={seed}: R*={} should be near planted rank 3",
                merged.len()
            );
            // And the subset it keeps still spans ḡ like single-shot does.
            let d_merged = final_proj_err(&owned.grads, &merged);
            let d_single = final_proj_err(&owned.grads, &sref);
            assert!(
                d_merged <= PROJ_TOL && (d_merged - d_single).abs() <= PROJ_TOL,
                "shards={shards} seed={seed}: merged d={d_merged} vs single d={d_single}"
            );
        }
    }
}

#[test]
fn strict_grad_merge_subset_matches_feature_only_merge() {
    // With a strict-budget authority the rank decision is the identity
    // (R* = budget), so the grad merge must return the feature-only
    // tournament's subset bit-for-bit — its projection error is therefore
    // trivially ≤ the feature-only merge's, and the decision is recorded.
    for seed in [61u64, 62] {
        let owned = planted_owned(256, 16, 24, 4, 0.02, seed);
        for &shards in &[2usize, 4, 8] {
            let policy = BudgetedRankPolicy::strict(EPS);
            let mut grad = scoped(shards, &policy);
            let g = grad.select(&owned.view(), 16);
            let feature_only = ShardedSelector::from_factory(
                shards,
                MergePolicy::Hierarchical,
                |_| Box::new(GraftSelector::new(BudgetedRankPolicy::strict(EPS))),
            )
            .select(&owned.view(), 16);
            assert_eq!(g, feature_only, "shards={shards} seed={seed}");
            let (dg, df) =
                (final_proj_err(&owned.grads, &g), final_proj_err(&owned.grads, &feature_only));
            assert!(dg <= df + 1e-12, "grad-aware must not degrade: {dg} vs {df}");
            let d = grad.last_rank_decision().expect("decision recorded");
            assert_eq!(d.rank, 16);
        }
    }
}

#[test]
fn grad_merge_decisions_are_deterministic_across_instances() {
    // Same batch stream, fresh executors → identical subsets and
    // identical authority trajectories (Hier-base ≡ Flat-base bitwise
    // equality for the two-list fold is pinned in merge.rs unit tests;
    // here the public Grad policy must at least be a pure function of the
    // stream at every fan-out).
    let owned = planted_owned(256, 16, 24, 4, 0.02, 71);
    for &shards in &[2usize, 4, 8] {
        let policy = BudgetedRankPolicy::adaptive(EPS, 0.5);
        let mut a = scoped(shards, &policy);
        let mut b = scoped(shards, &policy);
        for _ in 0..3 {
            assert_eq!(a.select(&owned.view(), 24), b.select(&owned.view(), 24));
        }
        assert_eq!(a.rank_stats(), b.rank_stats(), "shards={shards}");
    }
}

// ---------------------------------------------------------------------------
// 4. Budget accounting: one decision per refreshed batch, any fan-out
// ---------------------------------------------------------------------------

#[test]
fn budget_accounting_counts_each_refresh_exactly_once() {
    // The drift regression: per-shard policy clones used to accumulate
    // privately (shards × the real count).  The authority must log
    // exactly one entry per batch at every shard/worker combination, so
    // ε/budget semantics cannot depend on the fan-out.
    let batches: Vec<Owned> = (0..5).map(|i| random_owned(96, 12, 8, 4, 81 + i)).collect();
    let policy = BudgetedRankPolicy::adaptive(EPS, 0.25);
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    let mut counts: Vec<f64> = Vec::new();
    for &shards in &[2usize, 4, 8] {
        let mut sel = scoped(shards, &policy);
        for b in &batches {
            sel.select_into(&b.view(), 24, &mut ws, &mut out);
        }
        let stats = sel.rank_stats().expect("authority accounts");
        assert_eq!(
            stats.batches,
            batches.len() as f64,
            "scoped shards={shards}: each refresh counted exactly once"
        );
        counts.push(stats.batches);
        for &workers in &[1usize, 3] {
            let mut pool = pooled(shards, workers, &policy);
            for b in &batches {
                pool.select_into(&b.view(), 24, &mut ws, &mut out);
            }
            let pstats = pool.rank_stats().expect("authority accounts");
            let ctx = format!("pooled shards={shards} workers={workers}");
            assert_eq!(pstats.batches, batches.len() as f64, "{ctx}");
        }
    }
    assert!(
        counts.windows(2).all(|w| w[0] == w[1]),
        "accounting is shard-count-independent: {counts:?}"
    );
}

//! Cross-language integration tests: execute every artifact kind through
//! the PJRT runtime with the golden inputs and compare against the outputs
//! JAX computed at build time.  This validates the entire AOT bridge —
//! HLO-text round-trip, shape contracts, and numerics — for every config.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message) if
//! the artifacts directory is missing so `cargo test` works pre-build.

use graft::runtime::{default_dir, Engine, Golden, ModelParams, TrainState};

fn engine() -> Option<Engine> {
    match Engine::new(default_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP golden tests: {err:#} (run `make artifacts`)");
            None
        }
    }
}

fn params_from_golden(g: &Golden) -> ModelParams {
    ModelParams {
        w1: g.get("in.w1").unwrap().f32().unwrap().to_vec(),
        b1: g.get("in.b1").unwrap().f32().unwrap().to_vec(),
        w2: g.get("in.w2").unwrap().f32().unwrap().to_vec(),
        b2: g.get("in.b2").unwrap().f32().unwrap().to_vec(),
    }
}

fn assert_close(name: &str, got: &[f32], want: &[f32], atol: f32, rtol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    let mut worst = 0.0f32;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        let diff = (g - w).abs();
        worst = worst.max(diff - tol);
        assert!(
            diff <= tol,
            "{name}[{i}]: got {g}, want {w} (diff {diff} > tol {tol})"
        );
    }
    let _ = worst;
}

/// Small configs exercised exhaustively; big ones get one smoke config to
/// keep test time in check (shape logic is identical across configs).
const CONFIGS: &[&str] = &["iris", "imdb", "cifar10"];

#[test]
fn golden_select_matches_jax() {
    let Some(mut eng) = engine() else { return };
    for cfg in CONFIGS {
        let g = eng.golden(cfg).unwrap();
        let params = params_from_golden(&g);
        let x = g.get("in.x").unwrap().f32().unwrap().to_vec();
        let y = g.get("in.y1h").unwrap().f32().unwrap().to_vec();
        let out = eng.select(cfg, &params, &x, &y).unwrap();

        let want_p: Vec<usize> = g.get("select.p").unwrap().i32().unwrap().iter().map(|&i| i as usize).collect();
        assert_eq!(out.indices, want_p, "{cfg}: maxvol indices");
        let want_d = g.get("select.d").unwrap().f32().unwrap();
        let got_d: Vec<f32> = out.errors.iter().map(|&x| x as f32).collect();
        assert_close(&format!("{cfg}: select.d"), &got_d, want_d, 2e-4, 2e-3);
        let want_gnorm = g.get("select.gnorm").unwrap().scalar_f32().unwrap();
        assert!((out.gnorm as f32 - want_gnorm).abs() < 1e-4 + 1e-3 * want_gnorm.abs());
        let want_align = g.get("select.align").unwrap().scalar_f32().unwrap();
        assert!((out.align as f32 - want_align).abs() < 2e-3, "{cfg}: align");
    }
}

#[test]
fn golden_embed_matches_jax() {
    let Some(mut eng) = engine() else { return };
    for cfg in CONFIGS {
        let g = eng.golden(cfg).unwrap();
        let params = params_from_golden(&g);
        let x = g.get("in.x").unwrap().f32().unwrap().to_vec();
        let y = g.get("in.y1h").unwrap().f32().unwrap().to_vec();
        let out = eng.embed(cfg, &params, &x, &y).unwrap();

        let want_v = g.get("embed.v").unwrap().f32().unwrap();
        assert_close(&format!("{cfg}: embed.v"), &out.features.to_f32(), want_v, 5e-4, 5e-3);
        let want_g = g.get("embed.g").unwrap().f32().unwrap();
        assert_close(&format!("{cfg}: embed.g"), &out.grads.to_f32(), want_g, 1e-5, 1e-4);
        let want_losses = g.get("embed.losses").unwrap().f32().unwrap();
        let got_losses: Vec<f32> = out.losses.iter().map(|&x| x as f32).collect();
        assert_close(&format!("{cfg}: embed.losses"), &got_losses, want_losses, 1e-5, 1e-4);
        let want_preds = g.get("embed.preds").unwrap().i32().unwrap();
        assert_eq!(out.preds, want_preds, "{cfg}: preds");
    }
}

#[test]
fn golden_train_step_matches_jax() {
    let Some(mut eng) = engine() else { return };
    for cfg in CONFIGS {
        let g = eng.golden(cfg).unwrap();
        let params = params_from_golden(&g);
        let velocity = ModelParams {
            w1: vec![0.0; params.w1.len()],
            b1: vec![0.0; params.b1.len()],
            w2: vec![0.0; params.w2.len()],
            b2: vec![0.0; params.b2.len()],
        };
        let mut state = TrainState { params, velocity };
        let bucket = g.get("train.bucket").unwrap().i32().unwrap()[0] as usize;
        let spec = eng.spec(cfg).unwrap().clone();
        let x = g.get("in.x").unwrap().f32().unwrap()[..bucket * spec.d].to_vec();
        let y = g.get("in.y1h").unwrap().f32().unwrap()[..bucket * spec.c].to_vec();
        let w = vec![1.0f32 / bucket as f32; bucket];
        let loss = eng.train_step(cfg, bucket, &mut state, &x, &y, &w, 0.05, 0.9).unwrap();

        let want_loss = g.get("train.loss").unwrap().scalar_f32().unwrap();
        assert!((loss as f32 - want_loss).abs() < 1e-4 + 1e-4 * want_loss.abs(), "{cfg}: loss {loss} vs {want_loss}");
        for (name, got) in [
            ("train.w1", &state.params.w1),
            ("train.b1", &state.params.b1),
            ("train.w2", &state.params.w2),
            ("train.b2", &state.params.b2),
            ("train.v1", &state.velocity.w1),
            ("train.v2", &state.velocity.b1),
            ("train.v3", &state.velocity.w2),
            ("train.v4", &state.velocity.b2),
        ] {
            let want = g.get(name).unwrap().f32().unwrap();
            assert_close(&format!("{cfg}: {name}"), got, want, 1e-5, 1e-4);
        }
    }
}

#[test]
fn golden_eval_matches_jax() {
    let Some(mut eng) = engine() else { return };
    for cfg in CONFIGS {
        let g = eng.golden(cfg).unwrap();
        let params = params_from_golden(&g);
        let x = g.get("in.x").unwrap().f32().unwrap().to_vec();
        let y = g.get("in.y1h").unwrap().f32().unwrap().to_vec();
        let (loss, correct) = eng.eval_step(cfg, &params, &x, &y).unwrap();
        let want_loss = g.get("eval.loss").unwrap().scalar_f32().unwrap();
        let want_correct = g.get("eval.correct").unwrap().i32().unwrap();
        assert!((loss as f32 - want_loss).abs() < 1e-4 + 1e-4 * want_loss.abs());
        assert_eq!(correct, want_correct, "{cfg}: per-sample correctness");
    }
}

#[test]
fn select_errors_monotone_for_all_configs() {
    let Some(mut eng) = engine() else { return };
    let names: Vec<String> = eng.manifest().configs.keys().cloned().collect();
    for cfg in names {
        let g = eng.golden(&cfg).unwrap();
        let d = g.get("select.d").unwrap().f32().unwrap();
        for w in d.windows(2) {
            assert!(w[1] <= w[0] + 1e-5, "{cfg}: projection errors must be non-increasing");
        }
        let p = g.get("select.p").unwrap().i32().unwrap();
        let mut s: Vec<i32> = p.to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), p.len(), "{cfg}: unique maxvol indices");
    }
}

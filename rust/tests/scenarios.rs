//! Scenario-matrix harness pins (PR 10):
//!
//! 1. **Bit-identity** — `run_matrix` with the same `MatrixConfig`
//!    serialises to byte-identical `graft-scenario-v1` documents (what
//!    the CI `scenario-smoke` job asserts end-to-end with `diff`).
//! 2. **Coverage** — the smoke matrix reaches every roster method, ≥ 3
//!    scenario axes, ≥ 3 budget fractions, the serial + sharded shapes
//!    for every method, and the streaming shape for the reservoir
//!    methods.
//! 3. **Headline dominance** — on the planted low-rank + label-noise
//!    scenario, gradient-aware pivot ordering achieves gradient-
//!    approximation error ≤ the feature-only ordering at EVERY budget.
//!    This is not statistical: the strict rank cut makes every budget-r
//!    selection a prefix of the full pivot order over the same MaxVol
//!    winner set, and with mutually-orthogonal planted gradient columns
//!    the greedy residual-coverage order maximises covered mass at every
//!    prefix length.

use std::collections::BTreeSet;

use graft::engine::{EngineBuilder, PivotMode};
use graft::scenarios::{
    run_matrix, scenario_windows, subset_metrics, Axis, GenConfig, MatrixConfig, ScenarioSink,
};

fn tiny_matrix() -> MatrixConfig {
    MatrixConfig {
        gen: GenConfig { n: 96, d: 16, classes: 3, windows: 2, feat_r: 6, proj_e: 2, seed: 31 },
        axes: vec![Axis::LabelNoise(0.2), Axis::Shift(0.5)],
        fractions: vec![0.2, 0.5],
        shards: 2,
        seed: 42,
    }
}

fn doc_for(cfg: &MatrixConfig) -> String {
    let mut sink = ScenarioSink::new();
    for row in run_matrix(cfg).expect("matrix runs offline") {
        sink.record(row);
    }
    sink.to_doc()
}

#[test]
fn matrix_is_bit_identical_for_identical_configs() {
    let cfg = tiny_matrix();
    let a = doc_for(&cfg);
    let b = doc_for(&cfg);
    assert_eq!(a, b, "same config must serialise to the same bytes");
    assert!(a.contains("\"schema\":\"graft-scenario-v1\""));

    // And the seed must actually matter: a different engine seed moves at
    // least the seeded methods' rows.
    let mut other = tiny_matrix();
    other.seed = 43;
    assert_ne!(a, doc_for(&other), "engine seed must reach the seeded selectors");
}

#[test]
fn smoke_matrix_covers_roster_axes_fractions_and_shapes() {
    let cfg = MatrixConfig::smoke();
    let rows = run_matrix(&cfg).expect("smoke matrix runs offline");

    let methods: BTreeSet<&str> = rows.iter().map(|r| r.method.as_str()).collect();
    for want in [
        "graft",
        "graft+gradpivot",
        "maxvol",
        "cross-maxvol",
        "random",
        "craig",
        "gradmatch",
        "glister",
        "drop",
        "el2n",
        "badge",
        "moderate",
        "forget",
        "hybrid",
    ] {
        assert!(methods.contains(want), "no rows for method {want}");
    }

    let scenarios: BTreeSet<&str> = rows.iter().map(|r| r.scenario.as_str()).collect();
    assert!(scenarios.len() >= 3, "need ≥ 3 scenario axes, got {scenarios:?}");
    let fractions: BTreeSet<String> =
        rows.iter().map(|r| format!("{:.4}", r.fraction)).collect();
    assert!(fractions.len() >= 3, "need ≥ 3 budget fractions, got {fractions:?}");

    // Serial + sharded rows for every method; stream rows only for the
    // reservoir methods.
    for m in &methods {
        let shapes: BTreeSet<&str> = rows
            .iter()
            .filter(|r| r.method.as_str() == *m)
            .map(|r| r.shape.as_str())
            .collect();
        assert!(shapes.contains("serial"), "{m} is missing the serial shape");
        assert!(shapes.contains("sharded2"), "{m} is missing the sharded shape");
        let streams = shapes.contains("stream");
        assert_eq!(
            streams,
            matches!(*m, "graft" | "maxvol"),
            "stream rows must exist exactly for the reservoir methods ({m}: {shapes:?})"
        );
    }

    // Every cell ran every window healthily, with sane metric ranges.
    for r in &rows {
        assert!(r.budget >= 1.0, "{}/{}/{}: empty subsets", r.scenario, r.method, r.shape);
        assert_eq!(r.degraded, 0, "{}/{}/{}: degraded run", r.scenario, r.method, r.shape);
        for (name, v) in [
            ("grad_error", r.grad_error),
            ("coverage", r.coverage),
            ("probe_acc", r.probe_acc),
        ] {
            assert!(
                (0.0..=1.0 + 1e-9).contains(&v),
                "{}/{}/{}: {name}={v} out of range",
                r.scenario,
                r.method,
                r.shape
            );
        }
        assert!(r.mean_loss.is_finite() && r.mean_loss >= 0.0);
        assert!(r.mean_rank.is_finite() && r.mean_rank > 0.0);
    }

    // Fixed cell grid: every (axis, method, fraction) appears on the
    // serial and sharded shapes, plus stream rows for the 2 reservoir
    // methods.
    let expected =
        cfg.axes.len() * cfg.fractions.len() * (graft::scenarios::roster().len() * 2 + 2);
    assert_eq!(rows.len(), expected);
}

#[test]
fn gradpivot_dominates_feature_order_at_every_budget_on_label_noise_scenario() {
    // The headline acceptance criterion.  Planted construction: keep the
    // generator's low-rank features (the MaxVol winner set is shared by
    // both orderings — the pivot stage only re-orders it), but overwrite
    // the gradient sketches with mutually-orthogonal basis columns of
    // varying magnitude.  Coverage of the window-mean gradient by any
    // subset is then the sum of the distinct planted directions it
    // contains, so the greedy residual-coverage order attains the maximal
    // covered mass at every prefix length — and under the strict rank
    // cut, the budget-r selection IS the r-prefix of the full pivot
    // order.  Dominance at every budget is therefore exact, not
    // statistical.
    let cfg = GenConfig { n: 96, d: 16, classes: 3, windows: 1, feat_r: 8, proj_e: 2, seed: 33 };
    let mut wins = scenario_windows(Axis::LabelNoise(0.3), &cfg);
    {
        let win = &mut wins[0];
        let (k, e) = (win.grads.rows(), win.grads.cols());
        for i in 0..k {
            for j in 0..e {
                win.grads[(i, j)] = 0.0;
            }
            win.grads[(i, i % e)] = 1.0 + (i % 5) as f64 * 0.3;
        }
    }
    let win = &wins[0];

    let select = |pivot: PivotMode, budget: usize| -> Vec<usize> {
        let mut eng = EngineBuilder::new()
            .method("graft")
            .seed(42)
            .budget(budget)
            .pivot(pivot)
            .build()
            .expect("valid configuration");
        eng.select(&win.view()).expect("healthy selection").indices.to_vec()
    };

    let mut last_pivot_err = f64::INFINITY;
    for budget in 1..=cfg.feat_r {
        let sel_feature = select(PivotMode::FeatureVol, budget);
        let sel_pivot = select(PivotMode::GradAware, budget);
        assert_eq!(sel_feature.len(), budget);
        assert_eq!(sel_pivot.len(), budget);
        let err_feature = subset_metrics(win, &sel_feature).grad_error;
        let err_pivot = subset_metrics(win, &sel_pivot).grad_error;
        assert!(
            err_pivot <= err_feature + 1e-9,
            "budget {budget}: grad-aware pivot error {err_pivot} > feature-only {err_feature}"
        );
        assert!(
            err_pivot <= last_pivot_err + 1e-9,
            "budget {budget}: grad-aware error must be monotone along the prefix"
        );
        last_pivot_err = err_pivot;
    }

    // At full pivot depth the two orderings select the same SET, so the
    // errors coincide exactly.
    let mut full_f = select(PivotMode::FeatureVol, cfg.feat_r);
    let mut full_g = select(PivotMode::GradAware, cfg.feat_r);
    full_f.sort_unstable();
    full_g.sort_unstable();
    assert_eq!(full_f, full_g, "full budget keeps membership, only order changes");
}

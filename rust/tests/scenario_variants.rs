//! Engine-level pins for the PR 10 selector variants:
//!
//! * **Explore/exploit hybrid** — `explore_fraction(0.0)` is bitwise the
//!   pure MaxVol path and `explore_fraction(1.0)` bitwise the seeded
//!   random baseline, *through the engine* (builder plumbing, seed
//!   derivation, shape fallback included); selections are identical
//!   across requested execution shapes and deterministic in the seed.
//! * **Gradient-aware pivot ordering** — zero gradient signal reproduces
//!   the feature-volume order bitwise on the serial and sharded shapes;
//!   non-zero signal is deterministic in the engine seed per shape.
//! * **Typed rejections** — invalid pivot/explore configurations fail
//!   `build()`/`build_streaming()` with `EngineError`s naming the field.

use graft::engine::{EngineBuilder, EngineError, ExecShape, PivotMode};
use graft::linalg::Mat;
use graft::rng::Rng;
use graft::selection::BatchView;

struct Owned {
    features: Mat,
    grads: Mat,
    losses: Vec<f64>,
    labels: Vec<i32>,
    preds: Vec<i32>,
    classes: usize,
    row_ids: Vec<usize>,
}

impl Owned {
    fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

fn random_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, rc, |_, _| rng.normal());
    let grads = Mat::from_fn(k, e, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
    Owned {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes,
        row_ids: (0..k).collect(),
    }
}

fn zero_grad_owned(k: usize, rc: usize, e: usize, classes: usize, seed: u64) -> Owned {
    let mut o = random_owned(k, rc, e, classes, seed);
    o.grads = Mat::zeros(k, e);
    o
}

/// Run `windows` batches through a freshly built engine, collecting the
/// index streams.
fn select_stream(
    build: impl FnOnce() -> EngineBuilder,
    batches: &[Owned],
    budget: usize,
) -> Vec<Vec<usize>> {
    let mut eng = build().budget(budget).build().expect("valid configuration");
    batches
        .iter()
        .map(|b| eng.select(&b.view()).expect("healthy selection").indices.to_vec())
        .collect()
}

// ---------------------------------------------------------------------------
// Hybrid endpoints through the engine
// ---------------------------------------------------------------------------

#[test]
fn hybrid_explore_zero_is_pure_maxvol_through_engine() {
    let batches: Vec<Owned> = (0..3).map(|i| random_owned(48, 8, 12, 4, 100 + i)).collect();
    // Budget 12 > feature width 8 exercises the loss top-up too.
    let hybrid = select_stream(
        || EngineBuilder::new().method("hybrid").explore_fraction(0.0).seed(7),
        &batches,
        12,
    );
    let maxvol = select_stream(|| EngineBuilder::new().method("maxvol").seed(7), &batches, 12);
    assert_eq!(hybrid, maxvol, "explore 0 must be the FastMaxVol path bitwise");
}

#[test]
fn hybrid_explore_one_is_seeded_random_through_engine() {
    let batches: Vec<Owned> = (0..4).map(|i| random_owned(48, 8, 12, 4, 200 + i)).collect();
    let hybrid = select_stream(
        || EngineBuilder::new().method("hybrid").explore_fraction(1.0).seed(9),
        &batches,
        10,
    );
    let random = select_stream(|| EngineBuilder::new().method("random").seed(9), &batches, 10);
    assert_eq!(hybrid, random, "explore 1 must track the random baseline's RNG exactly");
}

#[test]
fn hybrid_identical_across_requested_shapes() {
    let batches: Vec<Owned> = (0..3).map(|i| random_owned(40, 6, 10, 4, 300 + i)).collect();
    // Hybrid is stateful (RNG advances per selection) so it is not
    // shardable: every requested shape must fall back to one instance
    // and reproduce the serial stream bitwise.
    let serial = select_stream(
        || EngineBuilder::new().method("hybrid").explore_fraction(0.5).seed(4),
        &batches,
        8,
    );
    let sharded = select_stream(
        || {
            EngineBuilder::new()
                .method("hybrid")
                .explore_fraction(0.5)
                .seed(4)
                .exec(ExecShape::Sharded { shards: 3 })
        },
        &batches,
        8,
    );
    let pooled = select_stream(
        || {
            EngineBuilder::new()
                .method("hybrid")
                .explore_fraction(0.5)
                .seed(4)
                .exec(ExecShape::Pooled { shards: 2, workers: 2, overlap: false })
        },
        &batches,
        8,
    );
    assert_eq!(serial, sharded, "sharded request falls back to the serial instance");
    assert_eq!(serial, pooled, "pooled request hosts one instance, same stream");
}

#[test]
fn hybrid_deterministic_in_seed_and_sensitive_to_it() {
    let batches: Vec<Owned> = (0..3).map(|i| random_owned(40, 6, 10, 4, 400 + i)).collect();
    let build = |seed: u64| {
        select_stream(
            move || EngineBuilder::new().method("hybrid").explore_fraction(0.5).seed(seed),
            &batches,
            8,
        )
    };
    assert_eq!(build(11), build(11), "same seed, same stream");
    assert_ne!(build(11), build(12), "the explore share must actually depend on the seed");
}

// ---------------------------------------------------------------------------
// Gradient-aware pivot through the engine
// ---------------------------------------------------------------------------

#[test]
fn grad_pivot_zero_signal_is_feature_order_through_engine() {
    let batches: Vec<Owned> = (0..2).map(|i| zero_grad_owned(48, 8, 12, 4, 500 + i)).collect();
    for shape in [ExecShape::Serial, ExecShape::Sharded { shards: 2 }] {
        let feature = select_stream(
            || {
                EngineBuilder::new()
                    .method("graft")
                    .seed(3)
                    .exec(shape)
                    .pivot(PivotMode::FeatureVol)
            },
            &batches,
            6,
        );
        let pivot = select_stream(
            || {
                EngineBuilder::new()
                    .method("graft")
                    .seed(3)
                    .exec(shape)
                    .pivot(PivotMode::GradAware)
            },
            &batches,
            6,
        );
        assert_eq!(
            pivot, feature,
            "zero gradient signal must keep the feature-volume order bitwise ({shape:?})"
        );
    }
}

#[test]
fn grad_pivot_deterministic_per_shape() {
    let batches: Vec<Owned> = (0..2).map(|i| random_owned(48, 8, 12, 4, 600 + i)).collect();
    for shape in [
        ExecShape::Serial,
        ExecShape::Sharded { shards: 2 },
        ExecShape::Pooled { shards: 2, workers: 2, overlap: false },
    ] {
        let run = || {
            select_stream(
                || {
                    EngineBuilder::new()
                        .method("graft")
                        .seed(5)
                        .exec(shape)
                        .pivot(PivotMode::GradAware)
                },
                &batches,
                6,
            )
        };
        assert_eq!(run(), run(), "grad-aware pivot must be deterministic on {shape:?}");
    }
}

#[test]
fn grad_pivot_keeps_selection_membership_on_serial_full_budget() {
    // At budget = feature width the strict cut keeps the whole pivot
    // prefix, so the two orderings select the same SET (order may differ).
    let o = random_owned(48, 8, 12, 4, 700);
    let sel = |pivot: PivotMode| {
        let mut eng = EngineBuilder::new()
            .method("graft")
            .seed(5)
            .pivot(pivot)
            .budget(8)
            .build()
            .expect("valid configuration");
        let mut v = eng.select(&o.view()).expect("healthy").indices.to_vec();
        v.sort_unstable();
        v
    };
    assert_eq!(sel(PivotMode::FeatureVol), sel(PivotMode::GradAware));
}

// ---------------------------------------------------------------------------
// Typed rejections
// ---------------------------------------------------------------------------

#[test]
fn pivot_on_non_graft_method_is_a_typed_error() {
    let err = EngineBuilder::new()
        .method("maxvol")
        .pivot(PivotMode::GradAware)
        .build()
        .err()
        .expect("pivot needs a GRAFT method");
    assert!(matches!(err, EngineError::PivotNeedsGraft { .. }), "{err}");
    assert_eq!(err.field(), "pivot");
    assert!(err.to_string().contains("no pivot stage"), "{err}");

    let err = EngineBuilder::new()
        .method("random")
        .pivot(PivotMode::GradAware)
        .budget(4)
        .build_streaming()
        .err()
        .expect("streaming pivot needs a GRAFT method too");
    assert!(matches!(err, EngineError::PivotNeedsGraft { .. }), "{err}");
}

#[test]
fn pivot_at_shards_without_grad_merge_is_a_typed_error() {
    for merge in ["flat", "hierarchical"] {
        let err = EngineBuilder::new()
            .method("graft")
            .pivot(PivotMode::GradAware)
            .exec(ExecShape::Sharded { shards: 2 })
            .merge_name(merge)
            .build()
            .err()
            .unwrap_or_else(|| panic!("merge {merge} carries no gradient context"));
        assert!(matches!(err, EngineError::PivotNeedsGradMerge { .. }), "{err}");
        assert_eq!(err.field(), "pivot");
        assert!(err.to_string().contains(merge), "{err}");
    }
    // One shard has no merge: the feature-only policy is fine there.
    EngineBuilder::new()
        .method("graft")
        .pivot(PivotMode::GradAware)
        .exec(ExecShape::Sharded { shards: 1 })
        .merge_name("flat")
        .build()
        .expect("one shard never merges");
}

#[test]
fn explore_out_of_range_is_a_typed_error() {
    for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
        let err = EngineBuilder::new()
            .method("hybrid")
            .explore_fraction(bad)
            .build()
            .err()
            .unwrap_or_else(|| panic!("explore {bad} must be rejected"));
        assert!(matches!(err, EngineError::ExploreOutOfRange { .. }), "{err}");
        assert_eq!(err.field(), "explore");

        let err = EngineBuilder::new()
            .method("maxvol")
            .explore_fraction(bad)
            .budget(4)
            .build_streaming()
            .err()
            .unwrap_or_else(|| panic!("streaming explore {bad} must be rejected"));
        assert!(matches!(err, EngineError::ExploreOutOfRange { .. }), "{err}");
    }
}

#[test]
fn inert_knobs_surface_notes_not_errors() {
    // Explore on a non-hybrid method builds, with a note.
    let eng = EngineBuilder::new()
        .method("maxvol")
        .explore_fraction(0.5)
        .build()
        .expect("inert explore is a note, not an error");
    assert!(
        eng.notes().iter().any(|n| n.contains("explore fraction")),
        "notes: {:?}",
        eng.notes()
    );
    // Streaming GRAFT ignores the pivot (no merged union to re-sort) and
    // says so.
    let eng = EngineBuilder::new()
        .method("graft")
        .pivot(PivotMode::GradAware)
        .budget(4)
        .build_streaming()
        .expect("streaming pivot is a note, not an error");
    assert!(
        eng.notes().iter().any(|n| n.contains("gradient-aware pivot ignored")),
        "notes: {:?}",
        eng.notes()
    );
}

//! Property tests for the PR 1 hot-path kernels: the blocked/threaded
//! `matmul`/`gram` against their scalar references across awkward shapes,
//! workspace-driven `fast_maxvol` bit-identical to the original
//! implementation, the fused prefix-error kernel against explicit QR, and
//! Sherman–Morrison `conventional_maxvol` converging to the same rows as
//! the full re-inversion reference.

use graft::linalg::{qr, qr_with, Mat, Workspace};
use graft::rng::Rng;
use graft::selection::maxvol::{
    conventional_maxvol, conventional_maxvol_reference, fast_maxvol, fast_maxvol_reference,
    fast_maxvol_with,
};

fn randmat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

#[test]
fn blocked_matmul_matches_naive_across_shapes() {
    // Odd, tall, wide, square, block-boundary and empty shapes; the
    // (200, 150, 150) case crosses PAR_MIN_FLOPS and exercises the
    // threaded row-panel path on multi-core machines (on a 1-core runner
    // num_threads() == 1 and it takes the serial path — same kernel,
    // different fan-out).  Row-split threading preserves per-element
    // summation order, so 1e-12 holds on both paths.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (17, 33, 9),
        (31, 32, 33),
        (64, 64, 64),
        (513, 3, 7),
        (3, 513, 5),
        (2, 600, 2),
        (5, 4, 600),
        (200, 150, 150),
        (0, 5, 3),
        (5, 0, 3),
        (5, 3, 0),
    ];
    for (si, &(m, k, n)) in shapes.iter().enumerate() {
        let a = randmat(m, k, si as u64 + 1);
        let b = randmat(k, n, si as u64 + 101);
        let fast = a.matmul(&b);
        let slow = a.matmul_naive(&b);
        assert_eq!((fast.rows(), fast.cols()), (m, n));
        assert!(
            fast.sub(&slow).max_abs() < 1e-12,
            "blocked matmul != naive at {m}x{k}x{n}"
        );
    }
}

#[test]
fn blocked_gram_matches_naive_across_shapes() {
    let shapes: &[(usize, usize)] =
        &[(1, 1), (9, 4), (33, 17), (64, 64), (600, 3), (3, 90), (300, 120), (0, 4), (4, 0)];
    for (si, &(m, n)) in shapes.iter().enumerate() {
        let a = randmat(m, n, si as u64 + 11);
        let fast = a.gram();
        let slow = a.gram_naive();
        // The threaded path (taken for the 300x120 case on multi-core
        // machines) reassociates the per-thread partial sums; 1e-9 leaves
        // ample headroom over the worst-case n·eps reassociation bound
        // while still catching any indexing bug.
        assert!(fast.sub(&slow).max_abs() < 1e-9, "blocked gram != naive at {m}x{n}");
        let viat = a.transpose().matmul_naive(&a);
        assert!(fast.sub(&viat).max_abs() < 1e-9, "gram != AᵀA at {m}x{n}");
    }
}

#[test]
fn blocked_transpose_and_take_cols_elementwise() {
    let a = randmat(67, 45, 21);
    let t = a.transpose();
    for i in 0..67 {
        for j in 0..45 {
            assert_eq!(t[(j, i)], a[(i, j)]);
        }
    }
    let idx = [44usize, 0, 7, 7, 13];
    let picked = a.take_cols(&idx);
    for i in 0..67 {
        for (jj, &j) in idx.iter().enumerate() {
            assert_eq!(picked[(i, jj)], a[(i, j)]);
        }
    }
}

#[test]
fn simd_kernels_match_naive_at_lane_remainder_shapes() {
    // K, R, E ∈ {1, 3, 5, 7, 63, 65}: every size leaves a different
    // remainder mod the 4-lane kernels (including the all-tail cases), so
    // an off-by-one in the unrolled chunks cannot hide behind a friendly
    // multiple-of-4 shape.
    const SIZES: [usize; 6] = [1, 3, 5, 7, 63, 65];
    for (i, &m) in SIZES.iter().enumerate() {
        for (j, &k) in SIZES.iter().enumerate() {
            let n = SIZES[(i + j) % SIZES.len()];
            let a = randmat(m, k, (i * 6 + j) as u64 + 301);
            let b = randmat(k, n, (i * 6 + j) as u64 + 601);
            assert!(
                a.matmul(&b).sub(&a.matmul_naive(&b)).max_abs() < 1e-12,
                "lane-remainder matmul {m}x{k}x{n}"
            );
            assert!(
                a.gram().sub(&a.gram_naive()).max_abs() < 1e-9,
                "lane-remainder gram {m}x{k}"
            );
        }
    }

    // Fused MGS prefix errors (now running on the lane axpy/dot kernels):
    // explicit QR plus a scalar column-by-column projection of ĝ is the
    // ground truth, at every lane-remainder (E, R) pair.
    use graft::graft::prefix_projection_errors;
    for (i, &e) in SIZES.iter().enumerate() {
        for (j, &rr) in SIZES.iter().enumerate() {
            let r = rr.min(e); // extra columns past E are dependent anyway
            let gsel = randmat(e, r, (i * 6 + j) as u64 + 901);
            let mut rng = Rng::new((i * 6 + j) as u64 + 1201);
            let gbar: Vec<f64> = (0..e).map(|_| rng.normal()).collect();
            let got = prefix_projection_errors(&gsel, &gbar);
            let nrm = gbar.iter().map(|x| x * x).sum::<f64>().sqrt();
            let d = qr(&gsel);
            let mut cum = 0.0;
            for jj in 0..r {
                let mut a = 0.0;
                for t in 0..e {
                    a += d.q[(t, jj)] * gbar[t] / nrm;
                }
                cum += a * a;
                let want = (1.0 - cum).max(0.0);
                assert!(
                    (got[jj] - want).abs() < 1e-9,
                    "prefix error diverged at E={e} R={r} j={jj}: {} vs {want}",
                    got[jj]
                );
            }
        }
    }
}

#[test]
fn fast_maxvol_workspace_bit_identical_to_reference() {
    // One workspace reused across every shape: selections must match the
    // pre-PR clone-per-call implementation bit for bit (same pivots, same
    // order), including on rank-deficient duplicate-row inputs.
    let mut ws = Workspace::default();
    let mut out = Vec::new();
    for (k, r, seed) in [
        (8usize, 2usize, 1u64),
        (32, 8, 2),
        (64, 12, 3),
        (128, 16, 4),
        (2048, 64, 5),
    ] {
        let v = randmat(k, r, seed);
        for depth in [1, r / 2, r] {
            let depth = depth.max(1);
            fast_maxvol_with(&v, depth, &mut ws, &mut out);
            assert_eq!(
                out,
                fast_maxvol_reference(&v, depth),
                "K={k} R={r} depth={depth}"
            );
        }
    }
    // Duplicate rows: uniqueness forced by the taken mask.
    let mut rng = Rng::new(6);
    let base = Mat::from_fn(4, 6, |_, _| rng.normal());
    let dup = Mat::from_fn(32, 6, |i, j| base[(i % 4, j)]);
    fast_maxvol_with(&dup, 6, &mut ws, &mut out);
    assert_eq!(out, fast_maxvol_reference(&dup, 6));
    // The allocating wrapper agrees too.
    assert_eq!(fast_maxvol(&dup, 6), out);
}

#[test]
fn qr_with_matches_qr() {
    let mut ws = Workspace::default();
    for (m, n, seed) in [(20usize, 6usize, 31u64), (15, 5, 32), (40, 1, 33), (6, 6, 34)] {
        let a = randmat(m, n, seed);
        let d1 = qr(&a);
        let d2 = qr_with(&a, &mut ws);
        assert_eq!(d1.rank, d2.rank);
        assert!(d1.q.sub(&d2.q).max_abs() == 0.0, "Q differs at {m}x{n}");
        assert!(d1.r.sub(&d2.r).max_abs() == 0.0, "R differs at {m}x{n}");
    }
}

#[test]
fn sherman_morrison_conventional_matches_reference_rows() {
    for seed in [7u64, 8, 9, 10] {
        let v = randmat(48, 6, seed);
        let (mut fast, _) = conventional_maxvol(&v, 6, 1.01, 100);
        let (mut slow, _) = conventional_maxvol_reference(&v, 6, 1.01, 100);
        fast.sort_unstable();
        slow.sort_unstable();
        assert_eq!(fast, slow, "seed {seed}");
    }
}

#[test]
fn sherman_morrison_dominance_at_scale() {
    // Larger K: the incremental B must stay accurate over many swaps.
    let v = randmat(256, 8, 42);
    let (rows, swaps) = conventional_maxvol(&v, 8, 1.01, 200);
    assert!(swaps <= 200);
    let cols: Vec<usize> = (0..8).collect();
    let vr = v.take_cols(&cols);
    let sub = vr.take_rows(&rows);
    let b = vr.matmul(&graft::linalg::pinv(&sub));
    assert!(b.max_abs() <= 1.02, "max |B| = {} after {swaps} swaps", b.max_abs());
}

//! End-to-end integration tests over the PJRT runtime: short training runs
//! per method asserting learning, determinism, energy ordering, and the
//! paper's qualitative claims at miniature scale.  Skipped (loudly) when
//! `make artifacts` has not run.

use graft::runtime::{default_dir, Engine};
use graft::train::{self, TrainConfig};

fn engine() -> Option<Engine> {
    match Engine::new(default_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP train integration: {err:#} (run `make artifacts`)");
            None
        }
    }
}

fn quick(dataset: &str, method: &str, fraction: f64, epochs: usize) -> TrainConfig {
    TrainConfig {
        dataset: dataset.into(),
        method: method.into(),
        fraction,
        epochs,
        refresh_epochs: 5,
        warm_epochs: 2,
        seed: 42,
        ..TrainConfig::default()
    }
}

#[test]
fn full_training_learns_iris() {
    let Some(mut eng) = engine() else { return };
    let out = train::run(&mut eng, &quick("iris", "full", 1.0, 40)).unwrap();
    assert!(out.result.final_acc > 0.8, "iris full acc {}", out.result.final_acc);
    assert!(out.result.co2_kg > 0.0);
    // Loss decreased over training.
    let first = out.result.curve.first().unwrap().train_loss;
    let last = out.result.curve.last().unwrap().train_loss;
    assert!(last < first, "loss should fall: {first} -> {last}");
}

#[test]
fn graft_learns_on_subset() {
    let Some(mut eng) = engine() else { return };
    let out = train::run(&mut eng, &quick("iris", "graft", 0.5, 40)).unwrap();
    assert!(out.result.final_acc > 0.7, "iris graft acc {}", out.result.final_acc);
    assert!(!out.alignment.samples.is_empty(), "alignment telemetry recorded");
    assert!(!out.alignment.class_counts.is_empty());
}

#[test]
fn every_method_runs_imdb() {
    let Some(mut eng) = engine() else { return };
    for method in [
        "graft", "graft-warm", "random", "craig", "gradmatch", "glister",
        "drop", "el2n", "forget", "cross-maxvol", "maxvol",
    ] {
        let out = train::run(&mut eng, &quick("imdb", method, 0.25, 4)).unwrap();
        assert!(
            out.result.final_acc > 0.4,
            "{method}: acc {} should beat degenerate",
            out.result.final_acc
        );
        assert!(out.result.co2_kg > 0.0, "{method}: emissions recorded");
    }
}

#[test]
fn runs_are_deterministic() {
    let Some(mut eng) = engine() else { return };
    let a = train::run(&mut eng, &quick("iris", "graft", 0.5, 10)).unwrap();
    let b = train::run(&mut eng, &quick("iris", "graft", 0.5, 10)).unwrap();
    assert_eq!(a.result.final_acc, b.result.final_acc);
    assert_eq!(a.result.steps, b.result.steps);
    assert!((a.result.co2_kg - b.result.co2_kg).abs() < 1e-15);
    assert_eq!(a.state.params.w1, b.state.params.w1);
}

#[test]
fn subset_training_emits_less_than_full() {
    let Some(mut eng) = engine() else { return };
    let full = train::run(&mut eng, &quick("imdb", "full", 1.0, 6)).unwrap();
    let sub = train::run(&mut eng, &quick("imdb", "graft", 0.1, 6)).unwrap();
    assert!(
        sub.result.co2_kg < full.result.co2_kg,
        "graft {} !< full {}",
        sub.result.co2_kg,
        full.result.co2_kg
    );
}

#[test]
fn warm_start_beats_cold_at_low_fraction() {
    let Some(mut eng) = engine() else { return };
    let cold = train::run(&mut eng, &quick("imdb", "graft", 0.1, 6)).unwrap();
    let warm = train::run(&mut eng, &quick("imdb", "graft-warm", 0.1, 6)).unwrap();
    // Table 2's key qualitative claim (warm ≥ cold at 10%); allow slack.
    assert!(
        warm.result.final_acc >= cold.result.final_acc - 0.02,
        "warm {} vs cold {}",
        warm.result.final_acc,
        cold.result.final_acc
    );
    assert!(warm.result.co2_kg > cold.result.co2_kg, "warm-up costs energy");
}

#[test]
fn adaptive_rank_stays_within_kernel_depth() {
    let Some(mut eng) = engine() else { return };
    let mut cfg = quick("iris", "graft", 0.25, 10);
    cfg.adaptive_rank = true;
    cfg.epsilon = 0.2;
    let out = train::run(&mut eng, &cfg).unwrap();
    let spec = eng.spec("iris").unwrap();
    for s in &out.alignment.samples {
        assert!(s.rank >= 1 && s.rank <= spec.rmax);
        assert!((0.0..=1.0 + 1e-9).contains(&s.error));
    }
    assert!(out.result.mean_rank >= 1.0);
}

#[test]
fn extractor_ablation_path_runs() {
    let Some(mut eng) = engine() else { return };
    for ext in ["svd", "pca"] {
        let mut cfg = quick("iris", "graft", 0.5, 4);
        cfg.extractor = Some(ext.into());
        let out = train::run(&mut eng, &cfg).unwrap();
        assert!(out.result.final_acc > 0.4, "{ext}: {}", out.result.final_acc);
    }
}

#[test]
fn curve_is_monotone_in_co2() {
    let Some(mut eng) = engine() else { return };
    let out = train::run(&mut eng, &quick("iris", "graft", 0.5, 8)).unwrap();
    let co2: Vec<f64> = out.result.curve.iter().map(|p| p.co2_kg).collect();
    for w in co2.windows(2) {
        assert!(w[1] >= w[0], "emissions are cumulative");
    }
}

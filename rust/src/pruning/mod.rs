//! Fast MaxVol channel pruning (paper §5 / Table 5): select the most
//! informative hidden channels by running Fast MaxVol on the activation
//! matrix Hᵀ (channels as rows, samples as columns → channel selection),
//! then rebuild a smaller network from the kept channels.
//!
//! Matches the paper's preliminary experiment: 50% channels pruned with a
//! modest accuracy drop and ~40% FLOPs reduction.

use crate::linalg::Mat;
use crate::runtime::{ConfigSpec, ModelParams};
use crate::selection::maxvol::fast_maxvol;

/// Outcome of pruning a model to `keep` hidden channels.
pub struct PrunedModel {
    pub params: ModelParams,
    pub kept: Vec<usize>,
    pub params_before: usize,
    pub params_after: usize,
    pub flops_before: f64,
    pub flops_after: f64,
}

/// Per-sample forward FLOPs of the 2-layer MLP with hidden width `h`.
pub fn mlp_flops(d: usize, h: usize, c: usize) -> f64 {
    2.0 * (d as f64 * h as f64 + h as f64 * c as f64)
}

/// Select `keep` channels by Fast MaxVol on the hidden activation matrix
/// `acts` (K×H, rows = samples): channels are rows of actsᵀ, and the
/// feature columns are importance-ordered by activation energy first.
pub fn select_channels(acts: &Mat, keep: usize) -> Vec<usize> {
    let h = acts.cols();
    let keep = keep.min(h);
    // Channel matrix: H×K.
    let chan = acts.transpose();
    // Order the K sample-columns by energy so the MaxVol "feature order"
    // contract holds, then truncate to `keep` columns for an H×keep input.
    let mut energy: Vec<(f64, usize)> = (0..chan.cols())
        .map(|j| {
            let col = chan.col(j);
            (-crate::linalg::dot(&col, &col), j)
        })
        .collect();
    energy.sort_by(|a, b| a.0.total_cmp(&b.0));
    let order: Vec<usize> = energy.iter().map(|&(_, j)| j).take(keep).collect();
    let reduced = chan.take_cols(&order);
    let mut kept = fast_maxvol(&reduced, keep);
    kept.sort_unstable();
    kept
}

/// Prune the MLP to the given channels: rows of W2 and columns of W1/b1.
pub fn prune_params(params: &ModelParams, spec: &ConfigSpec, kept: &[usize]) -> PrunedModel {
    let (d, h, c) = (spec.d, spec.h, spec.c);
    let hk = kept.len();
    let mut w1 = vec![0.0f32; d * hk];
    for row in 0..d {
        for (jn, &jo) in kept.iter().enumerate() {
            w1[row * hk + jn] = params.w1[row * h + jo];
        }
    }
    let b1: Vec<f32> = kept.iter().map(|&j| params.b1[j]).collect();
    let mut w2 = vec![0.0f32; hk * c];
    for (jn, &jo) in kept.iter().enumerate() {
        w2[jn * c..(jn + 1) * c].copy_from_slice(&params.w2[jo * c..(jo + 1) * c]);
    }
    let before = params.w1.len() + params.b1.len() + params.w2.len() + params.b2.len();
    let after = w1.len() + b1.len() + w2.len() + params.b2.len();
    PrunedModel {
        params: ModelParams { w1, b1, w2, b2: params.b2.clone() },
        kept: kept.to_vec(),
        params_before: before,
        params_after: after,
        flops_before: mlp_flops(d, h, c),
        flops_after: mlp_flops(d, hk, c),
    }
}

/// CPU-side forward pass for a pruned model (the pruned width has no AOT
/// artifact; Table 5 measures this Rust inference path directly).
pub fn forward_pruned(p: &ModelParams, d: usize, x: &[f32]) -> Vec<usize> {
    let h = p.b1.len();
    let c = p.b2.len();
    let n = x.len() / d;
    let mut preds = Vec::with_capacity(n);
    let mut hid = vec![0.0f32; h];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        for j in 0..h {
            hid[j] = p.b1[j];
        }
        for (t, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &p.w1[t * h..(t + 1) * h];
            for j in 0..h {
                hid[j] += xv * wrow[j];
            }
        }
        let mut best = (f32::MIN, 0usize);
        for cls in 0..c {
            let mut z = p.b2[cls];
            for j in 0..h {
                let a = hid[j].max(0.0);
                z += a * p.w2[j * c + cls];
            }
            if z > best.0 {
                best = (z, cls);
            }
        }
        preds.push(best.1);
    }
    preds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spec() -> ConfigSpec {
        ConfigSpec {
            name: "t".into(), d: 8, c: 3, h: 6, k: 16, rmax: 4, e: 9,
            buckets: vec![4, 16], artifacts: vec![],
        }
    }

    #[test]
    fn channel_selection_unique_and_sized() {
        let mut rng = Rng::new(1);
        let acts = Mat::from_fn(32, 6, |_, _| rng.normal().max(0.0));
        let kept = select_channels(&acts, 3);
        assert_eq!(kept.len(), 3);
        let mut u = kept.clone();
        u.dedup();
        assert_eq!(u.len(), 3);
        assert!(kept.iter().all(|&j| j < 6));
    }

    #[test]
    fn dominant_channels_survive() {
        // Channels 1 and 4 carry 100× the energy; keep=2 must pick them.
        let mut rng = Rng::new(2);
        let acts = Mat::from_fn(64, 6, |_, j| {
            let scale = if j == 1 || j == 4 { 10.0 } else { 0.1 };
            scale * rng.normal()
        });
        let kept = select_channels(&acts, 2);
        assert_eq!(kept, vec![1, 4]);
    }

    #[test]
    fn prune_shapes_and_flops() {
        let s = spec();
        let params = ModelParams::init(&s, 3);
        let pruned = prune_params(&params, &s, &[0, 2, 5]);
        assert_eq!(pruned.params.b1.len(), 3);
        assert_eq!(pruned.params.w1.len(), 8 * 3);
        assert_eq!(pruned.params.w2.len(), 3 * 3);
        assert!(pruned.params_after < pruned.params_before);
        assert!(pruned.flops_after < pruned.flops_before);
    }

    #[test]
    fn pruned_forward_matches_pruned_weights() {
        // Identity check: pruning all channels == original prediction path.
        let s = spec();
        let params = ModelParams::init(&s, 4);
        let all: Vec<usize> = (0..s.h).collect();
        let pruned = prune_params(&params, &s, &all);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..4 * s.d).map(|_| rng.normal() as f32).collect();
        let a = forward_pruned(&params, s.d, &x);
        let b = forward_pruned(&pruned.params, s.d, &x);
        assert_eq!(a, b);
    }
}

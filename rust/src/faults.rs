//! Deterministic fault injection for the selection stack.
//!
//! A [`FaultInjector`] is consulted right before each unit of selection
//! work runs — once per shard job on the pool workers and the scoped
//! shard fan-out, once per select on the serial path — and answers with a
//! [`FaultAction`]: do nothing, panic (contained by the normal
//! containment machinery, so this exercises the *real* respawn / retry /
//! ladder paths), sleep past the per-job deadline, or kill the worker
//! thread outright.
//!
//! [`FaultPlan`] is the deterministic schedule used by
//! `tests/fault_injection.rs`: a list of events ("panic shard 2 at window
//! 3", "delay worker 1 by 50 ms", "kill worker 0"), each with a fire
//! limit so a one-shot fault is injected exactly once and the retry then
//! observes a healthy run — which is what makes the headline bit-identity
//! property testable.  Plans can also be generated from a seed
//! ([`FaultPlan::seeded`]) to sweep random schedules.
//!
//! The injector hooks are compiled unconditionally (they are a handful of
//! `Option` checks on cold paths) but nothing installs one outside tests
//! and benches: production engines run with `None`.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::rng::Rng;

/// Where a unit of selection work is about to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardCtx {
    /// 1-based select/window ordinal (the pool epoch on pooled shapes,
    /// the engine's running select count elsewhere).
    pub window: u64,
    /// Batch-local shard index (0 on the serial path).
    pub shard: usize,
    /// Worker index executing the job (`shard % workers` on the pool; 0
    /// elsewhere).
    pub worker: usize,
}

/// What the injector asks the executing site to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultAction {
    /// Run normally.
    #[default]
    None,
    /// Panic before selecting (caught by the containment layer exactly
    /// like a selector bug would be).
    Panic,
    /// Sleep this long before selecting (drives a job past the pool's
    /// per-job deadline without killing anything).
    Delay(Duration),
    /// Kill the worker thread without answering (pool only; elsewhere
    /// treated like [`FaultAction::Panic`]).
    DieWorker,
}

/// A source of injected faults.  Implementations must be cheap and
/// deterministic: the same call sequence must see the same actions.
pub trait FaultInjector: Send + Sync {
    /// Consulted immediately before the work for `ctx` runs.
    fn before_shard(&self, ctx: ShardCtx) -> FaultAction;
}

/// Which work units an event applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Jobs for this shard index (any worker).
    Shard(usize),
    /// Any job on this worker index.
    Worker(usize),
}

/// One scheduled fault: fires for matching contexts until `limit` is
/// exhausted.
#[derive(Debug)]
pub struct FaultEvent {
    pub target: Target,
    /// Restrict to one 1-based window ordinal (`None` = any).
    pub window: Option<u64>,
    pub action: FaultAction,
    /// How many times this event may fire (1 = one-shot, so the retry of
    /// the faulted job observes a healthy run).
    pub limit: u32,
    fires: AtomicU32,
}

impl FaultEvent {
    fn matches(&self, ctx: ShardCtx) -> bool {
        let t = match self.target {
            Target::Shard(s) => ctx.shard == s,
            Target::Worker(w) => ctx.worker == w,
        };
        t && self.window.unwrap_or(ctx.window) == ctx.window
    }
}

/// A deterministic fault schedule ([`FaultInjector`] implementation).
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Empty plan (injects nothing).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    fn push(
        mut self,
        target: Target,
        window: Option<u64>,
        action: FaultAction,
        limit: u32,
    ) -> Self {
        self.events.push(FaultEvent { target, window, action, limit, fires: AtomicU32::new(0) });
        self
    }

    /// Panic shard `shard`'s job once, at 1-based window `window`.
    pub fn panic_shard(self, shard: usize, window: u64) -> Self {
        self.push(Target::Shard(shard), Some(window), FaultAction::Panic, 1)
    }

    /// Panic shard `shard`'s job on its next `times` runs (any window).
    pub fn panic_shard_times(self, shard: usize, times: u32) -> Self {
        self.push(Target::Shard(shard), None, FaultAction::Panic, times)
    }

    /// Panic every job of shard `shard`, forever (exhausts any retry
    /// budget).
    pub fn panic_shard_always(self, shard: usize) -> Self {
        self.push(Target::Shard(shard), None, FaultAction::Panic, u32::MAX)
    }

    /// Delay worker `worker`'s next job by `by` (one-shot).
    pub fn delay_worker(self, worker: usize, by: Duration) -> Self {
        self.push(Target::Worker(worker), None, FaultAction::Delay(by), 1)
    }

    /// Kill worker `worker` on its next job (one-shot; the pool respawns
    /// it under a retrying policy).
    pub fn kill_worker(self, worker: usize) -> Self {
        self.push(Target::Worker(worker), None, FaultAction::DieWorker, 1)
    }

    /// Kill every worker's next job — the all-workers-dead schedule.
    pub fn kill_all_workers(self, workers: usize) -> Self {
        (0..workers).fold(self, |p, w| p.kill_worker(w))
    }

    /// Generate a small random one-shot schedule over `shards` shards,
    /// `workers` workers, and `windows` windows — deterministic in
    /// `seed`.  Every event is one-shot, so under a retrying policy the
    /// final subsets must be bit-identical to the fault-free run.
    pub fn seeded(seed: u64, shards: usize, workers: usize, windows: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA017);
        let mut plan = FaultPlan::new();
        let events = 1 + rng.below(3); // 1..=3 faults
        for _ in 0..events {
            let window = 1 + rng.below(windows.max(1) as usize) as u64;
            match rng.below(3) {
                0 => {
                    let s = rng.below(shards.max(1));
                    plan = plan.push(Target::Shard(s), Some(window), FaultAction::Panic, 1);
                }
                1 => {
                    let w = rng.below(workers.max(1));
                    let ms = 1 + rng.below(5) as u64;
                    plan = plan.push(
                        Target::Worker(w),
                        Some(window),
                        FaultAction::Delay(Duration::from_millis(ms)),
                        1,
                    );
                }
                _ => {
                    let w = rng.below(workers.max(1));
                    plan = plan.push(Target::Worker(w), Some(window), FaultAction::DieWorker, 1);
                }
            }
        }
        plan
    }

    /// Wrap in the `Arc` every injector consumer takes.
    pub fn arc(self) -> Arc<dyn FaultInjector> {
        Arc::new(self)
    }

    /// Total fires across all events so far (test observability).
    pub fn fired(&self) -> u32 {
        self.events.iter().map(|e| e.fires.load(Ordering::Relaxed)).sum()
    }
}

impl FaultInjector for FaultPlan {
    fn before_shard(&self, ctx: ShardCtx) -> FaultAction {
        for ev in &self.events {
            if !ev.matches(ctx) {
                continue;
            }
            // First matching event with budget left fires; fetch_update
            // keeps the limit exact under concurrent workers.
            let won = ev
                .fires
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                    (n < ev.limit).then_some(n + 1)
                })
                .is_ok();
            if won {
                return ev.action;
            }
        }
        FaultAction::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(window: u64, shard: usize, worker: usize) -> ShardCtx {
        ShardCtx { window, shard, worker }
    }

    #[test]
    fn one_shot_event_fires_exactly_once() {
        let plan = FaultPlan::new().panic_shard(2, 3);
        assert_eq!(plan.before_shard(ctx(3, 1, 0)), FaultAction::None);
        assert_eq!(plan.before_shard(ctx(2, 2, 0)), FaultAction::None, "wrong window");
        assert_eq!(plan.before_shard(ctx(3, 2, 0)), FaultAction::Panic);
        assert_eq!(plan.before_shard(ctx(3, 2, 0)), FaultAction::None, "budget spent");
        assert_eq!(plan.fired(), 1);
    }

    #[test]
    fn persistent_event_never_exhausts() {
        let plan = FaultPlan::new().panic_shard_always(0);
        for w in 1..50u64 {
            assert_eq!(plan.before_shard(ctx(w, 0, 0)), FaultAction::Panic);
        }
    }

    #[test]
    fn worker_targets_match_any_shard() {
        let plan = FaultPlan::new().delay_worker(1, Duration::from_millis(1));
        assert_eq!(plan.before_shard(ctx(1, 5, 0)), FaultAction::None);
        assert_eq!(
            plan.before_shard(ctx(1, 5, 1)),
            FaultAction::Delay(Duration::from_millis(1))
        );
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(7, 4, 2, 6);
        let b = FaultPlan::seeded(7, 4, 2, 6);
        assert_eq!(a.events.len(), b.events.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.target, y.target);
            assert_eq!(x.window, y.window);
            assert_eq!(x.action, y.action);
            assert_eq!(x.limit, y.limit);
        }
    }
}

//! Config system: a tiny dependency-free flag parser (`--key value` /
//! `--flag`) plus optional `key = value` config files, merged with
//! defaults.  Every CLI subcommand and example builds its run
//! configuration through this module so behaviour is uniform.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::MergePolicy;
use crate::engine;
use crate::train::TrainConfig;

/// Parsed command line: subcommand + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first positional = subcommand, then `--key v` /
    /// `--flag` pairs.  `--config FILE` merges `key = value` lines first
    /// (explicit flags win).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    opts.insert(key.to_string(), it.next().unwrap());
                }
                _ => flags.push(key.to_string()),
            }
        }
        let mut args = Args { command, opts, flags };
        if let Some(path) = args.value_of("config")? {
            let merged = Self::parse_file(&path)?;
            for (k, v) in merged {
                args.opts.entry(k).or_insert(v);
            }
        }
        Ok(args)
    }

    fn parse_file(path: &str) -> Result<BTreeMap<String, String>> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut out = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path}:{}: expected key = value", ln + 1))?;
            out.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(out)
    }

    /// Raw option lookup: the value of `--key value`, or `None` when the
    /// key is absent *or* was given as a bare flag.  Value-taking keys
    /// should go through [`Args::value_of`] (or the typed `*_or`
    /// accessors), which turn the bare-flag case into an error instead of
    /// silently dropping the option.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.opts.get(key).cloned()
    }

    /// Value of a value-taking `--key`.  Unlike [`Args::opt`], a key that
    /// was demoted to a bare flag because its value was missing —
    /// `train --shards --overlap` parses `--shards` as a flag since the
    /// next token starts with `--` — is an **error naming the key**, not
    /// a silent `None`.  Boolean keys keep using [`Args::try_flag`],
    /// where the bare spelling is the point.
    pub fn value_of(&self, key: &str) -> Result<Option<String>> {
        if let Some(v) = self.opts.get(key) {
            return Ok(Some(v.clone()));
        }
        if self.flags.iter().any(|f| f == key) {
            bail!("missing value for --{key}");
        }
        Ok(None)
    }

    /// Whether a boolean option is on, treating anything unparseable as
    /// off.  Prefer [`Args::try_flag`] wherever an error can be
    /// surfaced — silently ignoring a misspelled boolean is exactly the
    /// config-file bug this parser used to have.
    pub fn flag(&self, key: &str) -> bool {
        self.try_flag(key).unwrap_or(false)
    }

    /// Whether a boolean option is on, with strict value parsing.  True
    /// when the key was given as a bare CLI flag (`--overlap`) or carries
    /// a truthy value (`true` / `1` / `yes` / `on`, case-insensitive) —
    /// which is how config files spell booleans (`overlap = true`) and
    /// how `--overlap true` parses.  Falsy spellings (`false` / `0` /
    /// `no` / `off`) are off; any other value is an **error naming the
    /// key**, so a typo like `overlap = bananas` (or `= True` would be,
    /// were matching case-sensitive) cannot silently disable the
    /// behaviour.  A bare CLI flag always wins: there is no negation
    /// syntax, so a truthy file value cannot be overridden — only left
    /// unset.
    pub fn try_flag(&self, key: &str) -> Result<bool> {
        if self.flags.iter().any(|f| f == key) {
            return Ok(true);
        }
        match self.opts.get(key) {
            None => Ok(false),
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                _ => bail!(
                    "--{key} must be a boolean (true/false, 1/0, yes/no, on/off), got '{v}'"
                ),
            },
        }
    }

    pub fn get_or(&self, key: &str, default: &str) -> Result<String> {
        Ok(self.value_of(key)?.unwrap_or_else(|| default.to_string()))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.value_of(key)? {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.value_of(key)? {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.value_of(key)? {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Result<Vec<String>> {
        Ok(match self.value_of(key)? {
            Some(v) => {
                v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
            None => default.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// Build a [`TrainConfig`] from the parsed options.
    pub fn train_config(&self) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let method = self.get_or("method", &d.method)?;
        // `--merge` default is method-aware; the rule lives in ONE place
        // ([`engine::default_merge`], shared with `EngineBuilder` and
        // `TrainConfig::default`).  An explicit flag wins.
        let merge_default = engine::default_merge(&method);
        Ok(TrainConfig {
            dataset: self.get_or("dataset", &d.dataset)?,
            method,
            fraction: self.f64_or("fraction", d.fraction)?,
            epochs: self.usize_or("epochs", d.epochs)?,
            refresh_epochs: self.usize_or("refresh-epochs", d.refresh_epochs)?,
            lr0: self.f64_or("lr", d.lr0)?,
            momentum: self.f64_or("momentum", d.momentum)?,
            epsilon: self.f64_or("epsilon", d.epsilon)?,
            warm_epochs: self.usize_or("warm-epochs", d.warm_epochs)?,
            adaptive_rank: self.try_flag("adaptive-rank")?,
            extractor: self.value_of("extractor")?,
            shards: self.usize_or("shards", d.shards)?,
            pool_workers: self.usize_or("pool-workers", d.pool_workers)?,
            overlap: self.try_flag("overlap")? || d.overlap,
            stream_chunk: self.usize_or("stream-chunk", d.stream_chunk)?,
            merge: {
                let s = self.get_or("merge", merge_default.name())?;
                MergePolicy::parse(&s).with_context(|| {
                    format!("unknown merge policy '{s}' (hierarchical|flat|grad)")
                })?
            },
            seed: self.u64_or("seed", d.seed)?,
        })
    }

    /// Build a [`ServeConfig`] from the parsed options (the `serve` /
    /// `serve-smoke` subcommands).
    pub fn serve_config(&self) -> Result<ServeConfig> {
        let cfg = ServeConfig {
            addr: self.value_of("addr")?,
            uds: self.value_of("uds")?,
            addr_file: self.value_of("addr-file")?,
            max_sessions: self.usize_or("max-sessions", 64)?,
            max_frame_mb: self.usize_or("max-frame-mb", 16)?,
            read_tick_ms: self.u64_or("read-tick-ms", 50)?,
            stall_ticks: self.usize_or("stall-ticks", 200)?,
        };
        if cfg.max_sessions == 0 {
            bail!("--max-sessions must be at least 1");
        }
        if cfg.max_frame_mb == 0 {
            bail!("--max-frame-mb must be at least 1");
        }
        if cfg.read_tick_ms == 0 {
            bail!("--read-tick-ms must be at least 1");
        }
        if cfg.addr.is_some() && cfg.uds.is_some() {
            bail!("--addr and --uds are mutually exclusive");
        }
        Ok(cfg)
    }
}

/// Daemon knobs for `graft serve` (see `rust/src/serve/`): where to
/// listen and the admission/framing bounds.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP listen address (`host:port`; port 0 = OS-assigned).  Default
    /// `127.0.0.1:4714` when neither `--addr` nor `--uds` is given.
    pub addr: Option<String>,
    /// Unix-domain socket path (mutually exclusive with `--addr`).
    pub uds: Option<String>,
    /// File to write the bound address to once listening — how scripts
    /// using port 0 learn the port (and that the daemon is ready).
    pub addr_file: Option<String>,
    /// Admission bound: connections beyond this get `Busy` + close.
    pub max_sessions: usize,
    /// Frame payload cap in MiB.
    pub max_frame_mb: usize,
    /// Idle read-poll tick in milliseconds.
    pub read_tick_ms: u64,
    /// Mid-frame stall budget, in ticks.
    pub stall_ticks: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_parse() {
        let a = parse("train --dataset cifar10 --fraction 0.25 --adaptive-rank");
        assert_eq!(a.command, "train");
        assert_eq!(a.opt("dataset").as_deref(), Some("cifar10"));
        assert!(a.flag("adaptive-rank"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn train_config_defaults_and_overrides() {
        let a = parse("train --method gradmatch --epochs 7");
        let c = a.train_config().unwrap();
        assert_eq!(c.method, "gradmatch");
        assert_eq!(c.epochs, 7);
        assert_eq!(c.dataset, "cifar10");
    }

    #[test]
    fn list_parsing() {
        let a = parse("sweep --methods graft,random, --x 1");
        assert_eq!(a.list_or("methods", &[]).unwrap(), vec!["graft", "random"]);
        assert_eq!(a.list_or("absent", &["d"]).unwrap(), vec!["d"]);
    }

    #[test]
    fn missing_value_is_an_error_not_a_flag() {
        // Regression: `train --shards --overlap` used to silently demote
        // `--shards` to a boolean flag (the next token starts with `--`),
        // so the run trained unsharded instead of erroring.
        let a = parse("train --shards --overlap");
        let err = a.train_config().err().expect("missing --shards value must error");
        assert!(
            format!("{err:#}").contains("missing value for --shards"),
            "error must name the key: {err:#}"
        );
        // The same guard covers every value accessor and trailing keys.
        let a = parse("train --epochs");
        assert!(format!("{:#}", a.train_config().unwrap_err()).contains("--epochs"));
        let a = parse("sweep --methods --x 1");
        assert!(a.list_or("methods", &[]).is_err());
        let a = parse("train --dataset --fraction 0.5");
        assert!(a.get_or("dataset", "cifar10").is_err());
        assert!(a.value_of("dataset").is_err());
        // Boolean keys are untouched: bare spelling is how flags work.
        assert!(a.try_flag("dataset").unwrap(), "bare key still visible as a flag");
        let c = parse("train --overlap --pool-workers 2").train_config().unwrap();
        assert!(c.overlap);
        // And `--config` without a path errors instead of being ignored.
        let err = Args::parse(["train".to_string(), "--config".to_string()])
            .err()
            .expect("bare --config must error");
        assert!(format!("{err:#}").contains("--config"));
    }

    #[test]
    fn serve_config_parses_and_validates() {
        let c = parse("serve --addr 127.0.0.1:0 --max-sessions 8").serve_config().unwrap();
        assert_eq!(c.addr.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(c.max_sessions, 8);
        assert_eq!(c.max_frame_mb, 16, "default frame cap");
        assert!(parse("serve --addr x --uds y").serve_config().is_err(), "exclusive endpoints");
        assert!(parse("serve --max-sessions 0").serve_config().is_err());
        assert!(
            parse("serve --addr --max-sessions 8").serve_config().is_err(),
            "missing --addr value is the parsing regression, served form"
        );
    }

    #[test]
    fn pool_flags_parse_and_default_off() {
        let a = parse("train --pool-workers 4 --overlap");
        let c = a.train_config().unwrap();
        assert_eq!(c.pool_workers, 4);
        assert!(c.overlap);
        let d = parse("train").train_config().unwrap();
        assert_eq!(d.pool_workers, 0, "pool off by default (scoped-thread fan-out)");
        assert!(!d.overlap, "overlap off by default");
    }

    #[test]
    fn merge_default_is_method_aware() {
        let g = parse("train").train_config().unwrap();
        assert_eq!(g.merge, MergePolicy::Grad, "GRAFT defaults to the gradient-aware merge");
        let m = parse("train --method maxvol").train_config().unwrap();
        assert_eq!(m.merge, MergePolicy::Hierarchical, "non-GRAFT keeps the feature-only merge");
        let h = parse("train --merge hierarchical").train_config().unwrap();
        assert_eq!(h.merge, MergePolicy::Hierarchical, "explicit flag opts GRAFT back out");
        let gm = parse("train --method maxvol --merge grad").train_config().unwrap();
        assert_eq!(gm.merge, MergePolicy::Grad, "explicit grad works for any method");
        assert!(parse("train --merge nope").train_config().is_err());
    }

    #[test]
    fn stream_chunk_parses_and_defaults_to_batch_mode() {
        let c = parse("train --stream-chunk 64").train_config().unwrap();
        assert_eq!(c.stream_chunk, 64);
        assert_eq!(parse("train").train_config().unwrap().stream_chunk, 0, "batch by default");
        assert!(parse("train --stream-chunk nope").train_config().is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("train --epochs abc");
        assert!(a.train_config().is_err());
    }

    #[test]
    fn config_file_merge() {
        let dir = std::env::temp_dir().join("graft_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.conf");
        std::fs::write(&path, "dataset = imdb\nepochs = 11 # comment\n").unwrap();
        let a = parse(&format!("train --config {} --epochs 3", path.display()));
        let c = a.train_config().unwrap();
        assert_eq!(c.dataset, "imdb"); // from file
        assert_eq!(c.epochs, 3); // CLI wins
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(["train".to_string(), "oops".to_string()]).is_err());
    }

    #[test]
    fn config_file_booleans_reach_flag_state() {
        // Regression: `adaptive-rank = true` / `overlap = true` in a
        // config file used to land in `opts` and be silently ignored by
        // `flag()` — the run quietly trained without the requested
        // behaviour.
        let dir = std::env::temp_dir().join("graft_cfg_bool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.conf");
        std::fs::write(&path, "adaptive-rank = true\noverlap = true\npool-workers = 2\n")
            .unwrap();
        let a = parse(&format!("train --config {}", path.display()));
        let c = a.train_config().unwrap();
        assert!(c.adaptive_rank, "file 'adaptive-rank = true' must be honoured");
        assert!(c.overlap, "file 'overlap = true' must be honoured");
        assert_eq!(c.pool_workers, 2);

        // Falsy spellings stay off; CLI flags still win over file values.
        std::fs::write(&path, "adaptive-rank = false\noverlap = 0\n").unwrap();
        let a = parse(&format!("train --config {}", path.display()));
        let c = a.train_config().unwrap();
        assert!(!c.adaptive_rank, "falsy file value stays off");
        assert!(!c.overlap, "falsy file value stays off");
        let a = parse(&format!("train --overlap --config {}", path.display()));
        assert!(a.train_config().unwrap().overlap, "bare CLI flag wins over falsy file value");

        // The inline CLI spelling `--overlap true` now also works, and
        // matching is case-insensitive (TOML/Python habits: `True`, `On`).
        assert!(parse("train --overlap true").train_config().unwrap().overlap);
        assert!(!parse("train --overlap false").train_config().unwrap().overlap);
        std::fs::write(&path, "adaptive-rank = True\noverlap = On\n").unwrap();
        let c = parse(&format!("train --config {}", path.display())).train_config().unwrap();
        assert!(c.adaptive_rank && c.overlap, "capitalized spellings are honoured");

        // An unrecognized spelling is an ERROR naming the key, never a
        // silent off — the failure class this satellite fixed.
        std::fs::write(&path, "overlap = bananas\n").unwrap();
        let err = parse(&format!("train --config {}", path.display()))
            .train_config()
            .err()
            .expect("garbage boolean must be rejected");
        assert!(format!("{err:#}").contains("overlap"), "{err:#}");
    }
}

//! Table harnesses: Table 2 (BERT/IMDB), Table 3 (feature extractors),
//! Table 4 (FastMaxVol vs CrossMaxVol on Iris), Table 5 (channel pruning).

use std::time::Instant;

use anyhow::Result;

use crate::config::Args;
use crate::data::iris::iris;
use crate::engine::EngineBuilder;
use crate::eval::report::{save_result, Table};
use crate::features::{AutoencoderFeatures, FeatureExtractor, IcaFeatures, SvdFeatures};
use crate::linalg::{lstsq, subspace_similarity_normalised, Mat};
use crate::pruning;
use crate::rng::Rng;
use crate::runtime::{default_dir, Engine, TrainState};
use crate::selection::cross_maxvol::CrossMaxVol;
use crate::selection::BatchView;
use crate::train::{self, TrainConfig};

/// Table 2: BERT on IMDB — Full vs GRAFT vs GRAFT-Warm at 10% / 35%.
pub fn table2(args: &Args) -> Result<()> {
    let mut engine = Engine::new(default_dir())?;
    let epochs = args.usize_or("epochs", 30)?;
    let mut t = Table::new(
        "Table 2 — CO2 Emissions (kg) and Accuracy (%) for (synthetic) BERT/IMDB",
        &["Method", "Emiss (kg)", "Top-1 Acc (%)"],
    );
    let mut csv = vec!["method,fraction,co2_kg,acc".to_string()];
    let runs: &[(&str, &str, f64)] = &[
        ("Full (Baseline)", "full", 1.0),
        ("GRAFT (10%)", "graft", 0.10),
        ("GRAFT Warm (10%)", "graft-warm", 0.10),
        ("GRAFT (35%)", "graft", 0.35),
        ("GRAFT Warm (35%)", "graft-warm", 0.35),
    ];
    for &(label, method, fraction) in runs {
        let cfg = TrainConfig {
            dataset: "imdb".into(),
            method: method.into(),
            fraction,
            epochs,
            refresh_epochs: 10, // paper: selection every 10 epochs
            lr0: 0.05,          // constant-ish fine-tuning regime
            warm_epochs: 3,
            seed: args.u64_or("seed", 42)?,
            ..TrainConfig::default()
        };
        let res = train::run(&mut engine, &cfg)?.result;
        eprintln!("  {}", res.summary_row());
        t.row(vec![
            label.to_string(),
            format!("{:.2e}", res.co2_kg),
            format!("{:.2}", res.final_acc * 100.0),
        ]);
        csv.push(format!("{method},{fraction},{:.6},{:.4}", res.co2_kg, res.final_acc));
    }
    let rendered = t.render();
    println!("{rendered}");
    save_result("table2_imdb.csv", &(csv.join("\n") + "\n"))?;
    save_result("table2_imdb.txt", &rendered)?;
    Ok(())
}

/// Table 3: feature-extractor accuracy (logistic probe) and time/batch.
pub fn table3(args: &Args) -> Result<()> {
    let trials = args.usize_or("trials", 5)?;
    let ds = train::load_dataset("cifar10")?;
    let k = 400; // probe batch (paper: 200; doubled to cut probe variance)
    let r = 64;
    // Probe on the TOP-16 ordered features: a linear probe over the full
    // feature set is invariant to the (invertible) rotation between SVD
    // and ICA spans; the paper's differences come from how well each
    // extractor *orders* relevance, which the truncated probe measures.
    let probe_cols: Vec<usize> = (0..16).collect();
    let extractors: Vec<Box<dyn FeatureExtractor>> = vec![
        Box::new(SvdFeatures),
        Box::new(AutoencoderFeatures::default()),
        Box::new(IcaFeatures::default()),
    ];
    let mut t = Table::new(
        "Table 3 — Feature extraction performance (mean ± std)",
        &["Method", "Acc (%)", "Time (s/batch)"],
    );
    let mut csv = vec!["method,trial,acc,time_s".to_string()];
    for e in &extractors {
        let mut accs = Vec::new();
        let mut times = Vec::new();
        for trial in 0..trials {
            let mut rng = Rng::new(42 + trial as u64);
            // Probe protocol: extract features on a batch, fit a linear
            // probe on 80%, test on 20% (the paper's logistic-probe proxy).
            let rows = rng.choose(ds.n, k);
            let batch = Mat::from_fn(k, ds.d, |i, j| ds.row(rows[i])[j] as f64);
            let t0 = Instant::now();
            let feats = e.extract(&batch, r).take_cols(&probe_cols);
            let dt = t0.elapsed().as_secs_f64();
            let ntr = (k as f64 * 0.8) as usize;
            // One-vs-rest least-squares probe.
            let ftr = feats.take_rows(&(0..ntr).collect::<Vec<_>>());
            let mut correct = 0usize;
            let mut scores = vec![vec![0.0f64; k - ntr]; ds.classes];
            for cls in 0..ds.classes {
                let targets: Vec<f64> = (0..ntr)
                    .map(|i| if ds.y[rows[i]] as usize == cls { 1.0 } else { -1.0 })
                    .collect();
                let w = lstsq(&ftr, &targets);
                for i in ntr..k {
                    scores[cls][i - ntr] = crate::linalg::dot(feats.row(i), &w);
                }
            }
            for i in 0..(k - ntr) {
                let pred = (0..ds.classes)
                    .max_by(|&a, &b| scores[a][i].total_cmp(&scores[b][i]))
                    .unwrap();
                if pred == ds.y[rows[ntr + i]] as usize {
                    correct += 1;
                }
            }
            let acc = correct as f64 / (k - ntr) as f64;
            accs.push(acc);
            times.push(dt);
            csv.push(format!("{},{},{:.4},{:.5}", e.name(), trial, acc, dt));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let std = |v: &[f64]| {
            let m = mean(v);
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
        };
        t.row(vec![
            e.name().to_uppercase(),
            format!("{:.2} ± {:.2}", mean(&accs) * 100.0, std(&accs) * 100.0),
            format!("{:.4} ± {:.4}", mean(&times), std(&times)),
        ]);
    }
    let rendered = t.render();
    println!("{rendered}");
    save_result("table3_features.csv", &(csv.join("\n") + "\n"))?;
    save_result("table3_features.txt", &rendered)?;
    Ok(())
}

/// Table 4: FastMaxVol vs CrossMaxVol on Iris — subspace similarity to the
/// optimal (SVD) subspace + wall-clock per selection.
pub fn table4(args: &Args) -> Result<()> {
    let reps = args.usize_or("reps", 200)?;
    let ds = iris();
    // r = 3: with r = d = 4 ANY independent selection spans all of R⁴ and
    // every method scores similarity 1.0 — the paper's 0.625-vs-0.594 gap
    // only exists on a proper subspace.
    let r = 3;
    let x = Mat::from_fn(ds.n, ds.d, |i, j| ds.row(i)[j] as f64);
    // Ordered feature matrix (SVD features — paper's extractor).
    let feats = SvdFeatures.extract(&x, r);
    // Fast MaxVol through the engine facade, like every other selection
    // caller: typed EngineError on a bad config instead of hand-wiring
    // the selector.
    let mut eng = EngineBuilder::new().method("maxvol").budget(r).build()?;
    let grads = Mat::zeros(ds.n, 1);
    let losses = vec![0.0; ds.n];
    let labels: Vec<i32> = ds.y.clone();
    let preds = vec![0i32; ds.n];
    let row_ids: Vec<usize> = (0..ds.n).collect();
    let view = BatchView {
        features: &feats,
        grads: &grads,
        losses: &losses,
        labels: &labels,
        preds: &preds,
        classes: ds.classes,
        row_ids: &row_ids,
    };
    let t0 = Instant::now();
    let mut p_fast = Vec::new();
    for _ in 0..reps {
        p_fast = eng.select(&view)?.indices.to_vec();
    }
    let fast_time = t0.elapsed().as_secs_f64() / reps as f64;
    // CrossMaxVol over the raw matrix (as teneva operates on X itself).
    // Deliberately NOT behind the engine: select_rows returns a
    // (rows, cols) cross skeleton of X, and column selection has no
    // engine-facade expression.
    let cm = CrossMaxVol::default();
    let t0 = Instant::now();
    let mut p_cross = Vec::new();
    for _ in 0..reps {
        (p_cross, _) = cm.select_rows(&x, r);
    }
    let cross_time = t0.elapsed().as_secs_f64() / reps as f64;

    let sim = |rows: &[usize]| {
        let sel = x.take_rows(rows).transpose(); // d×r: span of selected samples
        let opt = {
            let d = crate::linalg::svd(&x);
            let idx: Vec<usize> = (0..r).collect();
            d.v.take_cols(&idx)
        };
        subspace_similarity_normalised(&sel, &opt)
    };
    let (s_fast, s_cross) = (sim(&p_fast), sim(&p_cross));

    let mut t = Table::new(
        "Table 4 — Similarity & Speed (Iris)",
        &["Method", "Similarity", "Time (s)"],
    );
    t.row(vec!["Fast MaxVol".into(), format!("{s_fast:.4}"), format!("{fast_time:.6}")]);
    t.row(vec!["CrossMaxVol".into(), format!("{s_cross:.4}"), format!("{cross_time:.6}")]);
    let rendered = t.render();
    println!("{rendered}");
    println!("speedup: {:.1}x (paper: 84.6x)", cross_time / fast_time.max(1e-12));
    save_result(
        "table4_maxvol.csv",
        &format!(
            "method,similarity,time_s\nfast_maxvol,{s_fast:.6},{fast_time:.8}\ncross_maxvol,{s_cross:.6},{cross_time:.8}\n"
        ),
    )?;
    save_result("table4_maxvol.txt", &rendered)?;
    Ok(())
}

/// Table 5: Fast MaxVol channel pruning — params, accuracy, GFLOPs,
/// inference time, before vs after pruning 50% of hidden channels.
pub fn table5(args: &Args) -> Result<()> {
    let mut engine = Engine::new(default_dir())?;
    let dataset = args.get_or("dataset", "cifar10")?;
    let epochs = args.usize_or("epochs", 20)?;
    // 1. Train a full model.
    let cfg = TrainConfig {
        dataset: dataset.clone(),
        method: "full".into(),
        epochs,
        ..TrainConfig::default()
    };
    let spec = engine.spec(&dataset)?.clone();
    engine.warmup(&dataset)?;
    let ds = train::load_dataset(&dataset)?;
    let (trainset, test) = ds.split(0.8, cfg.seed ^ 0x5917);
    // Train directly (rather than via train::run) so we keep the final
    // parameter state for pruning.
    let mut state = TrainState::init(&spec, cfg.seed);
    {
        let mut b = crate::data::loader::Batcher::new(&trainset, spec.k, cfg.seed ^ 0x3A31);
        let steps = epochs * (trainset.n / spec.k);
        let sched = crate::train::Schedule::Cosine { lr0: cfg.lr0, lr_min: cfg.lr0 / 100.0, total_steps: steps };
        for s in 0..steps {
            let rows: Vec<usize> = b.next_batch().to_vec();
            let (x, y) = (trainset.gather(&rows), trainset.one_hot(&rows));
            let w = vec![1.0 / spec.k as f32; spec.k];
            engine.train_step(&dataset, spec.k, &mut state, &x, &y, &w, sched.at(s) as f32, 0.9)?;
        }
    }

    // 2. Collect hidden activations on a probe batch (via CPU forward,
    //    identical math to the artifact) and prune 50% of channels.
    let probe_rows: Vec<usize> = (0..spec.k.min(trainset.n)).collect();
    let xprobe = trainset.gather(&probe_rows);
    let acts = hidden_activations(&state.params, spec.d, spec.h, &xprobe);
    let keep = spec.h / 2;
    let kept = pruning::select_channels(&acts, keep);
    let pruned = pruning::prune_params(&state.params, &spec, &kept);

    // 3. Accuracy + timing before/after on the test split (CPU inference).
    let xt = test.gather(&(0..test.n).collect::<Vec<_>>());
    let yt: Vec<usize> = test.y.iter().map(|&y| y as usize).collect();
    let time_and_acc = |p: &crate::runtime::ModelParams| {
        let t0 = Instant::now();
        let preds = pruning::forward_pruned(p, spec.d, &xt);
        let dt = t0.elapsed().as_secs_f64();
        let acc = preds.iter().zip(&yt).filter(|(a, b)| a == b).count() as f64 / yt.len() as f64;
        (acc, dt)
    };
    let (acc_base, t_base) = time_and_acc(&state.params);
    let (acc_pruned, t_pruned) = time_and_acc(&pruned.params);

    let mut t = Table::new(
        "Table 5 — Fast MaxVol channel pruning (50% channels)",
        &["Method", "Params (M)", "Accuracy (%)", "MFLOPs/sample", "Inference Time (s)"],
    );
    t.row(vec![
        "Baseline".into(),
        format!("{:.4}", pruned.params_before as f64 / 1e6),
        format!("{:.2}", acc_base * 100.0),
        format!("{:.4}", pruned.flops_before / 1e6),
        format!("{t_base:.4}"),
    ]);
    t.row(vec![
        "Fast MaxVol".into(),
        format!("{:.4}", pruned.params_after as f64 / 1e6),
        format!("{:.2}", acc_pruned * 100.0),
        format!("{:.4}", pruned.flops_after / 1e6),
        format!("{t_pruned:.4}"),
    ]);
    let rendered = t.render();
    println!("{rendered}");
    save_result(
        "table5_pruning.csv",
        &format!(
            "method,params,acc,flops_per_sample,time_s\nbaseline,{},{:.4},{},{:.5}\nfast_maxvol,{},{:.4},{},{:.5}\n",
            pruned.params_before, acc_base, pruned.flops_before, t_base,
            pruned.params_after, acc_pruned, pruned.flops_after, t_pruned
        ),
    )?;
    save_result("table5_pruning.txt", &rendered)?;
    Ok(())
}

/// CPU hidden-layer activations (K×H) for channel pruning.
pub fn hidden_activations(p: &crate::runtime::ModelParams, d: usize, h: usize, x: &[f32]) -> Mat {
    let n = x.len() / d;
    let mut out = Mat::zeros(n, h);
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        for j in 0..h {
            let mut a = p.b1[j] as f64;
            for (t, &xv) in row.iter().enumerate() {
                a += xv as f64 * p.w1[t * h + j] as f64;
            }
            out[(i, j)] = a.max(0.0);
        }
    }
    out
}

//! `graft serve` — the selection-as-a-service daemon — and
//! `graft serve-smoke`, a self-contained multi-tenant client that proves
//! served selections are **bit-identical** to in-process engines built
//! through the same [`serve::engine_builder`](crate::serve::engine_builder)
//! mapping.  The smoke driver is what CI's `serve-smoke` job runs; its
//! `--stats-out` JSON feeds `scripts/validate_bench.py --strict`.

use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{Args, ServeConfig};
use crate::coordinator::SelectWindow;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::serve::protocol::TenantConfig;
use crate::serve::{engine_builder, Client, ServeOptions, Server, ServerBuilder};

/// Default TCP listen address when neither `--addr` nor `--uds` is given.
pub const DEFAULT_ADDR: &str = "127.0.0.1:4714";

fn serve_options(cfg: &ServeConfig) -> ServeOptions {
    ServeOptions {
        max_sessions: cfg.max_sessions,
        max_frame: cfg.max_frame_mb << 20,
        read_tick: Duration::from_millis(cfg.read_tick_ms),
        stall_ticks: cfg.stall_ticks as u32,
    }
}

fn bind(cfg: &ServeConfig) -> Result<Server> {
    let builder = ServerBuilder::new().options(serve_options(cfg));
    if let Some(uds) = &cfg.uds {
        #[cfg(unix)]
        {
            return builder.bind_unix(uds).with_context(|| format!("binding unix socket {uds}"));
        }
        #[cfg(not(unix))]
        {
            let _ = builder;
            bail!("--uds {uds} requested but this platform has no unix sockets");
        }
    }
    let addr = cfg.addr.as_deref().unwrap_or(DEFAULT_ADDR);
    builder.bind_tcp(addr).with_context(|| format!("binding tcp address {addr}"))
}

/// Where a freshly-bound server is actually reachable (TCP resolves the
/// OS-assigned port when `--addr` used port 0).
fn bound_endpoint(cfg: &ServeConfig, server: &Server) -> String {
    match server.local_addr() {
        Some(a) => a.to_string(),
        None => cfg.uds.clone().unwrap_or_else(|| DEFAULT_ADDR.to_string()),
    }
}

/// `graft serve`: bind, publish the address, then hold the process open
/// until killed.  All real work happens on the server's session threads.
pub fn serve(args: &Args) -> Result<()> {
    let cfg = args.serve_config()?;
    let server = bind(&cfg)?;
    let bound = bound_endpoint(&cfg, &server);
    if let Some(path) = &cfg.addr_file {
        // The newline-terminated write doubles as the readiness signal for
        // scripts polling the file (scripts/serve_smoke.sh).
        std::fs::write(path, format!("{bound}\n"))
            .with_context(|| format!("writing --addr-file {path}"))?;
    }
    println!(
        "graft serve: listening on {bound} (max {} sessions, {} MiB frames)",
        cfg.max_sessions, cfg.max_frame_mb
    );
    loop {
        thread::sleep(Duration::from_secs(3600));
    }
}

// ---------------------------------------------------------------------------
// serve-smoke: the bit-identity loopback driver
// ---------------------------------------------------------------------------

/// Mixed tenant profiles cycled across the fleet: serial-strict batch,
/// pooled-adaptive batch, streaming, and sharded FastMaxVol.  Seeds vary
/// per tenant so no two engines share an RNG stream.
fn tenant_profile(i: usize) -> TenantConfig {
    let seed = 0x5EED + 31 * i as u64;
    let base = TenantConfig { seed, budget: 8, ..TenantConfig::default() };
    match i % 4 {
        0 => base,
        1 => TenantConfig { adaptive: true, shards: 2, workers: 2, ..base },
        2 => TenantConfig { streaming: true, budget: 6, ..base },
        _ => TenantConfig { method: "maxvol".to_string(), shards: 2, ..base },
    }
}

/// Deterministic synthetic refresh window.  `base_id` offsets the global
/// row ids so a streaming tenant's windows never collide.
fn make_window(k: usize, seed: u64, base_id: usize) -> SelectWindow {
    const RC: usize = 6;
    const EC: usize = 8;
    const CLASSES: usize = 4;
    let mut rng = Rng::new(seed);
    let features = Mat::from_fn(k, RC, |_, _| rng.normal());
    let grads = Mat::from_fn(k, EC, |_, _| rng.normal());
    let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
    let labels: Vec<i32> = (0..k).map(|i| (i % CLASSES) as i32).collect();
    SelectWindow {
        features,
        grads,
        losses,
        preds: labels.clone(),
        labels,
        classes: CLASSES,
        row_ids: (base_id..base_id + k).collect(),
    }
}

fn tenant_windows(tenant: usize, windows: usize, rows: usize) -> Vec<SelectWindow> {
    (0..windows)
        .map(|w| make_window(rows, 0xA11CE ^ ((tenant as u64) << 20) ^ w as u64, w * rows))
        .collect()
}

/// Drive one tenant through the served path and return its per-window
/// selections (batch-local indices for batch tenants, global row ids for
/// streaming snapshots — matching what the in-process engines report).
fn drive_served(
    addr: &str,
    name: &str,
    cfg: &TenantConfig,
    windows: &[SelectWindow],
) -> Result<Vec<Vec<u64>>> {
    let mut client = Client::connect_tcp(addr)?;
    client.hello(name, cfg)?;
    let mut out = Vec::with_capacity(windows.len());
    for win in windows {
        if cfg.streaming {
            client.push_chunk(&win.view())?;
            out.push(client.snapshot()?.indices);
        } else {
            out.push(client.select(&win.view())?.indices);
        }
    }
    let drained = client.drain()?;
    let rows: u64 = windows.iter().map(|w| w.row_ids.len() as u64).sum();
    if drained.rows != rows {
        bail!("tenant {name}: drain reports {} rows ingested, sent {rows}", drained.rows);
    }
    client.bye()?;
    Ok(out)
}

/// The in-process reference: the same config through the same
/// [`engine_builder`] mapping, so any divergence is the transport's fault.
fn drive_reference(cfg: &TenantConfig, windows: &[SelectWindow]) -> Result<Vec<Vec<u64>>> {
    let mut out = Vec::with_capacity(windows.len());
    if cfg.streaming {
        let mut eng = engine_builder(cfg).build_streaming().map_err(|e| anyhow::anyhow!("{e}"))?;
        for win in windows {
            eng.push(&win.view()).map_err(|e| anyhow::anyhow!("{e}"))?;
            let snap = eng.snapshot().map_err(|e| anyhow::anyhow!("{e}"))?;
            out.push(snap.indices.iter().map(|&i| i as u64).collect());
        }
    } else {
        let mut eng = engine_builder(cfg).build().map_err(|e| anyhow::anyhow!("{e}"))?;
        for win in windows {
            let sel = eng.select(&win.view()).map_err(|e| anyhow::anyhow!("{e}"))?;
            out.push(sel.indices.iter().map(|&i| i as u64).collect());
        }
    }
    Ok(out)
}

/// `graft serve-smoke`: spin up (or dial) a daemon, run K mixed tenants
/// concurrently, and fail unless every served selection is bit-identical
/// to its in-process reference.
pub fn smoke(args: &Args) -> Result<()> {
    let tenants = args.usize_or("tenants", 4)?.max(3);
    let windows = args.usize_or("windows", 3)?.max(1);
    let rows = args.usize_or("rows", 48)?.max(16);
    let stats_out = args.value_of("stats-out")?;

    // Self-host on an OS-assigned port unless pointed at a live daemon.
    let external = args.value_of("addr")?;
    let mut hosted: Option<Server> = None;
    let addr = match &external {
        Some(a) => a.clone(),
        None => {
            let server = ServerBuilder::new().bind_tcp("127.0.0.1:0")?;
            let addr = server.local_addr().context("self-hosted server has no local addr")?;
            hosted = Some(server);
            addr.to_string()
        }
    };

    // All tenants run concurrently so the smoke exercises interleaved
    // sessions, not just the protocol.
    let mut handles = Vec::new();
    for i in 0..tenants {
        let addr = addr.clone();
        let cfg = tenant_profile(i);
        let wins = tenant_windows(i, windows, rows);
        handles.push((
            i,
            cfg.clone(),
            wins.clone(),
            thread::spawn(move || drive_served(&addr, &format!("smoke-{i}"), &cfg, &wins)),
        ));
    }

    let mut checked = 0usize;
    for (i, cfg, wins, handle) in handles {
        let served = match handle.join() {
            Ok(r) => r.map_err(|e| e.context(format!("tenant smoke-{i} (served path)")))?,
            Err(_) => bail!("tenant smoke-{i}: client thread panicked"),
        };
        let reference = drive_reference(&cfg, &wins)
            .map_err(|e| e.context(format!("tenant smoke-{i} (reference)")))?;
        if served != reference {
            bail!(
                "tenant smoke-{i} diverged: served {:?} != in-process {:?}",
                served, reference
            );
        }
        checked += served.len();
    }

    // Pull the daemon's telemetry through the same wire path clients use;
    // the file lands in graft-bench-v1 shape for validate_bench.py.
    let stats = {
        let mut monitor = Client::connect_tcp(&addr)?;
        let json = monitor.stats()?;
        monitor.bye()?;
        json
    };
    if !stats.contains("graft-serve") {
        bail!("stats reply is missing graft-serve records: {stats}");
    }
    if let Some(path) = &stats_out {
        std::fs::write(path, &stats).with_context(|| format!("writing --stats-out {path}"))?;
        println!("stats -> {path}");
    }

    if let Some(mut server) = hosted.take() {
        server.shutdown();
    }
    println!(
        "serve-smoke OK: {tenants} tenants x {windows} windows ({checked} selections) \
         bit-identical through {addr}"
    );
    Ok(())
}

//! CLI subcommand implementations — one per paper table/figure family
//! (see DESIGN.md §4 for the experiment index).

pub mod figures;
pub mod scenarios;
pub mod serve;
pub mod sweep;
pub mod tables;

use anyhow::Result;

use crate::config::Args;
use crate::eval::report::save_result;
use crate::runtime::{default_dir, Engine};
use crate::train;

pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "info" => info(),
        "train" => train_cmd(args),
        "sweep" => sweep::run(args),
        "scenarios" => scenarios::run(args),
        "serve" => serve::serve(args),
        "serve-smoke" => serve::smoke(args),
        "fig2" => figures::fig2(args),
        "fig3" => figures::fig3(args),
        "fig4" => figures::fig4(args),
        "fig5" => figures::fig5(args),
        "table2" => tables::table2(args),
        "table3" => tables::table3(args),
        "table4" => tables::table4(args),
        "table5" => tables::table5(args),
        "help" | _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "graft — GRAFT reproduction CLI (see DESIGN.md for the experiment map)

USAGE: graft <command> [--key value …]

COMMANDS
  info                      list artifact configs
  train                     one training run
                            --dataset D --method M --fraction F --epochs N
                            [--adaptive-rank] [--epsilon E] [--seed S]
                            [--shards N] [--merge hierarchical|flat|grad]
                            (grad = gradient-aware merge, default for graft)
                            [--pool-workers N] [--overlap]
                            [--stream-chunk N] (stream refresh windows
                            through the bounded-memory reservoir, N rows
                            at a time; 0 = batch selection)
  sweep                     Tables 8-14 grid: methods × fractions
                            --dataset D [--methods a,b,…] [--fractions …]
  scenarios                 offline scenario matrix: every selector ×
                            (imbalance, label-noise, shift, curriculum) ×
                            exec shapes × budget fractions, as
                            graft-scenario-v1 JSON rows
                            [--smoke] [--seed S] [--data-seed S]
                            [--fractions 0.1,0.25,…] [--shards N]
                            [--axes label_noise=0.2,shift=0.5,…]
                            [--out PATH]
  serve                     selection-as-a-service daemon (see src/serve/)
                            [--addr H:P | --uds PATH] [--addr-file PATH]
                            [--max-sessions N] [--max-frame-mb N]
                            [--read-tick-ms MS] [--stall-ticks N]
  serve-smoke               multi-tenant loopback check: served selections
                            must be bit-identical to in-process engines
                            [--addr H:P] [--tenants K] [--windows W]
                            [--rows N] [--stats-out PATH]
  fig2                      alignment heatmap / rank trend / class hist
  fig3                      exponential gain fits from sweep CSVs
  fig4                      extractor ablation + maxvol convergence
  fig5                      loss-landscape scan (full vs GRAFT)
  table2                    BERT/IMDB warm-vs-cold scenario
  table3                    feature-extraction accuracy/time ablation
  table4                    FastMaxVol vs CrossMaxVol on Iris
  table5                    Fast MaxVol channel pruning

Results land in ./results as CSV + ASCII tables."
    );
}

fn info() -> Result<()> {
    let engine = Engine::new(default_dir())?;
    println!("artifacts: {}", engine.manifest().dir.display());
    for (name, spec) in &engine.manifest().configs {
        println!(
            "  {name:<14} d={:<4} c={:<4} h={:<4} k={:<4} rmax={:<3} e={:<4} buckets={:?}",
            spec.d, spec.c, spec.h, spec.k, spec.rmax, spec.e, spec.buckets
        );
    }
    Ok(())
}

fn train_cmd(args: &Args) -> Result<()> {
    let cfg = args.train_config()?;
    let mut engine = Engine::new(default_dir())?;
    let out = train::run(&mut engine, &cfg)?;
    let (result, align) = (out.result, out.alignment);
    println!("{}", result.summary_row());
    let (mu, sigma) = align.mean_std();
    if !align.samples.is_empty() {
        println!(
            "alignment: mu={mu:.3} sigma={sigma:.3} frac(cos>0.5)={:.2} corr(align,rank)={:.3} mean_rank={:.1}",
            align.frac_above(0.5),
            align.align_rank_correlation(),
            result.mean_rank,
        );
    }
    let tag = format!("train_{}_{}_f{:.2}", result.dataset, result.method, result.fraction);
    let path = save_result(&format!("{tag}.curve.csv"), &result.curve_csv())?;
    println!("curve -> {}", path.display());
    Ok(())
}

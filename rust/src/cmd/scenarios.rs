//! `graft scenarios` — run the offline scenario matrix and write the
//! `graft-scenario-v1` document.  See `rust/src/scenarios/README.md` for
//! the matrix layout and schema.
//!
//! The run is a pure function of its flags: `--smoke --seed 42` twice
//! produces byte-identical files, which is exactly what the CI
//! `scenario-smoke` job asserts with `diff`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::Args;
use crate::scenarios::{run_matrix, Axis, MatrixConfig, ScenarioSink};

/// Whether the bench-style smoke switch is on (`GRAFT_BENCH_SMOKE` set to
/// anything but `0`) — the same convention the bench harness uses, so one
/// environment variable shrinks both.
fn smoke_env() -> bool {
    std::env::var("GRAFT_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

pub fn run(args: &Args) -> Result<()> {
    let smoke = args.try_flag("smoke")? || smoke_env();
    let mut cfg = if smoke { MatrixConfig::smoke() } else { MatrixConfig::full() };
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.gen.seed = args.u64_or("data-seed", cfg.gen.seed)?;
    cfg.shards = args.usize_or("shards", cfg.shards)?.max(1);
    let fractions = args.list_or("fractions", &[])?;
    if !fractions.is_empty() {
        cfg.fractions = fractions
            .iter()
            .map(|s| s.parse::<f64>().with_context(|| format!("--fractions entry '{s}'")))
            .collect::<Result<Vec<f64>>>()?;
    }
    if let Some(axes) = args.value_of("axes")? {
        cfg.axes = parse_axes(&axes)?;
    }

    let rows = run_matrix(&cfg)?;
    let mut sink = ScenarioSink::new();
    for row in rows {
        sink.record(row);
    }
    let out = args.get_or("out", "results/scenarios.json")?;
    let path = sink
        .write(Path::new(&out))
        .with_context(|| format!("writing scenario rows to {out}"))?;
    println!(
        "scenarios: {} rows ({} axes x {} methods x {} fractions) -> {}",
        sink.len(),
        cfg.axes.len(),
        crate::scenarios::roster().len(),
        cfg.fractions.len(),
        path.display()
    );
    Ok(())
}

/// Parse `--axes baseline,label_noise=0.2,shift=0.5` into [`Axis`] values.
fn parse_axes(spec: &str) -> Result<Vec<Axis>> {
    let mut axes = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (name, value) = match part.split_once('=') {
            Some((n, v)) => {
                let v: f64 = v
                    .parse()
                    .with_context(|| format!("--axes entry '{part}': severity must be a number"))?;
                (n.trim(), v)
            }
            None => (part, 0.5),
        };
        axes.push(match name {
            "baseline" => Axis::Baseline,
            "imbalance" => Axis::Imbalance(value),
            "label_noise" | "label-noise" => Axis::LabelNoise(value),
            "shift" => Axis::Shift(value),
            "curriculum" => Axis::Curriculum(value),
            other => anyhow::bail!(
                "--axes entry '{other}' (want baseline|imbalance|label_noise|shift|curriculum, \
                 optionally '=SEVERITY')"
            ),
        });
    }
    anyhow::ensure!(!axes.is_empty(), "--axes parsed to an empty list");
    Ok(axes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axes_parse_names_and_severities() {
        let axes =
            parse_axes("baseline, label_noise=0.2,shift=0.75,imbalance,curriculum=1").unwrap();
        assert_eq!(
            axes,
            vec![
                Axis::Baseline,
                Axis::LabelNoise(0.2),
                Axis::Shift(0.75),
                Axis::Imbalance(0.5),
                Axis::Curriculum(1.0),
            ]
        );
    }

    #[test]
    fn bad_axes_are_typed_errors() {
        assert!(parse_axes("bananas").is_err());
        assert!(parse_axes("shift=xyz").is_err());
        assert!(parse_axes(" , ").is_err());
    }
}

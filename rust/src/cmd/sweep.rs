//! `graft sweep` — regenerates the Tables 8-14 family: a methods ×
//! fractions grid on one dataset, reporting CO₂ (kg) and accuracy per
//! cell exactly in the paper's layout.

use anyhow::Result;

use crate::config::Args;
use crate::eval::report::{save_result, Table};
use crate::runtime::{default_dir, Engine};
use crate::train::{self, TrainConfig};

pub const DEFAULT_METHODS: &[&str] =
    &["full", "graft", "graft-warm", "glister", "craig", "gradmatch", "drop"];
pub const DEFAULT_FRACTIONS: &[&str] = &["0.05", "0.15", "0.25", "0.35"];

pub fn run(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "cifar10")?;
    let methods = args.list_or("methods", DEFAULT_METHODS)?;
    let fractions: Vec<f64> = args
        .list_or("fractions", DEFAULT_FRACTIONS)?
        .iter()
        .map(|s| s.parse::<f64>().map_err(Into::into))
        .collect::<Result<_>>()?;
    let base = args.train_config()?;
    let mut engine = Engine::new(default_dir())?;

    let mut headers: Vec<String> = vec!["Method".into()];
    for f in &fractions {
        headers.push(format!("CO2@{f:.2}"));
        headers.push(format!("Acc@{f:.2}"));
    }
    let mut table = Table::new(
        &format!("{dataset}: Training Methods Comparison (paper Tables 8-14)"),
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut csv_rows = vec!["method,fraction,co2_kg,acc,energy_kwh,steps,wall_secs,mean_rank".to_string()];
    for method in &methods {
        let mut cells = vec![method.clone()];
        for &fraction in &fractions {
            let cfg = TrainConfig {
                dataset: dataset.clone(),
                method: method.clone(),
                fraction,
                ..base.clone()
            };
            let res = train::run(&mut engine, &cfg)?.result;
            eprintln!("  {}", res.summary_row());
            cells.push(format!("{:.2e}", res.co2_kg));
            cells.push(format!("{:.2}", res.final_acc * 100.0));
            csv_rows.push(format!(
                "{},{},{:.6},{:.4},{:.6},{},{:.2},{:.1}",
                method, fraction, res.co2_kg, res.final_acc, res.energy_kwh,
                res.steps, res.wall_secs, res.mean_rank
            ));
            // Full training is fraction-independent; reuse the first cell.
            if method == "full" {
                for _ in 1..fractions.len() {
                    cells.push(cells[1].clone());
                    cells.push(cells[2].clone());
                }
                break;
            }
        }
        table.row(cells);
    }

    let rendered = table.render();
    println!("{rendered}");
    let csv = csv_rows.join("\n") + "\n";
    // --tag distinguishes variant sweeps (e.g. Table 14's random comparison)
    // so they don't clobber the main per-dataset results.
    let tag = args.value_of("tag")?.map(|t| format!("_{t}")).unwrap_or_default();
    let p1 = save_result(&format!("sweep_{dataset}{tag}.csv"), &csv)?;
    let p2 = save_result(&format!("sweep_{dataset}{tag}.txt"), &rendered)?;
    println!("wrote {} and {}", p1.display(), p2.display());
    Ok(())
}

//! Figure harnesses: Fig 2 (alignment / rank / class distribution),
//! Fig 3 (exponential gain fits), Fig 4 (extractor + sampler ablation),
//! Fig 5 (loss landscape).

use anyhow::{Context, Result};

use crate::config::Args;
use crate::eval::fit::fit_gain_curve;
use crate::eval::report::{save_result, Table};
use crate::runtime::{default_dir, Engine};
use crate::train::{self, landscape, TrainConfig};

/// Fig 2: run GRAFT with adaptive rank and dump the alignment telemetry —
/// per-batch cos heatmap CSV, epoch trend, class histogram.
pub fn fig2(args: &Args) -> Result<()> {
    let mut engine = Engine::new(default_dir())?;
    let cfg = TrainConfig {
        dataset: args.get_or("dataset", "cifar10")?,
        method: "graft".into(),
        fraction: args.f64_or("fraction", 0.25)?,
        epochs: args.usize_or("epochs", 30)?,
        refresh_epochs: args.usize_or("refresh-epochs", 3)?,
        adaptive_rank: true,
        epsilon: args.f64_or("epsilon", 0.1)?,
        ..args.train_config()?
    };
    let out = train::run(&mut engine, &cfg)?;
    let (res, align) = (out.result, out.alignment);
    println!("{}", res.summary_row());
    let (mu, sigma) = align.mean_std();
    println!(
        "Fig 2 stats: mu={mu:.3} sigma={sigma:.3} (paper: mu=0.72 sigma=0.15), \
         frac(cos>0.5)={:.2}, corr(align,rank)={:.3}",
        align.frac_above(0.5),
        align.align_rank_correlation()
    );
    save_result("fig2_alignment_heatmap.csv", &align.to_csv())?;
    // Epoch trend (Fig 2b).
    let mut trend = String::from("epoch,mean_cos,mean_rank\n");
    for (e, c, r) in align.epoch_trend() {
        trend.push_str(&format!("{e},{c:.4},{r:.2}\n"));
    }
    save_result("fig2_epoch_trend.csv", &trend)?;
    // Class distribution (Fig 2c).
    let mut hist = String::from("epoch,class,count\n");
    for (e, counts) in &align.class_counts {
        for (c, n) in counts.iter().enumerate() {
            hist.push_str(&format!("{e},{c},{n}\n"));
        }
    }
    save_result("fig2_class_distribution.csv", &hist)?;
    println!("wrote results/fig2_*.csv");
    Ok(())
}

/// Fig 3: fit E(x) = E₀ + (H−E₀)(1−e^{−λx/x_max}) to the sweep results —
/// Φ_acc(CO₂) and Ψ(f) per method — and report (E₀, H, λ, R²).
pub fn fig3(args: &Args) -> Result<()> {
    let datasets = args.list_or("datasets", &["cifar10"])?;
    let mut table = Table::new(
        "Fig 3 — exponential gain fits",
        &["dataset", "method", "curve", "E0", "H", "lambda", "R2"],
    );
    let mut csv = vec!["dataset,method,curve,e0,h,lambda,r2".to_string()];
    for dataset in &datasets {
        let path = format!("results/sweep_{dataset}.csv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("{path} missing — run `graft sweep --dataset {dataset}` first"))?;
        // Parse sweep CSV.  A malformed row is an error naming the file
        // and line — a truncated sweep used to be silently skipped here,
        // and the fits quietly ran on whatever rows survived.
        let mut rows: Vec<(String, f64, f64, f64)> = Vec::new(); // method, fraction, co2, acc
        for (ln, line) in text.lines().enumerate().skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            anyhow::ensure!(
                f.len() >= 4,
                "{path}:{}: malformed sweep row {line:?} (want method,fraction,co2,acc)",
                ln + 1
            );
            let num = |col: usize, what: &str| -> Result<f64> {
                f[col]
                    .parse()
                    .with_context(|| format!("{path}:{}: bad {what} {:?}", ln + 1, f[col]))
            };
            rows.push((f[0].into(), num(1, "fraction")?, num(2, "co2")?, num(3, "acc")?));
        }
        let full_acc = rows
            .iter()
            .find(|r| r.0 == "full")
            .map(|r| r.3)
            .unwrap_or_else(|| rows.iter().map(|r| r.3).fold(0.0, f64::max));
        let mut methods: Vec<String> = rows.iter().map(|r| r.0.clone()).collect();
        methods.sort();
        methods.dedup();
        for m in methods.iter().filter(|m| m.as_str() != "full") {
            let pts: Vec<&(String, f64, f64, f64)> = rows.iter().filter(|r| &r.0 == m).collect();
            if pts.len() < 3 {
                continue;
            }
            // Φ_acc vs CO₂ and Ψ vs fraction.
            for (curve, xs, ys) in [
                (
                    "phi_acc_vs_co2",
                    pts.iter().map(|p| p.2).collect::<Vec<_>>(),
                    pts.iter().map(|p| p.3 / full_acc).collect::<Vec<_>>(),
                ),
                (
                    "psi_vs_fraction",
                    pts.iter().map(|p| p.1).collect::<Vec<_>>(),
                    pts.iter().map(|p| p.3 / full_acc).collect::<Vec<_>>(),
                ),
            ] {
                if let Some(fit) = fit_gain_curve(&xs, &ys) {
                    table.row(vec![
                        dataset.clone(),
                        m.clone(),
                        curve.into(),
                        format!("{:.3}", fit.e0),
                        format!("{:.3}", fit.h),
                        format!("{:.2}", fit.lambda),
                        format!("{:.3}", fit.r2),
                    ]);
                    csv.push(format!(
                        "{dataset},{m},{curve},{:.4},{:.4},{:.3},{:.4}",
                        fit.e0, fit.h, fit.lambda, fit.r2
                    ));
                }
            }
        }
    }
    let rendered = table.render();
    println!("{rendered}");
    save_result("fig3_gain_fits.csv", &(csv.join("\n") + "\n"))?;
    save_result("fig3_gain_fits.txt", &rendered)?;
    Ok(())
}

/// Fig 4: (left) GRAFT accuracy with SVD vs AE vs ICA features @25%;
/// (right) FastMaxVol vs CrossMaxVol sampler convergence curves.
pub fn fig4(args: &Args) -> Result<()> {
    let mut engine = Engine::new(default_dir())?;
    let dataset = args.get_or("dataset", "cifar10")?;
    let epochs = args.usize_or("epochs", 20)?;
    let seeds: Vec<u64> = args
        .list_or("seeds", &["42", "43", "44"])?
        .iter()
        .map(|s| s.parse::<u64>().map_err(Into::into))
        .collect::<Result<_>>()?;

    // Left: feature-extractor ablation.
    let mut left = String::from("extractor,seed,epoch,test_acc\n");
    let mut summary = Table::new(
        "Fig 4 (left) — extractor ablation, GRAFT @25%",
        &["extractor", "final acc (mean ± std over seeds)"],
    );
    for ext in ["svd", "ae", "ica"] {
        let mut finals = Vec::new();
        for &seed in &seeds {
            let cfg = TrainConfig {
                dataset: dataset.clone(),
                method: "graft".into(),
                fraction: 0.25,
                epochs,
                extractor: Some(ext.to_string()),
                seed,
                ..args.train_config()?
            };
            let res = train::run(&mut engine, &cfg)?.result;
            eprintln!("  [{ext} seed {seed}] {}", res.summary_row());
            for p in &res.curve {
                left.push_str(&format!("{ext},{seed},{},{:.4}\n", p.epoch, p.test_acc));
            }
            finals.push(res.final_acc);
        }
        let mean = finals.iter().sum::<f64>() / finals.len() as f64;
        let std = (finals.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>()
            / finals.len() as f64)
            .sqrt();
        summary.row(vec![ext.to_uppercase(), format!("{:.2} ± {:.2}", mean * 100.0, std * 100.0)]);
    }
    save_result("fig4_extractors.csv", &left)?;

    // Right: sampler convergence (Fast MaxVol vs CrossMaxVol selectors).
    let mut right = String::from("sampler,seed,epoch,test_acc\n");
    let mut summary2 = Table::new(
        "Fig 4 (right) — sampler convergence @25%",
        &["sampler", "final acc (mean ± std over seeds)"],
    );
    for sampler in ["maxvol", "cross-maxvol"] {
        let mut finals = Vec::new();
        for &seed in &seeds {
            let cfg = TrainConfig {
                dataset: dataset.clone(),
                method: sampler.to_string(),
                fraction: 0.25,
                epochs,
                seed,
                ..args.train_config()?
            };
            let res = train::run(&mut engine, &cfg)?.result;
            eprintln!("  [{sampler} seed {seed}] {}", res.summary_row());
            for p in &res.curve {
                right.push_str(&format!("{sampler},{seed},{},{:.4}\n", p.epoch, p.test_acc));
            }
            finals.push(res.final_acc);
        }
        let mean = finals.iter().sum::<f64>() / finals.len() as f64;
        let std = (finals.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>()
            / finals.len() as f64)
            .sqrt();
        summary2.row(vec![sampler.into(), format!("{:.2} ± {:.2}", mean * 100.0, std * 100.0)]);
    }
    save_result("fig4_samplers.csv", &right)?;
    let rendered = format!("{}\n{}", summary.render(), summary2.render());
    println!("{rendered}");
    save_result("fig4_summary.txt", &rendered)?;
    Ok(())
}

/// Fig 5: loss-landscape grids around the full-data minimiser and the
/// GRAFT-subset minimiser.
pub fn fig5(args: &Args) -> Result<()> {
    let mut engine = Engine::new(default_dir())?;
    let dataset = args.get_or("dataset", "cifar10")?;
    let epochs = args.usize_or("epochs", 20)?;
    let half = args.usize_or("half-points", 8)?;
    let radius = args.f64_or("radius", 1.0)? as f32;
    let spec = engine.spec(&dataset)?.clone();
    let ds = train::load_dataset(&dataset)?;
    let (_, test) = ds.split(0.8, 42 ^ 0x5917);

    let mut summary = Table::new("Fig 5 — loss landscape sharpness", &["trained with", "center loss", "sharpness"]);
    for method in ["full", "graft"] {
        let cfg = TrainConfig {
            dataset: dataset.clone(),
            method: method.into(),
            fraction: 0.25,
            epochs,
            ..args.train_config()?
        };
        let out = train::run(&mut engine, &cfg)?;
        eprintln!("  [{method}] {}", out.result.summary_row());
        let params = out.state.params;
        let grid = landscape::scan(&mut engine, &dataset, &spec, &params, &test, half, radius, 0xF1657)?;
        let sharp = landscape::sharpness(&grid);
        let center = grid[half][half];
        summary.row(vec![method.into(), format!("{center:.4}"), format!("{sharp:.4}")]);
        save_result(&format!("fig5_landscape_{method}.csv"), &landscape::grid_csv(&grid, radius))?;
    }
    let rendered = summary.render();
    println!("{rendered}");
    save_result("fig5_summary.txt", &rendered)?;
    Ok(())
}

//! Gradient geometry shared between the single-shot GRAFT selector and
//! the coordinator's gradient-aware merge (`coordinator::merge`): prefix
//! projection errors of the batch-mean gradient sketch ḡ against a set of
//! selected gradient columns (paper §3.2, Lemma 1 normalised form).
//!
//! Factored out of `graft/mod.rs` so the sharded/pooled selection path can
//! recompute the error curve over *merged* winners with the exact fused
//! MGS kernel the single-shot path uses — the two paths read the same
//! geometry by construction, not by parallel implementation.

use crate::linalg::{mat::transpose_into, qr::mgs_column_step, Mat, Workspace};

/// Prefix projection errors d_r for r = 1..R over the selected gradient
/// columns (E×R), mirroring the L1 kernel (Lemma 1 normalised form).
///
/// Allocating wrapper over the fused in-place kernel; hot paths fill the
/// column buffer straight from gradient rows and skip the transpose.
pub fn prefix_projection_errors(gsel: &Mat, gbar: &[f64]) -> Vec<f64> {
    let (e, r) = (gsel.rows(), gsel.cols());
    let mut ws = Workspace::default();
    ws.pe_g.resize(e * r, 0.0);
    transpose_into(e, r, gsel.data(), &mut ws.pe_g);
    let mut out = Vec::with_capacity(r);
    prefix_errors_core(&mut ws.pe_g, e, r, gbar, &mut ws.pe_ghat, &mut out);
    out
}

/// Fused MGS + projection: orthonormalise the `r` columns (each length
/// `e`, stored contiguously in `cols`) in place via the shared
/// [`mgs_column_step`] kernel — the exact two-pass / relative-tolerance
/// semantics of [`crate::linalg::qr`], by construction — accumulating the
/// prefix projection errors of ĝ = ḡ/‖ḡ‖ as each column is finalised.
/// Zero allocations once `ghat` and `out` have capacity.
pub(crate) fn prefix_errors_core(
    cols: &mut [f64],
    e: usize,
    r: usize,
    gbar: &[f64],
    ghat: &mut Vec<f64>,
    out: &mut Vec<f64>,
) {
    use crate::linalg::dot;
    out.clear();
    let nrm = crate::linalg::norm2(gbar);
    if nrm < 1e-12 {
        out.resize(r, 0.0);
        return;
    }
    ghat.clear();
    ghat.extend(gbar.iter().map(|x| x / nrm));
    let mut cum = 0.0;
    for j in 0..r {
        let (done, rest) = cols.split_at_mut(j * e);
        let v = &mut rest[..e];
        // Dependent columns come back zero-filled and contribute nothing.
        let _ = mgs_column_step(done, e, j, v, |_, _| {});
        let a = dot(v, ghat);
        cum += a * a;
        out.push((1.0 - cum).max(0.0));
    }
}

/// Greedy gradient-aware pivot re-ordering
/// ([`PivotMode::GradAware`](crate::engine::PivotMode)): step j picks the
/// remaining winner column whose direction — after orthogonalisation
/// against the already-placed prefix — captures the largest share of the
/// remaining ĝ residual, then sweeps that component out of the rest.  The
/// winner *membership* is untouched (the feature-volume tournament already
/// fixed it); only the order the rank cut truncates is changed, so at a
/// given budget the kept prefix covers as much of ĝ as the greedy can.
///
/// `cols` holds the `r` candidate gradient columns (each length `e`,
/// contiguous, column j = winner `order[j]`); both are permuted in place.
/// The column buffer is **clobbered** (orthonormalised) — re-gather the
/// raw gradient rows before computing an error curve over the new order.
///
/// Returns `false` without touching anything when the gradient signal is
/// zero (‖ḡ‖ < 1e-12, the same threshold [`prefix_errors_core`] uses) —
/// the incoming feature-volume order is kept bit for bit, which is the
/// GradAware ≡ FeatureVol zero-signal fallback the engine tests pin.
pub(crate) fn grad_aware_order(
    cols: &mut [f64],
    e: usize,
    r: usize,
    gbar: &[f64],
    resid: &mut Vec<f64>,
    order: &mut [usize],
) -> bool {
    use crate::linalg::{axpy_lanes, dot, norm2};
    debug_assert!(cols.len() >= r * e, "need {r}×{e} columns, got {}", cols.len());
    debug_assert!(order.len() >= r);
    let nrm = norm2(gbar);
    if nrm < 1e-12 || e == 0 || r == 0 {
        return false;
    }
    resid.clear();
    resid.extend(gbar.iter().map(|x| x / nrm));
    for j in 0..r {
        // Columns j..r are already orthogonal to the placed prefix, so the
        // score is just the normalised projection onto the residual.
        let (mut best, mut bestscore) = (j, -1.0f64);
        for t in j..r {
            let v = &cols[t * e..(t + 1) * e];
            let n = norm2(v);
            let score = if n < 1e-12 { 0.0 } else { (dot(v, resid) / n).abs() };
            if score > bestscore {
                best = t;
                bestscore = score;
            }
        }
        if best != j {
            for t in 0..e {
                cols.swap(j * e + t, best * e + t);
            }
            order.swap(j, best);
        }
        // Normalise the placed column; a dependent (numerically zero)
        // column places as-is and captures nothing.
        let n = norm2(&cols[j * e..(j + 1) * e]);
        if n < 1e-12 {
            continue;
        }
        for v in cols[j * e..(j + 1) * e].iter_mut() {
            *v /= n;
        }
        // Gram–Schmidt sweep: the remaining columns and the residual both
        // lose their component along the placed direction.
        let (head, tail) = cols.split_at_mut((j + 1) * e);
        let q = &head[j * e..];
        for t in 0..(r - j - 1) {
            let v = &mut tail[t * e..(t + 1) * e];
            let c = dot(v, q);
            axpy_lanes(v, -c, q);
        }
        let c = dot(resid, q);
        axpy_lanes(resid, -c, q);
    }
    true
}

/// Accumulate the per-row sum of `grads` rows `range` into `out`
/// (cleared/zeroed first): the shard-local partial ḡ·count sum that
/// crosses the shard → merge boundary.  The exact global ḡ is the
/// count-weighted mean of these partial sums — no extra pass over the
/// batch at merge time.
pub(crate) fn grad_sum_into(grads: &Mat, range: std::ops::Range<usize>, out: &mut Vec<f64>) {
    let e = grads.cols();
    out.clear();
    out.resize(e, 0.0);
    for i in range {
        // Unit-coefficient lane axpy: 1.0·v is exactly v, so this is
        // bit-identical to the scalar accumulation loop.
        crate::linalg::axpy_lanes(out, 1.0, grads.row(i));
    }
}

//! Gradient-alignment telemetry (paper Fig 2): per-batch cosine between
//! the batch-mean gradient and (a) the selected-subset mean, (b) the
//! epoch-level mean; rank trajectory; class-distribution histogram.

/// One alignment observation (one batch at one refresh).
#[derive(Debug, Clone, Copy)]
pub struct AlignmentSample {
    pub epoch: usize,
    pub batch: usize,
    /// cos(ḡ_batch, mean selected sketch).
    pub cos: f64,
    /// Chosen rank R*.
    pub rank: usize,
    /// Projection error at R*.
    pub error: f64,
}

/// Accumulates Fig-2 style statistics over a run.
#[derive(Debug, Default, Clone)]
pub struct AlignmentStats {
    pub samples: Vec<AlignmentSample>,
    /// Per-class selected-sample counts over time: (epoch, class) → count.
    pub class_counts: Vec<(usize, Vec<usize>)>,
}

impl AlignmentStats {
    pub fn record(&mut self, s: AlignmentSample) {
        self.samples.push(s);
    }

    pub fn record_class_histogram(&mut self, epoch: usize, counts: Vec<usize>) {
        self.class_counts.push((epoch, counts));
    }

    /// Mean / std of alignment (paper reports μ = 0.72, σ = 0.15).
    pub fn mean_std(&self) -> (f64, f64) {
        if self.samples.is_empty() {
            return (0.0, 0.0);
        }
        let n = self.samples.len() as f64;
        let mean = self.samples.iter().map(|s| s.cos).sum::<f64>() / n;
        let var = self.samples.iter().map(|s| (s.cos - mean).powi(2)).sum::<f64>() / n;
        (mean, var.sqrt())
    }

    /// Fraction of samples with cos > threshold (paper: > 0.5 "majority").
    pub fn frac_above(&self, thr: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().filter(|s| s.cos > thr).count() as f64 / self.samples.len() as f64
    }

    /// Per-epoch mean (cos, rank): the Fig 2b trend series.
    pub fn epoch_trend(&self) -> Vec<(usize, f64, f64)> {
        let mut acc: std::collections::BTreeMap<usize, (f64, f64, usize)> = Default::default();
        for s in &self.samples {
            let e = acc.entry(s.epoch).or_insert((0.0, 0.0, 0));
            e.0 += s.cos;
            e.1 += s.rank as f64;
            e.2 += 1;
        }
        acc.into_iter()
            .map(|(ep, (c, r, n))| (ep, c / n as f64, r / n as f64))
            .collect()
    }

    /// Pearson correlation between alignment and rank — the paper's
    /// "strong correlation between high alignment and rank reduction"
    /// claim (expected negative).
    pub fn align_rank_correlation(&self) -> f64 {
        let n = self.samples.len() as f64;
        if n < 2.0 {
            return 0.0;
        }
        let mc = self.samples.iter().map(|s| s.cos).sum::<f64>() / n;
        let mr = self.samples.iter().map(|s| s.rank as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let mut dc = 0.0;
        let mut dr = 0.0;
        for s in &self.samples {
            let a = s.cos - mc;
            let b = s.rank as f64 - mr;
            num += a * b;
            dc += a * a;
            dr += b * b;
        }
        if dc <= 0.0 || dr <= 0.0 {
            0.0
        } else {
            num / (dc * dr).sqrt()
        }
    }

    /// CSV dump (heatmap source for Fig 2a).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("epoch,batch,cos,rank,error\n");
        for s in &self.samples {
            out.push_str(&format!("{},{},{:.6},{},{:.6}\n", s.epoch, s.batch, s.cos, s.rank, s.error));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_with(cos_rank: &[(f64, usize)]) -> AlignmentStats {
        let mut st = AlignmentStats::default();
        for (i, &(c, r)) in cos_rank.iter().enumerate() {
            st.record(AlignmentSample { epoch: i / 2, batch: i % 2, cos: c, rank: r, error: 0.1 });
        }
        st
    }

    #[test]
    fn mean_std() {
        let st = stats_with(&[(0.5, 4), (0.7, 4), (0.9, 4)]);
        let (m, s) = st.mean_std();
        assert!((m - 0.7).abs() < 1e-12);
        // var = ((0.2)² + 0 + (0.2)²)/3 → σ = √(0.08/3)
        assert!((s - (0.08f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn frac_above() {
        let st = stats_with(&[(0.4, 4), (0.6, 4), (0.8, 4), (0.9, 4)]);
        assert!((st.frac_above(0.5) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn negative_align_rank_correlation_detected() {
        let st = stats_with(&[(0.2, 16), (0.4, 12), (0.6, 8), (0.8, 4)]);
        assert!(st.align_rank_correlation() < -0.95);
    }

    #[test]
    fn epoch_trend_groups() {
        let st = stats_with(&[(0.5, 8), (0.7, 6), (0.8, 4), (1.0, 2)]);
        let trend = st.epoch_trend();
        assert_eq!(trend.len(), 2);
        assert!((trend[0].1 - 0.6).abs() < 1e-12);
        assert!((trend[1].2 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn csv_shape() {
        let st = stats_with(&[(0.5, 8)]);
        let csv = st.to_csv();
        assert!(csv.starts_with("epoch,batch,cos"));
        assert_eq!(csv.lines().count(), 2);
    }
}

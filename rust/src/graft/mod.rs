//! GRAFT core (paper §3.2): dynamic rank selection from prefix projection
//! errors, budget control, and the gradient-alignment statistics of Fig 2.
//!
//! Stage 1 (feature extraction + Fast MaxVol + prefix errors) runs inside
//! the AOT `select` artifact (L1/L2); this module is the Stage-2 policy
//! layer that turns the error curve d_r into a subset size R*.

pub mod alignment;
pub mod rank;

pub use alignment::AlignmentStats;
pub use rank::{choose_rank, BudgetedRankPolicy, RankDecision};

use crate::linalg::{qr, Mat};
use crate::selection::maxvol::fast_maxvol;
use crate::selection::{BatchView, Selector};

/// Pure-Rust GRAFT selection for non-AOT data paths (Iris, ablations):
/// Fast MaxVol on the feature matrix + prefix projection errors of the
/// batch-mean gradient sketch — mirrors the `select` artifact bit-for-bit
/// in structure (f64 instead of f32).
pub struct GraftSelector {
    pub policy: BudgetedRankPolicy,
    /// Last decision, for logging.
    pub last: Option<RankDecision>,
}

impl GraftSelector {
    pub fn new(policy: BudgetedRankPolicy) -> Self {
        GraftSelector { policy, last: None }
    }
}

/// Prefix projection errors d_r for r = 1..R over the selected gradient
/// columns (E×R), mirroring the L1 kernel (Lemma 1 normalised form).
pub fn prefix_projection_errors(gsel: &Mat, gbar: &[f64]) -> Vec<f64> {
    let r = gsel.cols();
    let nrm = crate::linalg::norm2(gbar);
    if nrm < 1e-12 {
        return vec![0.0; r];
    }
    let ghat: Vec<f64> = gbar.iter().map(|x| x / nrm).collect();
    let d = qr(gsel);
    let mut cum = 0.0;
    let mut out = Vec::with_capacity(r);
    for j in 0..r {
        // Zero (dependent) columns contribute nothing.
        let qj = d.q.col(j);
        let a = crate::linalg::dot(&qj, &ghat);
        cum += a * a;
        out.push((1.0 - cum).max(0.0));
    }
    out
}

impl Selector for GraftSelector {
    fn name(&self) -> &'static str {
        "graft"
    }

    fn select(&mut self, view: &BatchView<'_>, r_budget: usize) -> Vec<usize> {
        let k = view.k();
        let rmax = view.features.cols().min(k);
        // Stage 1: Fast MaxVol over the ordered features.
        let p = fast_maxvol(view.features, rmax);
        // Prefix errors of ḡ against the selected gradient columns.
        let e = view.grads.cols();
        let mut gbar = vec![0.0f64; e];
        for i in 0..k {
            for (t, &v) in view.grads.row(i).iter().enumerate() {
                gbar[t] += v;
            }
        }
        for v in gbar.iter_mut() {
            *v /= k as f64;
        }
        let gsel = view.grads.take_rows(&p).transpose(); // E×Rmax
        let errors = prefix_projection_errors(&gsel, &gbar);
        // Stage 2: dynamic rank.
        let decision = self.policy.choose(&errors, r_budget, rmax);
        let rstar = decision.rank;
        self.last = Some(decision);
        let mut out: Vec<usize> = p[..rstar.min(p.len())].to_vec();
        // Honour the requested budget contract (|S| == r_budget) when the
        // caller insists (comparison harness); top-up by loss otherwise.
        if out.len() < r_budget.min(k) && self.policy.strict_budget {
            let mut taken = vec![false; k];
            for &i in &out {
                taken[i] = true;
            }
            let mut rest: Vec<usize> = (0..k).filter(|&i| !taken[i]).collect();
            rest.sort_by(|&a, &b| view.losses[b].partial_cmp(&view.losses[a]).unwrap());
            out.extend(rest.into_iter().take(r_budget.min(k) - out.len()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::selection::testsupport::random_view;

    #[test]
    fn prefix_errors_match_kernel_semantics() {
        // Monotone non-increasing, in [0, 1], zero when ḡ ∈ span.
        let mut rng = Rng::new(1);
        let g = Mat::from_fn(12, 5, |_, _| rng.normal());
        let coef: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let gbar = g.matvec(&coef);
        let d = prefix_projection_errors(&g, &gbar);
        assert!(d[4] < 1e-10, "{d:?}");
        for w in d.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!(d.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn selector_respects_strict_budget() {
        let owned = random_view(64, 8, 16, 4, 3);
        let mut s = GraftSelector::new(BudgetedRankPolicy::strict(0.05));
        let sel = s.select(&owned.view(), 16);
        assert_eq!(sel.len(), 16);
        let mut u = sel.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 16);
    }

    #[test]
    fn adaptive_mode_shrinks_when_aligned() {
        // Gradients in a 2-D subspace: tiny ranks already reach d ≈ 0, so
        // the adaptive policy must pick a small R*.
        let mut rng = Rng::new(4);
        let k = 48;
        let basis = Mat::from_fn(2, 10, |_, _| rng.normal());
        let loads = Mat::from_fn(k, 2, |_, _| rng.normal());
        let grads = loads.matmul(&basis);
        let features = Mat::from_fn(k, 8, |_, _| rng.normal());
        let losses = vec![1.0; k];
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &features,
            grads: &grads,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        let mut s = GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0));
        let sel = s.select(&view, 8);
        assert!(sel.len() <= 4, "low-rank gradients → small subset, got {}", sel.len());
        assert!(s.last.unwrap().error <= 0.05 + 1e-9);
    }
}

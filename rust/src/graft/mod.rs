//! GRAFT core (paper §3.2): dynamic rank selection from prefix projection
//! errors, budget control, and the gradient-alignment statistics of Fig 2.
//!
//! Stage 1 (feature extraction + Fast MaxVol + prefix errors) runs inside
//! the AOT `select` artifact (L1/L2); this module is the Stage-2 policy
//! layer that turns the error curve d_r into a subset size R*.
//!
//! The Rust-side selection path ([`GraftSelector::select_into`]) is
//! allocation-free at steady state: the MaxVol working copy, the selected
//! gradient columns, ĝ, and the error curve all live in a reusable
//! [`Workspace`], and the prefix errors come from a fused MGS that
//! orthonormalises the selected sketches in place (numerically identical
//! to `qr` + per-column projection, without materialising Q or R).

pub mod alignment;
pub mod geometry;
pub mod rank;

pub use alignment::AlignmentStats;
pub use geometry::prefix_projection_errors;
pub use rank::{choose_rank, BudgetedRankPolicy, RankDecision, RankStats, StrictRankTally};

use geometry::{grad_aware_order, grad_sum_into, prefix_errors_core};

use crate::linalg::Workspace;
use crate::selection::maxvol::fast_maxvol_with;
use crate::selection::{BatchView, Selector};

/// Pure-Rust GRAFT selection for non-AOT data paths (Iris, ablations):
/// Fast MaxVol on the feature matrix + prefix projection errors of the
/// batch-mean gradient sketch — mirrors the `select` artifact bit-for-bit
/// in structure (f64 instead of f32).
pub struct GraftSelector {
    pub policy: BudgetedRankPolicy,
    /// Last decision, for logging.
    pub last: Option<RankDecision>,
    /// Gradient-aware pivot stage ([`PivotMode::GradAware`]): re-order the
    /// MaxVol winners by greedy residual-‖ĝ‖ coverage before the rank cut.
    ///
    /// [`PivotMode::GradAware`]: crate::engine::PivotMode
    grad_pivot: bool,
}

impl GraftSelector {
    pub fn new(policy: BudgetedRankPolicy) -> Self {
        GraftSelector { policy, last: None, grad_pivot: false }
    }

    /// Enable the gradient-aware pivot stage: MaxVol still fixes winner
    /// *membership*, but [`geometry::grad_aware_order`] re-orders them so
    /// the prefix the rank cut keeps covers as much of ĝ as the greedy
    /// can.  With zero gradient signal the feature order is kept bit for
    /// bit (the fallback the engine tests pin).
    pub fn with_grad_pivot(mut self, on: bool) -> Self {
        self.grad_pivot = on;
        self
    }
}

impl Selector for GraftSelector {
    fn name(&self) -> &'static str {
        "graft"
    }

    /// GRAFT's Stage 1 is Fast MaxVol on the ordered features; the sharded
    /// coordinator's second-stage MaxVol merge preserves that criterion
    /// over the union of per-shard winners.
    fn shardable(&self) -> bool {
        true
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r_budget: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let k = view.k();
        let rmax = view.features.cols().min(k);
        // Stage 1: Fast MaxVol over the ordered features.  The pivot order
        // lives in the workspace (taken out around the nested call).
        let mut order = std::mem::take(&mut ws.sel_order);
        fast_maxvol_with(view.features, rmax, ws, &mut order);
        // Prefix errors of ḡ against the selected gradient columns (same
        // accumulation kernel the sharded path sums per shard).
        let e = view.grads.cols();
        grad_sum_into(view.grads, 0..k, &mut ws.pe_gbar);
        for v in ws.pe_gbar.iter_mut() {
            *v /= k as f64;
        }
        // Column j of the E×Rmax selected-sketch matrix is gradient row
        // order[j] — contiguous by construction, no transpose needed.
        ws.pe_g.clear();
        for &i in &order {
            ws.pe_g.extend_from_slice(view.grads.row(i));
        }
        // Optional gradient-aware pivot: greedily permute the winners by
        // residual ĝ coverage (clobbers the column buffer, so re-gather
        // before the error curve).  Zero gradient signal falls through
        // with the feature order untouched.
        if self.grad_pivot
            && grad_aware_order(
                &mut ws.pe_g,
                e,
                rmax,
                &ws.pe_gbar,
                &mut ws.pe_ghat,
                &mut order,
            )
        {
            ws.pe_g.clear();
            for &i in &order {
                ws.pe_g.extend_from_slice(view.grads.row(i));
            }
        }
        prefix_errors_core(&mut ws.pe_g, e, rmax, &ws.pe_gbar, &mut ws.pe_ghat, &mut ws.pe_err);
        // Stage 2: dynamic rank.
        let decision = self.policy.choose(&ws.pe_err, r_budget, rmax);
        let rstar = decision.rank;
        self.last = Some(decision);
        out.clear();
        out.extend_from_slice(&order[..rstar.min(order.len())]);
        ws.sel_order = order;
        // Honour the requested budget contract (|S| == r_budget) when the
        // caller insists (comparison harness); dynamic mode keeps R*.
        if self.policy.strict_budget {
            crate::selection::top_up_by_loss(view, r_budget, ws, out);
        }
    }

    /// GRAFT's defining Stage 2 moved to the merge boundary: the
    /// coordinator's gradient-aware merge ([`MergePolicy::Grad`]) hands
    /// this rank authority the prefix projection errors of the *global* ĝ
    /// over the merged MaxVol pivot order, and the one policy held here is
    /// the single budget accumulator for the whole run — ε/budget
    /// semantics independent of the shard/worker count.
    ///
    /// [`MergePolicy::Grad`]: crate::coordinator::MergePolicy::Grad
    fn post_merge_rank(
        &mut self,
        errors: &[f64],
        r_budget: usize,
        rmax: usize,
    ) -> Option<RankDecision> {
        let decision = self.policy.choose(errors, r_budget, rmax);
        self.last = Some(decision);
        Some(decision)
    }

    fn rank_stats(&self) -> Option<RankStats> {
        Some(RankStats {
            mean_rank: self.policy.mean_rank(),
            batches: self.policy.batches(),
            last: self.last,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::selection::testsupport::random_view;

    #[test]
    fn prefix_errors_match_kernel_semantics() {
        // Monotone non-increasing, in [0, 1], zero when ḡ ∈ span.
        let mut rng = Rng::new(1);
        let g = Mat::from_fn(12, 5, |_, _| rng.normal());
        let coef: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let gbar = g.matvec(&coef);
        let d = prefix_projection_errors(&g, &gbar);
        assert!(d[4] < 1e-10, "{d:?}");
        for w in d.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!(d.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn prefix_errors_match_qr_reference() {
        // The fused in-place kernel must agree with the explicit QR path.
        let mut rng = Rng::new(7);
        let g = Mat::from_fn(10, 6, |_, _| rng.normal());
        let gbar: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let fused = prefix_projection_errors(&g, &gbar);
        // Reference: explicit thin QR, project ĝ column by column.
        let nrm = crate::linalg::norm2(&gbar);
        let ghat: Vec<f64> = gbar.iter().map(|x| x / nrm).collect();
        let d = crate::linalg::qr(&g);
        let mut cum = 0.0;
        for (j, &f) in fused.iter().enumerate() {
            let a = crate::linalg::dot(&d.q.col(j), &ghat);
            cum += a * a;
            let want = (1.0 - cum).max(0.0);
            assert!((f - want).abs() < 1e-12, "col {j}: {f} vs {want}");
        }
    }

    #[test]
    fn selector_respects_strict_budget() {
        let owned = random_view(64, 8, 16, 4, 3);
        let mut s = GraftSelector::new(BudgetedRankPolicy::strict(0.05));
        let sel = s.select(&owned.view(), 16);
        assert_eq!(sel.len(), 16);
        let mut u = sel.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 16);
    }

    #[test]
    fn adaptive_mode_shrinks_when_aligned() {
        // Gradients in a 2-D subspace: tiny ranks already reach d ≈ 0, so
        // the adaptive policy must pick a small R*.
        let mut rng = Rng::new(4);
        let k = 48;
        let basis = Mat::from_fn(2, 10, |_, _| rng.normal());
        let loads = Mat::from_fn(k, 2, |_, _| rng.normal());
        let grads = loads.matmul(&basis);
        let features = Mat::from_fn(k, 8, |_, _| rng.normal());
        let losses = vec![1.0; k];
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &features,
            grads: &grads,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        let mut s = GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0));
        let sel = s.select(&view, 8);
        assert!(sel.len() <= 4, "low-rank gradients → small subset, got {}", sel.len());
        assert!(s.last.unwrap().error <= 0.05 + 1e-9);
    }

    #[test]
    fn grad_pivot_zero_signal_is_feature_order_bitwise() {
        // All-zero gradient sketches → ‖ḡ‖ = 0 → the pivot stage must fall
        // through and leave the feature-volume order untouched, bit for
        // bit, at every budget.
        let mut rng = Rng::new(9);
        let k = 40;
        let features = Mat::from_fn(k, 8, |_, _| rng.normal());
        let grads = Mat::from_fn(k, 12, |_, _| 0.0);
        let losses: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &features,
            grads: &grads,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        for r in [2usize, 5, 8] {
            let plain =
                GraftSelector::new(BudgetedRankPolicy::strict(0.05)).select(&view, r);
            let pivoted = GraftSelector::new(BudgetedRankPolicy::strict(0.05))
                .with_grad_pivot(true)
                .select(&view, r);
            assert_eq!(plain, pivoted, "r={r}");
        }
    }

    #[test]
    fn grad_pivot_error_dominates_feature_order_at_every_prefix() {
        // Planted scenario: each row's gradient sketch is a scaled basis
        // vector (low-rank + orthogonal columns; the "noisy" rows get their
        // own large-magnitude basis dims, mimicking label-noise gradients).
        // With mutually orthogonal columns the prefix capture of any order
        // is a plain sum of per-column captures, so the greedy's descending
        // sort dominates every other order at every prefix — the headline
        // guarantee, checked here over the full error curves.
        let mut rng = Rng::new(15);
        let k = 32;
        let e = 16;
        let features = Mat::from_fn(k, 8, |_, _| rng.normal());
        let grads = Mat::from_fn(k, e, |i, j| {
            let dim = i % 6; // low-rank: only 6 of 16 dims used
            let scale = if i % 7 == 0 { 5.0 } else { 1.0 + (i % 3) as f64 };
            if j == dim {
                scale
            } else {
                0.0
            }
        });
        let losses = vec![1.0; k];
        let labels: Vec<i32> = (0..k).map(|i| (i % 4) as i32).collect();
        let preds = labels.clone();
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &features,
            grads: &grads,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 4,
            row_ids: &ids,
        };
        let rmax = 8;
        // Full-budget strict selections expose each ordering's whole pivot
        // sequence; membership is identical, only the order differs.
        let plain =
            GraftSelector::new(BudgetedRankPolicy::strict(0.5)).select(&view, rmax);
        let pivoted = GraftSelector::new(BudgetedRankPolicy::strict(0.5))
            .with_grad_pivot(true)
            .select(&view, rmax);
        let (mut a, mut b) = (plain.clone(), pivoted.clone());
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "pivot stage must not change winner membership");
        let gbar: Vec<f64> = (0..e)
            .map(|j| (0..k).map(|i| grads.row(i)[j]).sum::<f64>() / k as f64)
            .collect();
        let curve = |order: &[usize]| {
            let gsel = Mat::from_fn(e, order.len(), |row, col| grads.row(order[col])[row]);
            prefix_projection_errors(&gsel, &gbar)
        };
        let fe = curve(&plain);
        let ge = curve(&pivoted);
        for (r, (g, f)) in ge.iter().zip(fe.iter()).enumerate() {
            assert!(g <= &(f + 1e-9), "budget {}: grad-aware {g} > feature {f}", r + 1);
        }
        // Both curves are valid error curves over the same column set, so
        // they agree once every column is in (full-span capture).
        for w in ge.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "greedy curve must be non-increasing");
        }
        assert!(
            (ge.last().unwrap() - fe.last().unwrap()).abs() < 1e-9,
            "full-prefix error is order-independent"
        );
    }

    #[test]
    fn workspace_reuse_across_batches() {
        // Same workspace over several batches must match fresh-workspace
        // selections exactly.
        let mut ws = Workspace::default();
        let mut buf = Vec::new();
        for seed in 10..14 {
            let owned = random_view(32, 6, 12, 4, seed);
            let mut warm = GraftSelector::new(BudgetedRankPolicy::strict(0.05));
            warm.select_into(&owned.view(), 10, &mut ws, &mut buf);
            let mut cold = GraftSelector::new(BudgetedRankPolicy::strict(0.05));
            assert_eq!(buf, cold.select(&owned.view(), 10), "seed {seed}");
        }
    }
}

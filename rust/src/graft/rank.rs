//! Dynamic rank adjustment (paper §3.2, Alg. 1): pick the smallest rank
//! whose projection error meets the ε threshold, under a running budget
//! that keeps the *average* subset size at the requested data fraction.
//!
//! Corollary 1: keeping ‖ḡ − P_R ḡ‖² ≤ ε at every refresh preserves
//! convergence; the budget controller trades ε violations against the
//! emission target when the two conflict (logged via [`RankDecision`]).

/// Outcome of one rank choice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankDecision {
    /// Chosen subset size R*.
    pub rank: usize,
    /// Projection error at R*.
    pub error: f64,
    /// True when the ε constraint was met within budget.
    pub satisfied: bool,
}

/// The safe degenerate outcome for an empty error curve (a rank-0 kernel
/// depth or a K = 0 batch): nothing can be selected, so R* = 0 with a
/// vacuous error.  `satisfied` is false — no curve ever met ε — so callers
/// that branch on it treat the batch as unconstrained rather than solved.
impl RankDecision {
    pub const EMPTY: RankDecision = RankDecision { rank: 0, error: 0.0, satisfied: false };
}

/// Pure rank choice: smallest r ∈ [r_min, r_max] with d_r ≤ ε, else the
/// error-minimising r (= r_max since d is non-increasing).  An empty
/// error curve yields [`RankDecision::EMPTY`] instead of panicking.
pub fn choose_rank(errors: &[f64], epsilon: f64, r_min: usize, r_max: usize) -> RankDecision {
    if errors.is_empty() {
        return RankDecision::EMPTY;
    }
    let r_max = r_max.min(errors.len()).max(1);
    let r_min = r_min.clamp(1, r_max);
    for r in r_min..=r_max {
        if errors[r - 1] <= epsilon {
            return RankDecision { rank: r, error: errors[r - 1], satisfied: true };
        }
    }
    RankDecision { rank: r_max, error: errors[r_max - 1], satisfied: false }
}

/// Snapshot of a rank policy's accounting, surfaced through
/// [`crate::selection::Selector::rank_stats`] so the trainer (and the
/// budget-drift tests) can read the single top-level accumulator without
/// knowing the concrete selector type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankStats {
    /// Mean chosen subset size over all decided batches.
    pub mean_rank: f64,
    /// Number of batches decided (each refresh window exactly once).
    pub batches: f64,
    /// Most recent decision, for logging.
    pub last: Option<RankDecision>,
}

/// Stateful policy: ε-threshold choice with a running budget controller.
///
/// `budget_frac` is the target mean subset fraction (R*/K averaged over
/// refreshes).  The controller widens the admissible window when the run
/// is under budget (letting hard batches take more samples) and narrows it
/// when over budget — mirroring the paper's observation (Fig 2b) that high
/// alignment lets lower ranks through while rare low-alignment batches are
/// absorbed by the dynamic adjustment.
#[derive(Debug, Clone)]
pub struct BudgetedRankPolicy {
    pub epsilon: f64,
    /// Target mean fraction of the batch (0 < f ≤ 1); 1.0 = unconstrained.
    pub budget_frac: f64,
    /// When true, `GraftSelector` pads selections to the exact budget
    /// (used by the fixed-fraction comparison harness).
    pub strict_budget: bool,
    used: f64,
    batches: f64,
}

impl BudgetedRankPolicy {
    /// Adaptive mode: ε criterion + budget averaging.
    pub fn adaptive(epsilon: f64, budget_frac: f64) -> Self {
        BudgetedRankPolicy {
            epsilon,
            budget_frac: budget_frac.clamp(1e-3, 1.0),
            strict_budget: false,
            used: 0.0,
            batches: 0.0,
        }
    }

    /// Strict mode: always return exactly the requested budget (baseline-
    /// comparable); ε is still recorded in the decision.
    pub fn strict(epsilon: f64) -> Self {
        BudgetedRankPolicy {
            epsilon,
            budget_frac: 1.0,
            strict_budget: true,
            used: 0.0,
            batches: 0.0,
        }
    }

    /// Mean subset size chosen so far (for the emission accounting tests).
    pub fn mean_rank(&self) -> f64 {
        if self.batches == 0.0 {
            0.0
        } else {
            self.used / self.batches
        }
    }

    /// Number of batches this policy has decided — the budget-drift pin:
    /// at any shard/worker count, the one top-level policy must count each
    /// refreshed batch exactly once (per-shard clones no longer accumulate
    /// their own private copies of the run budget).
    pub fn batches(&self) -> f64 {
        self.batches
    }

    /// Choose R* for one batch. `r_budget` = f·K target; `rmax` = kernel
    /// depth.  An empty error curve (rank-0 / K-0 batch) yields
    /// [`RankDecision::EMPTY`] without entering the budget accounting —
    /// a degenerate batch is not a refresh.
    pub fn choose(&mut self, errors: &[f64], r_budget: usize, rmax: usize) -> RankDecision {
        if errors.is_empty() {
            return RankDecision::EMPTY;
        }
        let rmax = rmax.min(errors.len()).max(1);
        let target = r_budget.clamp(1, rmax);
        let decision = if self.strict_budget {
            let r = target;
            RankDecision { rank: r, error: errors[r - 1], satisfied: errors[r - 1] <= self.epsilon }
        } else {
            // Window around the target: under budget → allow up to 2×
            // target; over budget → squeeze toward half the target.
            let mean = self.mean_rank();
            let over = self.batches > 0.0 && mean > target as f64;
            let (lo, hi) = if over {
                (1, target)
            } else {
                (1, (2 * target).min(rmax))
            };
            choose_rank(errors, self.epsilon, lo, hi)
        };
        self.used += decision.rank as f64;
        self.batches += 1.0;
        decision
    }
}

/// Telemetry-only rank accounting for **strict** runs that skip the rank
/// authority entirely (the adaptive-only gradient-carry fast path).
///
/// A strict authority's post-merge cut is provably the identity — the
/// merged winner list already has exactly `min(budget, K)` entries, so
/// `choose` would return `rank == |out|` with `errors[rank-1]` read from a
/// freshly recomputed error curve that influences nothing.  Instead of
/// carrying O(shards·r·E) gradient sketches across the merge boundary and
/// re-running the fused MGS kernel just to fill `mean_rank`, the engine
/// records the subset size it already knows into this tally and
/// synthesizes an *administrative* [`RankDecision`]: `error: 0.0` (finite
/// by construction — no curve was measured, and downstream breakdown
/// checks key on non-finite errors), `satisfied: true` (the strict
/// contract — emit exactly the budget — is met by construction).
///
/// Only healthy, non-degraded, non-empty selections are recorded —
/// mirroring the old authority, which was consulted exactly once per
/// successfully merged window.
#[derive(Debug, Clone, Default)]
pub struct StrictRankTally {
    used: f64,
    batches: f64,
    last: Option<RankDecision>,
}

impl StrictRankTally {
    /// Record one healthy strict selection of `rank` rows; returns the
    /// synthesized administrative decision (also retained as `last`).
    pub fn record(&mut self, rank: usize) -> RankDecision {
        let d = RankDecision { rank, error: 0.0, satisfied: true };
        self.used += rank as f64;
        self.batches += 1.0;
        self.last = Some(d);
        d
    }

    /// Accounting snapshot, shaped like a policy-backed
    /// [`crate::selection::Selector::rank_stats`] so facade consumers
    /// cannot tell the fast path from the old authority by `mean_rank`
    /// or `batches`.
    pub fn stats(&self) -> RankStats {
        let mean_rank = if self.batches == 0.0 { 0.0 } else { self.used / self.batches };
        RankStats { mean_rank, batches: self.batches, last: self.last }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_tally_matches_policy_accounting() {
        // The tally must reproduce what a strict BudgetedRankPolicy would
        // have accumulated for the same sequence of merged subset sizes
        // (on the merge path the strict choice is always rank == |out|).
        let mut tally = StrictRankTally::default();
        let mut policy = BudgetedRankPolicy::strict(0.05);
        let errors = vec![0.5; 32];
        for rank in [16usize, 16, 9, 32] {
            let d = tally.record(rank);
            let p = policy.choose(&errors, rank, rank);
            assert_eq!(d.rank, p.rank);
            assert!(d.error.is_finite(), "administrative decision must pass finite checks");
            assert!(d.satisfied);
        }
        let s = tally.stats();
        assert_eq!(s.batches, policy.batches());
        assert_eq!(s.mean_rank, policy.mean_rank());
        assert_eq!(s.last.unwrap().rank, 32);
    }

    #[test]
    fn strict_tally_empty_is_degenerate_like_policy() {
        let tally = StrictRankTally::default();
        let s = tally.stats();
        assert_eq!(s.mean_rank, 0.0);
        assert_eq!(s.batches, 0.0);
        assert_eq!(s.last, None);
    }

    #[test]
    fn choose_rank_smallest_satisfying() {
        let errors = [0.9, 0.5, 0.04, 0.01];
        let d = choose_rank(&errors, 0.05, 1, 4);
        assert_eq!(d.rank, 3);
        assert!(d.satisfied);
        assert!((d.error - 0.04).abs() < 1e-12);
    }

    #[test]
    fn choose_rank_falls_back_to_max() {
        let errors = [0.9, 0.5, 0.4, 0.3];
        let d = choose_rank(&errors, 0.05, 1, 4);
        assert_eq!(d.rank, 4);
        assert!(!d.satisfied);
    }

    #[test]
    fn choose_rank_respects_window() {
        let errors = [0.01, 0.01, 0.01, 0.01];
        let d = choose_rank(&errors, 0.05, 2, 3);
        assert_eq!(d.rank, 2);
    }

    #[test]
    fn budget_controller_averages_to_target() {
        // Errors never satisfied → policy would always take hi; the budget
        // squeeze must pull the mean back toward the target.
        let mut p = BudgetedRankPolicy::adaptive(1e-9, 0.25);
        let errors = vec![1.0; 16];
        for _ in 0..50 {
            p.choose(&errors, 4, 16);
        }
        let mean = p.mean_rank();
        assert!(mean <= 6.5, "mean rank {mean} should hover near target 4");
    }

    #[test]
    fn strict_mode_exact() {
        let mut p = BudgetedRankPolicy::strict(0.05);
        let errors = vec![0.5; 16];
        let d = p.choose(&errors, 7, 16);
        assert_eq!(d.rank, 7);
        assert!(!d.satisfied);
    }

    #[test]
    fn empty_error_curve_is_safe_degenerate() {
        // Regression: a rank-0 kernel depth / K-0 batch used to clamp
        // r_max to 1 and index errors[0] → panic.  Both entry points must
        // return the degenerate decision instead.
        let d = choose_rank(&[], 0.05, 1, 4);
        assert_eq!(d, RankDecision::EMPTY);
        assert_eq!(d.rank, 0);
        assert!(!d.satisfied);

        let mut strict = BudgetedRankPolicy::strict(0.05);
        assert_eq!(strict.choose(&[], 7, 16), RankDecision::EMPTY);
        let mut adaptive = BudgetedRankPolicy::adaptive(0.05, 0.5);
        assert_eq!(adaptive.choose(&[], 7, 16), RankDecision::EMPTY);

        // Degenerate batches stay out of the budget accounting: a later
        // real batch sees the same window as a fresh policy would.
        assert_eq!(adaptive.batches(), 0.0);
        assert_eq!(adaptive.mean_rank(), 0.0);
        let errors = vec![0.01; 8];
        let d = adaptive.choose(&errors, 4, 8);
        assert_eq!(d.rank, 1, "first real batch decided as if no empty batches happened");
        assert_eq!(adaptive.batches(), 1.0);
    }

    #[test]
    fn aligned_batches_use_fewer_samples() {
        // Fig 2b: when alignment is high (errors drop fast) R* is small.
        let mut p = BudgetedRankPolicy::adaptive(0.05, 0.5);
        let fast_drop: Vec<f64> = (0..16).map(|r| 0.8f64.powi(r as i32 + 1) * 0.1).collect();
        let slow_drop: Vec<f64> = (0..16).map(|r| 1.0 - (r as f64 + 1.0) / 20.0).collect();
        let d_fast = p.choose(&fast_drop, 8, 16);
        let d_slow = p.choose(&slow_drop, 8, 16);
        assert!(d_fast.rank < d_slow.rank);
    }
}

//! Deterministic RNG substrate (no external `rand` crate in the vendored
//! dependency closure): SplitMix64 core + Box-Muller normals + Fisher-Yates.
//!
//! Every experiment in this repo is seeded through this module, so sweeps
//! and tables are bit-reproducible across runs.

/// SplitMix64 — tiny, statistically solid for simulation workloads, and
/// splittable (`fork`) so parallel workers get independent streams.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal from Box-Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (used by worker threads / per-batch
    /// selection so reordering work never changes the numbers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias < 2^-64, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k ≤ n), order random.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose({k}) from {n}");
        // Partial Fisher-Yates over an index table.
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_uniformity() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(4);
        let p = r.permutation(57);
        let mut seen = vec![false; 57];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let k = r.below(20) + 1;
            let sel = r.choose(100, k);
            assert_eq!(sel.len(), k);
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}

//! Training telemetry: loss/accuracy curves and run summaries, with CSV
//! output for the figure harnesses.

/// One point on the training curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub step: usize,
    pub epoch: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    pub co2_kg: f64,
    pub wall_secs: f64,
}

/// Aggregated outcome of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    pub dataset: String,
    pub fraction: f64,
    pub final_acc: f64,
    pub best_acc: f64,
    pub co2_kg: f64,
    pub energy_kwh: f64,
    pub wall_secs: f64,
    pub steps: usize,
    pub curve: Vec<CurvePoint>,
    /// Mean selected subset size per refresh (GRAFT telemetry).
    pub mean_rank: f64,
    /// Total duplicate winner rows dropped across refreshes.  Every
    /// selector pins unique winners, so anything non-zero means some
    /// refresh handed back duplicates and trained on fewer rows than the
    /// requested budget — previously this shrink was silent.
    pub dup_rows_dropped: usize,
}

impl RunResult {
    pub fn curve_csv(&self) -> String {
        let mut out = String::from("step,epoch,train_loss,test_acc,co2_kg,wall_secs\n");
        for p in &self.curve {
            out.push_str(&format!(
                "{},{},{:.6},{:.4},{:.6},{:.3}\n",
                p.step, p.epoch, p.train_loss, p.test_acc, p.co2_kg, p.wall_secs
            ));
        }
        out
    }

    pub fn summary_row(&self) -> String {
        let mut row = format!(
            "{:<12} {:<14} f={:<5.2} acc={:<7.4} co2={:<9.6}kg kwh={:<9.6} steps={}",
            self.method, self.dataset, self.fraction, self.final_acc, self.co2_kg,
            self.energy_kwh, self.steps
        );
        if self.dup_rows_dropped > 0 {
            row.push_str(&format!(" dup_rows_dropped={}", self.dup_rows_dropped));
        }
        row
    }
}

/// Simple moving-average loss tracker for stable logging.
#[derive(Debug, Clone)]
pub struct LossTracker {
    window: Vec<f64>,
    cap: usize,
}

/// A derived `Default` would set `cap = 0`, skipping the `cap.max(1)`
/// clamp in [`LossTracker::new`] — the first `push` then hits
/// `window.remove(0)` on an empty window and panics.  Delegate instead.
impl Default for LossTracker {
    fn default() -> Self {
        LossTracker::new(1)
    }
}

impl LossTracker {
    pub fn new(cap: usize) -> Self {
        LossTracker { window: Vec::new(), cap: cap.max(1) }
    }

    pub fn push(&mut self, loss: f64) {
        if self.window.len() == self.cap {
            self.window.remove(0);
        }
        self.window.push(loss);
    }

    pub fn mean(&self) -> f64 {
        if self.window.is_empty() {
            f64::NAN
        } else {
            self.window.iter().sum::<f64>() / self.window.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_tracker_window() {
        let mut t = LossTracker::new(3);
        for l in [1.0, 2.0, 3.0, 4.0] {
            t.push(l);
        }
        assert!((t.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn default_loss_tracker_accepts_pushes() {
        // Regression: the derived Default gave cap = 0, so the first push
        // panicked on `window.remove(0)` of an empty window.
        let mut t = LossTracker::default();
        t.push(1.5);
        t.push(2.5);
        assert!((t.mean() - 2.5).abs() < 1e-12, "cap-1 window keeps the latest loss");
        // new(0) keeps being clamped the same way.
        let mut z = LossTracker::new(0);
        z.push(7.0);
        assert!((z.mean() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let r = RunResult {
            method: "graft".into(),
            dataset: "cifar10".into(),
            fraction: 0.25,
            final_acc: 0.88,
            best_acc: 0.89,
            co2_kg: 0.065,
            energy_kwh: 0.18,
            wall_secs: 12.0,
            steps: 100,
            curve: vec![CurvePoint { step: 1, epoch: 0, train_loss: 2.0, test_acc: 0.1, co2_kg: 0.0, wall_secs: 0.1 }],
            mean_rank: 31.5,
            dup_rows_dropped: 0,
        };
        assert_eq!(r.curve_csv().lines().count(), 2);
        assert!(r.summary_row().contains("graft"));
        // The silent-shrink signal stays out of the row when clean and
        // shows up loudly when any refresh dropped duplicate winners.
        assert!(!r.summary_row().contains("dup_rows_dropped"));
        let noisy = RunResult { dup_rows_dropped: 3, ..r };
        assert!(noisy.summary_row().contains("dup_rows_dropped=3"));
    }
}

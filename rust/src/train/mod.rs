//! Training layer: orchestrator (Algorithm 1), LR schedules, energy/CO₂
//! accounting, telemetry, and the loss-landscape scan of Fig 5.

pub mod energy;
pub mod landscape;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use energy::{EnergyMeter, FlopModel, GRID_INTENSITY};
pub use metrics::{CurvePoint, RunResult};
pub use schedule::Schedule;
pub use trainer::{evaluate, load_dataset, run, TrainConfig, TrainOutput};

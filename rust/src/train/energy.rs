//! Energy / CO₂ accounting — the eco2AI substitution (DESIGN.md §2).
//!
//! The paper reports ℰ = P·t·I (power × time × grid intensity, eq. 3-4
//! of the supplement, I = 0.366 kg CO₂/kWh for Germany).  Real metering
//! is hardware-specific, so we use a deterministic analytic model:
//!
//!   energy = FLOPs_executed / η  +  E_host · executions
//!
//! with η an effective FLOPs/J and the FLOPs counted *analytically* per
//! artifact execution and per selection algorithm.  The model is monotone
//! in examples-processed — exactly the quantity subset selection reduces —
//! so method *orderings* and relative savings reproduce the paper's tables
//! even though absolute joules differ from a V100 testbed.

use crate::runtime::ConfigSpec;

/// Grid carbon intensity (kg CO₂ per kWh) — paper's German value.
pub const GRID_INTENSITY: f64 = 0.366;
/// Effective compute efficiency (FLOPs per joule) of the simulated device.
pub const FLOPS_PER_JOULE: f64 = 5.0e10;
/// Fixed host-side energy per artifact execution (J): launch + data
/// movement overhead.  Deterministic (work-proportional) rather than
/// wall-clock-based, so emissions reflect the *modeled* device, not the
/// speed of this CPU simulator (interpret-mode Pallas is pathologically
/// slow relative to a compiled kernel; metering it would invert every
/// comparison the paper makes).
pub const HOST_JOULES_PER_EXEC: f64 = 0.05;

/// FLOP costs of the artifact kinds for a config (per execution).
#[derive(Debug, Clone, Copy)]
pub struct FlopModel {
    pub fwd_per_sample: f64,
    pub train_per_sample: f64,
    pub embed_batch: f64,
    pub select_batch: f64,
}

impl FlopModel {
    pub fn for_spec(spec: &ConfigSpec) -> FlopModel {
        let (d, h, c, k, r, e) = (
            spec.d as f64,
            spec.h as f64,
            spec.c as f64,
            spec.k as f64,
            spec.rmax as f64,
            spec.e as f64,
        );
        // Forward: 2 matmuls; backward ≈ 2× forward (standard estimate).
        let fwd = 2.0 * (d * h + h * c);
        let train = 3.0 * fwd;
        // embed: forward + sketch + subspace iteration
        //   subspace iter: (2q+1) passes of K·D·R plus MGS K·R² sweeps.
        let power_iters = 2.0;
        let subspace = (2.0 * power_iters + 1.0) * 2.0 * k * d * r + (power_iters + 1.0) * 2.0 * k * r * r;
        let sketch = 2.0 * k * h * c;
        let embed = k * fwd + sketch + subspace;
        // select: embed + Fast MaxVol (2KR²) + prefix MGS (2ER² ×2 passes).
        let select = embed + 2.0 * k * r * r + 4.0 * e * r * r;
        FlopModel {
            fwd_per_sample: fwd,
            train_per_sample: train,
            embed_batch: embed,
            select_batch: select,
        }
    }
}

/// Per-method *selection-algorithm* FLOPs on one batch (Table 1 column):
/// what each baseline spends turning embeddings into a subset.
pub fn selection_flops(method: &str, spec: &ConfigSpec, r: usize) -> f64 {
    let (k, e, rf) = (spec.k as f64, spec.e as f64, r as f64);
    match method {
        // GRAFT's cost is inside the select artifact (Fast MaxVol + sweep).
        "graft" | "graft-warm" | "maxvol" => 0.0,
        "random" => k, // index shuffling only
        "craig" => k * k * e + rf * k * k,          // similarity matrix + greedy
        "gradmatch" => rf * k * e + rf * rf * e,    // OMP scoring + basis updates
        "glister" => rf * k * e,                    // greedy taylor scoring
        "drop" => k,                                // histogram + quotas
        "el2n" => k * e,
        "badge" => rf * k * e,                      // k-means++ distance updates
        "moderate" => k * e,                        // centroid distances
        "forget" => k,
        "cross-maxvol" => 20.0 * 2.0 * k * rf * rf, // alternating sweeps
        _ => k * e,
    }
}

/// Running energy/CO₂ meter for one training run.
#[derive(Debug, Default, Clone)]
pub struct EnergyMeter {
    pub flops: f64,
    pub executions: f64,
    pub wall_seconds: f64,
}

impl EnergyMeter {
    pub fn add_flops(&mut self, f: f64) {
        self.flops += f;
        self.executions += 1.0;
    }

    /// Wall-clock is tracked for reporting only — it does NOT enter the
    /// energy model (see HOST_JOULES_PER_EXEC).
    pub fn add_wall(&mut self, secs: f64) {
        self.wall_seconds += secs;
    }

    /// Total energy in kWh.
    pub fn energy_kwh(&self) -> f64 {
        let joules = self.flops / FLOPS_PER_JOULE + HOST_JOULES_PER_EXEC * self.executions;
        joules / 3.6e6
    }

    /// Emissions in kg CO₂ (ℰ = E · I).
    pub fn co2_kg(&self) -> f64 {
        self.energy_kwh() * GRID_INTENSITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ConfigSpec {
        ConfigSpec {
            name: "t".into(),
            d: 256,
            c: 10,
            h: 128,
            k: 128,
            rmax: 64,
            e: 138,
            buckets: vec![8, 128],
            artifacts: vec![],
        }
    }

    #[test]
    fn flops_positive_and_ordered() {
        let m = FlopModel::for_spec(&spec());
        assert!(m.fwd_per_sample > 0.0);
        assert!(m.train_per_sample > m.fwd_per_sample);
        assert!(m.select_batch > m.embed_batch);
    }

    #[test]
    fn emissions_monotone_in_flops() {
        let mut a = EnergyMeter::default();
        let mut b = EnergyMeter::default();
        a.add_flops(1e12);
        b.add_flops(2e12);
        assert!(b.co2_kg() > a.co2_kg());
        assert!(a.co2_kg() > 0.0);
    }

    #[test]
    fn subset_training_cheaper_than_full() {
        // The core claim of the paper's tables: training on f·N samples
        // costs ≈ f × the full-data energy (selection overhead amortised).
        let spec = spec();
        let m = FlopModel::for_spec(&spec);
        let steps = 1000.0;
        let mut full = EnergyMeter::default();
        full.add_flops(steps * spec.k as f64 * m.train_per_sample);
        let mut sub = EnergyMeter::default();
        sub.add_flops(steps * 32.0 * m.train_per_sample); // f = 0.25
        sub.add_flops((steps / 30.0) * m.select_batch); // periodic refresh
        assert!(sub.energy_kwh() < 0.5 * full.energy_kwh());
    }

    #[test]
    fn craig_selection_costlier_than_graft() {
        let s = spec();
        assert!(selection_flops("craig", &s, 32) > selection_flops("graft", &s, 32));
        assert!(selection_flops("gradmatch", &s, 32) > selection_flops("random", &s, 32));
    }
}

//! Loss-landscape scan (paper Fig 5 / Li et al. 2018): evaluate the loss
//! on a 2-D grid θ + α·δ₁ + β·δ₂ with filter-normalised random directions,
//! comparing GRAFT-trained vs full-data-trained minima.

use anyhow::Result;

use crate::data::Dataset;
use crate::rng::Rng;
use crate::runtime::{ConfigSpec, Engine, ModelParams};

/// A random direction in parameter space, filter-normalised per tensor
/// (each direction tensor rescaled to the norm of the corresponding
/// parameter tensor — the Li et al. convention).
pub struct Direction {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

fn norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

impl Direction {
    pub fn random(params: &ModelParams, seed: u64) -> Direction {
        let mut rng = Rng::new(seed);
        let gen = |like: &[f32], rng: &mut Rng| -> Vec<f32> {
            let mut d: Vec<f32> = (0..like.len()).map(|_| rng.normal() as f32).collect();
            let (nd, np) = (norm(&d), norm(like));
            let scale = if nd > 1e-12 { (np / nd.max(1e-12)) as f32 } else { 0.0 };
            for x in d.iter_mut() {
                *x *= scale;
            }
            d
        };
        Direction {
            w1: gen(&params.w1, &mut rng),
            b1: gen(&params.b1, &mut rng),
            w2: gen(&params.w2, &mut rng),
            b2: gen(&params.b2, &mut rng),
        }
    }
}

fn displaced(p: &ModelParams, d1: &Direction, d2: &Direction, a: f32, b: f32) -> ModelParams {
    let comb = |p: &[f32], x: &[f32], y: &[f32]| -> Vec<f32> {
        p.iter().zip(x).zip(y).map(|((&p, &x), &y)| p + a * x + b * y).collect()
    };
    ModelParams {
        w1: comb(&p.w1, &d1.w1, &d2.w1),
        b1: comb(&p.b1, &d1.b1, &d2.b1),
        w2: comb(&p.w2, &d1.w2, &d2.w2),
        b2: comb(&p.b2, &d1.b2, &d2.b2),
    }
}

/// Scan the loss surface on a (2·half+1)² grid over [−radius, radius]².
/// Returns the row-major grid of mean losses over the probe batch.
#[allow(clippy::too_many_arguments)]
pub fn scan(
    engine: &mut Engine,
    config: &str,
    spec: &ConfigSpec,
    params: &ModelParams,
    probe: &Dataset,
    half_points: usize,
    radius: f32,
    seed: u64,
) -> Result<Vec<Vec<f64>>> {
    let d1 = Direction::random(params, seed);
    let d2 = Direction::random(params, seed ^ 0xD1EC7102);
    let mut idx: Vec<usize> = (0..spec.k.min(probe.n)).collect();
    while idx.len() < spec.k {
        idx.push(idx.len() % probe.n);
    }
    let (x, y) = (probe.gather(&idx), probe.one_hot(&idx));
    let n = 2 * half_points + 1;
    let mut grid = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        let a = radius * ((i as f32) - half_points as f32) / half_points.max(1) as f32;
        for j in 0..n {
            let b = radius * ((j as f32) - half_points as f32) / half_points.max(1) as f32;
            let p = displaced(params, &d1, &d2, a, b);
            let (loss, _) = engine.eval_step(config, &p, &x, &y)?;
            grid[i][j] = loss;
        }
    }
    Ok(grid)
}

/// Sharpness proxy: mean loss increase one radius away from the center.
pub fn sharpness(grid: &[Vec<f64>]) -> f64 {
    let n = grid.len();
    let c = n / 2;
    let center = grid[c][c];
    let edges = [grid[0][c], grid[n - 1][c], grid[c][0], grid[c][n - 1]];
    edges.iter().map(|e| e - center).sum::<f64>() / 4.0
}

/// CSV dump of the grid (alpha, beta, loss) for contour plotting.
pub fn grid_csv(grid: &[Vec<f64>], radius: f32) -> String {
    let n = grid.len();
    let h = (n / 2) as f32;
    let mut out = String::from("alpha,beta,loss\n");
    for (i, row) in grid.iter().enumerate() {
        for (j, &v) in row.iter().enumerate() {
            let a = radius * ((i as f32) - h) / h.max(1.0);
            let b = radius * ((j as f32) - h) / h.max(1.0);
            out.push_str(&format!("{a:.4},{b:.4},{v:.6}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direction_is_filter_normalised() {
        let p = ModelParams { w1: vec![1.0; 64], b1: vec![0.5; 8], w2: vec![2.0; 16], b2: vec![0.0; 2] };
        let d = Direction::random(&p, 1);
        assert!((norm(&d.w1) - norm(&p.w1)).abs() / norm(&p.w1) < 1e-5);
        assert!((norm(&d.w2) - norm(&p.w2)).abs() / norm(&p.w2) < 1e-5);
        assert!(norm(&d.b2) < 1e-6); // zero-norm tensor → zero direction
    }

    #[test]
    fn sharpness_of_bowl() {
        let n = 5;
        let mut grid = vec![vec![0.0; n]; n];
        for (i, row) in grid.iter_mut().enumerate() {
            for (j, v) in row.iter_mut().enumerate() {
                let a = i as f64 - 2.0;
                let b = j as f64 - 2.0;
                *v = a * a + b * b;
            }
        }
        assert!((sharpness(&grid) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn grid_csv_rows() {
        let grid = vec![vec![0.0; 3]; 3];
        assert_eq!(grid_csv(&grid, 1.0).lines().count(), 10);
    }
}

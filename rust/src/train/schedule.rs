//! Learning-rate schedules — the paper uses SGD + CosineAnnealing for all
//! image benchmarks and a constant rate for BERT fine-tuning.

/// Learning-rate schedule over total training steps.
#[derive(Debug, Clone, Copy)]
pub enum Schedule {
    Constant { lr: f64 },
    /// η_t = η_min + ½(η₀ − η_min)(1 + cos(π t / T)).
    Cosine { lr0: f64, lr_min: f64, total_steps: usize },
}

impl Schedule {
    pub fn at(&self, step: usize) -> f64 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::Cosine { lr0, lr_min, total_steps } => {
                let t = (step.min(total_steps)) as f64 / total_steps.max(1) as f64;
                lr_min + 0.5 * (lr0 - lr_min) * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_endpoints() {
        let s = Schedule::Cosine { lr0: 0.1, lr_min: 0.001, total_steps: 100 };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(100) - 0.001).abs() < 1e-12);
        assert!((s.at(50) - (0.001 + 0.0495)).abs() < 1e-9);
    }

    #[test]
    fn cosine_monotone_decreasing() {
        let s = Schedule::Cosine { lr0: 0.1, lr_min: 0.0, total_steps: 40 };
        for t in 0..40 {
            assert!(s.at(t + 1) <= s.at(t) + 1e-15);
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 5e-5 };
        assert_eq!(s.at(0), s.at(1_000_000));
    }

    #[test]
    fn clamps_beyond_total() {
        let s = Schedule::Cosine { lr0: 1.0, lr_min: 0.1, total_steps: 10 };
        assert!((s.at(50) - 0.1).abs() < 1e-12);
    }
}

//! The training orchestrator: implements Algorithm 1 end-to-end against
//! the PJRT engine — periodic subset refresh (Stage 1) + masked-subset SGD
//! updates (Stage 2) — for GRAFT, GRAFT-Warm, and every baseline method.
//!
//! Python never runs here: selection and updates execute through the AOT
//! artifacts; Rust owns batching, scheduling, energy accounting and
//! telemetry.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{MergePolicy, SelectWindow, SubsetState};
use crate::data::{corpus, iris, loader::Batcher, synth, Dataset};
use crate::engine::{EngineBuilder, SelectionEngine, StreamingEngine, WindowsError};
use crate::features::FeatureExtractor;
use crate::graft::alignment::AlignmentSample;
use crate::graft::{AlignmentStats, BudgetedRankPolicy};
use crate::rng::Rng;
use crate::runtime::{ConfigSpec, Engine, ModelParams, TrainState};

use super::energy::{selection_flops, EnergyMeter, FlopModel};
use super::metrics::{CurvePoint, LossTracker, RunResult};
use super::schedule::Schedule;

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Dataset / artifact config name (cifar10, …, imdb, iris).
    pub dataset: String,
    /// full | graft | graft-warm | random | craig | gradmatch | glister |
    /// drop | el2n | forget | cross-maxvol.
    pub method: String,
    /// Data fraction f ∈ (0, 1]; forced to 1.0 for `full`.
    pub fraction: f64,
    /// Passes over the active training set.
    pub epochs: usize,
    /// Subset refresh period in active-set epochs (paper: BERT refreshes
    /// every 10 epochs, image runs every ~5).
    pub refresh_epochs: usize,
    /// Initial learning rate (cosine-annealed to lr0/100).
    pub lr0: f64,
    pub momentum: f64,
    /// Projection-error threshold ε for dynamic rank (GRAFT only).
    pub epsilon: f64,
    /// Full-data warm-up epochs (GRAFT-Warm).
    pub warm_epochs: usize,
    /// When true GRAFT adapts R* per batch (dynamic rank); when false it
    /// takes exactly f·K per batch (strict budget, used by the sweeps so
    /// fractions are comparable across methods).
    pub adaptive_rank: bool,
    /// Optional Rust-side feature extractor (svd | pca | ica | ae) for the
    /// GRAFT path: replaces the AOT subspace features in the selection
    /// stage (Fig 4 / Table 3 ablation).  None = AOT `select` artifact.
    pub extractor: Option<String>,
    /// Selection shards for the Rust-side selection paths.  `1` =
    /// single-shot, bit-identical to the pre-shard pipeline; `>1` fans
    /// each K-window across worker shards and merges the winners with a
    /// second-stage MaxVol ([`crate::coordinator::shard`]).  Only
    /// MaxVol-criterion selectors shard ([`crate::selection::Selector::shardable`]:
    /// maxvol, cross-maxvol, and the GRAFT extractor path); other
    /// methods ignore the knob and run single-shot, because the MaxVol
    /// merge would rewrite their selection criterion.  The AOT `select`
    /// artifact path is likewise unaffected — its selection runs inside
    /// the compiled kernel.
    pub shards: usize,
    /// How per-shard winners are merged when `shards > 1`:
    /// `hierarchical`/`flat` reduce by feature-space MaxVol only; `grad`
    /// additionally recomputes the prefix projection errors of the global
    /// ḡ over the merged pivot order and applies one coordinator-level
    /// dynamic-rank decision.  `grad` is the CLI default for GRAFT (it
    /// restores the paper's criterion on the sharded path) and behaves
    /// exactly like `hierarchical` for selectors without a rank stage.
    pub merge: MergePolicy,
    /// Persistent selection worker pool for the Rust-side selection
    /// paths.  `0` (the default) keeps the PR 2 behaviour: shard fan-out
    /// on per-refresh scoped threads.  `>= 1` routes shard jobs through a
    /// long-lived [`crate::coordinator::pool::SelectionPool`] of that many
    /// workers instead — results are bit-identical at any worker count
    /// (pinned by `rust/tests/selection_pool.rs`), refreshes stop paying
    /// per-refresh thread spawns, and the pool is what `overlap` runs on.
    pub pool_workers: usize,
    /// Overlap next-window assembly (`gather` + `embed` + extractor) with
    /// the in-flight shard selection of the previous window.  Requires
    /// `pool_workers >= 1` (ignored with a note otherwise).  The training
    /// trajectory is identical with the flag on or off: window assembly
    /// never depends on selection results, so only the wall-clock changes.
    pub overlap: bool,
    /// Stream each refresh window through the bounded-memory
    /// [`StreamingEngine`](crate::engine::StreamingEngine) in chunks of
    /// this many rows instead of batch-selecting it whole.  `0` (the
    /// default) keeps batch selection.  Applies to the Rust-side
    /// MaxVol-criterion paths (GRAFT with `--extractor`, and the
    /// maxvol/fast-maxvol baselines); other methods note and ignore the
    /// knob, like the shardability fallbacks.  Selections are
    /// bit-identical to batch mode at any chunk size whenever the window
    /// fits the reservoir (`K ≤ max(2·budget, feature width)`).
    pub stream_chunk: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // The method-aware merge default is derived from the method
        // field, not hardcoded, so the rule stays in one place
        // (`engine::default_merge`, shared with the CLI).  Struct-update
        // callers overriding `method` to a baseline may keep the GRAFT
        // default: a gradient-aware merge without a rank authority is
        // bitwise the hierarchical one (pinned in merge.rs tests), so it
        // stays correct — the CLI path re-derives it anyway.
        let method = String::from("graft");
        TrainConfig {
            dataset: "cifar10".into(),
            merge: crate::engine::default_merge(&method),
            method,
            fraction: 0.25,
            epochs: 30,
            refresh_epochs: 5,
            lr0: 0.1,
            momentum: 0.9,
            epsilon: 0.1,
            warm_epochs: 3,
            adaptive_rank: false,
            extractor: None,
            shards: 1,
            pool_workers: 0,
            overlap: false,
            stream_chunk: 0,
            seed: 42,
        }
    }
}

/// Load the Rust-side dataset matching an artifact config name.
pub fn load_dataset(name: &str) -> Result<Dataset> {
    if let Some(spec) = synth::spec(name) {
        return Ok(synth::synth_dataset(&spec));
    }
    match name {
        "imdb" => Ok(corpus::synth_imdb(6000, 128, 0x13DB)),
        "iris" => {
            // Raw Iris is in centimetres; standardize so the shared MLP
            // hyperparameters (lr etc.) transfer.
            let mut ds = iris::iris();
            ds.standardize();
            Ok(ds)
        }
        _ => bail!("unknown dataset '{name}'"),
    }
}

/// Largest train bucket ≤ `want`, floored at the smallest bucket.
fn largest_bucket_leq(spec: &ConfigSpec, want: usize) -> usize {
    spec.buckets.iter().copied().filter(|&b| b <= want).max().unwrap_or(spec.buckets[0])
}

/// Everything a finished run hands back: metrics, Fig-2 telemetry, and
/// the final optimiser state (for landscape scans / pruning).
pub struct TrainOutput {
    pub result: RunResult,
    pub alignment: AlignmentStats,
    pub state: TrainState,
}

/// Run one training configuration to completion.
pub fn run(engine: &mut Engine, cfg: &TrainConfig) -> Result<TrainOutput> {
    let spec = engine.spec(&cfg.dataset)?.clone();
    let flops = FlopModel::for_spec(&spec);
    let ds = load_dataset(&cfg.dataset)?;
    anyhow::ensure!(
        ds.d == spec.d && ds.classes == spec.c,
        "dataset {}×{} does not match artifact config {}×{}",
        ds.d, ds.classes, spec.d, spec.c
    );
    let (train, test) = ds.split(0.8, cfg.seed ^ 0x5917);
    anyhow::ensure!(train.n >= spec.k, "train split smaller than batch K");

    engine.warmup(&cfg.dataset)?;
    let mut meter = EnergyMeter::default();
    let mut state = TrainState::init(&spec, cfg.seed);
    let mut align = AlignmentStats::default();
    let mut losses = LossTracker::new(20);
    let mut curve: Vec<CurvePoint> = Vec::new();
    let t0 = Instant::now();

    let is_full = cfg.method == "full";
    let is_graft = cfg.method.starts_with("graft");
    let r_budget = ((cfg.fraction * spec.k as f64).round() as usize).clamp(1, spec.k);

    if cfg.overlap && cfg.pool_workers == 0 {
        eprintln!("note: --overlap needs a persistent selection pool (--pool-workers >= 1); running serial refreshes");
    }
    // Rust-side selection executes through the typed facade: one
    // `SelectionEngine` per run owns the selector instances in their
    // execution shape (serial / scoped shards / persistent pool), the
    // workspace and result buffers, the validated extractor, and — for
    // GRAFT at shards > 1 under the gradient-aware merge — the single
    // coordinator-level rank authority.  All the method-aware wiring the
    // trainer used to hand-roll lives in `EngineBuilder::build`.
    // Streaming refresh (`--stream-chunk`): the same facade's bounded-
    // memory session replaces batch selection for the MaxVol-criterion
    // Rust-side paths.  Built once per run like the batch engines, so the
    // reservoir buffers warm up once and the adaptive rank authority
    // accumulates across every refresh.
    let stream_ok = (is_graft && cfg.extractor.is_some())
        || matches!(cfg.method.as_str(), "maxvol" | "fast-maxvol");
    let mut stream_eng: Option<StreamingEngine> = if !is_full && cfg.stream_chunk > 0 {
        if stream_ok {
            Some(
                EngineBuilder::from_train_config(cfg)
                    .budget(r_budget)
                    .build_streaming()
                    .context("invalid streaming selection configuration")?,
            )
        } else {
            eprintln!(
                "note: --stream-chunk applies to the Rust-side MaxVol selection paths \
                 (graft with --extractor, maxvol/fast-maxvol); method '{}' selects in \
                 batch mode",
                cfg.method
            );
            None
        }
    } else {
        None
    };
    let streaming = stream_eng.is_some();
    let mut baseline: Option<SelectionEngine> = if !is_full && !is_graft && !streaming {
        Some(
            EngineBuilder::from_train_config(cfg)
                .budget(r_budget)
                .build()
                .context("invalid selection configuration")?,
        )
    } else {
        None
    };
    // GRAFT extractor ablation path: same facade, built once per *run*
    // (not per refresh) so pooled workers — and their warmed
    // workspaces/buffers — live across refreshes.
    let mut graft_eng: Option<SelectionEngine> = if is_graft && cfg.extractor.is_some() && !streaming
    {
        Some(
            EngineBuilder::from_train_config(cfg)
                .budget(r_budget)
                .build()
                .context("invalid selection configuration")?,
        )
    } else {
        None
    };
    let mut policy = if cfg.adaptive_rank {
        BudgetedRankPolicy::adaptive(cfg.epsilon, cfg.fraction)
    } else {
        BudgetedRankPolicy::strict(cfg.epsilon)
    };

    // Step budget for the cosine schedule.
    let warm_epochs = if cfg.method == "graft-warm" { cfg.warm_epochs } else { 0 };
    let full_steps_per_epoch = (train.n / spec.k).max(1);
    let active_n = if is_full { train.n } else { ((train.n as f64) * cfg.fraction) as usize };
    // Batch size stays at (up to) K regardless of the fraction — the paper
    // trains the selected subset with the same batch size as full data.
    // Buckets only shrink when the active set itself is smaller than K.
    let bucket = largest_bucket_leq(&spec, spec.k.min(active_n.max(spec.buckets[0])));
    let active_steps_per_epoch = (active_n / bucket).max(1);
    let total_steps = warm_epochs * full_steps_per_epoch + cfg.epochs * active_steps_per_epoch;
    let sched = Schedule::Cosine { lr0: cfg.lr0, lr_min: cfg.lr0 / 100.0, total_steps };
    let mut global_step = 0usize;

    // ---- GRAFT-Warm: full-data warm-up ----
    if warm_epochs > 0 {
        let mut b = Batcher::new(&train, spec.k, cfg.seed ^ 0x3A31);
        for _ in 0..warm_epochs * full_steps_per_epoch {
            let rows: Vec<usize> = b.next_batch().to_vec();
            let (x, y) = (train.gather(&rows), train.one_hot(&rows));
            let w = vec![1.0 / spec.k as f32; spec.k];
            let lr = sched.at(global_step) as f32;
            let loss = engine.train_step(
                &cfg.dataset, spec.k, &mut state, &x, &y, &w, lr, cfg.momentum as f32,
            )?;
            meter.add_flops(spec.k as f64 * flops.train_per_sample);
            losses.push(loss);
            global_step += 1;
        }
    }

    // ---- Main loop: refresh → train refresh_epochs on the active set ----
    let mut epoch = 0usize;
    let mut refresh_rng = Rng::new(cfg.seed ^ 0xF5);
    let mut active: Vec<usize> = (0..train.n).collect();
    // Provenance/invariant tracker for the active set: bounds-checks every
    // refresh and counts duplicate winners dropped (surfaced in
    // `RunResult::dup_rows_dropped`).  Training keeps iterating `active`
    // in selection order — `SubsetState` holds the sorted canonical copy,
    // so routing through it does not perturb batch composition.
    let mut subset = SubsetState::full(train.n);
    let mut dup_dropped = 0usize;
    while epoch < cfg.epochs {
        if !is_full {
            active = refresh_subset(
                engine, cfg, &spec, &train, &state.params, r_budget, &mut baseline,
                &mut graft_eng, &mut stream_eng, &mut policy, &mut align, &mut meter, &flops,
                epoch, &mut refresh_rng,
            )?;
            if active.is_empty() {
                bail!("selection produced an empty subset");
            }
            dup_dropped += subset.refresh(active.clone(), epoch, train.n);
            let mut counts = vec![0usize; spec.c];
            for &i in &active {
                counts[train.y[i] as usize] += 1;
            }
            align.record_class_histogram(epoch, counts);
        }

        let sub = train.subset("active", &active);
        let bsize = bucket.min(largest_bucket_leq(&spec, sub.n));
        let mut b = Batcher::new(&sub, bsize, cfg.seed ^ (0xE0 + epoch as u64));
        let inner = cfg.refresh_epochs.min(cfg.epochs - epoch).max(1);
        for _ in 0..inner {
            for _ in 0..b.batches_per_epoch().max(1) {
                let rows: Vec<usize> = b.next_batch().to_vec();
                let (x, y) = (sub.gather(&rows), sub.one_hot(&rows));
                let w = vec![1.0 / rows.len() as f32; rows.len()];
                let lr = sched.at(global_step) as f32;
                let loss = engine.train_step(
                    &cfg.dataset, rows.len(), &mut state, &x, &y, &w, lr, cfg.momentum as f32,
                )?;
                meter.add_flops(rows.len() as f64 * flops.train_per_sample);
                losses.push(loss);
                global_step += 1;
            }
            epoch += 1;
            let acc = evaluate(engine, &cfg.dataset, &spec, &state.params, &test, &mut meter, &flops)?;
            curve.push(CurvePoint {
                step: global_step,
                epoch,
                train_loss: losses.mean(),
                test_acc: acc,
                co2_kg: meter.co2_kg(),
                wall_secs: t0.elapsed().as_secs_f64(),
            });
            if epoch >= cfg.epochs {
                break;
            }
        }
    }

    meter.add_wall(t0.elapsed().as_secs_f64());
    let final_acc = curve.last().map(|p| p.test_acc).unwrap_or(0.0);
    let best_acc = curve.iter().map(|p| p.test_acc).fold(0.0f64, f64::max);
    Ok(TrainOutput {
        result: RunResult {
            method: cfg.method.clone(),
            dataset: cfg.dataset.clone(),
            fraction: if is_full { 1.0 } else { cfg.fraction },
            final_acc,
            best_acc,
            co2_kg: meter.co2_kg(),
            energy_kwh: meter.energy_kwh(),
            wall_secs: t0.elapsed().as_secs_f64(),
            steps: global_step,
            curve,
            // Extractor-path runs read the engine's single rank
            // accumulator (the gradient-merge authority, or the one-shard
            // selector itself); the AOT path keeps its own policy.  Known
            // gap: a one-shard *pool* hosts its selector on a worker
            // thread, reports no stats, and falls back to 0.0 like the
            // pre-PR4 extractor path.
            mean_rank: graft_eng
                .as_ref()
                .and_then(|e| e.rank_stats())
                .or_else(|| stream_eng.as_ref().and_then(|e| e.rank_stats()))
                .map(|s| s.mean_rank)
                .unwrap_or_else(|| policy.mean_rank()),
            dup_rows_dropped: dup_dropped,
        },
        alignment: align,
        state,
    })
}

/// Stage 1 of Algorithm 1: scan the training set in K-windows and select a
/// per-batch subset; returns the aggregated active row set S^t.
///
/// The AOT `select` path stays serial against the engine (its selection
/// runs inside the compiled kernel).  The Rust-side paths — baselines and
/// the GRAFT extractor ablation — are expressed as assemble/consume
/// closures over [`SelectWindow`]s handed to
/// [`SelectionEngine::windows`], which owns the execution-shape dispatch
/// and the assemble ∥ select overlap pipeline: with a pooled shape and
/// `overlap` on it assembles (gather + `embed` + extractor) window
/// `w + 1` while the pool workers select window `w`; otherwise the loop
/// runs serially, step-for-step identical to the pre-engine trainer.
#[allow(clippy::too_many_arguments)]
fn refresh_subset(
    engine: &mut Engine,
    cfg: &TrainConfig,
    spec: &ConfigSpec,
    train: &Dataset,
    params: &ModelParams,
    r_budget: usize,
    baseline: &mut Option<SelectionEngine>,
    graft_eng: &mut Option<SelectionEngine>,
    stream_eng: &mut Option<StreamingEngine>,
    policy: &mut BudgetedRankPolicy,
    align: &mut AlignmentStats,
    meter: &mut EnergyMeter,
    flops: &FlopModel,
    epoch: usize,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    let mut active = Vec::new();
    let mut order: Vec<usize> = (0..train.n).collect();
    rng.shuffle(&mut order);
    // Only full K-windows select; the shuffled tail shorter than K is
    // skipped, exactly as the pre-pool loop did by breaking early
    // (`run` ensures train.n >= K, so there is at least one window).
    let windows = train.n / spec.k;
    let is_ext = cfg.method.starts_with("graft") && cfg.extractor.is_some();
    if cfg.method.starts_with("graft") && !is_ext && stream_eng.is_none() {
        // AOT `select` artifact path: selection runs inside the compiled
        // kernel, so there is nothing to shard, pool, or overlap here.
        for wi in 0..windows {
            let rows = &order[wi * spec.k..(wi + 1) * spec.k];
            let (x, y) = (train.gather(rows), train.one_hot(rows));
            let out = engine.select(&cfg.dataset, params, &x, &y)?;
            meter.add_flops(flops.select_batch);
            let decision = policy.choose(&out.errors, r_budget, spec.rmax);
            align.record(AlignmentSample {
                epoch,
                batch: wi,
                cos: out.align,
                rank: decision.rank,
                error: decision.error,
            });
            // Prefix-nested MaxVol order → first R* indices are the rank-R*
            // selection.  Dynamic mode uses R* from the policy; strict mode
            // takes exactly the budget.
            let take = if cfg.adaptive_rank { decision.rank } else { r_budget };
            for &bi in out.indices.iter().take(take.min(out.indices.len())) {
                active.push(rows[bi]);
            }
            if take > out.indices.len() {
                // Budget beyond kernel depth: top up with unselected rows.
                let mut taken = vec![false; spec.k];
                for &bi in &out.indices {
                    taken[bi] = true;
                }
                for bi in (0..spec.k).filter(|&i| !taken[i]).take(take - out.indices.len()) {
                    active.push(rows[bi]);
                }
            }
        }
        return Ok(active);
    }

    // Rust-side selection (baselines / GRAFT extractor ablation): each
    // window is assembled into an owned [`SelectWindow`] so the pool
    // workers can read it while this thread assembles the next one.  The
    // engine hands its validated extractor into the assembly closure and
    // owns the per-window budget, scratch, and result buffers.
    let mut assemble = |wi: usize, ext: Option<&dyn FeatureExtractor>| -> Result<SelectWindow> {
        let rows = &order[wi * spec.k..(wi + 1) * spec.k];
        let (x, y) = (train.gather(rows), train.one_hot(rows));
        let emb = engine.embed(&cfg.dataset, params, &x, &y)?;
        meter.add_flops(flops.embed_batch);
        let labels: Vec<i32> = rows.iter().map(|&i| train.y[i]).collect();
        let (features, grads, losses, preds) = if let Some(ext) = ext {
            // Ablation path (Fig 4): embed for gradient sketches, features
            // from the engine-owned Rust-side extractor.
            let xmat = crate::linalg::Mat::from_f32(spec.k, spec.d, &x);
            // Only r_budget feature columns are consumed by the strict-
            // budget selection; extracting more would pay quadratic
            // extractor cost (Jacobi/ICA) for unused directions.
            let feats = ext.extract(&xmat, r_budget.min(spec.rmax));
            (feats, emb.grads, emb.losses, emb.preds)
        } else {
            meter.add_flops(selection_flops(&cfg.method, spec, r_budget));
            (emb.features, emb.grads, emb.losses, emb.preds)
        };
        Ok(SelectWindow {
            features,
            grads,
            losses,
            labels,
            preds,
            classes: spec.c,
            row_ids: rows.to_vec(),
        })
    };
    // Streaming refresh: each window is assembled once, streamed through
    // the bounded reservoir `--stream-chunk` rows at a time, and
    // snapshotted.  Snapshot indices are already global dataset rows
    // (the reservoir stores `row_ids`), and `reset()` keeps windows
    // independent while the engine-owned rank authority accumulates
    // across them — mirroring the batch facade's single accumulator.
    if let Some(se) = stream_eng.as_mut() {
        let chunk = cfg.stream_chunk.max(1);
        for wi in 0..windows {
            let win = assemble(wi, se.extractor())?;
            let view = win.view();
            let mut lo = 0usize;
            while lo < view.k() {
                let hi = (lo + chunk).min(view.k());
                se.push_range(&view, lo..hi)
                    .map_err(|s| anyhow::Error::new(s).context("streaming selection push"))?;
                lo = hi;
            }
            let snap = se
                .snapshot()
                .map_err(|s| anyhow::Error::new(s).context("streaming selection snapshot"))?;
            active.extend_from_slice(&snap.indices);
            se.reset();
        }
        return Ok(active);
    }

    let consume = |_wi: usize, win: &SelectWindow, winners: &[usize]| {
        for &bi in winners {
            active.push(win.row_ids[bi]);
        }
    };
    let exec = if is_ext {
        graft_eng.as_mut().expect("extractor engine built in run()")
    } else {
        baseline.as_mut().expect("baseline engine")
    };
    exec.windows(windows, assemble, consume).map_err(|e| match e {
        WindowsError::Assemble(err) => err.context("assembling selection window"),
        WindowsError::Select(s) => anyhow::Error::new(s).context("selecting subset"),
    })?;
    Ok(active)
}

/// Accuracy over a dataset (windowed; wrap-padded tails masked exactly
/// thanks to per-sample correctness from `eval_step`).
pub fn evaluate(
    engine: &mut Engine,
    config: &str,
    spec: &ConfigSpec,
    params: &ModelParams,
    test: &Dataset,
    meter: &mut EnergyMeter,
    flops: &FlopModel,
) -> Result<f64> {
    let mut correct = 0usize;
    let mut seen = 0usize;
    for (idx, valid) in Batcher::eval_windows(test.n, spec.k) {
        let (x, y) = (test.gather(&idx), test.one_hot(&idx));
        let (_, cvec) = engine.eval_step(config, params, &x, &y)?;
        correct += cvec[..valid].iter().filter(|&&c| c == 1).count();
        seen += valid;
    }
    // Test-set evaluation is reporting, not training: the paper meters the
    // training process (eco2AI wraps the train loop), so eval stays out of
    // the energy account.  `meter`/`flops` kept in the signature for call
    // sites that want to attribute it anyway.
    let _ = (meter, flops);
    Ok(correct as f64 / seen.max(1) as f64)
}

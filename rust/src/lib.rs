//! GRAFT — Gradient-Aware Fast MaxVol Technique for Dynamic Data Sampling.
//!
//! Reproduction of Jha et al. (2025) as a three-layer Rust + JAX + Pallas
//! system: this crate is the Layer-3 coordinator (streaming training
//! orchestrator, selection methods, evaluation harness); Layers 1-2 are
//! AOT-compiled to HLO artifacts by `python/compile` and executed here
//! through the PJRT C API (`runtime`).
//!
//! # Which API do I use?
//!
//! * **Selecting subsets from batches** — almost always [`engine`]: build
//!   a [`engine::SelectionEngine`] with [`engine::EngineBuilder`] (method,
//!   fraction/budget, typed [`engine::ExecShape`], merge policy, rank
//!   mode, extractor, seed) and call
//!   [`select`](engine::SelectionEngine::select) per batch or
//!   [`windows`](engine::SelectionEngine::windows) for a streaming
//!   session.  The engine owns selector construction, cross-knob
//!   validation, workspaces, the sharded/pooled execution shapes, and the
//!   gradient-merge rank authority, and returns first-class
//!   [`engine::Selection`] results.  See the quickstart in the [`engine`]
//!   module docs and `examples/quickstart.rs`.
//! * **Selecting from unbounded streams** — [`engine::StreamingEngine`],
//!   built with [`engine::EngineBuilder::build_streaming`]: rows arrive
//!   in chunks via [`push`](engine::StreamingEngine::push), a bounded
//!   reservoir (≤ 2·budget candidates) is maintained by incremental
//!   MaxVol swaps, gradient sketches accumulate into running partial
//!   sums, and [`snapshot`](engine::StreamingEngine::snapshot) applies
//!   the rank authority to the current reservoir — memory stays O(r·E)
//!   however long the stream runs.  A stream that fits the reservoir
//!   reproduces the batch selection bit for bit, at any chunking.
//!   CLI: `--stream-chunk N` on `train`.
//! * **Selection as a shared service** — [`serve`]: the `graft serve`
//!   daemon hosts N tenant engines (batch and streaming) behind a
//!   versioned length-prefixed binary protocol over TCP/Unix sockets,
//!   with per-tenant config validated by the same [`engine::EngineBuilder`],
//!   typed `Busy`/`Rejected` backpressure instead of unbounded queueing,
//!   drain-on-disconnect, and a `Stats` endpoint emitting graft-bench-v1
//!   telemetry.  Served selections are bit-identical to an in-process
//!   engine with the same config/seed.  See the frame table and loopback
//!   quickstart in the [`serve`] module docs; CLI: `graft serve` /
//!   `graft serve-smoke`.
//! * **Whole training runs** — [`train::run`] with a [`train::TrainConfig`]
//!   (the CLI's `train` subcommand); it drives the AOT artifacts through
//!   [`runtime`] and builds its Rust-side selection through the engine.
//! * **Implementing a new selection method** — the [`selection::Selector`]
//!   trait; register it in [`selection::by_name`] and the engine picks it
//!   up everywhere.
//! * **Coordinator internals** (shard fan-out, merge tournaments, worker
//!   pool, batch pipelines) — [`coordinator`], which the engine wraps.
//!   Construct [`coordinator::ShardedSelector`] /
//!   [`coordinator::PooledSelector`] directly only in tests and benches
//!   that pin the engine against them; application code goes through the
//!   facade (CI greps for violations).
//!
//! ```
//! use graft::engine::{EngineBuilder, ExecShape};
//! # use graft::linalg::Mat;
//! # use graft::selection::BatchView;
//! # let k = 8;
//! # let mut rng = graft::rng::Rng::new(7);
//! # let features = Mat::from_fn(k, 3, |_, _| rng.normal());
//! # let grads = Mat::from_fn(k, 4, |_, _| rng.normal());
//! # let losses = vec![1.0; k];
//! # let labels = vec![0i32; k];
//! # let preds = vec![0i32; k];
//! # let row_ids: Vec<usize> = (0..k).collect();
//! # let batch = BatchView { features: &features, grads: &grads, losses: &losses,
//! #     labels: &labels, preds: &preds, classes: 2, row_ids: &row_ids };
//! let mut eng = EngineBuilder::new()
//!     .method("graft")
//!     .fraction(0.5)
//!     .exec(ExecShape::Sharded { shards: 2 })
//!     .build()
//!     .expect("valid configuration");
//! let want = eng.budget_for(k);
//! let sel = eng.select(&batch).expect("selection fault");
//! assert_eq!(sel.indices.len(), want);
//! ```
//!
//! # Fault tolerance
//!
//! Selection can fail — a worker thread panics, the input batch carries
//! NaN rows, the MaxVol factorisation degenerates.  The engine surfaces
//! all of it through one typed ladder (see [`coordinator::fault`] and
//! `rust/src/coordinator/README.md`, "Failure modes & degradation
//! ladder"):
//!
//! * [`engine::SelectError`] — the error taxonomy:
//!   `PoisonedInput { rows }` (non-finite rows, found by a vectorized
//!   pre-scan), `NumericalBreakdown { stage, .. }` (degenerate pivots /
//!   non-finite rank error), `ShardFailure { shard, attempts }` (a shard
//!   job panicked or its worker died), and `PoolUnavailable` (selecting
//!   after shutdown).
//! * [`engine::FaultPolicy`] — what the engine does about it.
//!   `Fail` (default) returns the error; `Retry { max, backoff }`
//!   respawns dead workers and re-runs the same inputs on identically
//!   constructed selectors, so a successful retry is **bit-identical** to
//!   the fault-free run; `Degrade` quarantines poisoned rows and walks
//!   GRAFT → feature-only MaxVol → seeded-random, recording every rung in
//!   [`engine::Selection::degradations`].
//! * [`engine::SelectionEngine::fault_stats`] — respawn / retry /
//!   requeue / quarantine counters ([`engine::PoolStats`]).
//!
//! Zero-fault runs are bit-identical under every policy:
//!
//! ```
//! use graft::engine::{EngineBuilder, ExecShape, FaultPolicy};
//! # use graft::linalg::Mat;
//! # use graft::selection::BatchView;
//! # let k = 8;
//! # let mut rng = graft::rng::Rng::new(7);
//! # let features = Mat::from_fn(k, 3, |_, _| rng.normal());
//! # let grads = Mat::from_fn(k, 4, |_, _| rng.normal());
//! # let losses = vec![1.0; k];
//! # let labels = vec![0i32; k];
//! # let preds = vec![0i32; k];
//! # let row_ids: Vec<usize> = (0..k).collect();
//! # let batch = BatchView { features: &features, grads: &grads, losses: &losses,
//! #     labels: &labels, preds: &preds, classes: 2, row_ids: &row_ids };
//! let build = |policy: FaultPolicy| {
//!     EngineBuilder::new()
//!         .method("graft")
//!         .budget(4)
//!         .exec(ExecShape::Serial)
//!         .fault_policy(policy)
//!         .build()
//!         .expect("valid configuration")
//! };
//! let mut fail = build(FaultPolicy::Fail);
//! let mut degrade = build(FaultPolicy::Degrade);
//! let a = fail.select(&batch).expect("healthy").indices.to_vec();
//! let b = degrade.select(&batch).expect("healthy").indices.to_vec();
//! assert_eq!(a, b, "zero-fault runs are policy-invariant");
//! assert_eq!(degrade.fault_stats().retries, 0);
//! ```
//!
//! The deterministic fault-injection harness behind the fault suites
//! lives in [`faults`] ([`faults::FaultPlan`] — seeded, replayable
//! schedules of panics / delays / worker deaths).
//!
//! # Performance tuning
//!
//! The hot path is tuned out of the box; three knobs exist for unusual
//! deployments:
//!
//! * **Parallel kernel threshold** — [`linalg::par_min_flops`] is the
//!   flop count above which `Mat::matmul` / `Mat::gram` fan out across
//!   threads (below it the portable 4-lane SIMD kernels run serially).
//!   Override with the `GRAFT_PAR_MIN_FLOPS` environment variable
//!   (`0` forces threading, `18446744073709551615` pins the serial lane
//!   kernels; unparsable values fall back to the default).  Read once
//!   per process.  Results are shape-identical either way — the CI
//!   `kernel-parity` job runs the property suites at both extremes.
//! * **f32 gradient sketches** —
//!   [`engine::EngineBuilder::sketch_f32`] narrows the gradient-sketch
//!   columns carried across the shard → merge boundary to f32, halving
//!   merge bandwidth and pool-message memory on adaptive sharded/pooled/
//!   streaming engines.  Pivot order is computed on f64 features, so
//!   only the adaptive rank cut can differ — by at most one on generic
//!   data, not at all on well-separated batches (`tests/sketch_f32.rs`).
//!   Default off: f64 sketches, bitwise legacy behaviour.
//! * **Adaptive-only gradient carry** — automatic, not a knob: strict
//!   (fixed-budget) engines ship **zero** gradient-sketch bytes between
//!   shards and the merge, because the strict post-merge cut is provably
//!   the identity; the surfaced [`graft::RankDecision`] is synthesised
//!   by [`graft::StrictRankTally`].  See "Adaptive-only gradient carry"
//!   in `rust/src/coordinator/README.md`.
//!
//! Kernel-level throughput is priced by `cargo bench --bench
//! simd_kernels` (`matmul_simd` / `gram_simd` / `mgs_simd` rows) and the
//! carry saving by the `select_strict_nocarry` family in `cargo bench
//! --bench sharded_selection`; `scripts/bench_compare.py` diffs two
//! graft-bench-v1 documents with per-family regression thresholds.
//!
//! # Evaluating selectors
//!
//! The [`scenarios`] module is the offline evaluation harness: a
//! deterministic matrix of data pathologies (class imbalance, label
//! noise, mid-stream shift, curriculum ordering) × the full selector
//! roster × execution shapes × budget fractions, scored on
//! gradient-approximation error, class coverage, a loss proxy, and a
//! nearest-centroid probe, emitted as `graft-scenario-v1` JSON rows
//! (CLI: `graft scenarios --smoke`).  Same config, same bytes — the CI
//! `scenario-smoke` job diffs two runs.  One cell of the matrix, by
//! hand:
//!
//! ```
//! use graft::engine::{EngineBuilder, PivotMode};
//! use graft::scenarios::{scenario_windows, subset_metrics, Axis, GenConfig};
//!
//! let mut cfg = GenConfig::smoke();
//! cfg.n = 96;
//! cfg.windows = 2;
//! let windows = scenario_windows(Axis::LabelNoise(0.2), &cfg);
//! let mut eng = EngineBuilder::new()
//!     .method("graft")
//!     .pivot(PivotMode::GradAware) // gradient-aware pivot ordering
//!     .fraction(0.25)
//!     .build()
//!     .expect("valid configuration");
//! let sel = eng.select(&windows[0].view()).expect("healthy").indices.to_vec();
//! let m = subset_metrics(&windows[0], &sel);
//! assert!(m.grad_error <= 1.0 && m.coverage > 0.0);
//! ```

// Numeric-kernel lint posture: index-based loops mirror the maths (and the
// Pallas kernels they twin), and the orchestration layers legitimately
// pass many knobs; keep clippy's style lints quiet about both crate-wide
// so `-D warnings` in CI stays meaningful for correctness lints.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]

pub mod cmd;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod faults;
pub mod features;
pub mod linalg;
pub mod pruning;
pub mod rng;
pub mod runtime;
pub mod graft;
pub mod scenarios;
pub mod selection;
pub mod serve;
pub mod train;

//! GRAFT — Gradient-Aware Fast MaxVol Technique for Dynamic Data Sampling.
//!
//! Reproduction of Jha et al. (2025) as a three-layer Rust + JAX + Pallas
//! system: this crate is the Layer-3 coordinator (streaming training
//! orchestrator, selection methods, evaluation harness); Layers 1-2 are
//! AOT-compiled to HLO artifacts by `python/compile` and executed here
//! through the PJRT C API (`runtime`).
//!
//! # Which API do I use?
//!
//! * **Selecting subsets from batches** — almost always [`engine`]: build
//!   a [`engine::SelectionEngine`] with [`engine::EngineBuilder`] (method,
//!   fraction/budget, typed [`engine::ExecShape`], merge policy, rank
//!   mode, extractor, seed) and call
//!   [`select`](engine::SelectionEngine::select) per batch or
//!   [`windows`](engine::SelectionEngine::windows) for a streaming
//!   session.  The engine owns selector construction, cross-knob
//!   validation, workspaces, the sharded/pooled execution shapes, and the
//!   gradient-merge rank authority, and returns first-class
//!   [`engine::Selection`] results.  See the quickstart in the [`engine`]
//!   module docs and `examples/quickstart.rs`.
//! * **Whole training runs** — [`train::run`] with a [`train::TrainConfig`]
//!   (the CLI's `train` subcommand); it drives the AOT artifacts through
//!   [`runtime`] and builds its Rust-side selection through the engine.
//! * **Implementing a new selection method** — the [`selection::Selector`]
//!   trait; register it in [`selection::by_name`] and the engine picks it
//!   up everywhere.
//! * **Coordinator internals** (shard fan-out, merge tournaments, worker
//!   pool, batch pipelines) — [`coordinator`], which the engine wraps.
//!   Construct [`coordinator::ShardedSelector`] /
//!   [`coordinator::PooledSelector`] directly only in tests and benches
//!   that pin the engine against them; application code goes through the
//!   facade (CI greps for violations).
//!
//! ```
//! use graft::engine::{EngineBuilder, ExecShape};
//! # use graft::linalg::Mat;
//! # use graft::selection::BatchView;
//! # let k = 8;
//! # let mut rng = graft::rng::Rng::new(7);
//! # let features = Mat::from_fn(k, 3, |_, _| rng.normal());
//! # let grads = Mat::from_fn(k, 4, |_, _| rng.normal());
//! # let losses = vec![1.0; k];
//! # let labels = vec![0i32; k];
//! # let preds = vec![0i32; k];
//! # let row_ids: Vec<usize> = (0..k).collect();
//! # let batch = BatchView { features: &features, grads: &grads, losses: &losses,
//! #     labels: &labels, preds: &preds, classes: 2, row_ids: &row_ids };
//! let mut eng = EngineBuilder::new()
//!     .method("graft")
//!     .fraction(0.5)
//!     .exec(ExecShape::Sharded { shards: 2 })
//!     .build()
//!     .expect("valid configuration");
//! let want = eng.budget_for(k);
//! let sel = eng.select(&batch);
//! assert_eq!(sel.indices.len(), want);
//! ```

// Numeric-kernel lint posture: index-based loops mirror the maths (and the
// Pallas kernels they twin), and the orchestration layers legitimately
// pass many knobs; keep clippy's style lints quiet about both crate-wide
// so `-D warnings` in CI stays meaningful for correctness lints.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]

pub mod cmd;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod features;
pub mod linalg;
pub mod pruning;
pub mod rng;
pub mod runtime;
pub mod graft;
pub mod selection;
pub mod train;

//! GRAFT — Gradient-Aware Fast MaxVol Technique for Dynamic Data Sampling.
//!
//! Reproduction of Jha et al. (2025) as a three-layer Rust + JAX + Pallas
//! system: this crate is the Layer-3 coordinator (streaming training
//! orchestrator, selection methods, evaluation harness); Layers 1-2 are
//! AOT-compiled to HLO artifacts by `python/compile` and executed here
//! through the PJRT C API (`runtime`).

// Numeric-kernel lint posture: index-based loops mirror the maths (and the
// Pallas kernels they twin), and the orchestration layers legitimately
// pass many knobs; keep clippy's style lints quiet about both crate-wide
// so `-D warnings` in CI stays meaningful for correctness lints.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]

pub mod cmd;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod features;
pub mod linalg;
pub mod pruning;
pub mod rng;
pub mod runtime;
pub mod graft;
pub mod selection;
pub mod train;

//! Subset cache S^t (Algorithm 1): the active training rows between
//! refreshes, with provenance for invariant checking.

/// The active subset S^t plus bookkeeping.
#[derive(Debug, Clone)]
pub struct SubsetState {
    /// Global row ids of the active subset (unique).
    active: Vec<usize>,
    /// Epoch at which this subset was selected.
    pub selected_at_epoch: usize,
    /// Generation counter (number of refreshes so far).
    pub generation: usize,
}

impl SubsetState {
    /// Start with the full dataset active (before the first refresh).
    /// `n == 0` is rejected up front: every later `refresh` enforces a
    /// non-empty subset, so an empty initial state could never be
    /// maintained — fail at construction instead of first use.
    pub fn full(n: usize) -> SubsetState {
        assert!(n > 0, "empty dataset");
        SubsetState { active: (0..n).collect(), selected_at_epoch: 0, generation: 0 }
    }

    /// Install a fresh selection; deduplicates and validates.  Returns
    /// the number of duplicate rows dropped — every selector pins unique
    /// winners, so a non-zero count means the caller handed in a
    /// shrunken-below-budget subset and should surface it (the trainer
    /// reports it as [`crate::train::RunResult::dup_rows_dropped`])
    /// instead of training on silently fewer rows.
    #[must_use = "a non-zero count means the active set shrank below the requested budget"]
    pub fn refresh(&mut self, mut rows: Vec<usize>, epoch: usize, n: usize) -> usize {
        let before = rows.len();
        rows.sort_unstable();
        rows.dedup();
        let dropped = before - rows.len();
        assert!(rows.iter().all(|&r| r < n), "subset row out of range");
        assert!(!rows.is_empty(), "empty subset");
        self.active = rows;
        self.selected_at_epoch = epoch;
        self.generation += 1;
        dropped
    }

    pub fn rows(&self) -> &[usize] {
        &self.active
    }

    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Fraction of the dataset currently active.
    pub fn fraction(&self, n: usize) -> f64 {
        self.active.len() as f64 / n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_start() {
        let s = SubsetState::full(10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.generation, 0);
    }

    #[test]
    fn refresh_dedups_and_counts() {
        let mut s = SubsetState::full(100);
        let dropped = s.refresh(vec![5, 3, 5, 7, 3], 2, 100);
        assert_eq!(dropped, 2, "two duplicate rows (5 and 3) must be reported, not hidden");
        assert_eq!(s.rows(), &[3, 5, 7]);
        assert_eq!(s.generation, 1);
        assert_eq!(s.selected_at_epoch, 2);
        assert!((s.fraction(100) - 0.03).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        let mut s = SubsetState::full(10);
        let _ = s.refresh(vec![11], 0, 10);
    }

    #[test]
    #[should_panic]
    fn rejects_empty() {
        let mut s = SubsetState::full(10);
        let _ = s.refresh(vec![], 0, 10);
    }

    #[test]
    #[should_panic]
    fn rejects_empty_dataset() {
        let _ = SubsetState::full(0);
    }

    #[test]
    fn refresh_sorts_unsorted_rows() {
        let mut s = SubsetState::full(100);
        let dropped = s.refresh(vec![42, 7, 99, 0, 63], 1, 100);
        assert_eq!(dropped, 0, "unique rows drop nothing");
        assert_eq!(s.rows(), &[0, 7, 42, 63, 99]);
    }

    #[test]
    fn refresh_accepts_boundary_row() {
        // Row n-1 is in range; row n is the first out-of-range id.
        let mut s = SubsetState::full(10);
        assert_eq!(s.refresh(vec![9], 0, 10), 0);
        assert_eq!(s.rows(), &[9]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_boundary_overflow() {
        let mut s = SubsetState::full(10);
        let _ = s.refresh(vec![10], 0, 10);
    }

    #[test]
    #[should_panic]
    fn rejects_all_duplicates_of_out_of_range() {
        // Dedup happens before validation; a duplicated bad row must
        // still be caught.
        let mut s = SubsetState::full(5);
        let _ = s.refresh(vec![7, 7, 7], 0, 5);
    }

    #[test]
    fn generation_counts_every_refresh() {
        let mut s = SubsetState::full(20);
        for g in 1..=5 {
            assert_eq!(s.refresh((0..g).collect(), g, 20), 0);
            assert_eq!(s.generation, g);
            assert_eq!(s.len(), g);
        }
    }

    #[test]
    fn shrinking_to_singleton_and_back() {
        let mut s = SubsetState::full(8);
        let _ = s.refresh(vec![3], 0, 8);
        assert_eq!(s.rows(), &[3]);
        assert!(!s.is_empty());
        assert!((s.fraction(8) - 0.125).abs() < 1e-12);
        let _ = s.refresh((0..8).collect(), 1, 8);
        assert_eq!(s.len(), 8);
        assert!((s.fraction(8) - 1.0).abs() < 1e-12);
    }
}

//! Subset cache S^t (Algorithm 1): the active training rows between
//! refreshes, with provenance for invariant checking.

/// The active subset S^t plus bookkeeping.
#[derive(Debug, Clone)]
pub struct SubsetState {
    /// Global row ids of the active subset (unique).
    active: Vec<usize>,
    /// Epoch at which this subset was selected.
    pub selected_at_epoch: usize,
    /// Generation counter (number of refreshes so far).
    pub generation: usize,
}

impl SubsetState {
    /// Start with the full dataset active (before the first refresh).
    pub fn full(n: usize) -> SubsetState {
        SubsetState { active: (0..n).collect(), selected_at_epoch: 0, generation: 0 }
    }

    /// Install a fresh selection; deduplicates and validates.
    pub fn refresh(&mut self, mut rows: Vec<usize>, epoch: usize, n: usize) {
        rows.sort_unstable();
        rows.dedup();
        assert!(rows.iter().all(|&r| r < n), "subset row out of range");
        assert!(!rows.is_empty(), "empty subset");
        self.active = rows;
        self.selected_at_epoch = epoch;
        self.generation += 1;
    }

    pub fn rows(&self) -> &[usize] {
        &self.active
    }

    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Fraction of the dataset currently active.
    pub fn fraction(&self, n: usize) -> f64 {
        self.active.len() as f64 / n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_start() {
        let s = SubsetState::full(10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.generation, 0);
    }

    #[test]
    fn refresh_dedups_and_counts() {
        let mut s = SubsetState::full(100);
        s.refresh(vec![5, 3, 5, 7, 3], 2, 100);
        assert_eq!(s.rows(), &[3, 5, 7]);
        assert_eq!(s.generation, 1);
        assert_eq!(s.selected_at_epoch, 2);
        assert!((s.fraction(100) - 0.03).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        let mut s = SubsetState::full(10);
        s.refresh(vec![11], 0, 10);
    }

    #[test]
    #[should_panic]
    fn rejects_empty() {
        let mut s = SubsetState::full(10);
        s.refresh(vec![], 0, 10);
    }
}

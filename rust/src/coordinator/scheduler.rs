//! Refresh scheduling (the "every S iterations" of Algorithm 1), expressed
//! in training steps with an epoch-aligned option.

/// Decides at which steps the subset must be re-selected.
#[derive(Debug, Clone)]
pub struct RefreshScheduler {
    /// Refresh period in steps (S).
    period: usize,
    /// Step of the last refresh (None before the first).
    last: Option<usize>,
}

impl RefreshScheduler {
    pub fn every_steps(period: usize) -> RefreshScheduler {
        RefreshScheduler { period: period.max(1), last: None }
    }

    /// Period expressed in epochs over an active set of `steps_per_epoch`.
    pub fn every_epochs(epochs: usize, steps_per_epoch: usize) -> RefreshScheduler {
        Self::every_steps(epochs.max(1) * steps_per_epoch.max(1))
    }

    /// True when a refresh is due at `step` (always true at step 0).
    pub fn due(&self, step: usize) -> bool {
        match self.last {
            None => true,
            Some(l) => step >= l + self.period,
        }
    }

    /// Record that a refresh happened at `step`.
    pub fn mark(&mut self, step: usize) {
        self.last = Some(step);
    }

    pub fn period(&self) -> usize {
        self.period
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_always_due() {
        let s = RefreshScheduler::every_steps(30);
        assert!(s.due(0));
        assert!(s.due(17));
    }

    #[test]
    fn period_honoured() {
        let mut s = RefreshScheduler::every_steps(30);
        s.mark(0);
        assert!(!s.due(1));
        assert!(!s.due(29));
        assert!(s.due(30));
        s.mark(30);
        assert!(!s.due(59));
        assert!(s.due(60));
    }

    #[test]
    fn epoch_constructor() {
        let s = RefreshScheduler::every_epochs(5, 20);
        assert_eq!(s.period(), 100);
    }

    #[test]
    fn exact_refresh_count_over_run() {
        // Invariant: refreshes over T steps == ceil(T / S).
        let mut s = RefreshScheduler::every_steps(25);
        let mut refreshes = 0;
        for step in 0..251 {
            if s.due(step) {
                s.mark(step);
                refreshes += 1;
            }
        }
        assert_eq!(refreshes, 11); // steps 0,25,…,250
    }
}

//! Refresh scheduling (the "every S iterations" of Algorithm 1), expressed
//! in training steps with an epoch-aligned option.

/// Decides at which steps the subset must be re-selected.
#[derive(Debug, Clone)]
pub struct RefreshScheduler {
    /// Refresh period in steps (S).
    period: usize,
    /// Step of the last refresh (None before the first).
    last: Option<usize>,
}

impl RefreshScheduler {
    pub fn every_steps(period: usize) -> RefreshScheduler {
        RefreshScheduler { period: period.max(1), last: None }
    }

    /// Period expressed in epochs over an active set of `steps_per_epoch`.
    pub fn every_epochs(epochs: usize, steps_per_epoch: usize) -> RefreshScheduler {
        Self::every_steps(epochs.max(1) * steps_per_epoch.max(1))
    }

    /// True when a refresh is due at `step` (always true at step 0).
    pub fn due(&self, step: usize) -> bool {
        match self.last {
            None => true,
            Some(l) => step >= l + self.period,
        }
    }

    /// Record that a refresh happened at `step`.  A mark earlier than the
    /// last recorded one is clamped (the schedule never rewinds): an
    /// out-of-order caller used to silently move `last` backwards and
    /// re-trigger refreshes that had already happened.
    pub fn mark(&mut self, step: usize) {
        self.last = Some(self.last.map_or(step, |l| l.max(step)));
    }

    pub fn period(&self) -> usize {
        self.period
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_always_due() {
        let s = RefreshScheduler::every_steps(30);
        assert!(s.due(0));
        assert!(s.due(17));
    }

    #[test]
    fn period_honoured() {
        let mut s = RefreshScheduler::every_steps(30);
        s.mark(0);
        assert!(!s.due(1));
        assert!(!s.due(29));
        assert!(s.due(30));
        s.mark(30);
        assert!(!s.due(59));
        assert!(s.due(60));
    }

    #[test]
    fn epoch_constructor() {
        let s = RefreshScheduler::every_epochs(5, 20);
        assert_eq!(s.period(), 100);
    }

    #[test]
    fn period_zero_is_clamped_to_one() {
        // Period 0 would mean "refresh forever at the same step"; the
        // constructor clamps it to every-step refresh instead.
        let mut s = RefreshScheduler::every_steps(0);
        assert_eq!(s.period(), 1);
        s.mark(0);
        assert!(s.due(1));
    }

    #[test]
    fn period_one_refreshes_every_step() {
        let mut s = RefreshScheduler::every_steps(1);
        for step in 0..10 {
            assert!(s.due(step), "step {step}");
            s.mark(step);
            assert!(!s.due(step), "marked step {step} must not re-trigger");
        }
    }

    #[test]
    fn epoch_constructor_zero_args_clamp() {
        // Both zero epochs and zero steps-per-epoch degrade to the
        // smallest legal period instead of a zero period.
        assert_eq!(RefreshScheduler::every_epochs(0, 0).period(), 1);
        assert_eq!(RefreshScheduler::every_epochs(0, 20).period(), 20);
        assert_eq!(RefreshScheduler::every_epochs(3, 0).period(), 3);
    }

    #[test]
    fn epoch_boundary_alignment() {
        // 2 epochs × 5 steps: refreshes land exactly on epoch boundaries
        // 0, 10, 20, … and nowhere inside an epoch.
        let mut s = RefreshScheduler::every_epochs(2, 5);
        let mut hits = Vec::new();
        for step in 0..31 {
            if s.due(step) {
                s.mark(step);
                hits.push(step);
            }
        }
        assert_eq!(hits, vec![0, 10, 20, 30]);
    }

    #[test]
    fn skipped_steps_do_not_drift_the_schedule() {
        // A consumer that polls sparsely (e.g. only on batch boundaries)
        // still refreshes relative to the last mark, not to wall steps.
        let mut s = RefreshScheduler::every_steps(10);
        s.mark(0);
        assert!(!s.due(9));
        assert!(s.due(17)); // late poll: still due
        s.mark(17);
        assert!(!s.due(26));
        assert!(s.due(27)); // next window counts from 17
    }

    #[test]
    fn backwards_mark_does_not_rewind_schedule() {
        // Regression: an out-of-order caller (e.g. a late shard reporting
        // an old step) used to rewind `last`, making an already-served
        // window due again.  Backwards marks are clamped to the newest
        // mark instead.
        let mut s = RefreshScheduler::every_steps(10);
        s.mark(40);
        assert!(!s.due(45));
        s.mark(20); // stale mark arrives late
        assert!(!s.due(45), "stale mark must not make step 45 due again");
        assert!(!s.due(49));
        assert!(s.due(50), "schedule still counts from the newest mark");
        // A backwards mark before any forward progress is just a mark.
        let mut fresh = RefreshScheduler::every_steps(10);
        fresh.mark(7);
        assert!(!fresh.due(16));
        assert!(fresh.due(17));
    }

    #[test]
    fn exact_refresh_count_over_run() {
        // Invariant: refreshes over T steps == ceil(T / S).
        let mut s = RefreshScheduler::every_steps(25);
        let mut refreshes = 0;
        for step in 0..251 {
            if s.due(step) {
                s.mark(step);
                refreshes += 1;
            }
        }
        assert_eq!(refreshes, 11); // steps 0,25,…,250
    }
}

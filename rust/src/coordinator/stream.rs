//! Bounded-memory streaming selection state: the reservoir behind
//! [`crate::engine::StreamingEngine`].
//!
//! Rows arrive one at a time (the engine chunks views into per-row
//! pushes); the state keeps **at most `cap = max(2·r_budget, R)` resident
//! rows** — their feature rows, gradient sketches, losses, and ids — plus
//! an `E`-vector of accumulated gradient sums, so memory is O(cap·(R+E))
//! no matter how long the stream runs.  A snapshot at any point re-runs
//! the exact batch GRAFT pipeline (Fast MaxVol → prefix projection errors
//! of ḡ → rank decision → loss top-up) over the residents, which makes a
//! stream that fits in the reservoir **bit-identical** to the one-shot
//! batch selection (pinned by `tests/streaming.rs`).
//!
//! # Three regimes
//!
//! 1. **Growth** (`len < cap`): every row is appended verbatim.  A stream
//!    of at most `cap` rows is therefore *exactly* the batch input, in
//!    arrival order — equivalence with the batch selector is structural,
//!    not approximate.
//! 2. **Saturation** (first push past `cap`): one Fast MaxVol tournament
//!    over the residents fixes the pivot set, and
//!    [`crate::linalg::incremental::replay_pivot_cache`] distils its
//!    elimination trajectory into `pvals`/`prows`.
//! 3. **Steady state**: each incoming row is pushed through the cached
//!    trajectory ([`crate::linalg::incremental::eliminate_row`], O(R²),
//!    allocation-free).  Rows that would *strictly* win an argmax step
//!    trigger a full re-tournament with the candidate included (the
//!    cache is rebuilt; the displaced worst-by-loss non-pivot row is
//!    evicted); rows that would not change the pivot set only compete,
//!    by `(loss desc, arrival asc)`, for the non-pivot slots that feed
//!    the strict-budget loss top-up.  Either way the invariant holds
//!    that a fresh tournament over the residents reproduces the cached
//!    pivot set bit-for-bit — which is what lets the skip be exact.
//!
//! Gradient sketches of **every** streamed row (resident or evicted)
//! accumulate into `gsum`, so the snapshot's ḡ = `gsum / rows_seen` is
//! the exact batch mean in arrival order — element-wise the same
//! floating-point addition sequence as the batch kernel
//! (`graft::geometry::grad_sum_into`).
//!
//! Per-row processing makes the state **chunk-oblivious**: any chunking
//! of the same arrival order produces identical state, which is the
//! determinism property the engine tests pin.

use std::cmp::Ordering;

use super::merge::SketchBuf;
use crate::graft::geometry::prefix_errors_core;
use crate::graft::{BudgetedRankPolicy, RankDecision};
use crate::linalg::incremental::{eliminate_row, replay_pivot_cache};
use crate::linalg::Workspace;
use crate::selection::maxvol::fast_maxvol_core;

/// Reservoir of pivot candidates + gradient accumulator for one selection
/// stream.  See the [module docs](self) for the regime structure; drive
/// it through [`crate::engine::StreamingEngine`], which owns the fault
/// policy and the rank authority.
pub struct StreamState {
    r_budget: usize,
    /// Feature width R and sketch width E, fixed by the first row.
    rcols: usize,
    ecols: usize,
    /// Resident-row bound: `max(2·r_budget, R)` (≥ 1), fixed with dims.
    cap: usize,
    dims_set: bool,

    // -- resident rows (physical slot order = arrival order, with evicted
    //    slots overwritten in place; capacity cap+1 so an admission
    //    tournament can append the candidate without reallocating) -------
    feat: Vec<f64>,
    /// Resident gradient sketches (f64 by default, f32 when narrowed).
    /// Only maintained while `carry` is set: a stream whose snapshots will
    /// never consult a rank policy (the engine's strict mode) skips the
    /// per-row sketch copies and keeps the reservoir R-wide only.
    sketch: SketchBuf,
    losses: Vec<f64>,
    ids: Vec<usize>,
    arrivals: Vec<u64>,
    /// Whether resident sketches are kept at all (default true; cleared by
    /// the engine when no snapshot will read them).
    carry: bool,

    // -- stream-wide gradient accumulation --------------------------------
    gsum: Vec<f64>,
    seen: u64,

    // -- steady-state pivot machinery -------------------------------------
    saturated: bool,
    /// Physical slots of the current pivots, in pivot order (≤ R).
    pivot_idx: Vec<usize>,
    /// Cached pre-clamp pivot values per elimination step (≤ R).
    pvals: Vec<f64>,
    /// Cached scaled elimination rows, flattened ragged (step j holds
    /// R−j−1 entries).
    prows: Vec<f64>,
    /// Non-pivot physical slots sorted by `(loss desc, arrival asc)` —
    /// the candidates the strict-budget top-up draws from, worst last.
    rest_order: Vec<usize>,

    // -- owned scratch (retained capacity keeps steady state alloc-free) --
    pivots_flat: Vec<f64>,
    cache_work: Vec<f64>,
    taken: Vec<bool>,
}

impl StreamState {
    /// Empty stream targeting `r_budget` selected rows per snapshot.
    /// Dimensions (and the reservoir bound) are fixed by the first row.
    pub(crate) fn new(r_budget: usize) -> StreamState {
        assert!(r_budget >= 1, "streaming selection needs a budget of at least 1 row");
        StreamState {
            r_budget,
            rcols: 0,
            ecols: 0,
            cap: 0,
            dims_set: false,
            feat: Vec::new(),
            sketch: SketchBuf::default(),
            losses: Vec::new(),
            ids: Vec::new(),
            arrivals: Vec::new(),
            carry: true,
            gsum: Vec::new(),
            seen: 0,
            saturated: false,
            pivot_idx: Vec::new(),
            pvals: Vec::new(),
            prows: Vec::new(),
            rest_order: Vec::new(),
            pivots_flat: Vec::new(),
            cache_work: Vec::new(),
            taken: Vec::new(),
        }
    }

    /// Rows currently resident in the reservoir (≤ [`StreamState::capacity`]).
    pub(crate) fn len(&self) -> usize {
        self.ids.len()
    }

    /// Total rows streamed in so far (resident or not).
    pub(crate) fn rows_seen(&self) -> u64 {
        self.seen
    }

    /// Resident-row bound (0 until the first row fixes the dimensions).
    pub(crate) fn capacity(&self) -> usize {
        self.cap
    }

    /// Global row id of reservoir slot `slot` (for degraded fallbacks
    /// that select by slot).
    pub(crate) fn id_at(&self, slot: usize) -> usize {
        self.ids[slot]
    }

    /// Keep (`true`, default) or drop (`false`) resident gradient
    /// sketches.  With carry off, [`StreamState::snapshot_into`] must be
    /// called without a policy — the engine's strict mode, where the rank
    /// is `min(budget, R, len)` by construction and the sketches would
    /// never be read.  Call before the first row.
    pub(crate) fn set_carry(&mut self, on: bool) {
        debug_assert_eq!(self.seen, 0, "carry mode must be fixed before the first row");
        self.carry = on;
    }

    /// Store resident sketches narrowed to f32 (half the reservoir's
    /// sketch bytes).  Call before the first row.
    pub(crate) fn set_sketch_f32(&mut self, on: bool) {
        debug_assert_eq!(self.seen, 0, "sketch precision must be fixed before the first row");
        self.sketch.set_f32(on);
    }

    /// The rank a policy-free strict snapshot selects by construction:
    /// MaxVol depth capped by the budget, the feature width, and the
    /// resident count — exactly what `BudgetedRankPolicy::strict` would
    /// decide over any error curve of that depth.
    pub(crate) fn strict_rank(&self) -> usize {
        self.rcols.min(self.r_budget).min(self.len())
    }

    /// Payload bytes of resident gradient sketches — zero with carry off
    /// (the engine's strict mode), pinned by `tests/alloc_free.rs`.
    pub(crate) fn sketch_bytes(&self) -> usize {
        self.sketch.bytes()
    }

    /// Forget everything but the budget and the warmed buffer capacity:
    /// the next stream reuses every allocation.
    pub(crate) fn reset(&mut self) {
        self.feat.clear();
        self.sketch.clear();
        self.losses.clear();
        self.ids.clear();
        self.arrivals.clear();
        for v in self.gsum.iter_mut() {
            *v = 0.0;
        }
        self.seen = 0;
        self.saturated = false;
        self.pivot_idx.clear();
        self.pvals.clear();
        self.prows.clear();
        self.rest_order.clear();
    }

    fn init_dims(&mut self, rcols: usize, ecols: usize) {
        self.rcols = rcols;
        self.ecols = ecols;
        self.cap = (2 * self.r_budget).max(rcols).max(1);
        self.gsum.clear();
        self.gsum.resize(ecols, 0.0);
        let slots = self.cap + 1;
        self.feat.reserve(slots * rcols);
        self.sketch.reserve(slots * ecols);
        self.losses.reserve(slots);
        self.ids.reserve(slots);
        self.arrivals.reserve(slots);
        self.pivot_idx.reserve(rcols);
        self.pvals.reserve(rcols);
        self.prows.reserve(rcols * rcols);
        self.rest_order.reserve(slots);
        self.pivots_flat.reserve(rcols * rcols);
        self.cache_work.reserve(rcols * rcols);
        self.taken.reserve(slots);
        self.dims_set = true;
    }

    /// Ingest one row.  `f`/`g` are the feature row (R) and gradient
    /// sketch (E); `id` is the caller's global row identity, carried
    /// through to snapshots.  Dimensions must match the first row —
    /// feeding views of different shapes into one stream is a caller
    /// contract violation, not a data fault.
    pub(crate) fn push_row(
        &mut self,
        f: &[f64],
        g: &[f64],
        loss: f64,
        id: usize,
        ws: &mut Workspace,
    ) {
        if !self.dims_set {
            self.init_dims(f.len(), g.len());
        }
        assert_eq!(f.len(), self.rcols, "feature width changed mid-stream");
        assert_eq!(g.len(), self.ecols, "sketch width changed mid-stream");
        // ḡ accumulates every streamed row in arrival order — the exact
        // addition sequence of the batch kernel.
        for (t, &v) in g.iter().enumerate() {
            self.gsum[t] += v;
        }
        self.seen += 1;
        let arrival = self.seen;
        if self.ids.len() < self.cap {
            self.append_row(f, g, loss, id, arrival);
            return;
        }
        if !self.saturated {
            self.saturate(ws);
        }
        // Steady state: O(R²) cached-trajectory admission test.
        let x = &mut ws.st_x;
        x.clear();
        x.extend_from_slice(f);
        if eliminate_row(x, &self.prows, &self.pvals, self.rcols).is_some() {
            self.admit(f, g, loss, id, arrival, ws);
        } else {
            self.try_replace_rest(f, g, loss, id, arrival);
        }
    }

    fn append_row(&mut self, f: &[f64], g: &[f64], loss: f64, id: usize, arrival: u64) {
        self.feat.extend_from_slice(f);
        if self.carry {
            self.sketch.push_row(g);
        }
        self.losses.push(loss);
        self.ids.push(id);
        self.arrivals.push(arrival);
    }

    /// Overwrite physical slot `dst` with row data from slot `src`
    /// (`src > dst`), used when evicting: the last slot's row moves into
    /// the hole.
    fn move_row(&mut self, src: usize, dst: usize) {
        let (r, e) = (self.rcols, self.ecols);
        self.feat.copy_within(src * r..(src + 1) * r, dst * r);
        if self.carry {
            self.sketch.copy_row_within(src * e, dst * e, e);
        }
        self.losses[dst] = self.losses[src];
        self.ids[dst] = self.ids[src];
        self.arrivals[dst] = self.arrivals[src];
    }

    /// Overwrite physical slot `dst` with a fresh row.
    fn write_row(&mut self, dst: usize, f: &[f64], g: &[f64], loss: f64, id: usize, arrival: u64) {
        let (r, e) = (self.rcols, self.ecols);
        self.feat[dst * r..(dst + 1) * r].copy_from_slice(f);
        if self.carry {
            self.sketch.write_at(dst * e, g);
        }
        self.losses[dst] = loss;
        self.ids[dst] = id;
        self.arrivals[dst] = arrival;
    }

    fn truncate(&mut self, len: usize) {
        self.feat.truncate(len * self.rcols);
        self.sketch.truncate(len * self.ecols);
        self.losses.truncate(len);
        self.ids.truncate(len);
        self.arrivals.truncate(len);
    }

    /// `true` when slot `a` sorts after slot `b` under
    /// `(loss desc, arrival asc)` — i.e. `a` is the worse top-up
    /// candidate.
    fn sorts_after(losses: &[f64], arrivals: &[u64], a: usize, b: usize) -> bool {
        match losses[a].total_cmp(&losses[b]) {
            Ordering::Less => true,
            Ordering::Greater => false,
            Ordering::Equal => arrivals[a] > arrivals[b],
        }
    }

    /// First transition into steady state: tournament over the full
    /// reservoir, then distil the elimination cache.
    fn saturate(&mut self, ws: &mut Workspace) {
        let len = self.ids.len();
        let width = self.rcols.min(len);
        let mut order = std::mem::take(&mut ws.st_order);
        fast_maxvol_core(&self.feat[..len * self.rcols], len, self.rcols, width, ws, &mut order);
        self.pivot_idx.clear();
        self.pivot_idx.extend_from_slice(&order);
        ws.st_order = order;
        self.rebuild_cache();
        self.rebuild_rest_order();
        self.saturated = true;
    }

    /// A candidate that would win an argmax step: append it, re-run the
    /// tournament with it included, evict the worst non-pivot by
    /// `(loss desc, arrival asc)`, and rebuild the caches.
    fn admit(&mut self, f: &[f64], g: &[f64], loss: f64, id: usize, arrival: u64, ws: &mut Workspace) {
        self.append_row(f, g, loss, id, arrival);
        let len = self.ids.len(); // cap + 1
        let width = self.rcols.min(len);
        let mut order = std::mem::take(&mut ws.st_order);
        fast_maxvol_core(&self.feat[..len * self.rcols], len, self.rcols, width, ws, &mut order);
        self.pivot_idx.clear();
        self.pivot_idx.extend_from_slice(&order);
        ws.st_order = order;
        // Worst non-pivot row loses its slot.
        self.taken.clear();
        self.taken.resize(len, false);
        for &p in &self.pivot_idx {
            self.taken[p] = true;
        }
        let mut worst = usize::MAX;
        for i in 0..len {
            if self.taken[i] {
                continue;
            }
            if worst == usize::MAX || Self::sorts_after(&self.losses, &self.arrivals, i, worst) {
                worst = i;
            }
        }
        debug_assert!(worst != usize::MAX, "cap+1 rows cannot all be pivots (width ≤ R ≤ cap)");
        let last = len - 1;
        if worst != last {
            self.move_row(last, worst);
            for p in self.pivot_idx.iter_mut() {
                if *p == last {
                    *p = worst;
                }
            }
        }
        self.truncate(last);
        self.rebuild_cache();
        self.rebuild_rest_order();
    }

    /// A candidate that cannot change the pivot set only competes for the
    /// top-up pool: replace the worst non-pivot iff the candidate's loss
    /// is strictly higher (on ties the earlier arrival stays — the same
    /// `(loss desc, arrival asc)` rule the snapshot top-up sorts by).
    fn try_replace_rest(&mut self, f: &[f64], g: &[f64], loss: f64, id: usize, arrival: u64) {
        let Some(&worst) = self.rest_order.last() else {
            return; // cap == R and every slot is a pivot: nothing to trade
        };
        if loss.total_cmp(&self.losses[worst]) != Ordering::Greater {
            return;
        }
        self.rest_order.pop();
        self.write_row(worst, f, g, loss, id, arrival);
        let (losses, arrivals) = (&self.losses, &self.arrivals);
        let pos = self.rest_order.partition_point(|&i| match losses[i].total_cmp(&loss) {
            Ordering::Greater => true,
            Ordering::Less => false,
            Ordering::Equal => arrivals[i] < arrival,
        });
        self.rest_order.insert(pos, worst);
    }

    /// Gather the pivot rows (pivot order) and replay their elimination
    /// trajectory into `pvals`/`prows`.
    fn rebuild_cache(&mut self) {
        self.pivots_flat.clear();
        for &i in &self.pivot_idx {
            self.pivots_flat.extend_from_slice(&self.feat[i * self.rcols..(i + 1) * self.rcols]);
        }
        replay_pivot_cache(
            &self.pivots_flat,
            self.rcols,
            &mut self.cache_work,
            &mut self.prows,
            &mut self.pvals,
        );
    }

    fn rebuild_rest_order(&mut self) {
        let len = self.ids.len();
        self.taken.clear();
        self.taken.resize(len, false);
        for &p in &self.pivot_idx {
            self.taken[p] = true;
        }
        self.rest_order.clear();
        for i in 0..len {
            if !self.taken[i] {
                self.rest_order.push(i);
            }
        }
        let (losses, arrivals) = (&self.losses, &self.arrivals);
        self.rest_order
            .sort_unstable_by(|&a, &b| losses[b].total_cmp(&losses[a]).then(arrivals[a].cmp(&arrivals[b])));
    }

    /// Run the batch selection pipeline over the residents and write the
    /// selected **global row ids** into `out` (selection order: MaxVol
    /// pivots first, then the loss top-up).
    ///
    /// With a rank `policy` this mirrors `GraftSelector::select_into`
    /// operation-for-operation: Fast MaxVol to depth `min(R, len)`,
    /// prefix projection errors of ḡ over the pivot sketches, one
    /// `choose` call (the policy's budget accounting advances exactly
    /// once per snapshot, like one batch select), and — when `top_up` —
    /// padding to the budget by `(loss desc, arrival asc)`.  Without a
    /// policy it mirrors the feature-only `FastMaxVol` selector: depth
    /// `min(R, budget, len)`, full budget, loss top-up.
    ///
    /// Returns the rank decision (`None` for the feature-only path or an
    /// empty stream).  `&self`: snapshots never perturb the stream.
    pub(crate) fn snapshot_into(
        &self,
        mut policy: Option<&mut BudgetedRankPolicy>,
        top_up: bool,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) -> Option<RankDecision> {
        out.clear();
        let len = self.ids.len();
        if len == 0 {
            return None;
        }
        let depth = if policy.is_some() {
            self.rcols.min(len)
        } else {
            self.rcols.min(self.r_budget).min(len)
        };
        let mut order = std::mem::take(&mut ws.st_order);
        fast_maxvol_core(&self.feat[..len * self.rcols], len, self.rcols, depth, ws, &mut order);
        let decision = if let Some(p) = policy.as_deref_mut() {
            ws.pe_gbar.clear();
            ws.pe_gbar.extend(self.gsum.iter().map(|v| v / self.seen as f64));
            debug_assert!(self.carry, "policy-ful snapshot requires carried sketches");
            ws.pe_g.clear();
            for &i in &order {
                self.sketch.gather_into(i * self.ecols, self.ecols, &mut ws.pe_g);
            }
            prefix_errors_core(&mut ws.pe_g, self.ecols, depth, &ws.pe_gbar, &mut ws.pe_ghat, &mut ws.pe_err);
            Some(p.choose(&ws.pe_err, self.r_budget, depth))
        } else {
            None
        };
        let rank = decision.map_or(self.r_budget, |d| d.rank);
        let take = rank.min(order.len());
        out.extend_from_slice(&order[..take]);
        ws.st_order = order;
        let want = self.r_budget.min(len);
        if top_up && out.len() < want {
            // Same rule (and scratch) as `selection::top_up_by_loss`,
            // with arrival standing in for the batch-local index — equal
            // to it whenever the stream fit in the reservoir.
            let taken = &mut ws.sel_taken;
            taken.clear();
            taken.resize(len, false);
            for &i in out.iter() {
                taken[i] = true;
            }
            let rest = &mut ws.sel_rest;
            rest.clear();
            rest.extend((0..len).filter(|&i| !taken[i]));
            let (losses, arrivals) = (&self.losses, &self.arrivals);
            rest.sort_unstable_by(|&a, &b| {
                losses[b].total_cmp(&losses[a]).then(arrivals[a].cmp(&arrivals[b]))
            });
            let need = want - out.len();
            out.extend(rest.iter().copied().take(need));
        }
        // Physical slots → global ids, in place.
        for v in out.iter_mut() {
            *v = self.ids[*v];
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graft::GraftSelector;
    use crate::selection::testsupport::random_view;
    use crate::selection::Selector;

    fn push_all(state: &mut StreamState, owned: &crate::selection::testsupport::Owned, ws: &mut Workspace) {
        let view = owned.view();
        for i in 0..view.k() {
            state.push_row(
                view.features.row(i),
                view.grads.row(i),
                view.losses[i],
                view.row_ids[i],
                ws,
            );
        }
    }

    #[test]
    fn stream_within_reservoir_matches_batch_bitwise() {
        // K ≤ cap: the reservoir holds the whole stream, so the snapshot
        // is structurally the batch pipeline — outputs must be identical
        // in strict and adaptive mode.
        for (k, r, e, budget, seed) in
            [(24usize, 8usize, 12usize, 12usize, 1u64), (32, 6, 10, 16, 2), (16, 8, 8, 8, 3)]
        {
            let owned = random_view(k, r, e, 3, seed);
            for adaptive in [false, true] {
                let mk = || {
                    if adaptive {
                        BudgetedRankPolicy::adaptive(0.1, 0.5)
                    } else {
                        BudgetedRankPolicy::strict(0.1)
                    }
                };
                let mut state = StreamState::new(budget);
                let mut ws = Workspace::default();
                push_all(&mut state, &owned, &mut ws);
                assert!(state.len() <= state.capacity(), "reservoir bound");
                assert_eq!(state.len(), k, "K ≤ cap keeps every row resident");
                let mut policy = mk();
                let mut got = Vec::new();
                let d = state.snapshot_into(Some(&mut policy), !adaptive, &mut ws, &mut got);
                let mut reference = GraftSelector::new(mk());
                let want = reference.select(&owned.view(), budget);
                assert_eq!(got, want, "k={k} budget={budget} adaptive={adaptive}");
                assert_eq!(d, reference.last, "decision must match too");
            }
        }
    }

    #[test]
    fn cached_pivots_equal_fresh_tournament_after_long_stream() {
        // The steady-state invariant everything rests on: at any point, a
        // from-scratch tournament over the residents reproduces the
        // incrementally-maintained pivot set exactly.
        let owned = random_view(240, 6, 8, 4, 11);
        let mut state = StreamState::new(8);
        let mut ws = Workspace::default();
        push_all(&mut state, &owned, &mut ws);
        assert!(state.saturated, "240 rows must outgrow cap={}", state.capacity());
        assert_eq!(state.len(), state.capacity(), "reservoir pinned at cap");
        let len = state.len();
        let width = state.rcols.min(len);
        let mut fresh = Vec::new();
        fast_maxvol_core(&state.feat[..len * state.rcols], len, state.rcols, width, &mut ws, &mut fresh);
        assert_eq!(fresh, state.pivot_idx, "cached pivots drifted from the tournament");
        // And the rest-order bookkeeping covers exactly the non-pivots,
        // sorted worst-last.
        assert_eq!(state.rest_order.len(), len - width);
        for w in state.rest_order.windows(2) {
            assert!(
                !StreamState::sorts_after(&state.losses, &state.arrivals, w[0], w[1]),
                "rest_order out of order"
            );
        }
    }

    #[test]
    fn snapshot_is_repeatable_and_pure() {
        // Snapshots must not perturb the stream: two in a row (fresh
        // policies) agree, and pushing after a snapshot still works.
        let owned = random_view(100, 5, 7, 2, 21);
        let mut state = StreamState::new(6);
        let mut ws = Workspace::default();
        push_all(&mut state, &owned, &mut ws);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let mut p1 = BudgetedRankPolicy::strict(0.1);
        let mut p2 = BudgetedRankPolicy::strict(0.1);
        state.snapshot_into(Some(&mut p1), true, &mut ws, &mut a);
        state.snapshot_into(Some(&mut p2), true, &mut ws, &mut b);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        let ids: std::collections::HashSet<_> = a.iter().copied().collect();
        assert_eq!(ids.len(), 6, "snapshot ids unique");
    }

    #[test]
    fn feature_only_snapshot_matches_fast_maxvol_within_reservoir() {
        use crate::selection::maxvol::FastMaxVol;
        let owned = random_view(20, 6, 8, 2, 31);
        let mut state = StreamState::new(10);
        let mut ws = Workspace::default();
        push_all(&mut state, &owned, &mut ws);
        let mut got = Vec::new();
        let d = state.snapshot_into(None, true, &mut ws, &mut got);
        assert!(d.is_none());
        assert_eq!(got, FastMaxVol.select(&owned.view(), 10));
    }

    #[test]
    fn reset_reuses_the_reservoir_for_a_new_stream() {
        let owned = random_view(40, 5, 6, 2, 41);
        let mut state = StreamState::new(5);
        let mut ws = Workspace::default();
        push_all(&mut state, &owned, &mut ws);
        let mut first = Vec::new();
        state.snapshot_into(None, true, &mut ws, &mut first);
        state.reset();
        assert_eq!(state.len(), 0);
        assert_eq!(state.rows_seen(), 0);
        push_all(&mut state, &owned, &mut ws);
        let mut second = Vec::new();
        state.snapshot_into(None, true, &mut ws, &mut second);
        assert_eq!(first, second, "reset stream replays identically");
    }

    #[test]
    fn evicted_rows_never_resurface_but_ids_stay_consistent() {
        // Long stream with a known high-loss tail: the top-up pool must
        // track the best losses among non-pivots seen so far.
        let mut owned = random_view(200, 4, 6, 2, 51);
        for i in 150..200 {
            owned.losses[i] = 100.0 + i as f64; // late, loud rows
        }
        let mut state = StreamState::new(6);
        let mut ws = Workspace::default();
        push_all(&mut state, &owned, &mut ws);
        let mut got = Vec::new();
        state.snapshot_into(None, true, &mut ws, &mut got);
        assert_eq!(got.len(), 6);
        // Budget 6 at feature width 4 → at least two top-up slots, which
        // must come from the loud tail (losses 100+ dominate everything).
        let loud = got.iter().filter(|&&id| id >= 150).count();
        assert!(loud >= 2, "top-up missed the high-loss tail: {got:?}");
    }
}

//! Second-stage merge for sharded selection: fold the per-shard winner
//! lists into one subset whose feature rows still dominate the spanned
//! subspace, CRAIG-style select-then-merge (Mirzasoleiman et al.) with
//! MaxVol as the reduction operator.
//!
//! The default [`MergePolicy::Hierarchical`] is a tournament tree: winner
//! lists are folded pairwise, so every second-stage Fast MaxVol sees at
//! most `2·keep` candidate rows and peak memory stays O(shards · keep)
//! rather than O(n).  [`MergePolicy::Flat`] runs one MaxVol over the full
//! concatenation — same result class, larger single reduction — and is
//! kept as the reference shape for the property tests and the bench.

use crate::linalg::{Mat, Workspace};
use crate::selection::maxvol::fast_maxvol_with;
use crate::selection::BatchView;

/// How per-shard winners are folded into the final subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MergePolicy {
    /// Tournament tree: fold winner lists pairwise until one remains.
    #[default]
    Hierarchical,
    /// Single second-stage MaxVol over the concatenation of all winners.
    Flat,
}

impl MergePolicy {
    /// Parse a CLI / config spelling.
    pub fn parse(s: &str) -> Option<MergePolicy> {
        match s {
            "hierarchical" | "tournament" => Some(MergePolicy::Hierarchical),
            "flat" => Some(MergePolicy::Flat),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MergePolicy::Hierarchical => "hierarchical",
            MergePolicy::Flat => "flat",
        }
    }
}

/// Reusable scratch for the merge stage (one per `ShardedSelector`): the
/// candidate union, its gathered feature rows, the MaxVol pivot order,
/// and the tournament's ping-pong winner lists.  Buffers are cleared and
/// refilled per merge node, so capacity is retained across refreshes and
/// steady-state merging performs no heap allocations.
#[derive(Default)]
pub struct MergeScratch {
    /// Candidate union (batch-local row ids), in shard order.
    union: Vec<usize>,
    /// Row-gathered candidate features (|union| × R).
    feat: Vec<f64>,
    /// MaxVol pivot order over the union (union-local indices).
    local: Vec<usize>,
    /// Current-round winner lists (ping side).
    lists: Vec<Vec<usize>>,
    /// Next-round winner lists (pong side); swapped with `lists` per round.
    next: Vec<Vec<usize>>,
}

/// Fold the per-shard winner lists (disjoint batch-local row ids, one list
/// per shard in shard order) into at most `keep` rows written to `out`.
/// Winner lists arrive as an exact-size iterator of slices so callers can
/// stream them straight out of their worker slots without collecting.
///
/// Deterministic: the result is a pure function of `(view, winners, keep,
/// policy)` — the tournament pairing is fixed by list order, so worker
/// interleaving during the fan-out stage cannot change it.
pub fn merge_winners<'a, I>(
    view: &BatchView<'_>,
    winners: I,
    keep: usize,
    policy: MergePolicy,
    ws: &mut Workspace,
    scratch: &mut MergeScratch,
    out: &mut Vec<usize>,
) where
    I: IntoIterator<Item = &'a [usize]>,
    I::IntoIter: ExactSizeIterator,
{
    out.clear();
    let it = winners.into_iter();
    let count = it.len();
    if count == 0 {
        return;
    }
    if count == 1 {
        for w in it {
            out.extend_from_slice(w);
        }
        out.truncate(keep);
        return;
    }
    // Split the scratch into its disjoint buffers so the tournament can
    // hold the list arrays while reduce_union fills the union/feat/local
    // ones.
    let MergeScratch { union, feat, local, lists, next } = scratch;
    match policy {
        MergePolicy::Flat => {
            union.clear();
            for w in it {
                union.extend_from_slice(w);
            }
            reduce_union(view, keep, ws, union, feat, local, out);
        }
        MergePolicy::Hierarchical => {
            // Seed round: copy the winner slices into retained buffers.
            if lists.len() < count {
                lists.resize_with(count, Vec::new);
            }
            for (dst, w) in lists.iter_mut().zip(it) {
                dst.clear();
                dst.extend_from_slice(w);
            }
            let mut cur = count;
            while cur > 1 {
                let folded = cur.div_ceil(2);
                if next.len() < folded {
                    next.resize_with(folded, Vec::new);
                }
                for pi in 0..folded {
                    if 2 * pi + 1 == cur {
                        // Odd list passes through to the next round.
                        let (a, b) = (&lists[2 * pi], &mut next[pi]);
                        b.clear();
                        b.extend_from_slice(a);
                        continue;
                    }
                    union.clear();
                    union.extend_from_slice(&lists[2 * pi]);
                    union.extend_from_slice(&lists[2 * pi + 1]);
                    reduce_union(view, keep, ws, union, feat, local, &mut next[pi]);
                }
                std::mem::swap(lists, next);
                cur = folded;
            }
            out.extend_from_slice(&lists[0]);
        }
    }
}

/// One merge node: keep at most `keep` of the candidate rows in `union`
/// (unique batch-local ids).  Fast MaxVol over the gathered candidate
/// features picks up to `min(keep, R)` rows; any remaining budget is
/// topped up with the highest-loss leftover candidates (loss-descending,
/// id-ascending — the same NaN-safe rule as `selection::top_up_by_loss`,
/// restricted to the union).  `feat`/`local` are retained scratch from
/// [`MergeScratch`].
fn reduce_union(
    view: &BatchView<'_>,
    keep: usize,
    ws: &mut Workspace,
    union: &[usize],
    feat: &mut Vec<f64>,
    local: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    out.clear();
    let n = union.len();
    if n <= keep {
        out.extend_from_slice(union);
        return;
    }
    let rcols = view.features.cols();
    feat.clear();
    for &i in union {
        feat.extend_from_slice(view.features.row(i));
    }
    let width = keep.min(rcols).min(n);
    let fmat = Mat::from_vec(n, rcols, std::mem::take(feat));
    fast_maxvol_with(&fmat, width, ws, local);
    *feat = fmat.into_vec();
    for &li in local.iter() {
        out.push(union[li]);
    }
    if out.len() >= keep {
        return;
    }
    // keep beyond the feature rank: top up within the union by loss.
    let taken = &mut ws.sel_taken;
    taken.clear();
    taken.resize(n, false);
    for &li in local.iter() {
        taken[li] = true;
    }
    let rest = &mut ws.sel_rest;
    rest.clear();
    rest.extend((0..n).filter(|&li| !taken[li]));
    rest.sort_unstable_by(|&a, &b| {
        let (ra, rb) = (union[a], union[b]);
        view.losses[rb].total_cmp(&view.losses[ra]).then(ra.cmp(&rb))
    });
    let need = keep - out.len();
    out.extend(rest.iter().take(need).map(|&li| union[li]));
}

#[cfg(test)]
mod tests {
    //! Degenerate-geometry pins: the PR 2 suite covers well-conditioned
    //! shapes (shards ≫ r candidates, full-rank features); these lock the
    //! edges — shards holding fewer than `r` rows, single-candidate winner
    //! lists, empty lists, and `keep` beyond the feature rank where the
    //! loss top-up takes over.

    use super::*;
    use crate::selection::maxvol::fast_maxvol;
    use crate::selection::testsupport::random_view;

    fn merge(
        view: &BatchView<'_>,
        lists: &[Vec<usize>],
        keep: usize,
        policy: MergePolicy,
    ) -> Vec<usize> {
        let mut ws = Workspace::new();
        let mut scratch = MergeScratch::default();
        let mut out = Vec::new();
        merge_winners(view, lists.iter().map(|l| l.as_slice()), keep, policy, &mut ws, &mut scratch, &mut out);
        out
    }

    #[test]
    fn two_shards_below_rank_hier_is_bitwise_flat() {
        // Each shard holds fewer rows than `keep`, so both winner lists are
        // exhaustive; with exactly two lists the tournament has a single
        // fold node — definitionally the same reduction Flat runs, so the
        // two policies must agree bit for bit.
        let owned = random_view(24, 8, 4, 2, 901);
        let lists = vec![(0..12).collect::<Vec<_>>(), (12..24).collect::<Vec<_>>()];
        for keep in [1usize, 5, 8, 16, 23] {
            let h = merge(&owned.view(), &lists, keep, MergePolicy::Hierarchical);
            let f = merge(&owned.view(), &lists, keep, MergePolicy::Flat);
            assert_eq!(h, f, "keep={keep}");
            assert_eq!(h.len(), keep.min(24), "size keep={keep}");
        }
    }

    #[test]
    fn keep_covering_all_candidates_passes_through_in_shard_order() {
        // `keep` at or beyond the candidate count (the rank > k shape):
        // every node is a passthrough, both policies return the full union
        // in shard order, no MaxVol runs at all.
        let owned = random_view(20, 6, 4, 2, 903);
        let lists: Vec<Vec<usize>> =
            (0..8).map(|s| (0..20).filter(|i| i % 8 == s).collect()).collect();
        let all: Vec<usize> = lists.iter().flatten().copied().collect();
        for keep in [20usize, 25, 100] {
            for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
                assert_eq!(merge(&owned.view(), &lists, keep, policy), all, "keep={keep} {policy:?}");
            }
        }
    }

    #[test]
    fn single_candidate_unions_pick_the_global_winner() {
        // One winner per shard and keep == 1: every fold compares two
        // single rows; the tournament champion must equal Flat's global
        // pick, because Fast MaxVol's first pivot (argmax |first feature
        // column|) reduces associatively when those magnitudes are
        // distinct — which generic gaussian features are almost surely.
        let owned = random_view(16, 5, 4, 2, 905);
        for shards in [2usize, 3, 5, 8] {
            let lists: Vec<Vec<usize>> = (0..shards).map(|s| vec![2 * s]).collect();
            let h = merge(&owned.view(), &lists, 1, MergePolicy::Hierarchical);
            let f = merge(&owned.view(), &lists, 1, MergePolicy::Flat);
            assert_eq!(h.len(), 1, "shards={shards}");
            assert_eq!(h, f, "champion differs, shards={shards}");
            assert!(lists.iter().any(|l| l[0] == h[0]), "champion from candidates");
        }
    }

    #[test]
    fn empty_winner_lists_are_tolerated() {
        // A shard can legitimately win nothing (empty range after clamp);
        // merges must skip it without panicking or emitting phantoms.
        let owned = random_view(12, 4, 4, 2, 907);
        let lists = vec![vec![0usize, 1, 2], Vec::new(), vec![7, 8], Vec::new()];
        for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
            let out = merge(&owned.view(), &lists, 4, policy);
            assert_eq!(out.len(), 4, "{policy:?}");
            let mut u = out.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 4, "unique {policy:?}");
            assert!(out.iter().all(|i| [0usize, 1, 2, 7, 8].contains(i)), "{policy:?}");
        }
    }

    #[test]
    fn keep_beyond_feature_rank_tops_up_by_loss() {
        // R = 3 feature columns but keep = 10: MaxVol can only justify 3
        // rows; the remaining 7 must be exactly the highest-loss leftover
        // candidates, loss-descending with ascending-id tie-break.
        let mut owned = random_view(16, 3, 4, 2, 909);
        for (i, l) in owned.losses.iter_mut().enumerate() {
            *l = ((i * 7) % 16) as f64; // distinct, known ordering
        }
        let union: Vec<usize> = (0..12).collect();
        let lists = vec![union[..6].to_vec(), union[6..].to_vec()];
        let keep = 10;
        let out = merge(&owned.view(), &lists, keep, MergePolicy::Flat);
        assert_eq!(out.len(), keep);

        // The MaxVol head: pivots of the gathered 12×3 candidate block.
        let cand = Mat::from_fn(12, 3, |i, j| owned.features[(union[i], j)]);
        let picks: Vec<usize> = fast_maxvol(&cand, 3).iter().map(|&li| union[li]).collect();
        assert_eq!(&out[..picks.len()], &picks[..], "MaxVol head");

        // The tail: highest-loss leftovers in loss-desc / id-asc order.
        let mut rest: Vec<usize> =
            union.iter().copied().filter(|i| !picks.contains(i)).collect();
        rest.sort_by(|&a, &b| owned.losses[b].total_cmp(&owned.losses[a]).then(a.cmp(&b)));
        assert_eq!(&out[picks.len()..], &rest[..keep - picks.len()], "loss top-up tail");
    }

    #[test]
    fn single_list_truncates_to_keep() {
        let owned = random_view(10, 4, 4, 2, 911);
        let lists = vec![vec![9usize, 3, 5, 1, 7]];
        for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
            assert_eq!(merge(&owned.view(), &lists, 3, policy), vec![9, 3, 5], "{policy:?}");
            assert_eq!(merge(&owned.view(), &lists, 8, policy), lists[0], "{policy:?}");
        }
    }
}

//! Second-stage merge for sharded selection: fold the per-shard winner
//! lists into one subset whose feature rows still dominate the spanned
//! subspace, CRAIG-style select-then-merge (Mirzasoleiman et al.) with
//! MaxVol as the reduction operator.
//!
//! The default [`MergePolicy::Hierarchical`] is a tournament tree: winner
//! lists are folded pairwise, so every second-stage Fast MaxVol sees at
//! most `2·keep` candidate rows and peak memory stays O(shards · keep)
//! rather than O(n).  [`MergePolicy::Flat`] runs one MaxVol over the full
//! concatenation — same result class, larger single reduction — and is
//! kept as the reference shape for the property tests and the bench.
//!
//! [`MergePolicy::Grad`] (the default for the GRAFT selector) restores the
//! paper's gradient-awareness across the shard boundary: the MaxVol
//! tournament still fixes the merged pivot order, but then the prefix
//! projection errors of the **global** batch-mean gradient ĝ are
//! recomputed over that order (the fused MGS kernel of
//! `graft::geometry`), and one top-level rank authority applies the
//! single `BudgetedRankPolicy` decision — global dynamic rank, one budget
//! accumulator, ε semantics independent of the shard count.  What crosses
//! the shard → merge boundary is a [`ShardGrads`] per shard: the winner
//! rows' gradient-sketch columns plus the shard's partial ḡ sum
//! (O(shards·(r·E + E)) memory; the exact global ḡ is the count-weighted
//! mean, so no extra pass over the batch is ever taken).

use crate::graft::geometry::{grad_aware_order, prefix_errors_core};
use crate::graft::RankDecision;
use crate::linalg::{Mat, Workspace};
use crate::selection::maxvol::fast_maxvol_with;
use crate::selection::{BatchView, Selector};

/// How per-shard winners are folded into the final subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MergePolicy {
    /// Tournament tree: fold winner lists pairwise until one remains.
    #[default]
    Hierarchical,
    /// Single second-stage MaxVol over the concatenation of all winners.
    Flat,
    /// Gradient-aware merge: the hierarchical tournament fixes the merged
    /// pivot order, then prefix projection errors of the global ĝ over
    /// that order drive one top-level dynamic-rank decision (the
    /// coordinator's rank authority).  Default for the GRAFT selector —
    /// it is what keeps the sharded path on the paper's criterion.
    Grad,
}

impl MergePolicy {
    /// Parse a CLI / config spelling.
    pub fn parse(s: &str) -> Option<MergePolicy> {
        match s {
            "hierarchical" | "tournament" => Some(MergePolicy::Hierarchical),
            "flat" => Some(MergePolicy::Flat),
            "grad" | "gradient" | "grad-aware" => Some(MergePolicy::Grad),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MergePolicy::Hierarchical => "hierarchical",
            MergePolicy::Flat => "flat",
            MergePolicy::Grad => "grad",
        }
    }

    /// Whether this policy needs the per-shard gradient context
    /// ([`ShardGrads`]) threaded through the shard jobs.
    pub fn gradient_aware(self) -> bool {
        matches!(self, MergePolicy::Grad)
    }

    /// The tournament shape this policy reduces candidates with
    /// (`Grad` rides on the hierarchical tree).
    fn base(self) -> MergePolicy {
        match self {
            MergePolicy::Flat => MergePolicy::Flat,
            _ => MergePolicy::Hierarchical,
        }
    }
}

/// Storage for the gradient-sketch columns that cross the shard → merge
/// boundary.  `F64` is the default and keeps the carried sketches
/// bit-identical to the rows they were read from; `F32` halves the
/// boundary's bandwidth and resident memory (pool messages, streaming
/// reservoir) at the cost of one rounding per element.  The merged pivot
/// *order* is computed on f64 features and never touches this buffer, so
/// narrowing can only move the adaptive rank cut — never reorder winners.
///
/// The global ḡ partial sums stay f64 regardless ([`ShardGrads::gsum`]):
/// they are O(E) per shard, so narrowing them saves nothing, and they sum
/// over the whole range, where f32 accumulation error would compound.
#[derive(Debug, Clone)]
pub enum SketchBuf {
    /// Full-precision sketches (default): bitwise the source rows.
    F64(Vec<f64>),
    /// Narrowed sketches: half the boundary bytes, one rounding per value.
    F32(Vec<f32>),
}

impl Default for SketchBuf {
    fn default() -> Self {
        SketchBuf::F64(Vec::new())
    }
}

impl SketchBuf {
    /// Empty buffer of the requested precision.
    pub fn new(f32_mode: bool) -> SketchBuf {
        if f32_mode {
            SketchBuf::F32(Vec::new())
        } else {
            SketchBuf::F64(Vec::new())
        }
    }

    pub fn is_f32(&self) -> bool {
        matches!(self, SketchBuf::F32(_))
    }

    /// Normalise the variant (used when recycled buffers of unknown
    /// provenance re-enter a pool that runs in one fixed mode).  Switching
    /// variants drops the old storage; staying put keeps capacity.
    pub fn set_f32(&mut self, f32_mode: bool) {
        if self.is_f32() != f32_mode {
            *self = SketchBuf::new(f32_mode);
        }
    }

    /// Element count (not bytes) — rows·E once filled.
    pub fn len(&self) -> usize {
        match self {
            SketchBuf::F64(v) => v.len(),
            SketchBuf::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clear contents, keeping both the variant and the capacity.
    pub fn clear(&mut self) {
        match self {
            SketchBuf::F64(v) => v.clear(),
            SketchBuf::F32(v) => v.clear(),
        }
    }

    /// Append one sketch row, narrowing if this is an `F32` buffer.
    pub fn push_row(&mut self, row: &[f64]) {
        match self {
            SketchBuf::F64(v) => v.extend_from_slice(row),
            SketchBuf::F32(v) => v.extend(row.iter().map(|&x| x as f32)),
        }
    }

    /// Gather `e` elements starting at `at` into `dst`, widening to f64.
    /// This is the only read the merge performs; an `F64` buffer gathers
    /// bit-identically, an `F32` one pays exactly one rounding per value
    /// (the widening itself is exact).
    pub fn gather_into(&self, at: usize, e: usize, dst: &mut Vec<f64>) {
        match self {
            SketchBuf::F64(v) => dst.extend_from_slice(&v[at..at + e]),
            SketchBuf::F32(v) => dst.extend(v[at..at + e].iter().map(|&x| x as f64)),
        }
    }

    /// Overwrite `row.len()` elements starting at `at` (narrowing for
    /// `F32`) — the streaming reservoir's in-place slot overwrite.
    pub fn write_at(&mut self, at: usize, row: &[f64]) {
        match self {
            SketchBuf::F64(v) => v[at..at + row.len()].copy_from_slice(row),
            SketchBuf::F32(v) => {
                for (d, &x) in v[at..at + row.len()].iter_mut().zip(row) {
                    *d = x as f32;
                }
            }
        }
    }

    /// Copy `e` elements from offset `src` to offset `dst` within the
    /// buffer (no precision change) — the reservoir's evict-and-backfill
    /// move.
    pub fn copy_row_within(&mut self, src: usize, dst: usize, e: usize) {
        match self {
            SketchBuf::F64(v) => v.copy_within(src..src + e, dst),
            SketchBuf::F32(v) => v.copy_within(src..src + e, dst),
        }
    }

    /// Truncate to `len` elements (keeps variant and capacity).
    pub fn truncate(&mut self, len: usize) {
        match self {
            SketchBuf::F64(v) => v.truncate(len),
            SketchBuf::F32(v) => v.truncate(len),
        }
    }

    /// Payload bytes currently held (len · element width) — what actually
    /// crosses the boundary; pinned by the allocation-counting tests.
    pub fn bytes(&self) -> usize {
        match self {
            SketchBuf::F64(v) => v.len() * std::mem::size_of::<f64>(),
            SketchBuf::F32(v) => v.len() * std::mem::size_of::<f32>(),
        }
    }

    /// Bytes reserved by the backing allocation (capacity · width).
    pub fn capacity_bytes(&self) -> usize {
        match self {
            SketchBuf::F64(v) => v.capacity() * std::mem::size_of::<f64>(),
            SketchBuf::F32(v) => v.capacity() * std::mem::size_of::<f32>(),
        }
    }

    /// Reserve room for `additional` more elements.
    pub fn reserve(&mut self, additional: usize) {
        match self {
            SketchBuf::F64(v) => v.reserve(additional),
            SketchBuf::F32(v) => v.reserve(additional),
        }
    }
}

/// Per-shard gradient context crossing the shard → merge boundary: the
/// winner rows' gradient-sketch columns and the shard's partial ḡ sum.
/// This is everything the gradient-aware merge needs — a merge node never
/// re-reads the shard's rows, which is what keeps the design mergeable
/// across streams (SAGE-style) and O(shards·(r·E + E)) in memory.
///
/// Filled by `shard::run_shard` when the merge policy is gradient-aware;
/// buffers are recycled across refreshes (steady state allocation-free).
#[derive(Default)]
pub struct ShardGrads {
    /// Winner gradient rows, `|won|·E` elements, row `j` = winner `j`'s
    /// sketch — aligned with the shard's winner list.  f64 by default;
    /// f32 when the coordinator opted into narrowed sketches.
    pub cols: SketchBuf,
    /// Partial ḡ·count sum over **all** rows of the shard's range (not
    /// just winners), length E.  Always f64: O(E) per shard, and the
    /// count-weighted global mean must not compound narrowing error.
    pub gsum: Vec<f64>,
    /// Row count of the shard's range.
    pub count: usize,
}

impl ShardGrads {
    /// Payload bytes of the carried sketch columns (excludes `gsum`,
    /// which exists whether or not sketches are carried).
    pub fn sketch_bytes(&self) -> usize {
        self.cols.bytes()
    }
}

/// Borrowed context for one gradient-aware merge: the per-shard
/// [`ShardGrads`] (aligned with the winner lists) and the coordinator's
/// rank authority, if any.  With no authority the pivot order and error
/// curve are still computed the gradient-aware way, but no rank cut is
/// applied — the result is bitwise the feature-only merge.
pub struct MergeCtx<'g, 'a> {
    /// One gradient summary per shard, same order as the winner lists.
    pub grads: &'g [ShardGrads],
    /// The single top-level rank decision maker (one per coordinator).
    pub authority: Option<&'a mut dyn Selector>,
    /// Gradient-aware pivot stage ([`PivotMode::GradAware`]): after the
    /// feature tournament fixes winner *membership*, greedily re-order the
    /// merged list by residual ĝ coverage before the error curve / rank
    /// cut.  Zero gradient signal keeps the feature order bit for bit.
    ///
    /// [`PivotMode::GradAware`]: crate::engine::PivotMode
    pub grad_pivot: bool,
}

/// Reusable scratch for the merge stage (one per `ShardedSelector`): the
/// candidate union, its gathered feature rows, the MaxVol pivot order,
/// and the tournament's ping-pong winner lists.  Buffers are cleared and
/// refilled per merge node, so capacity is retained across refreshes and
/// steady-state merging performs no heap allocations.
#[derive(Default)]
pub struct MergeScratch {
    /// Candidate union (batch-local row ids), in shard order.
    union: Vec<usize>,
    /// Row-gathered candidate features (|union| × R).
    feat: Vec<f64>,
    /// MaxVol pivot order over the union (union-local indices).
    local: Vec<usize>,
    /// Current-round winner lists (ping side).
    lists: Vec<Vec<usize>>,
    /// Next-round winner lists (pong side); swapped with `lists` per round.
    next: Vec<Vec<usize>>,
    /// Gradient-aware merge: batch-local id → (shard, winner index) map,
    /// sorted by id for binary search.
    gmap: Vec<(usize, u32, u32)>,
    /// Gradient-aware merge: global batch-mean gradient ḡ (E).
    gbar: Vec<f64>,
    /// Gradient-aware merge: merged winners' gradient columns (≤ keep·E),
    /// orthonormalised in place by the fused prefix-error kernel.
    gcols: Vec<f64>,
}

/// Fold the per-shard winner lists (disjoint batch-local row ids, one list
/// per shard in shard order) into at most `keep` rows written to `out`.
/// Winner lists arrive as an exact-size iterator of slices so callers can
/// stream them straight out of their worker slots without collecting.
///
/// Deterministic: the result is a pure function of `(view, winners, keep,
/// policy)` — the tournament pairing is fixed by list order, so worker
/// interleaving during the fan-out stage cannot change it.
pub fn merge_winners<'a, I>(
    view: &BatchView<'_>,
    winners: I,
    keep: usize,
    policy: MergePolicy,
    ws: &mut Workspace,
    scratch: &mut MergeScratch,
    out: &mut Vec<usize>,
) where
    I: IntoIterator<Item = &'a [usize]>,
    I::IntoIter: ExactSizeIterator,
{
    out.clear();
    let it = winners.into_iter();
    let count = it.len();
    if count == 0 {
        return;
    }
    if count == 1 {
        for w in it {
            out.extend_from_slice(w);
        }
        out.truncate(keep);
        return;
    }
    // Split the scratch into its disjoint buffers so the tournament can
    // hold the list arrays while reduce_union fills the union/feat/local
    // ones.
    let MergeScratch { union, feat, local, lists, next, .. } = scratch;
    match policy.base() {
        MergePolicy::Flat => {
            union.clear();
            for w in it {
                union.extend_from_slice(w);
            }
            reduce_union(view, keep, ws, union, feat, local, out);
        }
        // base() collapses Grad onto the hierarchical tournament.
        MergePolicy::Hierarchical | MergePolicy::Grad => {
            // Seed round: copy the winner slices into retained buffers.
            if lists.len() < count {
                lists.resize_with(count, Vec::new);
            }
            for (dst, w) in lists.iter_mut().zip(it) {
                dst.clear();
                dst.extend_from_slice(w);
            }
            let mut cur = count;
            while cur > 1 {
                let folded = cur.div_ceil(2);
                if next.len() < folded {
                    next.resize_with(folded, Vec::new);
                }
                for pi in 0..folded {
                    if 2 * pi + 1 == cur {
                        // Odd list passes through to the next round.
                        let (a, b) = (&lists[2 * pi], &mut next[pi]);
                        b.clear();
                        b.extend_from_slice(a);
                        continue;
                    }
                    union.clear();
                    union.extend_from_slice(&lists[2 * pi]);
                    union.extend_from_slice(&lists[2 * pi + 1]);
                    reduce_union(view, keep, ws, union, feat, local, &mut next[pi]);
                }
                std::mem::swap(lists, next);
                cur = folded;
            }
            out.extend_from_slice(&lists[0]);
        }
    }
}

/// Gradient-aware fold ([`MergePolicy::Grad`]): run the MaxVol tournament
/// of `base` (`Grad`/`Hierarchical` → tournament tree, `Flat` → one
/// reduction) to fix the merged pivot order, then recompute the prefix
/// projection errors of the global ĝ over that order with the fused MGS
/// kernel (`graft::geometry::prefix_errors_core`) and apply **one**
/// top-level rank decision through `ctx.authority`'s
/// [`Selector::post_merge_rank`] hook, truncating `out` to R*.
///
/// The global ḡ is the count-weighted mean of the shards' partial sums —
/// exact, with no pass over the batch — and the winners' gradient columns
/// are read from the carried [`ShardGrads`], never from `view.grads`, so
/// the reduction only touches what crossed the shard boundary.
///
/// Deterministic like [`merge_winners`]: given the same winner lists,
/// gradient context, and authority state, the result (and the returned
/// [`RankDecision`]) is a pure function — the tournament shape only
/// changes *which* pivot order the one decision is applied to, and with
/// no authority the result is bitwise the feature-only merge.
pub fn merge_winners_grad<'a, I>(
    view: &BatchView<'_>,
    winners: I,
    keep: usize,
    base: MergePolicy,
    ctx: MergeCtx<'_, '_>,
    ws: &mut Workspace,
    scratch: &mut MergeScratch,
    out: &mut Vec<usize>,
) -> Option<RankDecision>
where
    I: IntoIterator<Item = &'a [usize]>,
    I::IntoIter: ExactSizeIterator + Clone,
{
    let it = winners.into_iter();
    let count = it.len();
    debug_assert_eq!(count, ctx.grads.len(), "one ShardGrads per winner list");
    let e = view.grads.cols();
    // id → (shard, winner index), sorted by id (ids are disjoint across
    // shards, so the sort key is unique).
    scratch.gmap.clear();
    for (s, w) in it.clone().enumerate() {
        debug_assert_eq!(
            ctx.grads.get(s).map(|g| g.cols.len()),
            Some(w.len() * e),
            "ShardGrads.cols misaligned with winner list {s}"
        );
        for (j, &id) in w.iter().enumerate() {
            scratch.gmap.push((id, s as u32, j as u32));
        }
    }
    scratch.gmap.sort_unstable_by_key(|&(id, _, _)| id);
    // Global ḡ: count-weighted mean of the partial sums.
    let total: usize = ctx.grads.iter().map(|g| g.count).sum();
    scratch.gbar.clear();
    scratch.gbar.resize(e, 0.0);
    for g in ctx.grads {
        debug_assert!(g.gsum.len() == e || g.count == 0, "partial ḡ sum has wrong width");
        for (t, &v) in g.gsum.iter().enumerate() {
            scratch.gbar[t] += v;
        }
    }
    if total > 0 {
        for v in scratch.gbar.iter_mut() {
            *v /= total as f64;
        }
    }
    // Stage 1 over the union: the feature-space MaxVol tournament fixes
    // the merged pivot order (prefix-nested by the final reduction).
    merge_winners(view, it, keep, base, ws, scratch, out);
    if out.is_empty() {
        return None;
    }
    // Stage 2, globally: prefix errors of ĝ over the merged order, from
    // the gradient columns that crossed the shard boundary.
    gather_cols(&scratch.gmap, ctx.grads, out, e, &mut scratch.gcols);
    let rmax = out.len();
    // Optional gradient-aware pivot: permute the merged order by greedy
    // residual ĝ coverage (clobbers the column buffer — re-gather before
    // the error curve).  Membership is already fixed; only the order the
    // rank cut truncates changes.  Zero signal keeps the feature order.
    if ctx.grad_pivot
        && grad_aware_order(&mut scratch.gcols, e, rmax, &scratch.gbar, &mut ws.pe_ghat, out)
    {
        gather_cols(&scratch.gmap, ctx.grads, out, e, &mut scratch.gcols);
    }
    prefix_errors_core(&mut scratch.gcols, e, rmax, &scratch.gbar, &mut ws.pe_ghat, &mut ws.pe_err);
    let decision = match ctx.authority {
        Some(authority) => authority.post_merge_rank(&ws.pe_err, keep, rmax),
        None => None,
    };
    if let Some(d) = decision {
        out.truncate(d.rank.min(rmax));
    }
    decision
}

/// Gather the gradient-sketch columns for the merged winner ids, widening
/// to f64 — the only read the merge performs from the carried boundary.
fn gather_cols(
    gmap: &[(usize, u32, u32)],
    grads: &[ShardGrads],
    ids: &[usize],
    e: usize,
    gcols: &mut Vec<f64>,
) {
    gcols.clear();
    for &id in ids {
        let li = gmap
            .binary_search_by_key(&id, |&(gid, _, _)| gid)
            .expect("merged winner must come from a shard winner list");
        let (_, s, j) = gmap[li];
        grads[s as usize].cols.gather_into(j as usize * e, e, gcols);
    }
}

/// One merge node: keep at most `keep` of the candidate rows in `union`
/// (unique batch-local ids).  Fast MaxVol over the gathered candidate
/// features picks up to `min(keep, R)` rows; any remaining budget is
/// topped up with the highest-loss leftover candidates (loss-descending,
/// id-ascending — the same NaN-safe rule as `selection::top_up_by_loss`,
/// restricted to the union).  `feat`/`local` are retained scratch from
/// [`MergeScratch`].
fn reduce_union(
    view: &BatchView<'_>,
    keep: usize,
    ws: &mut Workspace,
    union: &[usize],
    feat: &mut Vec<f64>,
    local: &mut Vec<usize>,
    out: &mut Vec<usize>,
) {
    out.clear();
    let n = union.len();
    if n <= keep {
        out.extend_from_slice(union);
        return;
    }
    let rcols = view.features.cols();
    feat.clear();
    for &i in union {
        feat.extend_from_slice(view.features.row(i));
    }
    let width = keep.min(rcols).min(n);
    let fmat = Mat::from_vec(n, rcols, std::mem::take(feat));
    fast_maxvol_with(&fmat, width, ws, local);
    *feat = fmat.into_vec();
    for &li in local.iter() {
        out.push(union[li]);
    }
    if out.len() >= keep {
        return;
    }
    // keep beyond the feature rank: top up within the union by loss.
    let taken = &mut ws.sel_taken;
    taken.clear();
    taken.resize(n, false);
    for &li in local.iter() {
        taken[li] = true;
    }
    let rest = &mut ws.sel_rest;
    rest.clear();
    rest.extend((0..n).filter(|&li| !taken[li]));
    rest.sort_unstable_by(|&a, &b| {
        let (ra, rb) = (union[a], union[b]);
        view.losses[rb].total_cmp(&view.losses[ra]).then(ra.cmp(&rb))
    });
    let need = keep - out.len();
    out.extend(rest.iter().take(need).map(|&li| union[li]));
}

#[cfg(test)]
mod tests {
    //! Degenerate-geometry pins: the PR 2 suite covers well-conditioned
    //! shapes (shards ≫ r candidates, full-rank features); these lock the
    //! edges — shards holding fewer than `r` rows, single-candidate winner
    //! lists, empty lists, and `keep` beyond the feature rank where the
    //! loss top-up takes over.

    use super::*;
    use crate::selection::maxvol::fast_maxvol;
    use crate::selection::testsupport::random_view;

    fn merge(
        view: &BatchView<'_>,
        lists: &[Vec<usize>],
        keep: usize,
        policy: MergePolicy,
    ) -> Vec<usize> {
        let mut ws = Workspace::new();
        let mut scratch = MergeScratch::default();
        let mut out = Vec::new();
        merge_winners(view, lists.iter().map(|l| l.as_slice()), keep, policy, &mut ws, &mut scratch, &mut out);
        out
    }

    #[test]
    fn two_shards_below_rank_hier_is_bitwise_flat() {
        // Each shard holds fewer rows than `keep`, so both winner lists are
        // exhaustive; with exactly two lists the tournament has a single
        // fold node — definitionally the same reduction Flat runs, so the
        // two policies must agree bit for bit.
        let owned = random_view(24, 8, 4, 2, 901);
        let lists = vec![(0..12).collect::<Vec<_>>(), (12..24).collect::<Vec<_>>()];
        for keep in [1usize, 5, 8, 16, 23] {
            let h = merge(&owned.view(), &lists, keep, MergePolicy::Hierarchical);
            let f = merge(&owned.view(), &lists, keep, MergePolicy::Flat);
            assert_eq!(h, f, "keep={keep}");
            assert_eq!(h.len(), keep.min(24), "size keep={keep}");
        }
    }

    #[test]
    fn keep_covering_all_candidates_passes_through_in_shard_order() {
        // `keep` at or beyond the candidate count (the rank > k shape):
        // every node is a passthrough, both policies return the full union
        // in shard order, no MaxVol runs at all.
        let owned = random_view(20, 6, 4, 2, 903);
        let lists: Vec<Vec<usize>> =
            (0..8).map(|s| (0..20).filter(|i| i % 8 == s).collect()).collect();
        let all: Vec<usize> = lists.iter().flatten().copied().collect();
        for keep in [20usize, 25, 100] {
            for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
                assert_eq!(merge(&owned.view(), &lists, keep, policy), all, "keep={keep} {policy:?}");
            }
        }
    }

    #[test]
    fn single_candidate_unions_pick_the_global_winner() {
        // One winner per shard and keep == 1: every fold compares two
        // single rows; the tournament champion must equal Flat's global
        // pick, because Fast MaxVol's first pivot (argmax |first feature
        // column|) reduces associatively when those magnitudes are
        // distinct — which generic gaussian features are almost surely.
        let owned = random_view(16, 5, 4, 2, 905);
        for shards in [2usize, 3, 5, 8] {
            let lists: Vec<Vec<usize>> = (0..shards).map(|s| vec![2 * s]).collect();
            let h = merge(&owned.view(), &lists, 1, MergePolicy::Hierarchical);
            let f = merge(&owned.view(), &lists, 1, MergePolicy::Flat);
            assert_eq!(h.len(), 1, "shards={shards}");
            assert_eq!(h, f, "champion differs, shards={shards}");
            assert!(lists.iter().any(|l| l[0] == h[0]), "champion from candidates");
        }
    }

    #[test]
    fn empty_winner_lists_are_tolerated() {
        // A shard can legitimately win nothing (empty range after clamp);
        // merges must skip it without panicking or emitting phantoms.
        let owned = random_view(12, 4, 4, 2, 907);
        let lists = vec![vec![0usize, 1, 2], Vec::new(), vec![7, 8], Vec::new()];
        for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
            let out = merge(&owned.view(), &lists, 4, policy);
            assert_eq!(out.len(), 4, "{policy:?}");
            let mut u = out.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 4, "unique {policy:?}");
            assert!(out.iter().all(|i| [0usize, 1, 2, 7, 8].contains(i)), "{policy:?}");
        }
    }

    #[test]
    fn keep_beyond_feature_rank_tops_up_by_loss() {
        // R = 3 feature columns but keep = 10: MaxVol can only justify 3
        // rows; the remaining 7 must be exactly the highest-loss leftover
        // candidates, loss-descending with ascending-id tie-break.
        let mut owned = random_view(16, 3, 4, 2, 909);
        for (i, l) in owned.losses.iter_mut().enumerate() {
            *l = ((i * 7) % 16) as f64; // distinct, known ordering
        }
        let union: Vec<usize> = (0..12).collect();
        let lists = vec![union[..6].to_vec(), union[6..].to_vec()];
        let keep = 10;
        let out = merge(&owned.view(), &lists, keep, MergePolicy::Flat);
        assert_eq!(out.len(), keep);

        // The MaxVol head: pivots of the gathered 12×3 candidate block.
        let cand = Mat::from_fn(12, 3, |i, j| owned.features[(union[i], j)]);
        let picks: Vec<usize> = fast_maxvol(&cand, 3).iter().map(|&li| union[li]).collect();
        assert_eq!(&out[..picks.len()], &picks[..], "MaxVol head");

        // The tail: highest-loss leftovers in loss-desc / id-asc order.
        let mut rest: Vec<usize> =
            union.iter().copied().filter(|i| !picks.contains(i)).collect();
        rest.sort_by(|&a, &b| owned.losses[b].total_cmp(&owned.losses[a]).then(a.cmp(&b)));
        assert_eq!(&out[picks.len()..], &rest[..keep - picks.len()], "loss top-up tail");
    }

    // -- gradient-aware fold ------------------------------------------------

    use crate::graft::{BudgetedRankPolicy, GraftSelector};
    use crate::linalg::Workspace as Ws;

    /// Build the per-shard gradient context a `run_shard` call would have
    /// produced for these winner lists over these contiguous ranges.
    fn shard_grads(
        view: &BatchView<'_>,
        lists: &[Vec<usize>],
        ranges: &[std::ops::Range<usize>],
    ) -> Vec<ShardGrads> {
        lists
            .iter()
            .zip(ranges)
            .map(|(w, r)| {
                let mut g = ShardGrads::default();
                for &id in w {
                    g.cols.push_row(view.grads.row(id));
                }
                crate::graft::geometry::grad_sum_into(view.grads, r.clone(), &mut g.gsum);
                g.count = r.len();
                g
            })
            .collect()
    }

    fn grad_merge(
        view: &BatchView<'_>,
        lists: &[Vec<usize>],
        grads: &[ShardGrads],
        keep: usize,
        base: MergePolicy,
        authority: Option<&mut dyn Selector>,
    ) -> (Vec<usize>, Option<crate::graft::RankDecision>) {
        let mut ws = Ws::new();
        let mut scratch = MergeScratch::default();
        let mut out = Vec::new();
        let d = merge_winners_grad(
            view,
            lists.iter().map(|l| l.as_slice()),
            keep,
            base,
            MergeCtx { grads, authority, grad_pivot: false },
            &mut ws,
            &mut scratch,
            &mut out,
        );
        (out, d)
    }

    #[test]
    fn grad_pivot_merge_keeps_membership_and_zero_signal_keeps_order() {
        let owned = random_view(24, 6, 8, 2, 931);
        let lists = vec![(0..12).collect::<Vec<_>>(), (12..24).collect()];
        let ranges = [0..12usize, 12..24];
        let grads = shard_grads(&owned.view(), &lists, &ranges);
        let keep = 6;
        let run = |grads: &[ShardGrads], pivot: bool| {
            let mut ws = Ws::new();
            let mut scratch = MergeScratch::default();
            let mut out = Vec::new();
            merge_winners_grad(
                &owned.view(),
                lists.iter().map(|l| l.as_slice()),
                keep,
                MergePolicy::Grad,
                MergeCtx { grads, authority: None, grad_pivot: pivot },
                &mut ws,
                &mut scratch,
                &mut out,
            );
            out
        };
        let plain = run(&grads, false);
        let pivoted = run(&grads, true);
        let (mut a, mut b) = (plain.clone(), pivoted.clone());
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "pivot must not change merged membership");

        // Zero gradient signal: wipe the partial ḡ sums → ‖ḡ‖ = 0 → the
        // pivot stage falls through and the feature order survives bitwise.
        let silent: Vec<ShardGrads> = grads
            .iter()
            .map(|g| {
                let mut wide = Vec::new();
                g.cols.gather_into(0, g.cols.len(), &mut wide);
                let mut n = ShardGrads {
                    cols: SketchBuf::default(),
                    gsum: vec![0.0; g.gsum.len()],
                    count: g.count,
                };
                n.cols.push_row(&wide);
                n
            })
            .collect();
        assert_eq!(run(&silent, true), run(&silent, false), "zero signal keeps feature order");
    }

    #[test]
    fn grad_merge_without_authority_is_bitwise_feature_only() {
        // No rank authority → the gradient context changes nothing about
        // the winners: pivot order and loss top-up come from the same
        // tournament, so the result is the feature-only merge, bit for bit.
        let owned = random_view(32, 6, 8, 4, 915);
        let lists = vec![(0..10).collect::<Vec<_>>(), (10..22).collect(), (22..32).collect()];
        let ranges = [0..10usize, 10..22, 22..32];
        let grads = shard_grads(&owned.view(), &lists, &ranges);
        for keep in [3usize, 8, 20] {
            for base in [MergePolicy::Hierarchical, MergePolicy::Flat] {
                let (out, d) = grad_merge(&owned.view(), &lists, &grads, keep, base, None);
                assert_eq!(out, merge(&owned.view(), &lists, keep, base), "keep={keep} {base:?}");
                assert!(d.is_none(), "no authority, no decision");
            }
        }
    }

    #[test]
    fn grad_merge_strict_authority_keeps_budget_and_counts_once() {
        let owned = random_view(24, 8, 6, 2, 917);
        let lists = vec![(0..12).collect::<Vec<_>>(), (12..24).collect()];
        let ranges = [0..12usize, 12..24];
        let grads = shard_grads(&owned.view(), &lists, &ranges);
        let mut auth = GraftSelector::new(BudgetedRankPolicy::strict(0.05));
        let keep = 8;
        let (out, d) =
            grad_merge(&owned.view(), &lists, &grads, keep, MergePolicy::Grad, Some(&mut auth));
        let d = d.expect("authority decides");
        assert_eq!(d.rank, keep, "strict policy keeps the exact budget");
        assert_eq!(out.len(), keep);
        assert_eq!(out, merge(&owned.view(), &lists, keep, MergePolicy::Hierarchical));
        let stats = auth.rank_stats().unwrap();
        assert_eq!(stats.batches, 1.0, "one merge = one budget-accounting entry");
        assert_eq!(stats.last, Some(d));
    }

    #[test]
    fn grad_merge_adaptive_truncates_on_planted_low_rank() {
        // Gradients confined to a 2-D subspace: the global error curve
        // collapses after two pivots, so the adaptive authority must cut
        // the merged subset far below the feature-only budget while
        // meeting ε — the paper's dynamic-rank behaviour, surviving the
        // shard boundary.
        let mut rng = crate::rng::Rng::new(919);
        let (k, e, keep) = (32usize, 10usize, 8usize);
        let loadings = Mat::from_fn(k, 2, |_, _| rng.normal());
        let basis = Mat::from_fn(2, e, |_, _| rng.normal());
        let grads = loadings.matmul(&basis);
        let mut owned = random_view(k, 6, e, 4, 921);
        owned.grads = grads;
        let lists = vec![(0..16).collect::<Vec<_>>(), (16..32).collect()];
        let ranges = [0..16usize, 16..32];
        let sg = shard_grads(&owned.view(), &lists, &ranges);
        let mut auth = GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0));
        let (out, d) =
            grad_merge(&owned.view(), &lists, &sg, keep, MergePolicy::Grad, Some(&mut auth));
        let d = d.expect("authority decides");
        assert!(d.satisfied, "planted low-rank must meet ε");
        assert!(d.error <= 0.05 + 1e-9, "error {}", d.error);
        assert_eq!(out.len(), d.rank);
        assert!(out.len() <= 4, "low-rank gradients → small global R*, got {}", out.len());
    }

    #[test]
    fn grad_merge_two_lists_hier_base_is_bitwise_flat_base() {
        // With two winner lists the tournament has a single fold node —
        // the same reduction Flat runs — so the grad-aware result
        // (winners, errors, decision) must agree bitwise across bases.
        let owned = random_view(20, 5, 7, 2, 923);
        let lists = vec![(0..10).collect::<Vec<_>>(), (10..20).collect()];
        let ranges = [0..10usize, 10..20];
        let grads = shard_grads(&owned.view(), &lists, &ranges);
        for keep in [2usize, 6, 9] {
            let mut a1 = GraftSelector::new(BudgetedRankPolicy::adaptive(0.1, 1.0));
            let mut a2 = GraftSelector::new(BudgetedRankPolicy::adaptive(0.1, 1.0));
            let (h, dh) = grad_merge(
                &owned.view(), &lists, &grads, keep, MergePolicy::Hierarchical, Some(&mut a1),
            );
            let (f, df) =
                grad_merge(&owned.view(), &lists, &grads, keep, MergePolicy::Flat, Some(&mut a2));
            assert_eq!(h, f, "keep={keep}");
            assert_eq!(dh, df, "keep={keep}");
        }
    }

    #[test]
    fn sketch_buf_f64_gather_is_bitwise_and_f32_rounds_once() {
        let row = [1.0f64, -2.5, 3.141592653589793, 1e-30, 7.0e7];
        let mut b64 = SketchBuf::default();
        b64.push_row(&row);
        let mut got = Vec::new();
        b64.gather_into(0, row.len(), &mut got);
        assert_eq!(got, row, "f64 buffer must round-trip bitwise");
        assert_eq!(b64.bytes(), row.len() * 8);

        let mut b32 = SketchBuf::new(true);
        b32.push_row(&row);
        assert_eq!(b32.len(), row.len());
        assert_eq!(b32.bytes(), row.len() * 4, "narrowed payload is half the bytes");
        got.clear();
        b32.gather_into(0, row.len(), &mut got);
        for (g, w) in got.iter().zip(&row) {
            assert_eq!(*g, *w as f32 as f64, "exactly one narrowing per element");
        }
    }

    #[test]
    fn sketch_buf_set_f32_normalises_variant_and_clear_keeps_it() {
        let mut b = SketchBuf::default();
        assert!(!b.is_f32());
        b.push_row(&[1.0, 2.0]);
        b.set_f32(true);
        assert!(b.is_f32());
        assert!(b.is_empty(), "variant switch drops stale contents");
        b.push_row(&[3.0]);
        b.set_f32(true); // no-op: same variant keeps contents
        assert_eq!(b.len(), 1);
        b.clear();
        assert!(b.is_f32(), "clear keeps the variant");
        assert!(b.is_empty());
    }

    #[test]
    fn grad_merge_f32_sketches_match_f64_rank_on_planted_low_rank() {
        // Same planted 2-D gradient subspace as the adaptive truncation
        // pin: the error curve collapses to ~1e-15 after two pivots, far
        // below both ε and f32 rounding noise (~1e-7), so the narrowed
        // boundary must produce the identical decision and subset.
        let mut rng = crate::rng::Rng::new(919);
        let (k, e, keep) = (32usize, 10usize, 8usize);
        let loadings = Mat::from_fn(k, 2, |_, _| rng.normal());
        let basis = Mat::from_fn(2, e, |_, _| rng.normal());
        let grads = loadings.matmul(&basis);
        let mut owned = random_view(k, 6, e, 4, 921);
        owned.grads = grads;
        let lists = vec![(0..16).collect::<Vec<_>>(), (16..32).collect()];
        let ranges = [0..16usize, 16..32];
        let sg64 = shard_grads(&owned.view(), &lists, &ranges);
        let sg32: Vec<ShardGrads> = sg64
            .iter()
            .map(|g| {
                let mut n = ShardGrads {
                    cols: SketchBuf::new(true),
                    gsum: g.gsum.clone(),
                    count: g.count,
                };
                let mut wide = Vec::new();
                g.cols.gather_into(0, g.cols.len(), &mut wide);
                n.cols.push_row(&wide);
                n
            })
            .collect();
        let mut a64 = GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0));
        let mut a32 = GraftSelector::new(BudgetedRankPolicy::adaptive(0.05, 1.0));
        let (o64, d64) =
            grad_merge(&owned.view(), &lists, &sg64, keep, MergePolicy::Grad, Some(&mut a64));
        let (o32, d32) =
            grad_merge(&owned.view(), &lists, &sg32, keep, MergePolicy::Grad, Some(&mut a32));
        let (d64, d32) = (d64.unwrap(), d32.unwrap());
        assert_eq!(d64.rank, d32.rank, "planted low-rank: narrowing cannot move the cut");
        assert_eq!(o64, o32, "identical rank → identical subset (order is f64-only)");
        assert!((d64.error - d32.error).abs() < 1e-6, "{} vs {}", d64.error, d32.error);
    }

    #[test]
    fn single_list_truncates_to_keep() {
        let owned = random_view(10, 4, 4, 2, 911);
        let lists = vec![vec![9usize, 3, 5, 1, 7]];
        for policy in [MergePolicy::Hierarchical, MergePolicy::Flat] {
            assert_eq!(merge(&owned.view(), &lists, 3, policy), vec![9, 3, 5], "{policy:?}");
            assert_eq!(merge(&owned.view(), &lists, 8, policy), lists[0], "{policy:?}");
        }
    }
}

//! Persistent selection worker pool: long-lived workers that replace the
//! per-refresh `std::thread::scope` fan-out of [`super::ShardedSelector`],
//! plus the async submit/finish API the trainer uses to overlap next-window
//! assembly (`gather` + `embed` + extractor) with in-flight shard selection.
//!
//! Architecture (see `README.md` in this directory for the full diagram):
//!
//! * [`SelectionPool`] spawns `workers` threads at construction.  Worker
//!   `w` owns the selector instances for shards `s ≡ w (mod workers)`, one
//!   pinned [`Workspace`], and recycled feature/gradient gather buffers.
//!   Jobs arrive over a per-worker bounded channel; results return over one
//!   shared bounded channel, tagged with the submission epoch so a late
//!   result from an abandoned epoch can never corrupt a newer one.
//! * [`PooledSelector`] wraps a pool with a [`MergePolicy`] and implements
//!   [`Selector`], so the trainer picks it up through the ordinary
//!   `Box<dyn Selector>` plumbing.  [`PooledSelector::begin`] submits the
//!   shard jobs and returns a [`Pending`] guard; [`Pending::finish`] blocks
//!   for the results and runs the hierarchical merge.  Between the two the
//!   caller is free to assemble the next window — that gap is the overlap.
//! * [`run_windows`] is the pipelined refresh loop: `assemble(w+1)` runs on
//!   the coordinator thread while the workers select window `w`.
//!
//! Guarantees pinned by `tests/selection_pool.rs` and
//! `tests/fault_injection.rs`:
//!
//! * **Bit-identity**: pooled execution at any worker count produces
//!   exactly the subset of the scoped-thread and serial [`ShardedSelector`]
//!   paths — both run the same [`run_shard`] kernel per shard and the same
//!   deterministic merge, so worker count and job interleaving are
//!   structurally invisible.
//! * **Containment + recovery**: a panicking selector is caught on the
//!   worker; under the configured [`FaultPolicy`] the worker is
//!   *respawned* (fresh thread, fresh [`Workspace`], fresh selector
//!   instances from the retained factory) and the shard job re-run with
//!   the same inputs — a successful retry is bit-identical to the
//!   fault-free run.  Exhausted retries surface as a typed
//!   [`SelectError::ShardFailure`] from [`Pending::finish`]; the
//!   [`Selector::select_into`] compatibility wrapper (no error channel in
//!   the trait) logs the typed error and degrades to a coordinator-side
//!   feature-only selection instead of panicking, so **no public entry
//!   point can panic on fault input**.
//! * **No hangs**: a worker that blows the per-job deadline gets its shard
//!   requeued on a fresh worker ([`PoolStats::deadline_requeues`]).  Every
//!   submission is tagged with the id of the thread it was handed to, and
//!   is written off (and retried) only when *that specific thread* is
//!   proven finished ([`std::thread::JoinHandle::is_finished`]) — current
//!   slot or retired predecessor alike — so `finish` cannot wedge on a
//!   dead thread, and a submission on a live-but-wedged thread is never
//!   abandoned.  (A worker that is alive but wedged *forever* with no
//!   retry budget still blocks `finish`: the raw view pointer it holds
//!   makes abandoning a live worker unsound.)
//! * **Clean shutdown**: dropping the pool (or calling
//!   [`PooledSelector::shutdown`] — idempotent) closes the job channels,
//!   joins every worker (including retired ones) with the shared
//!   timeout-then-log helper, counts timed-out joins in
//!   [`PoolStats::join_timeouts`], and never deadlocks.
//!
//! Steady-state refreshes are allocation-free (extended `alloc_free.rs`):
//! gather buffers live on the workers, winner buffers round-trip through
//! the job/result messages by move, and `sync_channel` slots are
//! preallocated at construction.  Only the fault paths allocate.
//!
//! # Safety model
//!
//! Jobs carry a raw pointer to the caller's [`BatchView`] so workers can
//! read the batch without copying it through the channel.  Soundness rests
//! on one invariant, enforced structurally by [`Pending`]: **every
//! submitted job is accounted for (result received, or its worker proven
//! dead) before the borrow of the view ends.**  `Pending` holds the view
//! borrow and drains outstanding results both in [`Pending::finish`] and in
//! its `Drop` (covering early returns and unwinding callers), so the
//! pointee provably outlives every worker-side dereference.  The fault
//! paths preserve it: a deadline requeue *adds* a duplicate submission and
//! keeps draining both results (the late one is discarded, never
//! abandoned), and each submission records the id of the thread it was
//! handed to, so it is only written off once `is_finished()` proves that
//! specific thread — and therefore any dereference of the view on it —
//! gone.  A requeue duplicate on a fresh thread is accounted separately
//! from the wedged original: the replacement dying never writes off the
//! original still running on a live retired thread.

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::faults::{FaultAction, FaultInjector, ShardCtx};
use crate::graft::{RankDecision, RankStats};
use crate::linalg::{Mat, Workspace};
use crate::selection::maxvol::fast_maxvol_with;
use crate::selection::{top_up_by_loss, BatchView, Selector};

use super::fault::{FaultPolicy, PoolStats, SelectError, WindowsError};
use super::merge::{
    merge_winners, merge_winners_grad, MergeCtx, MergePolicy, MergeScratch, ShardGrads,
};
use super::pipeline::join_or_log;
use super::shard::{run_shard, shard_ranges_into};

/// Per-job deadline before the coordinator probes worker health and
/// requeues wedged shards.  Generous: healthy selection is micro- to
/// milliseconds, so a trip means a genuinely stuck or dead worker.
const DEFAULT_JOB_DEADLINE: Duration = Duration::from_secs(30);

/// Raw pointer to a caller-owned [`BatchView`], sendable to a worker.
///
/// The lifetime is erased at the channel boundary and re-conjured on the
/// worker; see the module-level safety model for why the pointee is always
/// alive when [`ViewPtr::get`] runs.
#[derive(Clone, Copy)]
struct ViewPtr(*const ());

// SAFETY: the pointee is only dereferenced while the submitting `Pending`
// guard holds the view borrow (it drains all outstanding jobs before the
// borrow ends), and `BatchView`'s fields are all `Sync` shared references.
unsafe impl Send for ViewPtr {}

impl ViewPtr {
    fn new(view: &BatchView<'_>) -> ViewPtr {
        ViewPtr(view as *const BatchView<'_> as *const ())
    }

    /// SAFETY: caller must guarantee the pointed-to view (and everything it
    /// borrows) is alive for all of `'a`.  `BatchView`'s layout does not
    /// depend on its lifetime parameter, so the cast is representationally
    /// sound; the liveness obligation is discharged by the `Pending` drain
    /// protocol.
    unsafe fn get<'a>(&self) -> &'a BatchView<'a> {
        &*(self.0 as *const BatchView<'a>)
    }
}

/// One shard job, fed to a worker over its channel.  `winners` is the
/// coordinator-owned result buffer, moved in empty and moved back filled
/// through [`Done`]; `grads` is the shard's gradient context
/// ([`ShardGrads`]), filled only when `want_grads` (gradient-aware merge)
/// and round-tripped by move exactly like the winner buffer — the
/// recycling that keeps steady state allocation-free.
struct Job {
    view: ViewPtr,
    shard: usize,
    range: Range<usize>,
    budget: usize,
    epoch: u64,
    /// Id of the thread this job was handed to (echoed into [`Done`]);
    /// the coordinator's per-submission accounting key.
    owner: u64,
    winners: Vec<usize>,
    want_grads: bool,
    grads: ShardGrads,
}

/// One shard result.  `epoch` and `owner` let the coordinator match a
/// result to the exact submission it answers — and discard results from an
/// abandoned epoch or an already-written-off submission while still
/// recycling their buffers (into the spare lists, never a shard slot).
struct Done {
    shard: usize,
    epoch: u64,
    owner: u64,
    winners: Vec<usize>,
    grads: ShardGrads,
    panicked: bool,
}

/// A worker thread plus the monotonically-assigned id the coordinator uses
/// to account submissions to it (ids are never reused, so a stale result
/// can never be confused with a live submission's).
struct WorkerThread {
    id: u64,
    handle: JoinHandle<()>,
}

/// The selector factory a pool retains so it can respawn a worker with
/// fresh instances, constructed exactly as at pool creation (same seeds,
/// same policies) — which is what keeps a respawn-and-retry bit-identical
/// to the fault-free run for the deterministic selector family.
type SelectorFactory = Box<dyn FnMut(usize) -> Box<dyn Selector> + Send>;

/// Persistent pool of selection workers (one pinned [`Workspace`] and
/// recycled gather buffers each), fed shard jobs over bounded channels.
///
/// The pool is deliberately dumb: it knows nothing about merging.  It is
/// always driven through [`PooledSelector`], which owns the partition and
/// the merge stage.
pub struct SelectionPool {
    /// Per-worker job senders; worker `w` serves shards `s ≡ w (mod W)`.
    txs: Vec<SyncSender<Job>>,
    done_rx: Receiver<Done>,
    /// Master result sender, cloned into every (re)spawned worker.  Kept
    /// here so respawns are possible at any time; consequently the done
    /// channel never disconnects while the pool lives, and drain timeouts
    /// (not `Err`) are the all-workers-dead signal.
    done_tx: SyncSender<Done>,
    /// Live worker threads, one per worker slot (probed with
    /// `is_finished` by the deadline path; replaced on respawn).
    handles: Vec<WorkerThread>,
    /// Replaced worker threads: joined at shutdown, or reaped early by the
    /// deadline path once proven finished (which is also what writes off
    /// any submissions they still owned).  A retired worker has lost its
    /// job sender, so it winds down as soon as its queue drains.
    retired: Vec<WorkerThread>,
    /// Next [`WorkerThread::id`]; monotonic, never reused.
    next_thread: u64,
    /// Factory for fresh per-shard selector instances (respawn path).
    factory: SelectorFactory,
    /// Deterministic fault injection (tests/benches only; `None` in
    /// production).  Threaded into every worker at (re)spawn.
    injector: Option<Arc<dyn FaultInjector>>,
    /// Retained winner buffers, one per shard, taken at submit and
    /// returned by the drain.
    bufs: Vec<Vec<usize>>,
    /// Retained per-shard gradient contexts, round-tripped like `bufs`
    /// (filled by workers only for gradient-aware merges).
    gbufs: Vec<ShardGrads>,
    /// Free-listed winner buffers recycled from results that did not
    /// complete their shard (stale epochs, written-off submissions,
    /// requeue duplicates, contained panics).  Retry submissions draw
    /// from here; the live shard slots in `bufs` are only ever written by
    /// the result that actually completes the shard.
    spare_bufs: Vec<Vec<usize>>,
    /// Gradient-context twin of `spare_bufs`.
    spare_gbufs: Vec<ShardGrads>,
    /// Per-shard owner ids of submissions still unaccounted for in the
    /// current epoch — the thread each outstanding job was handed to.  A
    /// deadline requeue gives a shard two owners until the wedged result
    /// lands (or its thread is proven dead).
    inflight: Vec<Vec<u64>>,
    /// Per-shard completion flags for the current epoch (first healthy
    /// result wins; duplicates are discarded).
    sdone: Vec<bool>,
    /// Per-shard retry count in the current epoch.
    attempts: Vec<u32>,
    /// What to do when a shard job fails; see [`FaultPolicy`].
    policy: FaultPolicy,
    /// Per-job deadline before worker health is probed.
    deadline: Duration,
    stats: PoolStats,
    /// Carry gradient sketches across the worker → merge channel as f32
    /// (half the message bytes).  Every submission normalises its grads
    /// buffer to this variant, so recycled spares of the other precision
    /// can never leak a mixed-precision epoch.
    sketch_f32: bool,
    shards: usize,
    nworkers: usize,
    epoch: u64,
}

impl SelectionPool {
    /// Spawn `workers` threads serving `shards` selector instances;
    /// `make(s)` constructs shard `s`'s instance exactly as
    /// [`super::ShardedSelector::from_factory`] would, so the two paths
    /// hold identical selectors.  `workers` is clamped to `1..=shards`.
    /// The factory is retained for the life of the pool: respawning a
    /// failed worker re-runs it for that worker's shards.
    fn from_factory(shards: usize, workers: usize, make: SelectorFactory) -> SelectionPool {
        assert!(shards >= 1, "need at least one shard");
        let workers = workers.clamp(1, shards);
        // Capacity 4·shards + slack: originals, deadline requeues, and the
        // write-off retries of a faulted epoch can all deliver without a
        // send blocking under any realistic retry budget; and while an
        // epoch is live the drain is consuming, so even a pathological
        // budget only delays a worker send — it can never wedge shutdown,
        // whose joins are timeout-guarded.
        let (done_tx, done_rx) = sync_channel::<Done>(4 * shards + 8);
        let mut pool = SelectionPool {
            txs: Vec::with_capacity(workers),
            done_rx,
            done_tx,
            handles: Vec::with_capacity(workers),
            retired: Vec::new(),
            next_thread: 0,
            factory: make,
            injector: None,
            bufs: (0..shards).map(|_| Vec::new()).collect(),
            gbufs: (0..shards).map(|_| ShardGrads::default()).collect(),
            spare_bufs: Vec::new(),
            spare_gbufs: Vec::new(),
            inflight: (0..shards).map(|_| Vec::new()).collect(),
            sdone: vec![false; shards],
            attempts: vec![0; shards],
            policy: FaultPolicy::Fail,
            deadline: DEFAULT_JOB_DEADLINE,
            stats: PoolStats::default(),
            sketch_f32: false,
            shards,
            nworkers: workers,
            epoch: 0,
        };
        for w in 0..workers {
            let (tx, h) = pool.spawn_worker(w);
            pool.txs.push(tx);
            pool.handles.push(h);
        }
        pool
    }

    fn workers(&self) -> usize {
        self.nworkers.max(1)
    }

    /// Worker threads currently alive (their `JoinHandle` not yet
    /// finished).  Purely observational — the serve layer exports it as
    /// telemetry; fault handling keeps probing per-thread on its own.
    fn live_workers(&self) -> usize {
        self.handles.iter().filter(|t| !t.handle.is_finished()).count()
    }

    /// Build worker `w`'s thread: fresh selector instances for its shards
    /// (`w, w+W, w+2W, …` — the dealing [`worker_loop`] indexes by
    /// `shard / W`), a fresh [`Workspace`], a fresh job channel.
    fn spawn_worker(&mut self, w: usize) -> (SyncSender<Job>, WorkerThread) {
        let workers = self.workers();
        let mut sels: Vec<Box<dyn Selector>> = Vec::new();
        let mut s = w;
        while s < self.shards {
            sels.push((self.factory)(s));
            s += workers;
        }
        let job_depth = self.shards.div_ceil(workers);
        let (tx, rx) = sync_channel::<Job>(job_depth);
        let done = self.done_tx.clone();
        let injector = self.injector.clone();
        let id = self.next_thread;
        self.next_thread += 1;
        let h = std::thread::spawn(move || worker_loop(rx, done, sels, workers, w, injector));
        (tx, WorkerThread { id, handle: h })
    }

    /// Replace worker `w` with a fresh thread + selectors.  The old
    /// sender is dropped (the old thread winds down once its queue
    /// drains — its in-flight results still arrive through the retained
    /// master done sender) and its handle parked for the shutdown join.
    /// Callers count [`PoolStats::respawns`] when the replacement is
    /// fault recovery rather than reconfiguration.
    fn respawn_worker(&mut self, w: usize) {
        if w >= self.txs.len() {
            return; // pool already shut down
        }
        let (tx, h) = self.spawn_worker(w);
        self.txs[w] = tx;
        self.retired.push(std::mem::replace(&mut self.handles[w], h));
    }

    /// Install (or clear) the fault injector, rebuilding every worker so
    /// the hook is threaded through their loops.  Reconfiguration, not
    /// recovery: does not count as a respawn.
    fn install_injector(&mut self, injector: Option<Arc<dyn FaultInjector>>) {
        self.injector = injector;
        for w in 0..self.txs.len() {
            self.respawn_worker(w);
        }
    }

    /// Close the job channels and join every worker (current and
    /// retired).  Idempotent: a second call (or the `Drop` after an
    /// explicit call) is a no-op.  A wedged worker cannot hang teardown —
    /// joins go through the shared timeout-then-log helper, and timed-out
    /// joins are counted in [`PoolStats::join_timeouts`] instead of only
    /// a stderr line.
    fn shutdown(&mut self) {
        // Dropping the senders disconnects the job channels; workers exit
        // their recv loop.  The done channel has capacity for every
        // original + requeued result, so an in-flight worker can always
        // deliver its last result and reach the disconnect — no send can
        // block shutdown.
        self.txs.clear();
        for t in self.handles.drain(..).chain(self.retired.drain(..)) {
            if !join_or_log(t.handle, "selection pool worker") {
                self.stats.join_timeouts += 1;
            }
        }
    }
}

impl Drop for SelectionPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Body of one pool worker: receive shard jobs until the channel closes,
/// run each through the shared [`run_shard`] kernel with this worker's
/// pinned workspace and recycled gather buffers, and send the (epoch-
/// tagged) winners back.  A panicking selector is caught here so the
/// worker — and the pool — survive it; the coordinator resurfaces it
/// through the typed fault path.  When a fault injector is installed it
/// is consulted before each job: `Panic` raises a real panic inside the
/// containment boundary, `Delay` sleeps (driving the job past the
/// coordinator's deadline), `DieWorker` kills the thread without
/// answering — the schedule the deadline/respawn machinery is tested
/// against.
fn worker_loop(
    rx: Receiver<Job>,
    done: SyncSender<Done>,
    mut selectors: Vec<Box<dyn Selector>>,
    stride: usize,
    worker: usize,
    injector: Option<Arc<dyn FaultInjector>>,
) {
    let mut ws = Workspace::new();
    let mut feat: Vec<f64> = Vec::new();
    let mut grad: Vec<f64> = Vec::new();
    let mut local: Vec<usize> = Vec::new();
    while let Ok(job) = rx.recv() {
        let Job { view, shard, range, budget, epoch, owner, mut winners, want_grads, mut grads } =
            job;
        let action = match &injector {
            Some(i) => i.before_shard(ShardCtx { window: epoch, shard, worker }),
            None => FaultAction::None,
        };
        match action {
            // Vanish without answering: the job is only recovered once
            // the coordinator proves this thread dead via its handle.
            FaultAction::DieWorker => return,
            FaultAction::Delay(by) => std::thread::sleep(by),
            _ => {}
        }
        let sel = selectors[shard / stride].as_mut();
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            if matches!(action, FaultAction::Panic) {
                panic!("injected fault: worker {worker} shard {shard} window {epoch}");
            }
            // SAFETY: the submitting `Pending` guard keeps the view (and
            // all data it borrows) alive until this job's `Done` has been
            // received — see the module-level safety model.
            let view = unsafe { view.get() };
            run_shard(
                sel,
                view,
                range,
                budget,
                &mut ws,
                &mut feat,
                &mut grad,
                &mut local,
                &mut winners,
                want_grads.then_some(&mut grads),
            );
        }))
        .is_err();
        // The done channel is sized to hold every original + requeued
        // result, so this send never blocks; an Err means the coordinator
        // is gone and the worker can only wind down.
        if done.send(Done { shard, epoch, owner, winners, grads, panicked }).is_err() {
            return;
        }
    }
}

/// Pool-backed sharded selector: the persistent-worker replacement for the
/// scoped-thread [`super::ShardedSelector`] fan-out, with an async
/// [`PooledSelector::begin`]/[`Pending::finish`] API for the trainer's
/// assemble ∥ select overlap.  Implements [`Selector`], so the synchronous
/// path is just `begin(..).finish(..)`.
pub struct PooledSelector {
    pool: SelectionPool,
    merge: MergePolicy,
    /// The single top-level dynamic-rank decision maker for the
    /// gradient-aware merge — lives on the coordinator (never on a pool
    /// worker), so there is exactly one budget accumulator at any
    /// shard/worker count.
    authority: Option<Box<dyn Selector>>,
    /// Gradient-aware pivot stage ([`crate::engine::PivotMode::GradAware`]):
    /// re-order the merged winners by residual ĝ coverage before the rank
    /// cut; forces the gradient carry even without a rank authority.
    grad_pivot: bool,
    /// Last gradient-merge decision, for logging.
    last: Option<RankDecision>,
    scratch: MergeScratch,
    /// Retained partition buffer (recomputed per call, capacity reused).
    ranges: Vec<Range<usize>>,
}

impl PooledSelector {
    /// Build with one selector instance per shard on a pool of `workers`
    /// threads; `make(s)` constructs shard `s`'s instance (worker
    /// assignment is `s % workers`).  Matches
    /// [`super::ShardedSelector::from_factory`] instance-for-instance, so
    /// pooled and scoped execution are bit-identical.  The factory is
    /// retained (hence `Send + 'static`) so a failed worker can be
    /// respawned with identically-constructed selectors.
    ///
    /// Panics if `shards > 1` and a constructed selector does not opt in
    /// via [`Selector::shardable`] (the MaxVol merge only preserves the
    /// MaxVol family's criterion).  A single shard involves no merge, so
    /// `shards == 1` accepts any selector — that is how non-shardable
    /// methods still get off-thread selection and the overlap path.
    pub fn from_factory(
        shards: usize,
        workers: usize,
        merge: MergePolicy,
        mut make: impl FnMut(usize) -> Box<dyn Selector> + Send + 'static,
    ) -> PooledSelector {
        let pool = SelectionPool::from_factory(
            shards,
            workers,
            Box::new(move |s| {
                let sel = make(s);
                assert!(
                    shards == 1 || sel.shardable(),
                    "selector '{}' is not shardable: the MaxVol merge would not preserve \
                     its selection criterion",
                    sel.name()
                );
                sel
            }),
        );
        PooledSelector {
            pool,
            merge,
            authority: None,
            grad_pivot: false,
            last: None,
            scratch: MergeScratch::default(),
            ranges: Vec::new(),
        }
    }

    /// Enable the gradient-aware pivot stage on the merge — the pooled
    /// twin of [`super::ShardedSelector::with_grad_pivot`]; pooled and
    /// scoped execution apply it identically (inert at one shard, where no
    /// merge runs).  Facade-internal plumbing; application code goes
    /// through [`crate::engine::EngineBuilder`].
    pub fn with_grad_pivot(mut self, on: bool) -> Self {
        self.grad_pivot = on;
        self
    }

    /// Install the top-level rank authority for the gradient-aware merge
    /// ([`MergePolicy::Grad`]) — see
    /// [`super::ShardedSelector::with_rank_authority`]; pooled and scoped
    /// execution consult it identically — including being inert at one
    /// shard — which keeps pool ≡ scoped bit-identity intact under
    /// `--merge grad`.  Facade-internal plumbing (see the scoped twin's
    /// doc): application code goes through
    /// [`crate::engine::EngineBuilder`].
    pub fn with_rank_authority(mut self, authority: Box<dyn Selector>) -> Self {
        self.authority = Some(authority);
        self
    }

    /// Carry gradient sketches across the worker → merge channel as f32
    /// (`true`) instead of the default bitwise f64 — the pooled twin of
    /// [`super::ShardedSelector::with_f32_sketches`].  Existing shard
    /// slots and spares are renormalised immediately; submissions also
    /// renormalise per job, so the switch can never mix precisions within
    /// an epoch.
    pub fn with_f32_sketches(mut self, on: bool) -> Self {
        self.pool.sketch_f32 = on;
        for g in self.pool.gbufs.iter_mut().chain(self.pool.spare_gbufs.iter_mut()) {
            g.cols.set_f32(on);
        }
        self
    }

    /// Payload bytes of gradient sketches resident in the pool's shard
    /// slots and spare list — zero whenever no rank authority is
    /// installed (the adaptive-only carry), pinned by
    /// `tests/alloc_free.rs`.
    pub fn carried_sketch_bytes(&self) -> usize {
        self.pool
            .gbufs
            .iter()
            .chain(self.pool.spare_gbufs.iter())
            .map(|g| g.sketch_bytes())
            .sum()
    }

    /// Set what happens when a shard job fails: surface the typed error
    /// (`Fail`, default), respawn + retry (`Retry`), or retry once before
    /// the engine's degradation ladder takes over (`Degrade`).  Zero-fault
    /// behaviour is identical under every policy.
    pub fn set_fault_policy(&mut self, policy: FaultPolicy) {
        self.pool.policy = policy;
    }

    /// Per-job deadline before the coordinator probes worker health and
    /// requeues wedged shards (default 30 s).
    pub fn set_job_deadline(&mut self, deadline: Duration) {
        self.pool.deadline = deadline.max(Duration::from_millis(1));
    }

    /// Install (or clear) a deterministic fault injector (tests/benches).
    /// Workers are rebuilt so the hook reaches their loops; selector
    /// construction is re-run by the retained factory, so results are
    /// unchanged.
    pub fn set_fault_injector(&mut self, injector: Option<Arc<dyn FaultInjector>>) {
        self.pool.install_injector(injector);
    }

    /// Fault-path telemetry: respawns, retries, deadline requeues, and
    /// shutdown join timeouts observed by this pool.  All-zero on a
    /// healthy run.
    pub fn stats(&self) -> PoolStats {
        self.pool.stats
    }

    /// Decision of the most recent gradient-aware merge (for logging).
    /// Facade-internal; prefer [`crate::engine::Selection::decision`].
    pub fn last_rank_decision(&self) -> Option<RankDecision> {
        self.last
    }

    pub fn shards(&self) -> usize {
        self.pool.shards
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Worker threads currently alive (≤ [`PooledSelector::workers`]);
    /// a dead-but-not-yet-respawned worker shows up here before the next
    /// select's deadline path replaces it.  Telemetry for the serve
    /// layer's `Drain`/`Stats` replies.
    pub fn live_workers(&self) -> usize {
        self.pool.live_workers()
    }

    /// Explicitly tear the pool down (also happens on drop; idempotent).
    pub fn shutdown(&mut self) {
        self.pool.shutdown();
    }

    /// Submit the shard jobs for one batch and return the [`Pending`]
    /// guard.  The caller may do arbitrary work before
    /// [`Pending::finish`] — that window is the assemble ∥ select overlap.
    /// The guard mutably borrows `self` and holds the view borrow, so the
    /// batch data provably outlives the in-flight jobs.
    pub fn begin<'v>(&mut self, view: &'v BatchView<'v>, r: usize) -> Pending<'_, 'v> {
        let k = view.k();
        shard_ranges_into(k, self.pool.shards, &mut self.ranges);
        let live = self.ranges.len();
        let budget = r.min(k);
        self.pool.epoch += 1;
        let epoch = self.pool.epoch;
        // As in `ShardedSelector`: without a rank authority (or the
        // gradient-aware pivot stage) the grad merge is bitwise the
        // feature-only merge, so skip the gradient carry.  At one shard
        // the inner selector applies its own policy inline (bit-identity
        // with the scoped fast path and single-shot), so neither the
        // authority nor the pivot stage is consulted there.
        let want_grads = self.merge.gradient_aware()
            && (self.authority.is_some() || self.grad_pivot)
            && self.pool.shards > 1;
        if self.pool.txs.is_empty() {
            // Pool already shut down: nothing to submit; `finish` fails
            // with `PoolUnavailable` instead of deadlocking (pinned by the
            // post-shutdown regression in tests/selection_pool.rs).
            return Pending {
                sel: self,
                view,
                live: 0,
                budget,
                epoch,
                want_grads,
                outstanding: 0,
                requeued: false,
                error: Some(SelectError::PoolUnavailable),
            };
        }
        // Reset the per-epoch shard accounting (retained buffers).  Every
        // inflight list is cleared, not just the live ones: a prior epoch
        // that ended early (pool unavailable) may have left owners behind,
        // and their threads are provably gone by then.
        for infl in self.pool.inflight.iter_mut() {
            infl.clear();
        }
        for s in 0..live {
            self.pool.sdone[s] = false;
            self.pool.attempts[s] = 0;
        }
        let mut pending = Pending {
            sel: self,
            view,
            live,
            budget,
            epoch,
            want_grads,
            outstanding: 0,
            requeued: false,
            error: None,
        };
        for s in 0..live {
            let pool = &mut pending.sel.pool;
            let winners = std::mem::take(&mut pool.bufs[s]);
            let grads = std::mem::take(&mut pool.gbufs[s]);
            if !pending.submit_with(s, winners, grads) {
                // The worker slot is jammed or its thread died before the
                // epoch even started: rebuild it and retry the send once
                // if the policy allows, else record the typed failure.
                let pool = &mut pending.sel.pool;
                if pool.attempts[s] < pool.policy.max_retries() {
                    pool.attempts[s] += 1;
                    pool.stats.retries += 1;
                    pool.stats.respawns += 1;
                    let w = s % pool.workers();
                    pool.respawn_worker(w);
                    if pending.submit(s) {
                        continue;
                    }
                }
                let attempts = pending.sel.pool.attempts[s] + 1;
                pending.error.get_or_insert(SelectError::ShardFailure { shard: s, attempts });
            }
        }
        pending
    }
}

impl Selector for PooledSelector {
    fn name(&self) -> &'static str {
        "pooled"
    }

    /// Accounting of the rank authority.  At one shard the inner selector
    /// is the decision maker, but it lives on a worker thread and cannot
    /// be read — `None` (unlike the scoped path, which reads it inline);
    /// an installed-but-unconsulted authority is never reported.
    fn rank_stats(&self) -> Option<RankStats> {
        if self.pool.shards > 1 {
            self.authority.as_ref().and_then(|a| a.rank_stats())
        } else {
            None
        }
    }

    /// Legacy synchronous path: [`PooledSelector::begin`] +
    /// [`Pending::finish`].  The [`Selector`] trait has no error channel,
    /// so a typed failure that survives the pool's fault policy is
    /// **logged and degraded**, never panicked: the wrapper falls back to
    /// a deterministic coordinator-side feature-only MaxVol (+ loss-ranked
    /// top-up to the budget) computed on the caller's thread from the
    /// caller's view — the same bottom-rung criterion as the engine's
    /// degradation ladder, with no worker involvement, so it cannot fail
    /// again.  The drain in `finish` has already run, so the pool stays
    /// consistent and reusable afterwards.  Fault-aware callers — the
    /// engine — use `begin`/`finish` directly and get the [`SelectError`].
    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        if let Err(e) = self.begin(view, r).finish(ws, out) {
            eprintln!(
                "selection pool: {e}; degrading to coordinator-side feature-only selection \
                 (pool state stays consistent)"
            );
            self.last = None;
            out.clear();
            let width = r.min(view.features.cols()).min(view.k());
            if width > 0 {
                fast_maxvol_with(view.features, width, ws, out);
            }
            top_up_by_loss(view, r, ws, out);
        }
    }
}

/// In-flight selection epoch: proof that shard jobs reference a live view.
///
/// Obtained from [`PooledSelector::begin`]; consumed by
/// [`Pending::finish`], which blocks for the shard results, drives the
/// respawn/retry/deadline machinery, and runs the merge.  Dropping it
/// without finishing (early return, unwinding caller) still drains every
/// outstanding job first — the invariant the worker-side raw view pointer
/// depends on.
pub struct Pending<'s, 'v> {
    sel: &'s mut PooledSelector,
    view: &'v BatchView<'v>,
    live: usize,
    budget: usize,
    epoch: u64,
    want_grads: bool,
    /// Total submissions (originals + retries + requeues) not yet
    /// accounted for: received, or written off on a proven-dead worker.
    outstanding: usize,
    /// Deadline requeue already performed this epoch (once is enough:
    /// after it every shard has a fresh submission on a fresh worker).
    requeued: bool,
    error: Option<SelectError>,
}

impl Pending<'_, '_> {
    /// Submit a fresh job for shard `s` with the given buffers, stamped
    /// with the id of the thread currently serving the shard's slot (the
    /// submission's accounting key); returns false (recycling the buffers
    /// into the spare lists) if the worker's channel refused it.
    fn submit_with(&mut self, s: usize, winners: Vec<usize>, mut grads: ShardGrads) -> bool {
        let pool = &mut self.sel.pool;
        // Normalise the sketch variant before the buffer crosses the
        // channel: spares recycled from before a precision switch (or
        // freshly defaulted ones, which are f64) must not smuggle the
        // other width into this epoch.
        grads.cols.set_f32(pool.sketch_f32);
        let w = s % pool.txs.len();
        let owner = pool.handles[w].id;
        let job = Job {
            view: ViewPtr::new(self.view),
            shard: s,
            range: self.sel.ranges[s].clone(),
            budget: self.budget,
            epoch: self.epoch,
            owner,
            winners,
            want_grads: self.want_grads,
            grads,
        };
        match pool.txs[w].try_send(job) {
            Ok(()) => {
                pool.inflight[s].push(owner);
                self.outstanding += 1;
                true
            }
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => {
                pool.spare_bufs.push(j.winners);
                pool.spare_gbufs.push(j.grads);
                false
            }
        }
    }

    /// [`Pending::submit_with`] drawing from the spare buffer lists (the
    /// retry/requeue path, where the original buffers may still be in
    /// flight on the faulted worker); allocates only when no spares are
    /// free-listed yet.
    fn submit(&mut self, s: usize) -> bool {
        let pool = &mut self.sel.pool;
        let winners = pool.spare_bufs.pop().unwrap_or_default();
        let grads = pool.spare_gbufs.pop().unwrap_or_default();
        self.submit_with(s, winners, grads)
    }

    /// Either re-run shard `s` (within the policy's retry budget, counting
    /// [`PoolStats::retries`]) or record the typed shard failure.  Callers
    /// respawn the faulted worker first, so the retry lands on a fresh
    /// thread with a fresh [`Workspace`].
    fn retry_or_fail(&mut self, s: usize) {
        let pool = &mut self.sel.pool;
        if pool.attempts[s] < pool.policy.max_retries() {
            pool.attempts[s] += 1;
            pool.stats.retries += 1;
            let backoff = pool.policy.backoff();
            if backoff > Duration::ZERO {
                std::thread::sleep(backoff);
            }
            if self.submit(s) {
                return;
            }
        }
        let attempts = self.sel.pool.attempts[s] + 1;
        self.error.get_or_insert(SelectError::ShardFailure { shard: s, attempts });
    }

    /// Account one received result.  It counts only if it answers a
    /// still-accounted submission of this epoch — matched by (epoch,
    /// owner thread id) — otherwise it is stale (abandoned epoch, or a
    /// submission already written off when its thread was proven dead)
    /// and only its buffers are recycled, into the spare lists.  Of the
    /// counted results, the first healthy one completes the shard and its
    /// buffers become the shard slot; duplicates (deadline requeues) are
    /// discarded into the spares; a panicked result drives the
    /// respawn/retry path.  A result that does not complete its shard can
    /// therefore never overwrite one that did.
    fn absorb(&mut self, d: Done) {
        let pool = &mut self.sel.pool;
        let (shard, panicked) = (d.shard, d.panicked);
        let pos = (d.epoch == self.epoch)
            .then(|| pool.inflight[shard].iter().position(|&o| o == d.owner))
            .flatten();
        let Some(pos) = pos else {
            pool.spare_bufs.push(d.winners);
            pool.spare_gbufs.push(d.grads);
            return;
        };
        pool.inflight[shard].swap_remove(pos);
        self.outstanding -= 1;
        if !panicked && !pool.sdone[shard] {
            pool.bufs[shard] = d.winners;
            pool.gbufs[shard] = d.grads;
            pool.sdone[shard] = true;
            return;
        }
        pool.spare_bufs.push(d.winners);
        pool.spare_gbufs.push(d.grads);
        if pool.sdone[shard] {
            return; // duplicate of an already-completed shard (requeue)
        }
        // Contained panic: the worker thread survived, but its workspace
        // and selector state are suspect — replace both before retrying.
        let w = shard % pool.workers();
        pool.stats.respawns += 1;
        pool.respawn_worker(w);
        self.retry_or_fail(shard);
    }

    /// The per-job deadline fired with results still outstanding.  Two
    /// cases, in order:
    ///
    /// 1. A thread is *proven dead* (`is_finished`) — a current slot
    ///    (respawned in place) or a retired predecessor (reaped now; a
    ///    finished thread joins without blocking).  Only the submissions
    ///    *owned by that exact thread* are written off (its exit proves no
    ///    dereference of the view survives there; queued jobs died with
    ///    its channel) and their shards retried or failed.  A submission
    ///    owned by a live thread — say, the wedged original behind a
    ///    requeue whose replacement just died — stays accounted, so the
    ///    safety invariant holds even when replacements keep dying.
    /// 2. Every thread is alive but something is wedged: each missing
    ///    shard is requeued once on a freshly respawned worker
    ///    ([`PoolStats::deadline_requeues`]).  The wedged submissions stay
    ///    accounted — their late results are drained and discarded — so
    ///    the safety invariant holds without abandoning a live thread.
    fn handle_deadline(&mut self) {
        if self.sel.pool.handles.is_empty() {
            // Shut down mid-epoch (impossible through the public API, the
            // guard borrows the selector) — nothing can answer, and no
            // thread survives to dereference anything.
            self.error.get_or_insert(SelectError::PoolUnavailable);
            for infl in self.sel.pool.inflight.iter_mut() {
                infl.clear();
            }
            self.outstanding = 0;
            return;
        }
        // Collect every thread proven dead since the last probe.
        let mut dead: Vec<u64> = Vec::new();
        for w in 0..self.sel.pool.handles.len() {
            if !self.sel.pool.handles[w].handle.is_finished() {
                continue;
            }
            dead.push(self.sel.pool.handles[w].id);
            self.sel.pool.stats.respawns += 1;
            self.sel.pool.respawn_worker(w);
        }
        {
            let pool = &mut self.sel.pool;
            let mut i = 0;
            while i < pool.retired.len() {
                if pool.retired[i].handle.is_finished() {
                    let t = pool.retired.swap_remove(i);
                    dead.push(t.id);
                    let _ = t.handle.join();
                } else {
                    i += 1;
                }
            }
        }
        // Write off only submissions owned by a proven-dead thread; any
        // lost shard not yet completed gets an extra submission (safe:
        // first healthy result wins, duplicates are discarded), so the
        // epoch keeps making progress even while a wedged original is
        // still accounted on a live retired thread.
        let mut lost_any = false;
        for s in 0..self.live {
            let lost = {
                let infl = &mut self.sel.pool.inflight[s];
                let before = infl.len();
                infl.retain(|o| !dead.contains(o));
                before - infl.len()
            };
            if lost == 0 {
                continue;
            }
            lost_any = true;
            self.outstanding -= lost;
            if !self.sel.pool.sdone[s] {
                self.retry_or_fail(s);
            }
        }
        if lost_any || self.requeued {
            return;
        }
        // All workers alive, at least one wedged past the deadline:
        // requeue the missing shards on fresh workers (once per epoch).
        // The wedged worker keeps its slot's old channel and eventually
        // answers; that duplicate is drained and discarded above.
        self.requeued = true;
        let mut respawned = vec![false; self.sel.pool.handles.len()];
        for s in 0..self.live {
            let pool = &mut self.sel.pool;
            if pool.sdone[s] || pool.inflight[s].is_empty() {
                continue;
            }
            if pool.attempts[s] >= pool.policy.max_retries() {
                continue; // no budget: keep waiting on the wedged worker
            }
            pool.attempts[s] += 1;
            pool.stats.deadline_requeues += 1;
            let w = s % pool.workers();
            if !respawned[w] {
                respawned[w] = true;
                pool.stats.respawns += 1;
                pool.respawn_worker(w);
            }
            self.submit(s);
        }
    }

    /// Block until every submission of this epoch is accounted for,
    /// recycling winner buffers (the result completing a shard into its
    /// shard slot; everything else — stale epochs, written-off
    /// submissions, requeue duplicates, contained panics — into the spare
    /// lists) and driving the respawn/retry/deadline machinery.
    fn drain(&mut self) {
        while self.outstanding > 0 {
            let deadline = self.sel.pool.deadline;
            match self.sel.pool.done_rx.recv_timeout(deadline) {
                Ok(d) => self.absorb(d),
                Err(RecvTimeoutError::Timeout) => self.handle_deadline(),
                Err(RecvTimeoutError::Disconnected) => {
                    // Unreachable while the pool retains its master done
                    // sender; defensively: every sender gone means no job
                    // can still be running — safe to stop.
                    self.error.get_or_insert(SelectError::PoolUnavailable);
                    for infl in self.sel.pool.inflight.iter_mut() {
                        infl.clear();
                    }
                    self.outstanding = 0;
                }
            }
        }
    }

    /// Wait for the shard results and fold them with the merge policy into
    /// `out` (batch-local ids, `|out| == min(r, K)` for budget-honouring
    /// inner selectors).  A worker failure that survived the fault policy
    /// surfaces as a typed [`SelectError`] — after the drain, so the pool
    /// remains consistent and reusable either way.
    pub fn finish(mut self, ws: &mut Workspace, out: &mut Vec<usize>) -> Result<(), SelectError> {
        self.drain();
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        out.clear();
        if self.live == 0 {
            return Ok(());
        }
        let sel = &mut *self.sel;
        // Must mirror `begin`'s want_grads gate (authority and shard count
        // cannot change while this guard borrows the selector): gbufs are
        // only filled when the jobs were asked to carry gradient context.
        if self.want_grads {
            sel.last = merge_winners_grad(
                self.view,
                sel.pool.bufs[..self.live].iter().map(|b| b.as_slice()),
                self.budget,
                sel.merge,
                MergeCtx {
                    grads: &sel.pool.gbufs[..self.live],
                    authority: sel.authority.as_deref_mut(),
                    grad_pivot: sel.grad_pivot,
                },
                ws,
                &mut sel.scratch,
                out,
            );
        } else {
            merge_winners(
                self.view,
                sel.pool.bufs[..self.live].iter().map(|b| b.as_slice()),
                self.budget,
                sel.merge,
                ws,
                &mut sel.scratch,
                out,
            );
        }
        Ok(())
    }
}

impl Drop for Pending<'_, '_> {
    fn drop(&mut self) {
        // `finish` drains before it can return, so reaching here with jobs
        // outstanding means the guard was dropped without finishing (early
        // return or an unwinding caller).  Drain now: the raw view pointer
        // on the workers must not outlive this borrow.
        self.drain();
    }
}

// ---------------------------------------------------------------------------
// Pipelined refresh windows (assemble ∥ select)
// ---------------------------------------------------------------------------

/// One assembled selection window, owned so pool workers can read it while
/// the coordinator assembles the next one.  Field layout mirrors
/// [`BatchView`]; `row_ids` carries the global dataset ids the caller maps
/// the batch-local winners back through.
#[derive(Clone)]
pub struct SelectWindow {
    pub features: Mat,
    pub grads: Mat,
    pub losses: Vec<f64>,
    pub labels: Vec<i32>,
    pub preds: Vec<i32>,
    pub classes: usize,
    pub row_ids: Vec<usize>,
}

impl SelectWindow {
    pub fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

/// Per-window hook deciding what a finished selection *means*: it receives
/// the window ordinal, the view, the budget, the workspace/buffer, and the
/// [`Pending::finish`] result, and may run post-checks or a degradation
/// ladder before declaring the window failed.  [`run_windows`] passes the
/// identity (propagate errors as-is); the engine passes its
/// breakdown-check + ladder.
pub(crate) type WindowResolve<'r> = &'r mut dyn FnMut(
    usize,
    &BatchView<'_>,
    usize,
    &mut Workspace,
    &mut Vec<usize>,
    Result<(), SelectError>,
) -> Result<(), SelectError>;

/// Drive `count` selection windows through a [`PooledSelector`],
/// overlapping `assemble(w + 1)` (batch gather / `embed` / extractor —
/// whatever the closure does) with the in-flight shard selection and merge
/// of window `w` when `overlap` is true.  With `overlap == false` the loop
/// is strictly serial — assemble, select, consume — and produces exactly
/// the same `consume` calls as the pipelined path (pinned by
/// `tests/selection_pool.rs::overlap_and_serial_paths_agree`), because
/// window assembly never depends on selection results.
///
/// `consume(w, window, winners)` receives the batch-local winner ids for
/// window `w`; `selbuf` is the retained winner buffer threaded through
/// every select call.  An `Err` from `assemble` aborts the loop as
/// [`WindowsError::Assemble`]; a selection failure that survives the
/// pool's fault policy aborts it as [`WindowsError::Select`].  Either way
/// an in-flight epoch is drained by the [`Pending`] drop (or its
/// `finish`) before the error propagates.
pub fn run_windows<E>(
    sel: &mut PooledSelector,
    budget: usize,
    overlap: bool,
    count: usize,
    ws: &mut Workspace,
    selbuf: &mut Vec<usize>,
    assemble: impl FnMut(usize) -> Result<SelectWindow, E>,
    consume: impl FnMut(usize, &SelectWindow, &[usize]),
) -> Result<(), WindowsError<E>> {
    run_windows_with(
        sel,
        |_| budget,
        overlap,
        count,
        ws,
        selbuf,
        assemble,
        consume,
        &mut |_, _, _, _, _, res| res,
    )
}

/// [`run_windows`] with a per-window budget and a per-window result
/// resolver: `budget_for(K)` is consulted with each window's row count
/// before its jobs are submitted, and `resolve` (see [`WindowResolve`])
/// decides what each finished selection means — the engine's breakdown
/// checks and degradation ladder plug in there.  This is the ONE
/// implementation of the overlap pipeline — [`run_windows`] (fixed
/// budget, propagate-errors) and
/// [`crate::engine::SelectionEngine::windows`] (fraction-derived budgets,
/// fault policy) are both thin wrappers, so the subtle drain-on-error
/// ordering lives in exactly one place.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_windows_with<E>(
    sel: &mut PooledSelector,
    mut budget_for: impl FnMut(usize) -> usize,
    overlap: bool,
    count: usize,
    ws: &mut Workspace,
    selbuf: &mut Vec<usize>,
    mut assemble: impl FnMut(usize) -> Result<SelectWindow, E>,
    mut consume: impl FnMut(usize, &SelectWindow, &[usize]),
    resolve: WindowResolve<'_>,
) -> Result<(), WindowsError<E>> {
    if count == 0 {
        return Ok(());
    }
    if !overlap {
        for wi in 0..count {
            let win = assemble(wi).map_err(WindowsError::Assemble)?;
            let view = win.view();
            let budget = budget_for(view.k());
            let res = sel.begin(&view, budget).finish(ws, selbuf);
            resolve(wi, &view, budget, ws, selbuf, res).map_err(WindowsError::Select)?;
            consume(wi, &win, selbuf);
        }
        return Ok(());
    }
    let mut cur = assemble(0).map_err(WindowsError::Assemble)?;
    for wi in 0..count {
        let view = cur.view();
        let budget = budget_for(view.k());
        let pending = sel.begin(&view, budget);
        // The overlap: workers are selecting window `wi` right now, while
        // this thread assembles window `wi + 1`.  If assembly fails, the
        // `pending` drop drains the in-flight epoch before `?` returns.
        let next = match (wi + 1 < count).then(|| assemble(wi + 1)).transpose() {
            Ok(n) => n,
            Err(e) => return Err(WindowsError::Assemble(e)),
        };
        let res = pending.finish(ws, selbuf);
        resolve(wi, &view, budget, ws, selbuf, res).map_err(WindowsError::Select)?;
        consume(wi, &cur, selbuf);
        if let Some(n) = next {
            cur = n;
        }
    }
    Ok(())
}

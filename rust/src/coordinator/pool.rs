//! Persistent selection worker pool: long-lived workers that replace the
//! per-refresh `std::thread::scope` fan-out of [`super::ShardedSelector`],
//! plus the async submit/finish API the trainer uses to overlap next-window
//! assembly (`gather` + `embed` + extractor) with in-flight shard selection.
//!
//! Architecture (see `README.md` in this directory for the full diagram):
//!
//! * [`SelectionPool`] spawns `workers` threads at construction.  Worker
//!   `w` owns the selector instances for shards `s ≡ w (mod workers)`, one
//!   pinned [`Workspace`], and recycled feature/gradient gather buffers.
//!   Jobs arrive over a per-worker bounded channel; results return over one
//!   shared bounded channel, tagged with the submission epoch so a late
//!   result from an abandoned epoch can never corrupt a newer one.
//! * [`PooledSelector`] wraps a pool with a [`MergePolicy`] and implements
//!   [`Selector`], so the trainer picks it up through the ordinary
//!   `Box<dyn Selector>` plumbing.  [`PooledSelector::begin`] submits the
//!   shard jobs and returns a [`Pending`] guard; [`Pending::finish`] blocks
//!   for the results and runs the hierarchical merge.  Between the two the
//!   caller is free to assemble the next window — that gap is the overlap.
//! * [`run_windows`] is the pipelined refresh loop: `assemble(w+1)` runs on
//!   the coordinator thread while the workers select window `w`.
//!
//! Guarantees pinned by `tests/selection_pool.rs`:
//!
//! * **Bit-identity**: pooled execution at any worker count produces
//!   exactly the subset of the scoped-thread and serial [`ShardedSelector`]
//!   paths — both run the same [`run_shard`] kernel per shard and the same
//!   deterministic merge, so worker count and job interleaving are
//!   structurally invisible.
//! * **Containment**: a panicking selector is caught on the worker, the
//!   worker thread survives, the panic resurfaces on the caller in
//!   [`Pending::finish`], and the pool stays usable.
//! * **Clean shutdown**: dropping the pool (or calling
//!   [`PooledSelector::shutdown`] — idempotent) closes the job channels,
//!   joins every worker with the shared timeout-then-log helper, and never
//!   deadlocks, even mid-epoch after a drop of a [`Pending`] guard.
//!
//! Steady-state refreshes are allocation-free (extended `alloc_free.rs`):
//! gather buffers live on the workers, winner buffers round-trip through
//! the job/result messages by move, and `sync_channel` slots are
//! preallocated at construction.
//!
//! # Safety model
//!
//! Jobs carry a raw pointer to the caller's [`BatchView`] so workers can
//! read the batch without copying it through the channel.  Soundness rests
//! on one invariant, enforced structurally by [`Pending`]: **every
//! submitted job is accounted for (result received, or its worker proven
//! dead) before the borrow of the view ends.**  `Pending` holds the view
//! borrow and drains outstanding results both in [`Pending::finish`] and in
//! its `Drop` (covering early returns and unwinding callers), so the
//! pointee provably outlives every worker-side dereference.

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;

use crate::graft::{RankDecision, RankStats};
use crate::linalg::{Mat, Workspace};
use crate::selection::{BatchView, Selector};

use super::merge::{
    merge_winners, merge_winners_grad, MergeCtx, MergePolicy, MergeScratch, ShardGrads,
};
use super::pipeline::join_or_log;
use super::shard::{run_shard, shard_ranges_into};

/// Raw pointer to a caller-owned [`BatchView`], sendable to a worker.
///
/// The lifetime is erased at the channel boundary and re-conjured on the
/// worker; see the module-level safety model for why the pointee is always
/// alive when [`ViewPtr::get`] runs.
#[derive(Clone, Copy)]
struct ViewPtr(*const ());

// SAFETY: the pointee is only dereferenced while the submitting `Pending`
// guard holds the view borrow (it drains all outstanding jobs before the
// borrow ends), and `BatchView`'s fields are all `Sync` shared references.
unsafe impl Send for ViewPtr {}

impl ViewPtr {
    fn new(view: &BatchView<'_>) -> ViewPtr {
        ViewPtr(view as *const BatchView<'_> as *const ())
    }

    /// SAFETY: caller must guarantee the pointed-to view (and everything it
    /// borrows) is alive for all of `'a`.  `BatchView`'s layout does not
    /// depend on its lifetime parameter, so the cast is representationally
    /// sound; the liveness obligation is discharged by the `Pending` drain
    /// protocol.
    unsafe fn get<'a>(&self) -> &'a BatchView<'a> {
        &*(self.0 as *const BatchView<'a>)
    }
}

/// One shard job, fed to a worker over its channel.  `winners` is the
/// coordinator-owned result buffer, moved in empty and moved back filled
/// through [`Done`]; `grads` is the shard's gradient context
/// ([`ShardGrads`]), filled only when `want_grads` (gradient-aware merge)
/// and round-tripped by move exactly like the winner buffer — the
/// recycling that keeps steady state allocation-free.
struct Job {
    view: ViewPtr,
    shard: usize,
    range: Range<usize>,
    budget: usize,
    epoch: u64,
    winners: Vec<usize>,
    want_grads: bool,
    grads: ShardGrads,
}

/// One shard result.  `epoch` lets the coordinator discard results from an
/// abandoned epoch while still recycling their buffers.
struct Done {
    shard: usize,
    epoch: u64,
    winners: Vec<usize>,
    grads: ShardGrads,
    panicked: bool,
}

/// Persistent pool of selection workers (one pinned [`Workspace`] and
/// recycled gather buffers each), fed shard jobs over bounded channels.
///
/// The pool is deliberately dumb: it knows nothing about merging.  It is
/// always driven through [`PooledSelector`], which owns the partition and
/// the merge stage.
pub struct SelectionPool {
    /// Per-worker job senders; worker `w` serves shards `s ≡ w (mod W)`.
    txs: Vec<SyncSender<Job>>,
    done_rx: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
    /// Retained winner buffers, one per shard, taken at submit and
    /// returned by the drain.
    bufs: Vec<Vec<usize>>,
    /// Retained per-shard gradient contexts, round-tripped like `bufs`
    /// (filled by workers only for gradient-aware merges).
    gbufs: Vec<ShardGrads>,
    shards: usize,
    epoch: u64,
}

impl SelectionPool {
    /// Spawn `workers` threads serving `shards` selector instances;
    /// `make(s)` constructs shard `s`'s instance exactly as
    /// [`super::ShardedSelector::from_factory`] would, so the two paths
    /// hold identical selectors.  `workers` is clamped to `1..=shards`.
    fn from_factory(
        shards: usize,
        workers: usize,
        mut make: impl FnMut(usize) -> Box<dyn Selector>,
    ) -> SelectionPool {
        assert!(shards >= 1, "need at least one shard");
        let workers = workers.clamp(1, shards);
        // Deal selector instances to their owning workers: worker w gets
        // shards w, w+W, w+2W, … (local index s / W).
        let mut per_worker: Vec<Vec<Box<dyn Selector>>> =
            (0..workers).map(|_| Vec::new()).collect();
        for s in 0..shards {
            per_worker[s % workers].push(make(s));
        }
        let (done_tx, done_rx) = sync_channel::<Done>(shards);
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        let job_depth = shards.div_ceil(workers);
        for sels in per_worker {
            let (tx, rx) = sync_channel::<Job>(job_depth);
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || worker_loop(rx, done, sels, workers)));
            txs.push(tx);
        }
        SelectionPool {
            txs,
            done_rx,
            handles,
            bufs: (0..shards).map(|_| Vec::new()).collect(),
            gbufs: (0..shards).map(|_| ShardGrads::default()).collect(),
            shards,
            epoch: 0,
        }
    }

    fn workers(&self) -> usize {
        self.txs.len().max(1)
    }

    /// Close the job channels and join every worker.  Idempotent: a second
    /// call (or the `Drop` after an explicit call) is a no-op.  A wedged
    /// worker cannot hang teardown — joins go through the shared
    /// timeout-then-log helper.
    fn shutdown(&mut self) {
        // Dropping the senders disconnects the job channels; workers exit
        // their recv loop.  The done channel has capacity for every shard,
        // so an in-flight worker can always deliver its last result and
        // reach the disconnect — no send can block shutdown.
        self.txs.clear();
        for h in self.handles.drain(..) {
            join_or_log(h, "selection pool worker");
        }
    }
}

impl Drop for SelectionPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Body of one pool worker: receive shard jobs until the channel closes,
/// run each through the shared [`run_shard`] kernel with this worker's
/// pinned workspace and recycled gather buffers, and send the (epoch-
/// tagged) winners back.  A panicking selector is caught here so the
/// worker — and the pool — survive it; the coordinator resurfaces it.
fn worker_loop(
    rx: Receiver<Job>,
    done: SyncSender<Done>,
    mut selectors: Vec<Box<dyn Selector>>,
    stride: usize,
) {
    let mut ws = Workspace::new();
    let mut feat: Vec<f64> = Vec::new();
    let mut grad: Vec<f64> = Vec::new();
    let mut local: Vec<usize> = Vec::new();
    while let Ok(job) = rx.recv() {
        let Job { view, shard, range, budget, epoch, mut winners, want_grads, mut grads } = job;
        let sel = selectors[shard / stride].as_mut();
        let panicked = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: the submitting `Pending` guard keeps the view (and
            // all data it borrows) alive until this job's `Done` has been
            // received — see the module-level safety model.
            let view = unsafe { view.get() };
            run_shard(
                sel,
                view,
                range,
                budget,
                &mut ws,
                &mut feat,
                &mut grad,
                &mut local,
                &mut winners,
                want_grads.then_some(&mut grads),
            );
        }))
        .is_err();
        // The done channel is sized to hold every shard's result, so this
        // send never blocks; an Err means the coordinator is gone and the
        // worker can only wind down.
        if done.send(Done { shard, epoch, winners, grads, panicked }).is_err() {
            return;
        }
    }
}

/// Pool-backed sharded selector: the persistent-worker replacement for the
/// scoped-thread [`super::ShardedSelector`] fan-out, with an async
/// [`PooledSelector::begin`]/[`Pending::finish`] API for the trainer's
/// assemble ∥ select overlap.  Implements [`Selector`], so the synchronous
/// path is just `begin(..).finish(..)`.
pub struct PooledSelector {
    pool: SelectionPool,
    merge: MergePolicy,
    /// The single top-level dynamic-rank decision maker for the
    /// gradient-aware merge — lives on the coordinator (never on a pool
    /// worker), so there is exactly one budget accumulator at any
    /// shard/worker count.
    authority: Option<Box<dyn Selector>>,
    /// Last gradient-merge decision, for logging.
    last: Option<RankDecision>,
    scratch: MergeScratch,
    /// Retained partition buffer (recomputed per call, capacity reused).
    ranges: Vec<Range<usize>>,
}

impl PooledSelector {
    /// Build with one selector instance per shard on a pool of `workers`
    /// threads; `make(s)` constructs shard `s`'s instance (worker
    /// assignment is `s % workers`).  Matches
    /// [`super::ShardedSelector::from_factory`] instance-for-instance, so
    /// pooled and scoped execution are bit-identical.
    ///
    /// Panics if `shards > 1` and a constructed selector does not opt in
    /// via [`Selector::shardable`] (the MaxVol merge only preserves the
    /// MaxVol family's criterion).  A single shard involves no merge, so
    /// `shards == 1` accepts any selector — that is how non-shardable
    /// methods still get off-thread selection and the overlap path.
    pub fn from_factory(
        shards: usize,
        workers: usize,
        merge: MergePolicy,
        mut make: impl FnMut(usize) -> Box<dyn Selector>,
    ) -> PooledSelector {
        let pool = SelectionPool::from_factory(shards, workers, |s| {
            let sel = make(s);
            assert!(
                shards == 1 || sel.shardable(),
                "selector '{}' is not shardable: the MaxVol merge would not preserve \
                 its selection criterion",
                sel.name()
            );
            sel
        });
        PooledSelector {
            pool,
            merge,
            authority: None,
            last: None,
            scratch: MergeScratch::default(),
            ranges: Vec::new(),
        }
    }

    /// Install the top-level rank authority for the gradient-aware merge
    /// ([`MergePolicy::Grad`]) — see
    /// [`super::ShardedSelector::with_rank_authority`]; pooled and scoped
    /// execution consult it identically — including being inert at one
    /// shard — which keeps pool ≡ scoped bit-identity intact under
    /// `--merge grad`.  Facade-internal plumbing (see the scoped twin's
    /// doc): application code goes through
    /// [`crate::engine::EngineBuilder`].
    pub fn with_rank_authority(mut self, authority: Box<dyn Selector>) -> Self {
        self.authority = Some(authority);
        self
    }

    /// Decision of the most recent gradient-aware merge (for logging).
    /// Facade-internal; prefer [`crate::engine::Selection::decision`].
    pub fn last_rank_decision(&self) -> Option<RankDecision> {
        self.last
    }

    pub fn shards(&self) -> usize {
        self.pool.shards
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Explicitly tear the pool down (also happens on drop; idempotent).
    pub fn shutdown(&mut self) {
        self.pool.shutdown();
    }

    /// Submit the shard jobs for one batch and return the [`Pending`]
    /// guard.  The caller may do arbitrary work before
    /// [`Pending::finish`] — that window is the assemble ∥ select overlap.
    /// The guard mutably borrows `self` and holds the view borrow, so the
    /// batch data provably outlives the in-flight jobs.
    pub fn begin<'v>(&mut self, view: &'v BatchView<'v>, r: usize) -> Pending<'_, 'v> {
        let k = view.k();
        shard_ranges_into(k, self.pool.shards, &mut self.ranges);
        let live = self.ranges.len();
        let budget = r.min(k);
        self.pool.epoch += 1;
        let epoch = self.pool.epoch;
        if self.pool.txs.is_empty() {
            // Pool already shut down: nothing to submit; `finish` fails
            // loudly instead of deadlocking (pinned by the post-shutdown
            // regression in tests/selection_pool.rs).
            return Pending { sel: self, view, live: 0, budget, epoch, outstanding: 0, panicked: true };
        }
        // As in `ShardedSelector`: without a rank authority the grad merge
        // is bitwise the feature-only merge, so skip the gradient carry.
        // At one shard the inner selector applies its own policy inline
        // (bit-identity with the scoped fast path and single-shot), so the
        // authority is never consulted there either.
        let want_grads =
            self.merge.gradient_aware() && self.authority.is_some() && self.pool.shards > 1;
        let mut outstanding = 0usize;
        let mut panicked = false;
        for (s, range) in self.ranges.iter().cloned().enumerate() {
            let winners = std::mem::take(&mut self.pool.bufs[s]);
            let grads = std::mem::take(&mut self.pool.gbufs[s]);
            let job = Job {
                view: ViewPtr::new(view),
                shard: s,
                range,
                budget,
                epoch,
                winners,
                want_grads,
                grads,
            };
            // Channels are sized so a live worker always has queue room;
            // try_send only fails if the worker thread died (disconnect).
            match self.pool.txs[s % self.pool.txs.len()].try_send(job) {
                Ok(()) => outstanding += 1,
                Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => {
                    self.pool.bufs[s] = j.winners;
                    self.pool.gbufs[s] = j.grads;
                    panicked = true;
                }
            }
        }
        Pending { sel: self, view, live, budget, epoch, outstanding, panicked }
    }
}

impl Selector for PooledSelector {
    fn name(&self) -> &'static str {
        "pooled"
    }

    /// Accounting of the rank authority.  At one shard the inner selector
    /// is the decision maker, but it lives on a worker thread and cannot
    /// be read — `None` (unlike the scoped path, which reads it inline);
    /// an installed-but-unconsulted authority is never reported.
    fn rank_stats(&self) -> Option<RankStats> {
        if self.pool.shards > 1 {
            self.authority.as_ref().and_then(|a| a.rank_stats())
        } else {
            None
        }
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        self.begin(view, r).finish(ws, out);
    }
}

/// In-flight selection epoch: proof that shard jobs reference a live view.
///
/// Obtained from [`PooledSelector::begin`]; consumed by
/// [`Pending::finish`], which blocks for the shard results and runs the
/// merge.  Dropping it without finishing (early return, unwinding caller)
/// still drains every outstanding job first — the invariant the worker-side
/// raw view pointer depends on.
pub struct Pending<'s, 'v> {
    sel: &'s mut PooledSelector,
    view: &'v BatchView<'v>,
    live: usize,
    budget: usize,
    epoch: u64,
    outstanding: usize,
    panicked: bool,
}

impl Pending<'_, '_> {
    /// Block until every job of this epoch is accounted for, recycling
    /// winner buffers (current-epoch results into their shard slot; stale
    /// results from an abandoned epoch likewise, without counting them).
    fn drain(&mut self) {
        while self.outstanding > 0 {
            match self.sel.pool.done_rx.recv() {
                Ok(d) => {
                    let current = d.epoch == self.epoch;
                    if d.panicked && current {
                        self.panicked = true;
                    }
                    self.sel.pool.bufs[d.shard] = d.winners;
                    self.sel.pool.gbufs[d.shard] = d.grads;
                    if current {
                        self.outstanding -= 1;
                    }
                }
                Err(_) => {
                    // Every worker (and its done sender) is gone, so no job
                    // of this epoch can still be running — safe to stop.
                    self.panicked = true;
                    self.outstanding = 0;
                }
            }
        }
    }

    /// Wait for the shard results and fold them with the merge policy into
    /// `out` (batch-local ids, `|out| == min(r, K)` for budget-honouring
    /// inner selectors).  Propagates a worker panic to the caller — after
    /// the drain, so the pool remains consistent and reusable.
    pub fn finish(mut self, ws: &mut Workspace, out: &mut Vec<usize>) {
        self.drain();
        if self.panicked {
            panic!(
                "selection pool: a shard worker panicked or was unavailable \
                 (contained; pool state stays consistent)"
            );
        }
        out.clear();
        if self.live == 0 {
            return;
        }
        let sel = &mut *self.sel;
        // Must mirror `begin`'s want_grads gate (authority and shard count
        // cannot change while this guard borrows the selector): gbufs are
        // only filled when the jobs were asked to carry gradient context.
        if sel.merge.gradient_aware() && sel.authority.is_some() && sel.pool.shards > 1 {
            sel.last = merge_winners_grad(
                self.view,
                sel.pool.bufs[..self.live].iter().map(|b| b.as_slice()),
                self.budget,
                sel.merge,
                MergeCtx {
                    grads: &sel.pool.gbufs[..self.live],
                    authority: sel.authority.as_deref_mut(),
                },
                ws,
                &mut sel.scratch,
                out,
            );
        } else {
            merge_winners(
                self.view,
                sel.pool.bufs[..self.live].iter().map(|b| b.as_slice()),
                self.budget,
                sel.merge,
                ws,
                &mut sel.scratch,
                out,
            );
        }
    }
}

impl Drop for Pending<'_, '_> {
    fn drop(&mut self) {
        // `finish` drains before it can panic, so reaching here with jobs
        // outstanding means the guard was dropped without finishing (early
        // return or an unwinding caller).  Drain now: the raw view pointer
        // on the workers must not outlive this borrow.
        self.drain();
    }
}

// ---------------------------------------------------------------------------
// Pipelined refresh windows (assemble ∥ select)
// ---------------------------------------------------------------------------

/// One assembled selection window, owned so pool workers can read it while
/// the coordinator assembles the next one.  Field layout mirrors
/// [`BatchView`]; `row_ids` carries the global dataset ids the caller maps
/// the batch-local winners back through.
pub struct SelectWindow {
    pub features: Mat,
    pub grads: Mat,
    pub losses: Vec<f64>,
    pub labels: Vec<i32>,
    pub preds: Vec<i32>,
    pub classes: usize,
    pub row_ids: Vec<usize>,
}

impl SelectWindow {
    pub fn view(&self) -> BatchView<'_> {
        BatchView {
            features: &self.features,
            grads: &self.grads,
            losses: &self.losses,
            labels: &self.labels,
            preds: &self.preds,
            classes: self.classes,
            row_ids: &self.row_ids,
        }
    }
}

/// Drive `count` selection windows through a [`PooledSelector`],
/// overlapping `assemble(w + 1)` (batch gather / `embed` / extractor —
/// whatever the closure does) with the in-flight shard selection and merge
/// of window `w` when `overlap` is true.  With `overlap == false` the loop
/// is strictly serial — assemble, select, consume — and produces exactly
/// the same `consume` calls as the pipelined path (pinned by
/// `tests/selection_pool.rs::overlap_and_serial_paths_agree`), because
/// window assembly never depends on selection results.
///
/// `consume(w, window, winners)` receives the batch-local winner ids for
/// window `w`; `selbuf` is the retained winner buffer threaded through
/// every select call.  An `Err` from `assemble` aborts the loop; an
/// in-flight epoch is drained by the [`Pending`] drop before the error
/// propagates.
pub fn run_windows<E>(
    sel: &mut PooledSelector,
    budget: usize,
    overlap: bool,
    count: usize,
    ws: &mut Workspace,
    selbuf: &mut Vec<usize>,
    assemble: impl FnMut(usize) -> Result<SelectWindow, E>,
    consume: impl FnMut(usize, &SelectWindow, &[usize]),
) -> Result<(), E> {
    run_windows_with(sel, |_| budget, overlap, count, ws, selbuf, assemble, consume)
}

/// [`run_windows`] with a per-window budget: `budget_for(K)` is consulted
/// with each window's row count before its jobs are submitted.  This is
/// the ONE implementation of the overlap pipeline — [`run_windows`]
/// (fixed budget) and [`crate::engine::SelectionEngine::windows`]
/// (fraction-derived budgets) are both thin wrappers, so the subtle
/// drain-on-error ordering lives in exactly one place.
pub(crate) fn run_windows_with<E>(
    sel: &mut PooledSelector,
    mut budget_for: impl FnMut(usize) -> usize,
    overlap: bool,
    count: usize,
    ws: &mut Workspace,
    selbuf: &mut Vec<usize>,
    mut assemble: impl FnMut(usize) -> Result<SelectWindow, E>,
    mut consume: impl FnMut(usize, &SelectWindow, &[usize]),
) -> Result<(), E> {
    if count == 0 {
        return Ok(());
    }
    if !overlap {
        for wi in 0..count {
            let win = assemble(wi)?;
            let budget = budget_for(win.view().k());
            sel.select_into(&win.view(), budget, ws, selbuf);
            consume(wi, &win, selbuf);
        }
        return Ok(());
    }
    let mut cur = assemble(0)?;
    for wi in 0..count {
        let view = cur.view();
        let pending = sel.begin(&view, budget_for(view.k()));
        // The overlap: workers are selecting window `wi` right now, while
        // this thread assembles window `wi + 1`.  If assembly fails, the
        // `pending` drop drains the in-flight epoch before `?` returns.
        let next = if wi + 1 < count { Some(assemble(wi + 1)?) } else { None };
        pending.finish(ws, selbuf);
        consume(wi, &cur, selbuf);
        if let Some(n) = next {
            cur = n;
        }
    }
    Ok(())
}

//! Sharded selection: fan one K-row batch across N worker shards, run the
//! wrapped [`Selector`] per shard with a shard-private
//! [`Workspace`], then fold the winners with the hierarchical MaxVol merge
//! ([`super::merge`]).  This is the Stage-1 scaling substrate the ROADMAP
//! north star asks for: per-shard work is O(K/N · R · r), the shards run
//! on scoped threads, and merge memory stays O(N · r).
//!
//! Guarantees pinned by `tests/sharded_selection.rs`:
//!
//! * `shards == 1` delegates straight to the wrapped selector with the
//!   caller's workspace — **bit-identical** to the single-shot path.
//! * Results are deterministic and independent of worker interleaving:
//!   each shard writes to its own slot and the merge order is fixed, so
//!   serial and parallel execution produce identical subsets.
//! * The output keeps the selector contract: unique batch-local ids,
//!   `|out| == min(r, K)` for budget-honouring inner selectors.

use std::ops::Range;
use std::sync::Arc;

use crate::faults::{FaultAction, FaultInjector, ShardCtx};
use crate::graft::geometry::grad_sum_into;
use crate::graft::{RankDecision, RankStats};
use crate::linalg::{Mat, Workspace};
use crate::selection::{BatchView, Selector};

use super::merge::{
    merge_winners, merge_winners_grad, MergeCtx, MergePolicy, MergeScratch, ShardGrads,
};

/// Fan shards out on scoped threads only for batches at least this many
/// rows; below it spawn overhead dominates the saved work.  Purely a
/// performance knob: serial and parallel execution are bit-identical
/// (pinned by tests), so crossing the threshold never changes results.
pub const SHARD_PAR_MIN_K: usize = 512;

/// Balanced contiguous partition of `0..k` into `min(shards, k)` non-empty
/// ranges (empty for `k == 0`); the first `k % s` ranges are one row
/// longer.  Allocating wrapper over [`shard_ranges_into`].
pub fn shard_ranges(k: usize, shards: usize) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    shard_ranges_into(k, shards, &mut out);
    out
}

/// [`shard_ranges`] writing into a retained buffer (cleared first) — the
/// hot-path variant the [`ShardedSelector`] reuses across calls.
pub fn shard_ranges_into(k: usize, shards: usize, out: &mut Vec<Range<usize>>) {
    out.clear();
    if k == 0 {
        return;
    }
    let s = shards.clamp(1, k);
    let (base, extra) = (k / s, k % s);
    let mut start = 0;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
}

/// Run one shard job: gather the contiguous row `range` of `view` into the
/// recycled `feat`/`grad` buffers, run `selector` over the shard-local view
/// with up to `budget` winners, and write **batch-local** winner ids into
/// `won` (cleared first).  The shard feature/gradient blocks are contiguous
/// row slices of the batch matrices, so building the shard-local view is
/// two memcpys into retained buffers (`from_vec`/`into_vec` round-trip) —
/// allocation-free once the buffers have warmed up.
///
/// When `grads` is `Some` (the gradient-aware merge), the job also fills
/// the shard's [`ShardGrads`]: the winners' gradient-sketch rows and the
/// partial ḡ sum over the whole range — the only gradient state that
/// crosses the shard → merge boundary.
///
/// This is the single shard-execution kernel shared by the scoped-thread
/// fan-out ([`ShardedSelector`]) and the persistent worker pool
/// ([`super::pool::SelectionPool`]): both paths run byte-for-byte the same
/// gather + select, which is what makes pool ≡ scoped ≡ serial bit-identity
/// (pinned by `tests/selection_pool.rs`) a structural property rather than
/// a numerical coincidence.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_shard(
    selector: &mut dyn Selector,
    view: &BatchView<'_>,
    range: Range<usize>,
    budget: usize,
    ws: &mut Workspace,
    feat: &mut Vec<f64>,
    grad: &mut Vec<f64>,
    local: &mut Vec<usize>,
    won: &mut Vec<usize>,
    grads: Option<&mut ShardGrads>,
) {
    won.clear();
    let len = range.len();
    if len > 0 {
        if len == view.k() {
            // Full-range job (one shard, or K collapsed into a single
            // range): the "shard" is the batch itself, so select in place
            // and skip the gather — same arithmetic on the same rows, zero
            // copies.  This is what keeps the pool's single-shard hosting
            // of non-shardable selectors (and the overlap path) copy-free
            // like the inline single-shot path.
            selector.select_into(view, budget.min(len), ws, local);
            won.extend_from_slice(local);
        } else {
            let (rc, ec) = (view.features.cols(), view.grads.cols());
            let mut fb = std::mem::take(feat);
            fb.clear();
            fb.extend_from_slice(&view.features.data()[range.start * rc..range.end * rc]);
            let fmat = Mat::from_vec(len, rc, fb);
            let mut gb = std::mem::take(grad);
            gb.clear();
            gb.extend_from_slice(&view.grads.data()[range.start * ec..range.end * ec]);
            let gmat = Mat::from_vec(len, ec, gb);
            let shard_view = BatchView {
                features: &fmat,
                grads: &gmat,
                losses: &view.losses[range.clone()],
                labels: &view.labels[range.clone()],
                preds: &view.preds[range.clone()],
                classes: view.classes,
                row_ids: &view.row_ids[range.clone()],
            };
            selector.select_into(&shard_view, budget.min(len), ws, local);
            won.extend(local.iter().map(|&i| range.start + i));
            *feat = fmat.into_vec();
            *grad = gmat.into_vec();
        }
    }
    if let Some(g) = grads {
        // Gradient context for the grad-aware merge: partial ḡ sum over
        // the whole range (winners or not) + the winners' sketch rows,
        // all read from the caller's view so both gather paths agree.
        grad_sum_into(view.grads, range, &mut g.gsum);
        g.count = len;
        g.cols.clear();
        for &id in won.iter() {
            // `push_row` narrows to f32 when the coordinator opted into
            // narrowed sketches; the default f64 buffer copies bitwise.
            g.cols.push_row(view.grads.row(id));
        }
    }
}

/// One shard's selector plus all of its private scratch: a [`Workspace`],
/// reusable feature/gradient gather buffers, and the winner list.  Owning
/// everything per shard keeps the fan-out free of shared mutable state —
/// what makes interleaving-independence trivial rather than subtle.
struct ShardWorker {
    selector: Box<dyn Selector>,
    ws: Workspace,
    feat: Vec<f64>,
    grad: Vec<f64>,
    local: Vec<usize>,
    /// Batch-local winners from the last run.
    won: Vec<usize>,
}

impl ShardWorker {
    fn new(selector: Box<dyn Selector>) -> ShardWorker {
        ShardWorker {
            selector,
            ws: Workspace::new(),
            feat: Vec::new(),
            grad: Vec::new(),
            local: Vec::new(),
            won: Vec::new(),
        }
    }

    /// Select up to `budget` rows from the contiguous row range of `view`
    /// assigned to this shard; winners land in `self.won` as batch-local
    /// ids (and the gradient context in `grads`, when the merge wants
    /// it).  Delegates to the shared [`run_shard`] kernel.
    fn run(
        &mut self,
        view: &BatchView<'_>,
        range: Range<usize>,
        budget: usize,
        grads: Option<&mut ShardGrads>,
    ) {
        run_shard(
            self.selector.as_mut(),
            view,
            range,
            budget,
            &mut self.ws,
            &mut self.feat,
            &mut self.grad,
            &mut self.local,
            &mut self.won,
            grads,
        );
    }
}

/// Sharded wrapper around any [`Selector`]: partitions the batch into
/// contiguous shards, selects per shard in parallel, and merges the
/// winners with a second-stage MaxVol.  Implements [`Selector`] itself, so
/// the trainer (and anything else holding a `Box<dyn Selector>`) is
/// oblivious to the fan-out.
pub struct ShardedSelector {
    merge: MergePolicy,
    parallel: bool,
    workers: Vec<ShardWorker>,
    /// Retained selector factory, mirroring the pool's respawn factory:
    /// [`ShardedSelector::rebuild_workers`] re-runs it to replace the
    /// per-shard workers with identically-constructed instances after a
    /// contained panic left their state suspect.
    make: Box<dyn FnMut(usize) -> Box<dyn Selector>>,
    /// Per-shard gradient context, parallel to `workers`; filled by the
    /// shard jobs only when the merge policy is gradient-aware.
    grads: Vec<ShardGrads>,
    /// The single top-level dynamic-rank decision maker consulted by the
    /// gradient-aware merge — one per coordinator, so ε/budget accounting
    /// is shard-count-independent.  `None`: feature-only rank behaviour.
    authority: Option<Box<dyn Selector>>,
    /// Gradient-aware pivot stage ([`PivotMode::GradAware`]): re-order the
    /// merged winners by residual ĝ coverage before the rank cut.  Forces
    /// the gradient carry even without a rank authority.
    ///
    /// [`PivotMode::GradAware`]: crate::engine::PivotMode
    grad_pivot: bool,
    /// Last gradient-merge decision, for logging.
    last: Option<RankDecision>,
    scratch: MergeScratch,
    /// Retained partition buffer (recomputed per call, capacity reused).
    ranges: Vec<Range<usize>>,
    /// Deterministic fault injection (tests only; `None` in production).
    /// On this path an injected fault is a real panic on the scoped
    /// thread, which propagates to the caller — the engine's containment
    /// and retry/ladder machinery is what is being exercised.
    injector: Option<Arc<dyn FaultInjector>>,
    /// Running select count (the injector's 1-based window ordinal).
    calls: u64,
}

/// Apply an injected fault at a shard-execution site without its own
/// containment: `Delay` sleeps in place; `Panic` and `DieWorker` (which
/// has no dedicated thread to kill here) raise a real panic that unwinds
/// to the engine's catch.
fn trip(injector: Option<&dyn FaultInjector>, window: u64, shard: usize) {
    let Some(i) = injector else { return };
    match i.before_shard(ShardCtx { window, shard, worker: shard }) {
        FaultAction::None => {}
        FaultAction::Delay(by) => std::thread::sleep(by),
        FaultAction::Panic | FaultAction::DieWorker => {
            panic!("injected fault: shard {shard} window {window}")
        }
    }
}

impl ShardedSelector {
    /// Build with one selector instance per shard; `make(i)` constructs
    /// shard `i`'s instance (stateful selectors must not be shared across
    /// shards).  `make(0)` should use the caller's base seed so a
    /// one-shard wrapper matches the unsharded construction.
    ///
    /// Panics if a constructed selector does not opt in via
    /// [`Selector::shardable`]: the second-stage MaxVol merge only
    /// preserves the criterion of the MaxVol family, so wrapping anything
    /// else would silently measure a different method (the trainer routes
    /// those to single-shot instead — see
    /// `engine::EngineBuilder::build`).
    ///
    /// The factory is retained (hence `'static`) so
    /// [`ShardedSelector::rebuild_workers`] can replace the workers with
    /// identically-constructed instances after a contained panic.
    pub fn from_factory(
        shards: usize,
        merge: MergePolicy,
        make: impl FnMut(usize) -> Box<dyn Selector> + 'static,
    ) -> ShardedSelector {
        assert!(shards >= 1, "need at least one shard");
        let mut make: Box<dyn FnMut(usize) -> Box<dyn Selector>> = Box::new(make);
        let workers = (0..shards)
            .map(|i| {
                let sel = make(i);
                assert!(
                    sel.shardable(),
                    "selector '{}' is not shardable: the MaxVol merge would not preserve \
                     its selection criterion",
                    sel.name()
                );
                ShardWorker::new(sel)
            })
            .collect();
        ShardedSelector {
            merge,
            parallel: true,
            grads: (0..shards).map(|_| ShardGrads::default()).collect(),
            authority: None,
            grad_pivot: false,
            last: None,
            workers,
            make,
            scratch: MergeScratch::default(),
            ranges: Vec::new(),
            injector: None,
            calls: 0,
        }
    }

    /// Replace every shard worker with a factory-fresh one — fresh
    /// selector instance, fresh [`Workspace`], empty gather buffers —
    /// keeping the merge policy, the rank authority (and its accumulated
    /// budget state), the fault injector, and the call counter.  The
    /// scoped-thread mirror of the pool's worker respawn: after a
    /// contained shard panic the worker-side state is suspect, but the
    /// authority never ran (a shard panic re-raises at scope exit, before
    /// the merge), so keeping it is what makes a retry bit-identical
    /// under the adaptive rank policy's cross-window accounting.  The
    /// per-shard instances themselves are selection-stateless (strict
    /// policies on the engine-built path), so rebuilding them never
    /// changes a healthy rerun's subset.
    pub fn rebuild_workers(&mut self) {
        for i in 0..self.workers.len() {
            let sel = (self.make)(i);
            assert!(
                sel.shardable(),
                "selector '{}' is not shardable: the MaxVol merge would not preserve \
                 its selection criterion",
                sel.name()
            );
            self.workers[i] = ShardWorker::new(sel);
        }
    }

    /// Install (or clear) a deterministic fault injector (tests only).
    pub fn set_fault_injector(&mut self, injector: Option<Arc<dyn FaultInjector>>) {
        self.injector = injector;
    }

    /// Force shard execution serial (`false`) or allow scoped threads
    /// (`true`, the default).  Results are identical either way — the
    /// property tests pin serial == parallel.
    pub fn with_parallel(mut self, on: bool) -> Self {
        self.parallel = on;
        self
    }

    /// Install the top-level rank authority for the gradient-aware merge
    /// ([`MergePolicy::Grad`]): the **one** instance whose
    /// [`Selector::post_merge_rank`] decides the global dynamic rank per
    /// merged batch — a single `BudgetedRankPolicy` accumulator at any
    /// shard count, instead of one budget clone per shard.  Inert at one
    /// shard: that path delegates whole batches to the inner selector,
    /// which applies its own policy inline (bit-identity with single-shot).
    ///
    /// Facade-internal plumbing: application code gets this wiring from
    /// [`crate::engine::EngineBuilder`] and reads decisions from
    /// [`crate::engine::Selection`]; this stays public only for the
    /// pinning suites and benches that compare the facade against direct
    /// construction (`scripts/check_facade.sh` rejects other `src/`
    /// callers).
    pub fn with_rank_authority(mut self, authority: Box<dyn Selector>) -> Self {
        self.authority = Some(authority);
        self
    }

    /// Decision of the most recent gradient-aware merge (for logging).
    /// Facade-internal like
    /// [`with_rank_authority`](ShardedSelector::with_rank_authority);
    /// prefer [`crate::engine::Selection::decision`].
    pub fn last_rank_decision(&self) -> Option<RankDecision> {
        self.last
    }

    /// Enable the gradient-aware pivot stage
    /// ([`crate::engine::PivotMode::GradAware`]) on the merge: the feature
    /// tournament still fixes winner membership, but the merged order the
    /// rank cut truncates is re-sorted by greedy residual ĝ coverage.
    /// Requires a gradient-aware [`MergePolicy`] (the builder validates
    /// this with a typed error) and forces the gradient carry even when no
    /// rank authority is installed.  Facade-internal like
    /// [`with_rank_authority`](ShardedSelector::with_rank_authority).
    pub fn with_grad_pivot(mut self, on: bool) -> Self {
        self.grad_pivot = on;
        self
    }

    /// Carry the gradient sketches across the shard → merge boundary as
    /// f32 (`true`) instead of the default bitwise f64 (`false`): half
    /// the boundary bytes, one rounding per element.  The merged pivot
    /// order is computed on f64 features either way; only the adaptive
    /// rank cut can observe the narrowing (tolerance-pinned by
    /// `tests/sketch_f32.rs`).
    pub fn with_f32_sketches(mut self, on: bool) -> Self {
        for g in self.grads.iter_mut() {
            g.cols.set_f32(on);
        }
        self
    }

    /// Payload bytes of gradient sketches currently held at the merge
    /// boundary — zero whenever no rank authority is installed (the
    /// adaptive-only carry), pinned by `tests/alloc_free.rs`.
    pub fn carried_sketch_bytes(&self) -> usize {
        self.grads.iter().map(|g| g.sketch_bytes()).sum()
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }
}

impl Selector for ShardedSelector {
    fn name(&self) -> &'static str {
        "sharded"
    }

    /// Accounting of the actual decision maker: at one shard the inner
    /// selector (that path delegates whole batches, so the inner policy
    /// *is* the global one — an installed authority is never consulted);
    /// otherwise the rank authority.
    fn rank_stats(&self) -> Option<RankStats> {
        if self.workers.len() == 1 {
            self.workers[0].selector.rank_stats()
        } else {
            self.authority.as_ref().and_then(|a| a.rank_stats())
        }
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let k = view.k();
        out.clear();
        if k == 0 {
            return;
        }
        self.calls += 1;
        let window = self.calls;
        let inj = self.injector.as_deref();
        if self.workers.len() == 1 {
            // Single-shot fast path: same selector, same caller workspace,
            // no partition, no merge — bit-identical to the unsharded call
            // (pinned by tests/sharded_selection.rs).
            trip(inj, window, 0);
            self.workers[0].selector.select_into(view, r, ws, out);
            return;
        }
        shard_ranges_into(k, self.workers.len(), &mut self.ranges);
        let live = self.ranges.len();
        let budget = r.min(k);
        // Gradient context is only worth carrying when someone will read
        // it: without a rank authority (or the gradient-aware pivot stage)
        // the grad merge is provably bitwise the feature-only merge
        // (pinned in merge.rs tests), so skip the per-shard sketch copies
        // and the stage-2 error recomputation.
        let want_grads =
            self.merge.gradient_aware() && (self.authority.is_some() || self.grad_pivot);
        if self.parallel && k >= SHARD_PAR_MIN_K {
            std::thread::scope(|scope| {
                for (s, ((w, g), range)) in self.workers[..live]
                    .iter_mut()
                    .zip(self.grads[..live].iter_mut())
                    .zip(self.ranges.iter().cloned())
                    .enumerate()
                {
                    scope.spawn(move || {
                        // An injected panic unwinds this scoped thread and
                        // re-raises at scope exit — exactly the path a
                        // selector bug would take to the engine's catch.
                        trip(inj, window, s);
                        w.run(view, range, budget, want_grads.then_some(g));
                    });
                }
            });
        } else {
            for (s, ((w, g), range)) in self.workers[..live]
                .iter_mut()
                .zip(self.grads[..live].iter_mut())
                .zip(self.ranges.iter().cloned())
                .enumerate()
            {
                trip(inj, window, s);
                w.run(view, range, budget, want_grads.then_some(g));
            }
        }
        if want_grads {
            self.last = merge_winners_grad(
                view,
                self.workers[..live].iter().map(|w| w.won.as_slice()),
                budget,
                self.merge,
                MergeCtx {
                    grads: &self.grads[..live],
                    authority: self.authority.as_deref_mut(),
                    grad_pivot: self.grad_pivot,
                },
                ws,
                &mut self.scratch,
                out,
            );
        } else {
            merge_winners(
                view,
                self.workers[..live].iter().map(|w| w.won.as_slice()),
                budget,
                self.merge,
                ws,
                &mut self.scratch,
                out,
            );
        }
    }
}

//! Streaming coordinator: the data-pipeline layer that feeds the PJRT
//! engine.  Batch assembly (row gather + one-hot encode) runs on a
//! producer thread and hands prepared buffers to the engine thread over a
//! bounded channel — backpressure keeps memory flat, and the engine never
//! waits on host-side encoding (the L3 hot-path optimisation in §Perf).
//!
//! Since PR 2 the coordinator also owns the **sharded selection
//! pipeline**: [`ShardedSelector`] fans a batch across worker shards
//! ([`shard`]) and folds the per-shard winners with a hierarchical MaxVol
//! merge ([`merge`]), and [`FanOutProducer`] generalises the single
//! producer thread to a multi-worker fan-out.  PR 3 adds the **persistent
//! selection worker pool** ([`pool`]): long-lived workers replace the
//! per-refresh scoped-thread fan-out, and the [`run_windows`] pipelined
//! refresh overlaps next-window assembly/`embed` with in-flight shard
//! selection.  PR 4 makes the merge **gradient-aware**
//! ([`MergePolicy::Grad`], the default for GRAFT): each shard ships its
//! winners' gradient-sketch columns plus its partial ḡ sum
//! ([`ShardGrads`]), and after the MaxVol tournament one top-level rank
//! authority applies the single global dynamic-rank decision — the
//! paper's criterion now survives shard → merge → rank at any
//! shard/worker count.  See `README.md` in this directory for the
//! dataflow and the test matrix that pins it.
//!
//! Since PR 5 application code does not construct these wrappers
//! directly: [`crate::engine::SelectionEngine`] is the typed facade over
//! every execution shape here (builder-validated knobs, first-class
//! `Selection` results, the windows/overlap session).  This module
//! remains the machinery underneath — its pieces stay public for the
//! pinning suites and benches that compare the facade against direct
//! construction.
//!
//! PR 6 hardens the whole stack against faults: [`fault`] is the typed
//! failure surface ([`SelectError`], [`FaultPolicy`], [`Degradation`],
//! [`PoolStats`]), the pool respawns panicked/dead workers and retries
//! their shard jobs deterministically, non-finite input rows are
//! quarantined, and `rust/src/faults.rs` provides the deterministic
//! injection harness the fault suites drive all of it with.  See
//! "Failure modes & degradation ladder" in `README.md`.
//!
//! PR 7 adds **streaming selection**: [`stream`] keeps a bounded
//! reservoir of pivot candidates (incremental MaxVol admission via a
//! replayable elimination cache) plus stream-wide gradient sums, so rows
//! can arrive in chunks of any size and a snapshot at any point
//! reproduces the batch GRAFT selection bit-for-bit whenever the stream
//! fits the reservoir.  Drive it through
//! [`crate::engine::StreamingEngine`].

pub mod fault;
pub mod merge;
pub mod pipeline;
pub mod pool;
pub mod scheduler;
pub mod shard;
pub mod state;
pub(crate) mod stream;

pub use fault::{Degradation, FaultPolicy, PoolStats, SelectError, WindowsError};
pub use merge::{merge_winners, merge_winners_grad, MergeCtx, MergePolicy, ShardGrads, SketchBuf};
pub use pipeline::{BatchProducer, FanOutProducer, PreparedBatch};
pub use pool::{run_windows, PooledSelector, SelectWindow};
pub use scheduler::RefreshScheduler;
pub use shard::{shard_ranges, shard_ranges_into, ShardedSelector, SHARD_PAR_MIN_K};
pub use state::SubsetState;

//! Streaming coordinator: the data-pipeline layer that feeds the PJRT
//! engine.  Batch assembly (row gather + one-hot encode) runs on a
//! producer thread and hands prepared buffers to the engine thread over a
//! bounded channel — backpressure keeps memory flat, and the engine never
//! waits on host-side encoding (the L3 hot-path optimisation in §Perf).

pub mod pipeline;
pub mod scheduler;
pub mod state;

pub use pipeline::{BatchProducer, PreparedBatch};
pub use scheduler::RefreshScheduler;
pub use state::SubsetState;

//! Producer/consumer batch pipeline with bounded-channel backpressure.
//!
//! The producer thread walks the active subset with a seeded [`Batcher`],
//! gathers rows and one-hot labels into flat f32 buffers, and pushes them
//! into a `sync_channel(depth)`.  The consumer (engine thread) pops
//! prepared batches and runs `train_step` — overlap hides the host-side
//! encoding latency.  Dropping the producer handle stops the thread.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::data::{loader::Batcher, Dataset};

/// A fully assembled training batch, ready for the engine.
#[derive(Debug, Clone)]
pub struct PreparedBatch {
    /// Active-set-local row ids (provenance / invariants).
    pub rows: Vec<usize>,
    /// bucket×d features.
    pub x: Vec<f32>,
    /// bucket×c one-hot labels.
    pub y1h: Vec<f32>,
    /// Uniform subset weights (1/bucket each).
    pub w: Vec<f32>,
    /// Epoch (over the active set) this batch belongs to.
    pub epoch: usize,
    /// Monotone sequence number.
    pub seq: usize,
}

/// Handle to the producer thread; iterate with [`BatchProducer::next`].
pub struct BatchProducer {
    rx: Receiver<PreparedBatch>,
    handle: Option<JoinHandle<()>>,
    stop: SyncSender<()>,
}

impl BatchProducer {
    /// Spawn a producer over `dataset` emitting `total` batches of size
    /// `bucket`, with channel capacity `depth` (the backpressure bound).
    pub fn spawn(dataset: Dataset, bucket: usize, total: usize, depth: usize, seed: u64) -> BatchProducer {
        let (tx, rx) = sync_channel::<PreparedBatch>(depth.max(1));
        let (stop_tx, stop_rx) = sync_channel::<()>(1);
        let handle = std::thread::spawn(move || {
            let mut batcher = Batcher::new(&dataset, bucket, seed);
            for seq in 0..total {
                if stop_rx.try_recv().is_ok() {
                    return;
                }
                let rows: Vec<usize> = batcher.next_batch().to_vec();
                let batch = PreparedBatch {
                    x: dataset.gather(&rows),
                    y1h: dataset.one_hot(&rows),
                    w: vec![1.0 / rows.len() as f32; rows.len()],
                    epoch: batcher.epoch(),
                    rows,
                    seq,
                };
                // Blocks when the queue is full — backpressure.
                if tx.send(batch).is_err() {
                    return; // consumer dropped
                }
            }
        });
        BatchProducer { rx, handle: Some(handle), stop: stop_tx }
    }

    /// Next prepared batch (None when the producer finished).
    pub fn next(&mut self) -> Option<PreparedBatch> {
        self.rx.recv().ok()
    }

    /// Non-blocking variant with timeout (used by tests).
    pub fn next_timeout(&mut self, d: Duration) -> Result<PreparedBatch, RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }
}

impl Drop for BatchProducer {
    fn drop(&mut self) {
        let _ = self.stop.try_send(());
        // Drain so a blocked send unblocks, then join.
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize, d: usize, c: usize) -> Dataset {
        let x = (0..n * d).map(|i| i as f32).collect();
        let y = (0..n).map(|i| (i % c) as i32).collect();
        Dataset::new("p", x, y, d, c)
    }

    #[test]
    fn produces_exactly_total() {
        let mut p = BatchProducer::spawn(ds(64, 3, 2), 16, 10, 2, 1);
        let mut got = 0;
        while let Some(b) = p.next() {
            assert_eq!(b.rows.len(), 16);
            assert_eq!(b.x.len(), 16 * 3);
            assert_eq!(b.y1h.len(), 16 * 2);
            assert_eq!(b.seq, got);
            got += 1;
        }
        assert_eq!(got, 10);
    }

    #[test]
    fn batches_match_dataset_content() {
        let data = ds(32, 4, 2);
        let mut p = BatchProducer::spawn(data.clone(), 8, 4, 2, 2);
        while let Some(b) = p.next() {
            for (k, &row) in b.rows.iter().enumerate() {
                assert_eq!(&b.x[k * 4..(k + 1) * 4], data.row(row), "gather mismatch");
                let cls = data.y[row] as usize;
                assert_eq!(b.y1h[k * 2 + cls], 1.0);
            }
            let wsum: f32 = b.w.iter().sum();
            assert!((wsum - 1.0).abs() < 1e-6, "weights sum to 1");
        }
    }

    #[test]
    fn no_duplicates_within_epoch() {
        let mut p = BatchProducer::spawn(ds(64, 2, 2), 16, 4, 2, 3);
        let mut seen = Vec::new();
        while let Some(b) = p.next() {
            assert_eq!(b.epoch, 0);
            seen.extend(b.rows);
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 64);
    }

    #[test]
    fn backpressure_bounds_queue() {
        // Slow consumer: producer must not run ahead more than depth+1.
        let mut p = BatchProducer::spawn(ds(64, 2, 2), 8, 100, 2, 4);
        std::thread::sleep(Duration::from_millis(50));
        // Only depth (2) + 1 in-flight batch could have been produced; the
        // rest waits. Consume everything and verify ordering (no drops).
        let mut seqs = Vec::new();
        while let Some(b) = p.next() {
            seqs.push(b.seq);
        }
        assert_eq!(seqs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn early_drop_terminates_producer() {
        let p = BatchProducer::spawn(ds(64, 2, 2), 8, 1_000_000, 2, 5);
        drop(p); // must not hang
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<Vec<usize>> = {
            let mut p = BatchProducer::spawn(ds(32, 2, 2), 8, 6, 2, 7);
            std::iter::from_fn(|| p.next()).map(|b| b.rows).collect()
        };
        let b: Vec<Vec<usize>> = {
            let mut p = BatchProducer::spawn(ds(32, 2, 2), 8, 6, 2, 7);
            std::iter::from_fn(|| p.next()).map(|b| b.rows).collect()
        };
        assert_eq!(a, b);
    }
}

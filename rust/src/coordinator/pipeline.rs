//! Producer/consumer batch pipeline with bounded-channel backpressure.
//!
//! The producer thread walks the active subset with a seeded [`Batcher`],
//! gathers rows and one-hot labels into flat f32 buffers, and pushes them
//! into a `sync_channel(depth)`.  The consumer (engine thread) pops
//! prepared batches and runs `train_step` — overlap hides the host-side
//! encoding latency.  Dropping the producer handle stops the thread.
//!
//! [`FanOutProducer`] generalises this to N assembly workers over disjoint
//! row partitions, zipped back into one deterministic seq-ordered stream.
//!
//! Shutdown contract (pinned by the regression tests below): dropping a
//! producer handle signals stop, drains the channel so a blocked `send`
//! unblocks, and **joins** every worker thread — no leaked threads or
//! senders, whether the consumer finished, timed out in
//! [`BatchProducer::next_timeout`], or dropped the handle mid-stream.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::{loader::Batcher, Dataset};

/// How long a `Drop` is willing to wait for a worker thread before logging
/// and detaching it.  Generous: a healthy worker unblocks within
/// microseconds of the stop + drain; only a genuinely wedged one (stuck in
/// a gather, livelocked selector, …) ever reaches the deadline, and
/// hanging the caller's teardown would be strictly worse than leaking the
/// thread until process exit.
const TEARDOWN_TIMEOUT: Duration = Duration::from_secs(5);

/// Core of every guarded teardown join: run `poll` (e.g. a channel drain
/// that unblocks a worker's `send`), check `is_finished`, and repeat until
/// `timeout`; on expiry log to stderr and detach (drop the handle) instead
/// of hanging the caller.  Returns whether the thread was actually joined.
fn join_with_deadline(
    h: JoinHandle<()>,
    timeout: Duration,
    who: &str,
    mut poll: impl FnMut(),
) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        poll();
        if h.is_finished() {
            // Cannot block: the thread already ran to completion.  A
            // panicked worker still counts as joined — its panic was its
            // own; teardown's job is only to not leak or hang.
            let _ = h.join();
            return true;
        }
        if Instant::now() >= deadline {
            eprintln!(
                "WARN coordinator teardown: {who} still running after {timeout:?}; \
                 detaching it instead of hanging shutdown"
            );
            return false;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// [`join_with_deadline`] without a poll step, at a caller-chosen deadline
/// (the wedged-worker regression test uses a short one).
pub(crate) fn join_within(h: JoinHandle<()>, timeout: Duration, who: &str) -> bool {
    join_with_deadline(h, timeout, who, || {})
}

/// [`join_within`] at the standard teardown deadline.  Shared by the
/// producer `Drop` impls and the selection pool's shutdown.
pub(crate) fn join_or_log(h: JoinHandle<()>, who: &str) -> bool {
    join_within(h, TEARDOWN_TIMEOUT, who)
}

/// Producer-side teardown step: keep draining `rx` (so a blocked `send`
/// always unblocks, even if the worker squeezes one more batch in after a
/// first drain) while waiting for the worker to finish, with the same
/// timeout-then-log guarantee.
fn drain_until_joined<T>(rx: &Receiver<T>, h: JoinHandle<()>, who: &str) {
    join_with_deadline(h, TEARDOWN_TIMEOUT, who, || while rx.try_recv().is_ok() {});
}

/// A fully assembled training batch, ready for the engine.
#[derive(Debug, Clone)]
pub struct PreparedBatch {
    /// Active-set-local row ids (provenance / invariants).
    pub rows: Vec<usize>,
    /// bucket×d features.
    pub x: Vec<f32>,
    /// bucket×c one-hot labels.
    pub y1h: Vec<f32>,
    /// Uniform subset weights (1/bucket each).
    pub w: Vec<f32>,
    /// Epoch (over the active set) this batch belongs to.
    pub epoch: usize,
    /// Monotone sequence number.
    pub seq: usize,
}

/// Handle to the producer thread; iterate with [`BatchProducer::next`].
pub struct BatchProducer {
    rx: Receiver<PreparedBatch>,
    handle: Option<JoinHandle<()>>,
    stop: SyncSender<()>,
}

impl BatchProducer {
    /// Spawn a producer over `dataset` emitting `total` batches of size
    /// `bucket`, with channel capacity `depth` (the backpressure bound).
    pub fn spawn(dataset: Dataset, bucket: usize, total: usize, depth: usize, seed: u64) -> BatchProducer {
        let (tx, rx) = sync_channel::<PreparedBatch>(depth.max(1));
        let (stop_tx, stop_rx) = sync_channel::<()>(1);
        let handle = std::thread::spawn(move || {
            let mut batcher = Batcher::new(&dataset, bucket, seed);
            for seq in 0..total {
                if stop_rx.try_recv().is_ok() {
                    return;
                }
                let rows: Vec<usize> = batcher.next_batch().to_vec();
                let batch = PreparedBatch {
                    x: dataset.gather(&rows),
                    y1h: dataset.one_hot(&rows),
                    w: vec![1.0 / rows.len() as f32; rows.len()],
                    epoch: batcher.epoch(),
                    rows,
                    seq,
                };
                // Blocks when the queue is full — backpressure.
                if tx.send(batch).is_err() {
                    return; // consumer dropped
                }
            }
        });
        BatchProducer { rx, handle: Some(handle), stop: stop_tx }
    }

    /// Next prepared batch (None when the producer finished).
    pub fn next(&mut self) -> Option<PreparedBatch> {
        self.rx.recv().ok()
    }

    /// Non-blocking variant with timeout (used by tests).
    pub fn next_timeout(&mut self, d: Duration) -> Result<PreparedBatch, RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }
}

impl Drop for BatchProducer {
    fn drop(&mut self) {
        // Order matters: signal stop *before* draining, so a producer that
        // unblocks from `send` observes the stop on its next loop
        // iteration instead of racing ahead and refilling the channel.
        let _ = self.stop.try_send(());
        // Drain so a blocked send unblocks, then join — with the
        // timeout-then-log guard, so a wedged worker (stuck mid-gather)
        // can degrade to a logged leak but never hang teardown.
        if let Some(h) = self.handle.take() {
            drain_until_joined(&self.rx, h, "batch producer");
        }
    }
}

/// Multi-worker fan-out producer: `workers` assembly threads over disjoint
/// strided row partitions, merged back into one deterministic seq-ordered
/// stream.  Worker `w` owns rows `{w, w+N, w+2N, …}` of `dataset` and
/// assembles exactly the batches with `seq ≡ w (mod N)`, so
/// [`FanOutProducer::next`] round-robins the receivers and the output
/// order is 0, 1, 2, … with per-worker backpressure of `depth`.
///
/// With `workers == 1` the stream is identical to [`BatchProducer`] with
/// the same arguments (pinned by a test).  Epochs are tracked per worker
/// over its own partition.
pub struct FanOutProducer {
    rxs: Vec<Receiver<PreparedBatch>>,
    handles: Vec<JoinHandle<()>>,
    stops: Vec<SyncSender<()>>,
    next_seq: usize,
    total: usize,
}

impl FanOutProducer {
    /// Spawn `workers` producer threads emitting `total` batches of size
    /// `bucket` overall.  `workers` is clamped so every partition holds at
    /// least one full bucket (and never exceeds `total`).
    pub fn spawn(
        dataset: Dataset,
        bucket: usize,
        total: usize,
        depth: usize,
        seed: u64,
        workers: usize,
    ) -> FanOutProducer {
        assert!(bucket <= dataset.n, "bucket {} > dataset {}", bucket, dataset.n);
        let workers = workers.clamp(1, (dataset.n / bucket).max(1)).min(total.max(1));
        let mut rxs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        let mut stops = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = sync_channel::<PreparedBatch>(depth.max(1));
            let (stop_tx, stop_rx) = sync_channel::<()>(1);
            let part: Vec<usize> = (w..dataset.n).step_by(workers).collect();
            let sub = dataset.subset("shard", &part);
            // Worker 0 keeps the base seed so workers == 1 reproduces the
            // single-producer stream exactly.
            let wseed = seed ^ (w as u64).wrapping_mul(0xA24BAED4963EE407);
            let handle = std::thread::spawn(move || {
                let mut batcher = Batcher::new(&sub, bucket, wseed);
                let mut seq = w;
                while seq < total {
                    if stop_rx.try_recv().is_ok() {
                        return;
                    }
                    let local: Vec<usize> = batcher.next_batch().to_vec();
                    let batch = PreparedBatch {
                        x: sub.gather(&local),
                        y1h: sub.one_hot(&local),
                        w: vec![1.0 / local.len() as f32; local.len()],
                        epoch: batcher.epoch(),
                        rows: local.iter().map(|&i| part[i]).collect(),
                        seq,
                    };
                    if tx.send(batch).is_err() {
                        return; // consumer dropped
                    }
                    seq += workers;
                }
            });
            rxs.push(rx);
            handles.push(handle);
            stops.push(stop_tx);
        }
        FanOutProducer { rxs, handles, stops, next_seq: 0, total }
    }

    /// Next prepared batch in global seq order (None when exhausted).
    pub fn next(&mut self) -> Option<PreparedBatch> {
        if self.next_seq >= self.total {
            return None;
        }
        let b = self.rxs[self.next_seq % self.rxs.len()].recv().ok()?;
        debug_assert_eq!(b.seq, self.next_seq, "fan-out stream out of order");
        self.next_seq += 1;
        Some(b)
    }

    /// Timed variant of [`FanOutProducer::next`], mirroring
    /// [`BatchProducer::next_timeout`].  A `Timeout` does **not** advance
    /// the stream cursor: the retry polls the same worker again, so the
    /// zip-merge stays seq-ordered and gap-free no matter how many
    /// expiries interleave with successes (pinned by
    /// `tests::fanout_next_timeout_expiry_keeps_order`).  An exhausted
    /// stream reports `Disconnected`, like a finished single producer.
    pub fn next_timeout(&mut self, d: Duration) -> Result<PreparedBatch, RecvTimeoutError> {
        if self.next_seq >= self.total {
            return Err(RecvTimeoutError::Disconnected);
        }
        let b = self.rxs[self.next_seq % self.rxs.len()].recv_timeout(d)?;
        debug_assert_eq!(b.seq, self.next_seq, "fan-out stream out of order");
        self.next_seq += 1;
        Ok(b)
    }

    pub fn workers(&self) -> usize {
        self.rxs.len()
    }
}

impl Drop for FanOutProducer {
    fn drop(&mut self) {
        // Same shutdown dance as BatchProducer, once per worker: stop
        // first, then drain-while-joining each worker under the
        // timeout-then-log guard (one wedged worker must not hang the
        // teardown of the others — or of the caller).
        for stop in &self.stops {
            let _ = stop.try_send(());
        }
        for (w, (h, rx)) in self.handles.drain(..).zip(self.rxs.iter()).enumerate() {
            drain_until_joined(rx, h, &format!("fan-out producer {w}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize, d: usize, c: usize) -> Dataset {
        let x = (0..n * d).map(|i| i as f32).collect();
        let y = (0..n).map(|i| (i % c) as i32).collect();
        Dataset::new("p", x, y, d, c)
    }

    #[test]
    fn produces_exactly_total() {
        let mut p = BatchProducer::spawn(ds(64, 3, 2), 16, 10, 2, 1);
        let mut got = 0;
        while let Some(b) = p.next() {
            assert_eq!(b.rows.len(), 16);
            assert_eq!(b.x.len(), 16 * 3);
            assert_eq!(b.y1h.len(), 16 * 2);
            assert_eq!(b.seq, got);
            got += 1;
        }
        assert_eq!(got, 10);
    }

    #[test]
    fn batches_match_dataset_content() {
        let data = ds(32, 4, 2);
        let mut p = BatchProducer::spawn(data.clone(), 8, 4, 2, 2);
        while let Some(b) = p.next() {
            for (k, &row) in b.rows.iter().enumerate() {
                assert_eq!(&b.x[k * 4..(k + 1) * 4], data.row(row), "gather mismatch");
                let cls = data.y[row] as usize;
                assert_eq!(b.y1h[k * 2 + cls], 1.0);
            }
            let wsum: f32 = b.w.iter().sum();
            assert!((wsum - 1.0).abs() < 1e-6, "weights sum to 1");
        }
    }

    #[test]
    fn no_duplicates_within_epoch() {
        let mut p = BatchProducer::spawn(ds(64, 2, 2), 16, 4, 2, 3);
        let mut seen = Vec::new();
        while let Some(b) = p.next() {
            assert_eq!(b.epoch, 0);
            seen.extend(b.rows);
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 64);
    }

    #[test]
    fn backpressure_bounds_queue() {
        // Slow consumer: producer must not run ahead more than depth+1.
        let mut p = BatchProducer::spawn(ds(64, 2, 2), 8, 100, 2, 4);
        std::thread::sleep(Duration::from_millis(50));
        // Only depth (2) + 1 in-flight batch could have been produced; the
        // rest waits. Consume everything and verify ordering (no drops).
        let mut seqs = Vec::new();
        while let Some(b) = p.next() {
            seqs.push(b.seq);
        }
        assert_eq!(seqs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn early_drop_terminates_producer() {
        let p = BatchProducer::spawn(ds(64, 2, 2), 8, 1_000_000, 2, 5);
        drop(p); // must not hang
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<Vec<usize>> = {
            let mut p = BatchProducer::spawn(ds(32, 2, 2), 8, 6, 2, 7);
            std::iter::from_fn(|| p.next()).map(|b| b.rows).collect()
        };
        let b: Vec<Vec<usize>> = {
            let mut p = BatchProducer::spawn(ds(32, 2, 2), 8, 6, 2, 7);
            std::iter::from_fn(|| p.next()).map(|b| b.rows).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn drop_mid_stream_joins_worker() {
        // Shutdown audit: consume a few batches of a long stream, then
        // drop while the producer is blocked on a full channel.  Drop must
        // signal stop, drain, and join — returning at all is the
        // assertion (a leaked blocked thread would hang the join).
        let mut p = BatchProducer::spawn(ds(64, 2, 2), 8, 1_000_000, 2, 11);
        for _ in 0..3 {
            assert!(p.next().is_some());
        }
        drop(p);
    }

    #[test]
    fn next_timeout_expiry_then_drop_is_clean() {
        // A timed-out consumer must still shut the producer down cleanly:
        // no leaked sender keeps the worker alive after the handle drops.
        // The first assembly (100k-row shuffle + 8k-row gather) takes far
        // longer than the 1ns budget, so the recv reliably times out.
        let mut p = BatchProducer::spawn(ds(100_000, 4, 2), 8192, 1_000_000, 1, 12);
        let r = p.next_timeout(Duration::from_nanos(1));
        assert!(matches!(r, Err(RecvTimeoutError::Timeout)), "got {r:?}");
        drop(p); // must join, not hang
    }

    // ---- FanOutProducer ---------------------------------------------------

    #[test]
    fn fanout_produces_total_in_seq_order() {
        for workers in [1usize, 2, 3, 4] {
            let mut p = FanOutProducer::spawn(ds(64, 3, 2), 8, 12, 2, 21, workers);
            let mut seqs = Vec::new();
            while let Some(b) = p.next() {
                assert_eq!(b.rows.len(), 8);
                assert_eq!(b.x.len(), 8 * 3);
                seqs.push(b.seq);
            }
            assert_eq!(seqs, (0..12).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn fanout_batches_match_dataset_content() {
        let data = ds(60, 4, 3);
        let mut p = FanOutProducer::spawn(data.clone(), 6, 9, 2, 22, 3);
        while let Some(b) = p.next() {
            for (k, &row) in b.rows.iter().enumerate() {
                assert_eq!(&b.x[k * 4..(k + 1) * 4], data.row(row), "gather mismatch");
                let cls = data.y[row] as usize;
                assert_eq!(b.y1h[k * 3 + cls], 1.0);
            }
        }
    }

    #[test]
    fn fanout_partitions_are_disjoint_within_worker_epoch() {
        // Each worker walks its own strided partition without repeats
        // inside an epoch, so one full fan-out epoch covers the dataset.
        let n = 64;
        let workers = 4;
        let mut p = FanOutProducer::spawn(ds(n, 2, 2), 4, 16, 2, 23, workers);
        let mut seen = Vec::new();
        while let Some(b) = p.next() {
            assert_eq!(b.epoch, 0);
            seen.extend(b.rows);
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), n);
    }

    #[test]
    fn fanout_single_worker_matches_batch_producer() {
        let take = |mut f: Box<dyn FnMut() -> Option<PreparedBatch>>| -> Vec<(usize, Vec<usize>)> {
            std::iter::from_fn(move || f()).map(|b| (b.seq, b.rows)).collect()
        };
        let a = {
            let mut p = BatchProducer::spawn(ds(32, 2, 2), 8, 6, 2, 7);
            take(Box::new(move || p.next()))
        };
        let b = {
            let mut p = FanOutProducer::spawn(ds(32, 2, 2), 8, 6, 2, 7, 1);
            take(Box::new(move || p.next()))
        };
        assert_eq!(a, b);
    }

    #[test]
    fn fanout_deterministic_given_seed() {
        let run = || -> Vec<Vec<usize>> {
            let mut p = FanOutProducer::spawn(ds(48, 2, 2), 8, 9, 2, 31, 3);
            std::iter::from_fn(move || p.next()).map(|b| b.rows).collect()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fanout_drop_mid_stream_joins_all_workers() {
        let mut p = FanOutProducer::spawn(ds(64, 2, 2), 8, 1_000_000, 2, 24, 4);
        for _ in 0..5 {
            assert!(p.next().is_some());
        }
        drop(p); // must join all four workers, not hang
    }

    #[test]
    fn fanout_clamps_workers_to_partition_capacity() {
        // 32 rows / bucket 16 → at most 2 workers can hold a full bucket.
        let p = FanOutProducer::spawn(ds(32, 2, 2), 16, 4, 2, 25, 8);
        assert_eq!(p.workers(), 2);
    }

    #[test]
    fn fanout_more_workers_than_batches_clamps_and_stays_gap_free() {
        // 8 requested workers but only 3 batches: the clamp must cap the
        // fan-out at 3 so no worker starts with an empty job set, and the
        // zip-merge must still deliver exactly seq 0, 1, 2.
        let mut p = FanOutProducer::spawn(ds(64, 2, 2), 4, 3, 2, 26, 8);
        assert_eq!(p.workers(), 3);
        let seqs: Vec<usize> = std::iter::from_fn(|| p.next()).map(|b| b.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn fanout_zero_batch_epoch_is_empty_and_clean() {
        // total == 0: every worker exits immediately, next() reports
        // exhaustion without blocking, next_timeout reports Disconnected
        // (not Timeout — there is nothing to wait for), and drop joins.
        let mut p = FanOutProducer::spawn(ds(16, 2, 2), 4, 0, 2, 27, 3);
        assert!(p.next().is_none());
        assert!(matches!(
            p.next_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Disconnected)
        ));
        drop(p); // must join, not hang
    }

    #[test]
    fn fanout_next_timeout_expiry_keeps_order() {
        // Assembly of 100k-row shuffles + 8k-row gathers is far slower
        // than a 10µs budget, so early polls reliably time out.  Expired
        // polls must not advance the cursor: retrying until every batch
        // arrives must yield the exact gap-free seq order 0..total.
        let total = 6;
        let mut p = FanOutProducer::spawn(ds(100_000, 4, 2), 8192, total, 1, 28, 2);
        let mut timeouts = 0usize;
        let mut seqs = Vec::new();
        while seqs.len() < total {
            match p.next_timeout(Duration::from_micros(10)) {
                Ok(b) => seqs.push(b.seq),
                Err(RecvTimeoutError::Timeout) => timeouts += 1,
                Err(RecvTimeoutError::Disconnected) => panic!("stream died early"),
            }
        }
        assert_eq!(seqs, (0..total).collect::<Vec<_>>());
        assert!(matches!(
            p.next_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Disconnected)
        ));
        // Not asserted > 0 strictly for robustness on slow CI, but on any
        // real machine the first poll expires; log for humans.
        eprintln!("fanout_next_timeout_expiry_keeps_order: {timeouts} timeouts interleaved");
    }

    #[test]
    fn wedged_worker_join_times_out_and_detaches() {
        // Shutdown-hygiene regression: a worker that never finishes must
        // cost teardown the deadline at most, then get logged + detached —
        // never an indefinite hang (the pre-PR-3 `h.join()` behaviour).
        let wedged = std::thread::spawn(|| std::thread::sleep(Duration::from_secs(60)));
        let t0 = Instant::now();
        assert!(!join_within(wedged, Duration::from_millis(50), "test sleeper"));
        assert!(t0.elapsed() < Duration::from_secs(5), "timed join took {:?}", t0.elapsed());
        // And a healthy (even already-finished) worker joins normally.
        let quick = std::thread::spawn(|| {});
        assert!(join_within(quick, TEARDOWN_TIMEOUT, "quick worker"));
    }
}

//! Typed failure surface and fault policy for the selection stack.
//!
//! Selection can fail for exactly four reasons, and each gets its own
//! [`SelectError`] variant instead of a `panic!`: poisoned input rows
//! (non-finite features / gradient sketches / losses), numerical breakdown
//! inside the MaxVol / rank kernels (near-zero pivots, non-finite
//! projection errors), a shard job that keeps failing after its retry
//! budget, and a pool whose workers are gone.  What happens *next* is the
//! caller's choice, expressed as a [`FaultPolicy`] on
//! [`EngineBuilder`](crate::engine::EngineBuilder):
//!
//! * [`FaultPolicy::Fail`] (default) — surface the typed error.
//! * [`FaultPolicy::Retry`] — respawn / re-run up to `max` times with a
//!   fixed backoff; a successful retry is **bit-identical** to the
//!   fault-free run (same inputs, same deterministic kernels).
//! * [`FaultPolicy::Degrade`] — walk the degradation ladder: GRAFT
//!   grad-merge → feature-only MaxVol → seeded-random subset, recording
//!   every step as a [`Degradation`] in the returned
//!   [`Selection`](crate::engine::Selection) so a degraded subset is never
//!   silently mistaken for the paper's criterion (Balles et al.'s negative
//!   result is exactly about silently-wrong gradient selection).
//!
//! Fault-path activity (respawns, retries, deadline requeues, shutdown
//! join timeouts, quarantined rows) is counted in [`PoolStats`], readable
//! via [`SelectionEngine::fault_stats`](crate::engine::SelectionEngine::fault_stats).

use std::time::Duration;

/// Why a selection could not be produced by the configured method.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SelectError {
    /// Non-finite rows in the batch (features, gradient sketches, or
    /// losses), detected by the quarantine pre-scan under
    /// [`FaultPolicy::Fail`].  `rows` are batch-local indices, ascending.
    PoisonedInput { rows: Vec<usize> },
    /// The numerics broke down: a (near-)zero MaxVol pivot was clamped,
    /// the prefix-error curve was empty, or a projection error went
    /// non-finite.  Deterministic — retrying cannot help — so this is
    /// non-retryable and jumps straight to the seeded-random rung under
    /// [`FaultPolicy::Degrade`].
    NumericalBreakdown { stage: &'static str, detail: String },
    /// A shard job panicked (or its worker died) and kept doing so for
    /// every one of its `attempts` runs.
    ShardFailure { shard: usize, attempts: u32 },
    /// The worker pool is shut down (or every worker is dead); nothing
    /// can be submitted.
    PoolUnavailable,
}

impl std::fmt::Display for SelectError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SelectError::PoisonedInput { rows } => {
                write!(f, "poisoned input: {} non-finite row(s) {:?}", rows.len(), rows)
            }
            SelectError::NumericalBreakdown { stage, detail } => {
                write!(f, "numerical breakdown in {stage}: {detail}")
            }
            SelectError::ShardFailure { shard, attempts } => {
                write!(f, "shard {shard} failed after {attempts} attempt(s)")
            }
            SelectError::PoolUnavailable => write!(f, "selection pool unavailable (shut down)"),
        }
    }
}

impl std::error::Error for SelectError {}

impl SelectError {
    /// Whether another attempt with the same inputs could succeed.
    /// Numerical breakdown and poisoned input are deterministic; shard
    /// failures and pool hiccups are not.
    pub fn retryable(&self) -> bool {
        matches!(self, SelectError::ShardFailure { .. } | SelectError::PoolUnavailable)
    }
}

/// What the engine (and the pool underneath it) does when selection
/// faults.  Configured per engine via
/// [`EngineBuilder::fault_policy`](crate::engine::EngineBuilder::fault_policy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPolicy {
    /// Surface the first failure as a typed [`SelectError`].  The
    /// default — zero-fault behaviour is bit-identical to the other
    /// policies, so upgrading a config to `Retry`/`Degrade` never changes
    /// healthy results.
    #[default]
    Fail,
    /// Retry failed work up to `max` more times, sleeping `backoff`
    /// between attempts.  Pool workers are respawned (fresh thread, fresh
    /// `Workspace`) and the in-flight shard job re-submitted with the
    /// same inputs, so a successful retry is bit-identical to the
    /// fault-free run.  Exhausted retries surface the error.
    Retry { max: u32, backoff: Duration },
    /// Retry once, then walk the degradation ladder (feature-only MaxVol
    /// → seeded random) instead of failing; every rung is recorded as a
    /// [`Degradation`].
    Degrade,
}

impl FaultPolicy {
    /// Retry budget this policy grants a failing unit of work.
    pub fn max_retries(self) -> u32 {
        match self {
            FaultPolicy::Fail => 0,
            FaultPolicy::Retry { max, .. } => max,
            FaultPolicy::Degrade => 1,
        }
    }

    /// Sleep between attempts.
    pub fn backoff(self) -> Duration {
        match self {
            FaultPolicy::Retry { backoff, .. } => backoff,
            _ => Duration::ZERO,
        }
    }
}

/// One recorded step down the degradation ladder, carried by
/// [`Selection`](crate::engine::Selection) so callers can tell a paper-
/// criterion subset from a fallback.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Degradation {
    /// Non-finite rows excluded from the batch before selection
    /// (batch-local indices, ascending).
    Quarantined { rows: Vec<usize> },
    /// The configured method failed; this subset came from a serial
    /// feature-only Fast MaxVol over the same batch.
    FeatureOnlyMaxVol { cause: String },
    /// Even feature-only MaxVol failed; this subset is a seeded random
    /// draw (deterministic in the engine seed and window index).
    SeededRandom { cause: String },
}

impl std::fmt::Display for Degradation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Degradation::Quarantined { rows } => {
                write!(f, "quarantined {} poisoned row(s) {:?}", rows.len(), rows)
            }
            Degradation::FeatureOnlyMaxVol { cause } => {
                write!(f, "degraded to feature-only MaxVol: {cause}")
            }
            Degradation::SeededRandom { cause } => {
                write!(f, "degraded to seeded-random subset: {cause}")
            }
        }
    }
}

/// Fault-path telemetry: every count a healthy run leaves at zero.
/// Pool-side counts (respawns, deadline requeues, join timeouts) and
/// engine-side counts (retries, quarantined rows) are merged by
/// [`SelectionEngine::fault_stats`](crate::engine::SelectionEngine::fault_stats).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Workers replaced with a fresh thread + `Workspace`.
    pub respawns: u64,
    /// Shard jobs / engine selects re-run after a failure.
    pub retries: u64,
    /// Shard jobs re-submitted because their worker blew the per-job
    /// deadline (the original result is still awaited and discarded).
    pub deadline_requeues: u64,
    /// Worker joins that timed out during shutdown (previously only a
    /// stderr line).
    pub join_timeouts: u64,
    /// Total batch rows excluded by the input quarantine.
    pub quarantined_rows: u64,
}

impl PoolStats {
    /// Field-wise sum (engine-side + pool-side counters).
    pub fn merged(self, other: PoolStats) -> PoolStats {
        PoolStats {
            respawns: self.respawns + other.respawns,
            retries: self.retries + other.retries,
            deadline_requeues: self.deadline_requeues + other.deadline_requeues,
            join_timeouts: self.join_timeouts + other.join_timeouts,
            quarantined_rows: self.quarantined_rows + other.quarantined_rows,
        }
    }
}

/// Error surface of [`SelectionEngine::windows`](crate::engine::SelectionEngine::windows):
/// either the caller's assembly closure failed (`Assemble`, carrying the
/// caller's own error type) or a window's selection did (`Select`).
#[derive(Debug, PartialEq)]
pub enum WindowsError<E> {
    /// The `assemble` closure returned `Err`.
    Assemble(E),
    /// Selection of a window failed (after the configured fault policy
    /// was exhausted).
    Select(SelectError),
}

impl<E: std::fmt::Display> std::fmt::Display for WindowsError<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WindowsError::Assemble(e) => write!(f, "window assembly failed: {e}"),
            WindowsError::Select(e) => write!(f, "window selection failed: {e}"),
        }
    }
}

impl<E: std::fmt::Display + std::fmt::Debug> std::error::Error for WindowsError<E> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_retry_budgets() {
        assert_eq!(FaultPolicy::Fail.max_retries(), 0);
        assert_eq!(
            FaultPolicy::Retry { max: 3, backoff: Duration::ZERO }.max_retries(),
            3
        );
        assert_eq!(FaultPolicy::Degrade.max_retries(), 1);
        assert_eq!(FaultPolicy::default(), FaultPolicy::Fail);
    }

    #[test]
    fn retryability_matches_determinism() {
        assert!(SelectError::ShardFailure { shard: 0, attempts: 1 }.retryable());
        assert!(SelectError::PoolUnavailable.retryable());
        assert!(!SelectError::PoisonedInput { rows: vec![1] }.retryable());
        assert!(!SelectError::NumericalBreakdown {
            stage: "maxvol",
            detail: String::new()
        }
        .retryable());
    }

    #[test]
    fn stats_merge_is_fieldwise() {
        let a = PoolStats { respawns: 1, retries: 2, ..Default::default() };
        let b = PoolStats { retries: 1, quarantined_rows: 5, ..Default::default() };
        let m = a.merged(b);
        assert_eq!(m.respawns, 1);
        assert_eq!(m.retries, 3);
        assert_eq!(m.quarantined_rows, 5);
    }

    #[test]
    fn errors_and_degradations_display() {
        let e = SelectError::PoisonedInput { rows: vec![5, 17] };
        assert!(e.to_string().contains("[5, 17]"));
        let d = Degradation::SeededRandom { cause: "shard 2 failed".into() };
        assert!(d.to_string().contains("seeded-random"));
    }
}

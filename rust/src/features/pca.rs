//! PCA feature extractor: scores of the top-R principal components of the
//! centered batch (eigendecomposition of the covariance via the batch SVD;
//! identical subspace to `SvdFeatures` but variance-scaled scores — kept
//! separate because the paper's supplement lists PCA as its own method).

use super::FeatureExtractor;
use crate::linalg::{svd, Mat};

#[derive(Default)]
pub struct PcaFeatures;

impl FeatureExtractor for PcaFeatures {
    fn name(&self) -> &'static str {
        "pca"
    }

    fn extract(&self, batch: &Mat, r: usize) -> Mat {
        let mut xc = batch.clone();
        xc.center_cols();
        let d = svd(&xc);
        let r = r.min(d.s.len());
        // Scores: U_R Σ_R (projection of samples onto the PCs).
        let mut out = Mat::zeros(batch.rows(), r);
        for j in 0..r {
            let col = d.u.col(j);
            for i in 0..batch.rows() {
                out[(i, j)] = col[i] * d.s[j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::testsupport::{check_extractor, structured_batch};

    #[test]
    fn contract() {
        check_extractor(&PcaFeatures);
    }

    #[test]
    fn column_variances_descend() {
        let x = structured_batch(50, 25, 4, 3);
        let v = PcaFeatures.extract(&x, 4);
        let var = |j: usize| {
            let c = v.col(j);
            let m: f64 = c.iter().sum::<f64>() / c.len() as f64;
            c.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        };
        for j in 0..3 {
            assert!(var(j) >= var(j + 1) - 1e-9, "{} vs {}", var(j), var(j + 1));
        }
    }

    #[test]
    fn same_subspace_as_svd() {
        use crate::linalg::subspace_similarity;
        let x = structured_batch(40, 18, 3, 4);
        let a = PcaFeatures.extract(&x, 3);
        let b = super::super::svd::SvdFeatures.extract(&x, 3);
        assert!((subspace_similarity(&a, &b) - 3.0).abs() < 1e-6);
    }
}

//! Shallow autoencoder feature extractor — the "AE" row of Table 3.
//!
//! A one-hidden-layer tied-weight autoencoder (x̂ = Wᵀ tanh(W x + b))
//! trained by a few epochs of mini-batch SGD on the batch itself.  The
//! paper's AE achieves the best logistic-probe accuracy but ~5× the cost
//! of SVD (Table 3) — our implementation reproduces exactly that
//! accuracy/cost profile because training is in the extraction path.
//!
//! Encodings are ordered by activation variance (relevance contract).

use super::FeatureExtractor;
use crate::linalg::Mat;
use crate::rng::Rng;

pub struct AutoencoderFeatures {
    pub epochs: usize,
    pub lr: f64,
    pub seed: u64,
}

impl Default for AutoencoderFeatures {
    fn default() -> Self {
        AutoencoderFeatures { epochs: 40, lr: 0.01, seed: 0xAE }
    }
}

impl AutoencoderFeatures {
    /// Train on `batch` and return the final relative reconstruction error
    /// ‖X − X̂‖_F / ‖X‖_F — the honest training-quality metric used by the
    /// tests (the extractor output itself is the ordered code matrix).
    pub fn reconstruction_error(&self, batch: &Mat, r: usize) -> f64 {
        let (xc, rms, w, b) = self.train(batch, r);
        let mut x = xc.clone();
        x.scale(1.0 / rms);
        let (k, _) = (x.rows(), x.cols());
        let mut h = x.matmul(&w.transpose());
        for i in 0..k {
            for j in 0..w.rows() {
                h[(i, j)] = (h[(i, j)] + b[j]).tanh();
            }
        }
        let xhat = h.matmul(&w);
        xhat.sub(&x).frob_norm() / x.frob_norm().max(1e-12)
    }
}

impl FeatureExtractor for AutoencoderFeatures {
    fn name(&self) -> &'static str {
        "ae"
    }

    fn extract(&self, batch: &Mat, r: usize) -> Mat {
        let (xc, rms, w, b) = self.train(batch, r);
        let (k, _) = (xc.rows(), xc.cols());
        let mut x = xc;
        x.scale(1.0 / rms);

        // Final encodings, variance-ordered.
        let mut h = x.matmul(&w.transpose());
        for i in 0..k {
            for j in 0..r {
                h[(i, j)] = (h[(i, j)] + b[j]).tanh();
            }
        }
        let mut scores: Vec<(f64, usize)> = (0..r)
            .map(|j| {
                let c = h.col(j);
                let mean: f64 = c.iter().sum::<f64>() / k as f64;
                (-c.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>(), j)
            })
            .collect();
        scores.sort_by(|a, b| a.0.total_cmp(&b.0));
        let order: Vec<usize> = scores.iter().map(|&(_, j)| j).collect();
        h.take_cols(&order)
    }
}

impl AutoencoderFeatures {
    /// Gradient-descent training of the tied-weight AE; returns the
    /// centered batch, its RMS scale, the encoder W (r×m) and bias b.
    fn train(&self, batch: &Mat, r: usize) -> (Mat, f64, Mat, Vec<f64>) {
        let (k, m) = (batch.rows(), batch.cols());
        let mut xc = batch.clone();
        xc.center_cols();
        // Scale inputs to unit RMS so tanh stays in its active range.
        let rms = (xc.frob_norm() / ((k * m) as f64).sqrt()).max(1e-12);
        let mut x = xc.clone();
        x.scale(1.0 / rms);

        let mut rng = Rng::new(self.seed);
        let scale = (2.0 / m as f64).sqrt();
        let mut w = Mat::from_fn(r, m, |_, _| rng.normal() * scale); // encoder r×m
        let mut b = vec![0.0f64; r];

        // Full-batch gradient descent on ‖X − tanh(XWᵀ+b) W‖² (tied weights).
        for _ in 0..self.epochs {
            // h = tanh(x Wᵀ + b)  (k×r)
            let mut h = x.matmul(&w.transpose());
            for i in 0..k {
                for j in 0..r {
                    h[(i, j)] = (h[(i, j)] + b[j]).tanh();
                }
            }
            // x̂ = h W (k×m); e = x̂ − x
            let xhat = h.matmul(&w);
            let e = xhat.sub(&x);
            // grad wrt decoder path: dW_dec = hᵀ e (r×m)
            let gdec = h.transpose().matmul(&e);
            // backprop into h: dh = e Wᵀ ⊙ (1−h²)
            let mut dh = e.matmul(&w.transpose());
            for i in 0..k {
                for j in 0..r {
                    let hv = h[(i, j)];
                    dh[(i, j)] *= 1.0 - hv * hv;
                }
            }
            // grad wrt encoder path: dW_enc = dhᵀ x (r×m); db = Σ dh
            let genc = dh.transpose().matmul(&x);
            let inv = self.lr / k as f64;
            for i in 0..r {
                for j in 0..m {
                    w[(i, j)] -= inv * (gdec[(i, j)] + genc[(i, j)]);
                }
                let dbi: f64 = (0..k).map(|s| dh[(s, i)]).sum();
                b[i] -= inv * dbi;
            }
        }
        (xc, rms, w, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::testsupport::{check_extractor, structured_batch};

    #[test]
    fn contract() {
        check_extractor(&AutoencoderFeatures::default());
    }

    #[test]
    fn training_reduces_reconstruction_error() {
        let x = structured_batch(40, 16, 3, 21);
        let fast = AutoencoderFeatures { epochs: 1, ..Default::default() };
        let slow = AutoencoderFeatures { epochs: 80, ..Default::default() };
        let e1 = fast.reconstruction_error(&x, 3);
        let e80 = slow.reconstruction_error(&x, 3);
        assert!(e80 < e1, "training must reduce error: 1-epoch {e1}, 80-epoch {e80}");
        assert!(e80 < 0.7, "trained AE captures structure: {e80}");
    }

    #[test]
    fn variance_ordered() {
        let x = structured_batch(50, 20, 4, 22);
        let v = AutoencoderFeatures::default().extract(&x, 4);
        let var = |j: usize| {
            let c = v.col(j);
            let m: f64 = c.iter().sum::<f64>() / c.len() as f64;
            c.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        };
        for j in 0..3 {
            assert!(var(j) >= var(j + 1) - 1e-9);
        }
    }
}

//! FastICA feature extractor (Hyvärinen fixed-point iteration, tanh
//! nonlinearity, symmetric decorrelation) — the "ICA" row of Table 3.
//!
//! Components are ordered by non-Gaussianity (negentropy proxy) so the
//! leftmost column is the most relevant, matching the extractor contract.

use super::FeatureExtractor;
use crate::linalg::{dot, svd, Mat};
use crate::rng::Rng;

pub struct IcaFeatures {
    pub max_iters: usize,
    pub tol: f64,
    pub seed: u64,
}

impl Default for IcaFeatures {
    fn default() -> Self {
        IcaFeatures { max_iters: 60, tol: 1e-5, seed: 0x1CA }
    }
}

impl FeatureExtractor for IcaFeatures {
    fn name(&self) -> &'static str {
        "ica"
    }

    fn extract(&self, batch: &Mat, r: usize) -> Mat {
        let k = batch.rows();
        let mut xc = batch.clone();
        xc.center_cols();
        // Whiten to the top-r PCA subspace: Z = U_r (K×r), unit variance.
        let d = svd(&xc);
        let r = r.min(d.s.len()).max(1);
        let mut z = Mat::zeros(k, r);
        for j in 0..r {
            let col = d.u.col(j);
            let scale = (k as f64).sqrt(); // unit-variance whitening
            for i in 0..k {
                z[(i, j)] = col[i] * scale;
            }
        }
        // Symmetric FastICA on Zᵀ (components in the whitened space).
        let mut rng = Rng::new(self.seed);
        let mut w = Mat::from_fn(r, r, |_, _| rng.normal());
        sym_decorrelate(&mut w);
        for _ in 0..self.max_iters {
            let prev = w.clone();
            // For each component i: w ← E[z g(wᵀz)] − E[g'(wᵀz)] w
            let mut neww = Mat::zeros(r, r);
            for ci in 0..r {
                let wi = w.row(ci).to_vec();
                let mut ez_g = vec![0.0; r];
                let mut eg_prime = 0.0;
                for s in 0..k {
                    let zs = z.row(s);
                    let u = dot(&wi, zs);
                    let g = u.tanh();
                    let gp = 1.0 - g * g;
                    eg_prime += gp;
                    for t in 0..r {
                        ez_g[t] += zs[t] * g;
                    }
                }
                let inv = 1.0 / k as f64;
                for t in 0..r {
                    neww[(ci, t)] = ez_g[t] * inv - eg_prime * inv * wi[t];
                }
            }
            sym_decorrelate(&mut neww);
            // Convergence: |diag(W Wprevᵀ)| → 1.
            let mut delta = 0.0f64;
            for i in 0..r {
                let d = dot(neww.row(i), prev.row(i)).abs();
                delta = delta.max((1.0 - d).abs());
            }
            w = neww;
            if delta < self.tol {
                break;
            }
        }
        // Sources S = Z Wᵀ (K×r); order by the data energy each source
        // explains (Rel(j) of §3.1 — the extractor contract requires
        // importance-ordered columns; negentropy alone does not give an
        // energy ordering because whitened sources all have unit variance).
        let mut s = z.matmul(&w.transpose());
        let mut scores: Vec<(f64, usize)> = (0..r)
            .map(|j| {
                let cj = s.col(j);
                let n = crate::linalg::norm2(&cj).max(1e-12);
                let dir: Vec<f64> = cj.iter().map(|x| x / n).collect();
                let proj = xc.tmatvec(&dir);
                (-dot(&proj, &proj), j)
            })
            .collect();
        scores.sort_by(|a, b| a.0.total_cmp(&b.0));
        let order: Vec<usize> = scores.iter().map(|&(_, j)| j).collect();
        s = s.take_cols(&order);
        s
    }
}

/// Symmetric decorrelation: W ← (W Wᵀ)^{-1/2} W.
fn sym_decorrelate(w: &mut Mat) {
    let g = w.matmul(&w.transpose());
    let d = svd(&g);
    // (W Wᵀ)^{-1/2} = U diag(1/√s) Uᵀ (g symmetric PSD → U≈V).
    let n = g.rows();
    let mut inv_sqrt = Mat::zeros(n, n);
    for j in 0..n {
        let s = d.s[j].max(1e-12);
        let col = d.u.col(j);
        for i in 0..n {
            for t in 0..n {
                inv_sqrt[(i, t)] += col[i] * col[t] / s.sqrt();
            }
        }
    }
    *w = inv_sqrt.matmul(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::testsupport::{check_extractor, structured_batch};

    #[test]
    fn contract() {
        check_extractor(&IcaFeatures::default());
    }

    #[test]
    fn separates_independent_sources() {
        // Mix two clearly non-Gaussian independent sources; ICA should
        // recover components highly correlated with the originals.
        let mut rng = Rng::new(11);
        let k = 400;
        let s1: Vec<f64> = (0..k).map(|_| if rng.uniform() < 0.5 { 1.0 } else { -1.0 }).collect();
        let s2: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0 - 1.0).collect();
        let mut x = Mat::zeros(k, 2);
        for i in 0..k {
            x[(i, 0)] = 0.7 * s1[i] + 0.3 * s2[i];
            x[(i, 1)] = 0.4 * s1[i] - 0.6 * s2[i];
        }
        let v = IcaFeatures::default().extract(&x, 2);
        let corr = |a: &[f64], b: &[f64]| {
            let na = crate::linalg::norm2(a);
            let nb = crate::linalg::norm2(b);
            (dot(a, b) / (na * nb)).abs()
        };
        let c0 = v.col(0);
        let c1 = v.col(1);
        let best_s1 = corr(&c0, &s1).max(corr(&c1, &s1));
        assert!(best_s1 > 0.9, "source-1 recovery {best_s1}");
    }

    #[test]
    fn decorrelated_outputs() {
        let x = structured_batch(60, 20, 4, 12);
        let v = IcaFeatures::default().extract(&x, 4);
        // Components should be (nearly) uncorrelated.
        let mut vc = v.clone();
        vc.center_cols();
        let g = vc.gram();
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    let denom = (g[(i, i)] * g[(j, j)]).sqrt().max(1e-12);
                    assert!(
                        (g[(i, j)] / denom).abs() < 0.2,
                        "corr[{i},{j}] = {}",
                        g[(i, j)] / denom
                    );
                }
            }
        }
    }
}

//! SVD feature extractor: V = U_R, the top-R left singular vectors of the
//! centered batch — the paper's best-performing extractor (Table 3,
//! 90.3% vs 86.7% AE / 80.8% ICA on CIFAR-10 @25%).

use super::FeatureExtractor;
use crate::linalg::{orth, svd, Mat};
use crate::rng::Rng;

#[derive(Default)]
pub struct SvdFeatures;

impl FeatureExtractor for SvdFeatures {
    fn name(&self) -> &'static str {
        "svd"
    }

    fn extract(&self, batch: &Mat, r: usize) -> Mat {
        let mut xc = batch.clone();
        xc.center_cols();
        let r = r.min(xc.rows()).min(xc.cols());
        // §Perf L3: truncated randomized SVD (HMT 2011, q=2 power
        // iterations) — O(K·M·r) instead of full one-sided Jacobi's
        // O(K·min(K,M)²·sweeps).  Falls back to exact Jacobi when r is
        // most of the spectrum (randomized gains vanish there).
        if r * 3 >= xc.cols().min(xc.rows()) {
            let d = svd(&xc);
            let idx: Vec<usize> = (0..r).collect();
            return d.u.take_cols(&idx);
        }
        let mut rng = Rng::new(0x5D);
        let p = (r + 8).min(xc.cols()); // oversampling
        let omega = Mat::from_fn(xc.cols(), p, |_, _| rng.normal());
        let mut q = orth(&xc.matmul(&omega));
        for _ in 0..2 {
            q = orth(&xc.transpose().matmul(&q));
            q = orth(&xc.matmul(&q));
        }
        // Project: B = Qᵀ Xc (p×M), small exact SVD, U = Q·U_B.
        let b = q.transpose().matmul(&xc);
        let d = svd(&b);
        let idx: Vec<usize> = (0..r).collect();
        q.matmul(&d.u.take_cols(&idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::testsupport::{check_extractor, structured_batch};

    #[test]
    fn contract() {
        check_extractor(&SvdFeatures);
    }

    #[test]
    fn captures_dominant_subspace() {
        let x = structured_batch(40, 20, 3, 1);
        let v = SvdFeatures.extract(&x, 3);
        // Reconstruction through V (left projector) retains most energy.
        let mut xc = x.clone();
        xc.center_cols();
        let proj = v.matmul(&v.transpose()).matmul(&xc);
        let retained = proj.frob_norm() / xc.frob_norm();
        assert!(retained > 0.98, "{retained}");
    }

    #[test]
    fn orthonormal_columns() {
        let x = structured_batch(30, 15, 5, 2);
        let v = SvdFeatures.extract(&x, 5);
        let g = v.gram();
        assert!(g.sub(&Mat::eye(5)).max_abs() < 1e-8);
    }
}

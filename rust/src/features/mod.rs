//! Feature extraction f: R^{K×M} → R^{K×R} (paper §3.1 Step 1).
//!
//! Columns of the returned matrix are ordered by descending relevance
//! (Rel(1) ≥ … ≥ Rel(R)) — the contract the Fast MaxVol sampler relies on.
//! Four instantiations, matching the paper's ablation (Table 3 / Fig 4):
//! SVD, PCA, FastICA, and a shallow autoencoder.

pub mod ae;
pub mod ica;
pub mod pca;
pub mod svd;

use crate::linalg::Mat;

/// Why feature extraction refused a batch.
///
/// Every extractor in this module silently propagates non-finite inputs —
/// SVD/PCA power iterations turn one NaN cell into an all-NaN factor, and
/// the selector downstream then "selects" garbage.  The typed pre-check in
/// [`FeatureExtractor::try_extract`] catches that at the boundary instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExtractError {
    /// The batch contains non-finite cells; `row` is the first offending
    /// batch-local row (the quarantine pass in the engine reports all of
    /// them).
    NonFiniteInput { row: usize },
}

impl std::fmt::Display for ExtractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExtractError::NonFiniteInput { row } => {
                write!(f, "non-finite feature input at batch row {row}")
            }
        }
    }
}

impl std::error::Error for ExtractError {}

/// A batch feature extractor. Implementations must return a K×R matrix
/// with importance-ordered columns.
pub trait FeatureExtractor: Send + Sync {
    fn name(&self) -> &'static str;
    /// Extract R ordered features from the K×M batch.
    ///
    /// Assumes finite input; a non-finite batch produces non-finite
    /// features rather than a panic.  Gate untrusted batches through
    /// [`FeatureExtractor::try_extract`].
    fn extract(&self, batch: &Mat, r: usize) -> Mat;

    /// [`FeatureExtractor::extract`] behind a cheap finite pre-scan: one
    /// pass over the K×M cells (branch-free accumulation per row),
    /// refusing the batch with a typed [`ExtractError`] instead of
    /// propagating NaN/±∞ into the factorisation.
    fn try_extract(&self, batch: &Mat, r: usize) -> Result<Mat, ExtractError> {
        let m = batch.cols();
        if m > 0 {
            for (row, chunk) in batch.data().chunks_exact(m).enumerate() {
                // One fold per row: summing keeps the scan vectorizable,
                // and any NaN/±∞ cell poisons the row sum.  A tripped sum
                // is re-checked cell-wise, since huge-but-finite values
                // can overflow the fold without the row being poisoned.
                let acc: f64 = chunk.iter().sum();
                if !acc.is_finite() && chunk.iter().any(|x| !x.is_finite()) {
                    return Err(ExtractError::NonFiniteInput { row });
                }
            }
        }
        Ok(self.extract(batch, r))
    }
}

pub use ae::AutoencoderFeatures;
pub use ica::IcaFeatures;
pub use pca::PcaFeatures;
pub use svd::SvdFeatures;

/// Construct an extractor by name (CLI / config entry point).
pub fn by_name(name: &str) -> Option<Box<dyn FeatureExtractor>> {
    match name {
        "svd" => Some(Box::new(SvdFeatures::default())),
        "pca" => Some(Box::new(PcaFeatures::default())),
        "ica" => Some(Box::new(IcaFeatures::default())),
        "ae" => Some(Box::new(AutoencoderFeatures::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::testsupport::structured_batch;
    use super::*;

    /// Regression (fault-tolerance PR): extractors used to silently
    /// propagate NaN — one poisoned cell became an all-NaN feature matrix
    /// and the selector downstream picked garbage.  `try_extract` now
    /// refuses the batch with a typed error naming the first bad row.
    #[test]
    fn try_extract_rejects_non_finite_rows() {
        for name in ["svd", "pca", "ica", "ae"] {
            let e = by_name(name).unwrap();
            let mut x = structured_batch(32, 12, 3, 11);
            assert!(e.try_extract(&x, 4).is_ok(), "{name}: clean batch refused");
            x[(17, 5)] = f64::NAN;
            assert_eq!(
                e.try_extract(&x, 4),
                Err(ExtractError::NonFiniteInput { row: 17 }),
                "{name}: poisoned batch accepted"
            );
            x[(17, 5)] = 0.0;
            x[(3, 0)] = f64::INFINITY;
            assert_eq!(
                e.try_extract(&x, 4),
                Err(ExtractError::NonFiniteInput { row: 3 }),
                "{name}: infinite cell accepted"
            );
        }
    }

    /// Huge-but-finite rows may overflow the vectorized row-sum; they are
    /// still finite input and must pass.
    #[test]
    fn try_extract_tolerates_finite_overflowing_rows() {
        let e = by_name("svd").unwrap();
        let mut x = structured_batch(16, 8, 2, 13);
        for j in 0..8 {
            x[(5, j)] = f64::MAX;
        }
        assert!(e.try_extract(&x, 3).is_ok());
    }
}

#[cfg(test)]
pub(crate) mod testsupport {
    use super::*;
    use crate::rng::Rng;

    /// Low-rank-plus-noise batch with a known dominant subspace.
    pub fn structured_batch(k: usize, m: usize, rank: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let u = Mat::from_fn(k, rank, |_, _| rng.normal());
        let mut s = Mat::zeros(rank, rank);
        for i in 0..rank {
            s[(i, i)] = 10.0 / (i + 1) as f64;
        }
        let v = Mat::from_fn(rank, m, |_, _| rng.normal());
        let mut x = u.matmul(&s).matmul(&v);
        for i in 0..k {
            for j in 0..m {
                x[(i, j)] += 0.05 * rng.normal();
            }
        }
        x
    }

    /// Shared contract checks for any extractor.
    pub fn check_extractor(e: &dyn FeatureExtractor) {
        let x = structured_batch(48, 24, 4, 7);
        let v = e.extract(&x, 6);
        assert_eq!((v.rows(), v.cols()), (48, 6), "{}", e.name());
        assert!(v.data().iter().all(|x| x.is_finite()), "{}", e.name());
        // Ordered relevance: leading column explains at least as much of
        // the (centered) batch as the trailing one.
        let mut xc = x.clone();
        xc.center_cols();
        let energy = |j: usize| {
            let col = v.col(j);
            let n = crate::linalg::norm2(&col);
            if n < 1e-12 {
                return 0.0;
            }
            let cn: Vec<f64> = col.iter().map(|c| c / n).collect();
            let proj = xc.tmatvec(&cn);
            crate::linalg::dot(&proj, &proj)
        };
        assert!(
            energy(0) >= energy(5) * 0.8,
            "{}: first column should dominate",
            e.name()
        );
    }
}

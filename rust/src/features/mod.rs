//! Feature extraction f: R^{K×M} → R^{K×R} (paper §3.1 Step 1).
//!
//! Columns of the returned matrix are ordered by descending relevance
//! (Rel(1) ≥ … ≥ Rel(R)) — the contract the Fast MaxVol sampler relies on.
//! Four instantiations, matching the paper's ablation (Table 3 / Fig 4):
//! SVD, PCA, FastICA, and a shallow autoencoder.

pub mod ae;
pub mod ica;
pub mod pca;
pub mod svd;

use crate::linalg::Mat;

/// A batch feature extractor. Implementations must return a K×R matrix
/// with importance-ordered columns.
pub trait FeatureExtractor: Send + Sync {
    fn name(&self) -> &'static str;
    /// Extract R ordered features from the K×M batch.
    fn extract(&self, batch: &Mat, r: usize) -> Mat;
}

pub use ae::AutoencoderFeatures;
pub use ica::IcaFeatures;
pub use pca::PcaFeatures;
pub use svd::SvdFeatures;

/// Construct an extractor by name (CLI / config entry point).
pub fn by_name(name: &str) -> Option<Box<dyn FeatureExtractor>> {
    match name {
        "svd" => Some(Box::new(SvdFeatures::default())),
        "pca" => Some(Box::new(PcaFeatures::default())),
        "ica" => Some(Box::new(IcaFeatures::default())),
        "ae" => Some(Box::new(AutoencoderFeatures::default())),
        _ => None,
    }
}

#[cfg(test)]
pub(crate) mod testsupport {
    use super::*;
    use crate::rng::Rng;

    /// Low-rank-plus-noise batch with a known dominant subspace.
    pub fn structured_batch(k: usize, m: usize, rank: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let u = Mat::from_fn(k, rank, |_, _| rng.normal());
        let mut s = Mat::zeros(rank, rank);
        for i in 0..rank {
            s[(i, i)] = 10.0 / (i + 1) as f64;
        }
        let v = Mat::from_fn(rank, m, |_, _| rng.normal());
        let mut x = u.matmul(&s).matmul(&v);
        for i in 0..k {
            for j in 0..m {
                x[(i, j)] += 0.05 * rng.normal();
            }
        }
        x
    }

    /// Shared contract checks for any extractor.
    pub fn check_extractor(e: &dyn FeatureExtractor) {
        let x = structured_batch(48, 24, 4, 7);
        let v = e.extract(&x, 6);
        assert_eq!((v.rows(), v.cols()), (48, 6), "{}", e.name());
        assert!(v.data().iter().all(|x| x.is_finite()), "{}", e.name());
        // Ordered relevance: leading column explains at least as much of
        // the (centered) batch as the trailing one.
        let mut xc = x.clone();
        xc.center_cols();
        let energy = |j: usize| {
            let col = v.col(j);
            let n = crate::linalg::norm2(&col);
            if n < 1e-12 {
                return 0.0;
            }
            let cn: Vec<f64> = col.iter().map(|c| c / n).collect();
            let proj = xc.tmatvec(&cn);
            crate::linalg::dot(&proj, &proj)
        };
        assert!(
            energy(0) >= energy(5) * 0.8,
            "{}: first column should dominate",
            e.name()
        );
    }
}

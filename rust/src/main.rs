//! GRAFT CLI — the Layer-3 entrypoint.  See `graft help` / DESIGN.md §4
//! for the experiment map (every paper table and figure has a command).

use graft::cmd;
use graft::config::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    cmd::dispatch(&args)
}

//! Tenant state behind one daemon session: the wire-config →
//! [`EngineBuilder`] mapping, the per-tenant engine (batch or streaming),
//! and the shared latency/telemetry registry whose `Stats` reply is a
//! graft-bench-v1 document.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::SelectWindow;
use crate::engine::{EngineBuilder, FaultPolicy, RankMode, SelectionEngine, StreamingEngine};
use crate::faults::FaultInjector;
use crate::linalg::Mat;

use super::protocol::{TenantConfig, WireBatch, WireFaultPolicy};

/// Map a wire [`TenantConfig`] onto an [`EngineBuilder`].  This is **the**
/// config path for served tenants — the daemon builds every engine
/// through it, so all validation (budget/fraction/ε ranges, shape
/// compatibility, streaming constraints) is the `EngineBuilder`'s, and a
/// client that builds its in-process reference engine through this same
/// function gets served selections bit-identical by construction.
pub fn engine_builder(cfg: &TenantConfig) -> EngineBuilder {
    let mut b = EngineBuilder::new()
        .method(&cfg.method)
        .seed(cfg.seed)
        .fraction(cfg.fraction)
        .epsilon(cfg.epsilon)
        .shards(cfg.shards as usize)
        .pool_workers(cfg.workers as usize)
        .overlap(cfg.overlap)
        .fault_policy(match cfg.fault {
            WireFaultPolicy::Fail => FaultPolicy::Fail,
            WireFaultPolicy::Retry { max, backoff_ms } => FaultPolicy::Retry {
                max,
                backoff: Duration::from_millis(backoff_ms as u64),
            },
            WireFaultPolicy::Degrade => FaultPolicy::Degrade,
        });
    if cfg.budget > 0 {
        b = b.budget(cfg.budget as usize);
    }
    if cfg.adaptive {
        b = b.rank(RankMode::Adaptive { epsilon: cfg.epsilon });
    }
    if !cfg.extractor.is_empty() {
        b = b.extractor(&cfg.extractor);
    }
    if !cfg.merge.is_empty() {
        b = b.merge_name(&cfg.merge);
    }
    b
}

/// Tenant names travel inside JSON and logs unescaped, so the daemon
/// only admits `[A-Za-z0-9_.-]{1,64}`.
pub fn valid_tenant_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'.' || b == b'-')
}

/// The engine behind a session, in its declared mode.
pub(crate) enum EngineKind {
    Batch {
        eng: SelectionEngine,
        /// The one admitted-but-unselected window (the per-session
        /// backpressure bound: a second `SubmitBatch` is `Rejected`).
        pending: Option<SelectWindow>,
    },
    Stream {
        eng: StreamingEngine,
        /// Feature/sketch widths fixed by the first chunk; later chunks
        /// must match (the `StreamState` contract).
        dims: Option<(u32, u32)>,
    },
}

/// One live tenant: its engine plus session-scoped counters.
pub(crate) struct Tenant {
    pub name: String,
    pub kind: EngineKind,
    /// Selections answered (selects + snapshots).
    pub windows: u64,
    /// Rows ingested (batch rows submitted + stream rows pushed).
    pub rows: u64,
}

impl Tenant {
    /// Build a tenant engine from its `Hello`.  `Err` carries the
    /// `EngineError` display text for the `Rejected { BadHello }` reply.
    pub fn build(
        name: &str,
        cfg: &TenantConfig,
        injector: Option<Arc<dyn FaultInjector>>,
    ) -> Result<Tenant, String> {
        let kind = if cfg.streaming {
            let eng = engine_builder(cfg).build_streaming().map_err(|e| e.to_string())?;
            EngineKind::Stream { eng, dims: None }
        } else {
            let mut eng = engine_builder(cfg).build().map_err(|e| e.to_string())?;
            if injector.is_some() {
                eng.set_fault_injector(injector);
            }
            EngineKind::Batch { eng, pending: None }
        };
        Ok(Tenant { name: name.to_string(), kind, windows: 0, rows: 0 })
    }

    pub fn notes(&self) -> Vec<String> {
        match &self.kind {
            EngineKind::Batch { eng, .. } => eng.notes().to_vec(),
            EngineKind::Stream { eng, .. } => eng.notes().to_vec(),
        }
    }

    /// Drain: release execution resources eagerly (the pool's
    /// drop-senders-then-join shutdown).  Idempotent; a batch engine
    /// keeps answering `PoolUnavailable` afterwards rather than panicking.
    pub fn shutdown(&mut self) {
        if let EngineKind::Batch { eng, .. } = &mut self.kind {
            eng.shutdown();
        }
    }
}

/// Materialise a wire batch as an owned [`SelectWindow`] (whose `view()`
/// is the `BatchView` every engine entry point takes).  Shape consistency
/// was already enforced by the decoder; this is a straight reshape.
pub(crate) fn window_from_wire(b: &WireBatch) -> SelectWindow {
    let (k, rc, ec) = (b.rows as usize, b.rcols as usize, b.ecols as usize);
    SelectWindow {
        features: Mat::from_vec(k, rc, b.features.clone()),
        grads: Mat::from_vec(k, ec, b.grads.clone()),
        losses: b.losses.clone(),
        labels: b.labels.clone(),
        preds: b.preds.clone(),
        classes: b.classes as usize,
        row_ids: b.row_ids.iter().map(|&i| i as usize).collect(),
    }
}

// ---------------------------------------------------------------------------
// Stats registry
// ---------------------------------------------------------------------------

/// Welford accumulator over nanosecond samples — mean/std/min in one
/// pass, no sample retention, exactly what a graft-bench-v1 record needs.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct LatAcc {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
}

impl LatAcc {
    pub fn push(&mut self, ns: f64) {
        self.count += 1;
        if self.count == 1 {
            self.mean = ns;
            self.m2 = 0.0;
            self.min = ns;
        } else {
            let d = ns - self.mean;
            self.mean += d / self.count as f64;
            self.m2 += d * (ns - self.mean);
            if ns < self.min {
                self.min = ns;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    fn std(&self) -> f64 {
        if self.count > 1 {
            (self.m2 / (self.count - 1) as f64).max(0.0).sqrt()
        } else {
            0.0
        }
    }
}

/// Per-tenant telemetry, keyed by tenant name in the registry so a
/// tenant that disconnects and returns keeps accumulating one row set.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct TenantStats {
    pub streaming: bool,
    pub select: LatAcc,
    pub push: LatAcc,
    pub snapshot: LatAcc,
    pub windows: u64,
    pub rows: u64,
    /// Typed selection faults surfaced to the client (`Fault` replies).
    pub faults: u64,
}

/// The daemon-wide stats registry behind the `Stats` endpoint.  Sessions
/// record into it live (per-op lock, negligible next to a select), so a
/// monitoring connection sees current numbers for active tenants too.
#[derive(Debug, Default)]
pub(crate) struct StatsRegistry {
    tenants: BTreeMap<String, TenantStats>,
}

impl StatsRegistry {
    pub fn entry(&mut self, tenant: &str, streaming: bool) -> &mut TenantStats {
        let e = self.tenants.entry(tenant.to_string()).or_default();
        e.streaming = streaming;
        e
    }

    /// Render the registry as a graft-bench-v1 document: one record per
    /// (tenant, op) with samples, `bench = "graft-serve"`, and the tenant
    /// + mode + progress counters packed into `shape` (records carry
    /// exactly the six schema fields — `scripts/validate_bench.py`
    /// rejects extras, which is the point: production telemetry passes
    /// the same validator as bench output).
    pub fn to_bench_json(&self) -> String {
        let mut out = String::from("{\"schema\":\"graft-bench-v1\",\"records\":[");
        let mut first = true;
        for (name, t) in &self.tenants {
            let mode = if t.streaming { "stream" } else { "batch" };
            let shape = format!(
                "tenant={name},mode={mode},windows={},rows={},faults={}",
                t.windows, t.rows, t.faults
            );
            for (op, acc) in [
                ("serve_select", &t.select),
                ("serve_push", &t.push),
                ("serve_snapshot", &t.snapshot),
            ] {
                if acc.count() == 0 {
                    continue;
                }
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!(
                    "{{\"bench\":\"graft-serve\",\"op\":\"{op}\",\"shape\":\"{shape}\",\
                     \"mean_ns\":{:.1},\"std_ns\":{:.1},\"min_ns\":{:.1}}}",
                    acc.mean, acc.std(), acc.min
                ));
            }
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_names_are_vetted() {
        assert!(valid_tenant_name("job-a.7_x"));
        assert!(!valid_tenant_name(""));
        assert!(!valid_tenant_name("has space"));
        assert!(!valid_tenant_name("quote\""));
        assert!(!valid_tenant_name(&"x".repeat(65)));
    }

    #[test]
    fn welford_matches_direct_moments() {
        let xs = [5.0, 3.0, 8.0, 8.0, 1.0, 4.0];
        let mut acc = LatAcc::default();
        for &x in &xs {
            acc.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((acc.mean - mean).abs() < 1e-12);
        assert!((acc.std() - var.sqrt()).abs() < 1e-12);
        assert_eq!(acc.min, 1.0);
        assert_eq!(acc.count(), xs.len() as u64);
    }

    #[test]
    fn registry_emits_schema_rows() {
        let mut reg = StatsRegistry::default();
        {
            let t = reg.entry("job-a", false);
            t.select.push(1200.0);
            t.select.push(900.0);
            t.windows = 2;
            t.rows = 64;
        }
        reg.entry("idle", true); // no samples → no records
        let json = reg.to_bench_json();
        assert!(json.starts_with("{\"schema\":\"graft-bench-v1\""));
        assert!(json.contains("\"op\":\"serve_select\""));
        assert!(json.contains("tenant=job-a,mode=batch,windows=2,rows=64,faults=0"));
        assert!(!json.contains("idle"), "sample-free tenants emit no records");
    }

    #[test]
    fn builder_mapping_validates_through_engine_builder() {
        // A bad fraction is the builder's error, not the daemon's.
        let cfg = TenantConfig { fraction: 0.0, ..TenantConfig::default() };
        let err = Tenant::build("t", &cfg, None).unwrap_err();
        assert!(err.contains("fraction"), "builder error names the field: {err}");
        // Streaming without a budget is rejected the same way.
        let cfg = TenantConfig { streaming: true, budget: 0, ..TenantConfig::default() };
        assert!(Tenant::build("t", &cfg, None).is_err());
        // A healthy config builds.
        let cfg = TenantConfig { budget: 4, ..TenantConfig::default() };
        assert!(Tenant::build("t", &cfg, None).is_ok());
    }
}

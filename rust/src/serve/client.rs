//! Loopback/remote client for the selection daemon: a thin, blocking
//! request/response wrapper over the [`protocol`](super::protocol) codec.
//! The integration suites and `graft serve-smoke` drive the daemon
//! through this type, so the client-side codec is exercised by the same
//! tests that pin the server.

use std::io;
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
#[cfg(unix)]
use std::path::Path;
use std::time::Duration;

use crate::selection::BatchView;

use super::protocol::{
    read_frame, write_msg, FaultKind, FrameRead, Msg, ProtoError, RejectCode, TenantConfig,
    WireBatch, WireDrain, WireSelection, WireSnapshot, DEFAULT_MAX_FRAME,
};
use super::Conn;

/// Everything a daemon round-trip can come back with, typed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure (connect, send, or receive).
    Io(io::Error),
    /// The reply frame failed to decode.
    Proto(ProtoError),
    /// Admission control turned the connection away.
    Busy { active: u32, max: u32 },
    /// The server refused the request; the session is still usable.
    Rejected { code: RejectCode, detail: String },
    /// A typed selection fault (or, for `Protocol`, a codec violation
    /// after which the server closes the connection).
    Fault { kind: FaultKind, detail: String },
    /// The server closed the connection where a reply was expected.
    Closed,
    /// A structurally valid reply of the wrong type for the request.
    Unexpected(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "transport error: {e}"),
            ClientError::Proto(e) => write!(f, "protocol error: {e}"),
            ClientError::Busy { active, max } => {
                write!(f, "server busy ({active}/{max} sessions)")
            }
            ClientError::Rejected { code, detail } => {
                write!(f, "rejected ({code:?}): {detail}")
            }
            ClientError::Fault { kind, detail } => write!(f, "fault ({kind:?}): {detail}"),
            ClientError::Closed => write!(f, "server closed the connection"),
            ClientError::Unexpected(what) => write!(f, "unexpected reply: {what}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

impl From<ProtoError> for ClientError {
    fn from(e: ProtoError) -> ClientError {
        ClientError::Proto(e)
    }
}

/// One tenant connection to a running daemon.
pub struct Client {
    conn: Conn,
}

/// How many read-timeout ticks the client tolerates while waiting for a
/// reply (a pooled selection can legitimately take a while; 120 × 250 ms
/// = 30 s).
const REPLY_TICKS: u32 = 120;

impl Client {
    /// Connect over TCP (`host:port`).
    pub fn connect_tcp(addr: &str) -> Result<Client, ClientError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let conn = Conn::Tcp(stream);
        let _ = conn.set_read_timeout(Some(Duration::from_millis(250)));
        Ok(Client { conn })
    }

    /// Connect over a Unix-domain socket.
    #[cfg(unix)]
    pub fn connect_unix(path: impl AsRef<Path>) -> Result<Client, ClientError> {
        let conn = Conn::Unix(UnixStream::connect(path)?);
        let _ = conn.set_read_timeout(Some(Duration::from_millis(250)));
        Ok(Client { conn })
    }

    fn read_reply(&mut self) -> Result<Msg, ClientError> {
        // Waiting for a reply, an idle tick is just the server thinking;
        // bounded by REPLY_TICKS so a dead server surfaces as an error.
        let mut idle = 0u32;
        loop {
            match read_frame(&mut self.conn, DEFAULT_MAX_FRAME, REPLY_TICKS)? {
                FrameRead::Frame(p) => return Ok(Msg::decode(&p)?),
                FrameRead::Eof => return Err(ClientError::Closed),
                FrameRead::Idle => {
                    idle += 1;
                    if idle >= REPLY_TICKS {
                        return Err(ClientError::Io(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "no reply within the reply budget",
                        )));
                    }
                }
            }
        }
    }

    /// Send one message, read one reply, and translate the generic
    /// failure replies (`Busy`/`Rejected`/`Fault`) into typed errors.
    fn roundtrip(&mut self, msg: &Msg) -> Result<Msg, ClientError> {
        write_msg(&mut self.conn, msg)?;
        match self.read_reply()? {
            Msg::Busy { active, max } => Err(ClientError::Busy { active, max }),
            Msg::Rejected { code, detail } => Err(ClientError::Rejected { code, detail }),
            Msg::Fault { kind, detail } => Err(ClientError::Fault { kind, detail }),
            reply => Ok(reply),
        }
    }

    /// Claim a tenant name and build its engine on the daemon.  Returns
    /// the session id and the engine's build notes.
    pub fn hello(
        &mut self,
        tenant: &str,
        config: &TenantConfig,
    ) -> Result<(u64, Vec<String>), ClientError> {
        let msg = Msg::Hello { tenant: tenant.to_string(), config: config.clone() };
        match self.roundtrip(&msg)? {
            Msg::HelloAck { session, notes } => Ok((session, notes)),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Submit one batch window (batch tenants).  Returns rows accepted.
    pub fn submit_batch(&mut self, view: &BatchView<'_>) -> Result<u64, ClientError> {
        match self.roundtrip(&Msg::SubmitBatch(WireBatch::from_view(view)))? {
            Msg::Ack { rows } => Ok(rows),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Run selection on the pending window (batch tenants).
    pub fn get_selection(&mut self) -> Result<WireSelection, ClientError> {
        match self.roundtrip(&Msg::GetSelection)? {
            Msg::Selection(s) => Ok(s),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Submit + select in one call — the common batch window shape.
    pub fn select(&mut self, view: &BatchView<'_>) -> Result<WireSelection, ClientError> {
        self.submit_batch(view)?;
        self.get_selection()
    }

    /// Push one chunk of rows (streaming tenants).
    pub fn push_chunk(&mut self, view: &BatchView<'_>) -> Result<u64, ClientError> {
        match self.roundtrip(&Msg::PushChunk(WireBatch::from_view(view)))? {
            Msg::Ack { rows } => Ok(rows),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Materialise a selection from the stream (streaming tenants).
    pub fn snapshot(&mut self) -> Result<WireSnapshot, ClientError> {
        match self.roundtrip(&Msg::Snapshot)? {
            Msg::SnapshotR(s) => Ok(s),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Quiesce the tenant and fetch progress + fault telemetry.
    pub fn drain(&mut self) -> Result<WireDrain, ClientError> {
        match self.roundtrip(&Msg::Drain)? {
            Msg::DrainAck(d) => Ok(d),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Fetch the daemon-wide graft-bench-v1 telemetry document.  Works
    /// on any connection, before or without `Hello`.
    pub fn stats(&mut self) -> Result<String, ClientError> {
        match self.roundtrip(&Msg::Stats)? {
            Msg::StatsR { json } => Ok(json),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Graceful goodbye: the server acknowledges, then both sides close.
    pub fn bye(mut self) -> Result<(), ClientError> {
        match self.roundtrip(&Msg::Bye)? {
            Msg::ByeAck => Ok(()),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }
}

//! Wire protocol for the selection daemon: versioned, length-prefixed
//! binary frames over TCP or Unix sockets.
//!
//! # Frame layout
//!
//! ```text
//! ┌────────────┬───────────┬──────────┬───────────────┐
//! │ u32 LE len │ u8 version│ u8 type  │ body (len-2 B)│
//! └────────────┴───────────┴──────────┴───────────────┘
//! ```
//!
//! `len` counts the payload (version byte onward).  The current version
//! is [`PROTOCOL_VERSION`]; a frame with any other version decodes to
//! [`ProtoError::UnknownVersion`] without touching the body, so the
//! server can reject a future client with a typed reply instead of
//! misparsing it.
//!
//! # Message table
//!
//! | type | message        | direction | body |
//! |------|----------------|-----------|------|
//! | 1    | `Hello`        | c → s     | tenant name + [`TenantConfig`] |
//! | 2    | `SubmitBatch`  | c → s     | [`WireBatch`] (one selection window) |
//! | 3    | `PushChunk`    | c → s     | [`WireBatch`] (streamed rows) |
//! | 4    | `GetSelection` | c → s     | — |
//! | 5    | `Snapshot`     | c → s     | — |
//! | 6    | `Drain`        | c → s     | — |
//! | 7    | `Stats`        | c → s     | — |
//! | 8    | `Bye`          | c → s     | — |
//! | 64   | `HelloAck`     | s → c     | session id + build notes |
//! | 65   | `Ack`          | s → c     | rows accepted |
//! | 66   | `Selection`    | s → c     | [`WireSelection`] |
//! | 67   | `SnapshotR`    | s → c     | [`WireSnapshot`] |
//! | 68   | `DrainAck`     | s → c     | [`WireDrain`] telemetry |
//! | 69   | `StatsR`       | s → c     | graft-bench-v1 JSON text |
//! | 70   | `Busy`         | s → c     | active / max sessions |
//! | 71   | `Rejected`     | s → c     | [`RejectCode`] + detail |
//! | 72   | `Fault`        | s → c     | [`FaultKind`] + detail |
//! | 73   | `ByeAck`       | s → c     | — |
//!
//! Scalars are little-endian; `f64` travels as its IEEE-754 bit pattern;
//! strings and arrays are a `u32` count followed by the elements.  Every
//! decode is bounds-checked against the frame — truncated fields,
//! trailing bytes, oversized declared counts, and bad UTF-8 all return a
//! typed [`ProtoError`], never a panic or an unbounded allocation
//! (element counts are validated against the bytes actually present
//! before anything is reserved).

use std::io::{self, Read};

/// The one protocol version this build speaks.
pub const PROTOCOL_VERSION: u8 = 1;

/// Default cap on a frame payload (16 MiB ≈ a 100k-row batch at R+E=20).
/// A length prefix above the configured cap is rejected *before* the body
/// is read, so a hostile prefix cannot make the server allocate.
pub const DEFAULT_MAX_FRAME: usize = 16 << 20;

/// Everything that can go wrong reading or decoding a frame.  All
/// variants are terminal for the connection except as noted by the
/// session layer; none of them panic.
#[derive(Debug)]
pub enum ProtoError {
    /// Transport error (including read timeouts surfaced by the session
    /// layer's stall budget).
    Io(io::Error),
    /// The peer closed the connection inside a frame (`got` of the
    /// expected `want` bytes had arrived).
    MidFrameEof { got: usize, want: usize },
    /// Declared payload length exceeds the configured cap.
    FrameTooLarge { len: usize, max: usize },
    /// A frame with a zero-length payload (no version byte).
    EmptyFrame,
    /// Unknown protocol version byte.
    UnknownVersion { version: u8 },
    /// Unknown message-type byte (valid version).
    UnknownMsgType { ty: u8 },
    /// A field ran past the end of the frame.
    Truncated { field: &'static str },
    /// Structurally invalid content (bad UTF-8, trailing bytes, an
    /// out-of-range enum byte, inconsistent counts).
    Malformed { what: String },
    /// The peer stalled mid-frame past the stall budget.
    Stalled { got: usize, want: usize },
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "transport error: {e}"),
            ProtoError::MidFrameEof { got, want } => {
                write!(f, "connection closed mid-frame ({got}/{want} bytes)")
            }
            ProtoError::FrameTooLarge { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte cap")
            }
            ProtoError::EmptyFrame => write!(f, "empty frame (no version byte)"),
            ProtoError::UnknownVersion { version } => {
                write!(f, "unknown protocol version {version} (this build speaks {PROTOCOL_VERSION})")
            }
            ProtoError::UnknownMsgType { ty } => write!(f, "unknown message type {ty}"),
            ProtoError::Truncated { field } => write!(f, "frame truncated in field '{field}'"),
            ProtoError::Malformed { what } => write!(f, "malformed frame: {what}"),
            ProtoError::Stalled { got, want } => {
                write!(f, "peer stalled mid-frame ({got}/{want} bytes)")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<io::Error> for ProtoError {
    fn from(e: io::Error) -> ProtoError {
        ProtoError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Wire payloads
// ---------------------------------------------------------------------------

/// Per-tenant fault policy, on the wire.  Mirrors
/// [`FaultPolicy`](crate::coordinator::FaultPolicy) with a millisecond
/// backoff (a `Duration` has no canonical wire form).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFaultPolicy {
    Fail,
    Retry { max: u32, backoff_ms: u32 },
    Degrade,
}

/// Everything a tenant declares in `Hello`.  The server feeds this
/// through [`crate::serve::engine_builder`] so per-tenant budgets, seeds,
/// shapes, and policies are validated by the exact same
/// [`EngineBuilder`](crate::engine::EngineBuilder) rules as in-process
/// construction — which is also what makes served selections bit-identical
/// to an in-process engine built from the same config.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantConfig {
    /// Selection method (`graft`, `maxvol`, any `selection::by_name`).
    pub method: String,
    /// Streaming session (`PushChunk`/`Snapshot`) instead of batch
    /// (`SubmitBatch`/`GetSelection`).
    pub streaming: bool,
    /// Explicit per-window row budget; 0 = fraction-derived (batch only —
    /// streaming requires an explicit budget and the builder enforces it).
    pub budget: u64,
    /// Target data fraction ∈ (0, 1].
    pub fraction: f64,
    /// Projection-error threshold ε ∈ (0, 1].
    pub epsilon: f64,
    /// Adaptive dynamic rank (GRAFT Stage 2) instead of strict.
    pub adaptive: bool,
    /// Tenant RNG seed.
    pub seed: u64,
    /// Shard count (≥ 1).
    pub shards: u32,
    /// Pool workers (0 = no pool).
    pub workers: u32,
    /// Overlap assembly with in-flight selection (pooled shapes only).
    pub overlap: bool,
    /// What the tenant's engine does on selection faults.
    pub fault: WireFaultPolicy,
    /// Feature extractor name; empty = none.
    pub extractor: String,
    /// Merge policy spelling; empty = method-aware default.
    pub merge: String,
}

impl Default for TenantConfig {
    /// Mirrors [`EngineBuilder::new`](crate::engine::EngineBuilder::new):
    /// serial GRAFT, fraction 0.25, ε = 0.1, strict rank, seed 42,
    /// fail-fast faults.
    fn default() -> TenantConfig {
        TenantConfig {
            method: "graft".to_string(),
            streaming: false,
            budget: 0,
            fraction: 0.25,
            epsilon: 0.1,
            adaptive: false,
            seed: 42,
            shards: 1,
            workers: 0,
            overlap: false,
            fault: WireFaultPolicy::Fail,
            extractor: String::new(),
            merge: String::new(),
        }
    }
}

/// One batch (or streamed chunk) of rows, on the wire: the serialized
/// form of a [`BatchView`](crate::selection::BatchView).
#[derive(Debug, Clone, PartialEq)]
pub struct WireBatch {
    pub rows: u32,
    /// Feature columns (R).
    pub rcols: u32,
    /// Gradient-sketch columns (E).
    pub ecols: u32,
    pub classes: u32,
    /// Row-major K×R.
    pub features: Vec<f64>,
    /// Row-major K×E.
    pub grads: Vec<f64>,
    pub losses: Vec<f64>,
    pub labels: Vec<i32>,
    pub preds: Vec<i32>,
    /// Global dataset row ids.
    pub row_ids: Vec<u64>,
}

impl WireBatch {
    /// Serialize a batch view (the client-side gather).
    pub fn from_view(view: &crate::selection::BatchView<'_>) -> WireBatch {
        WireBatch {
            rows: view.k() as u32,
            rcols: view.features.cols() as u32,
            ecols: view.grads.cols() as u32,
            classes: view.classes as u32,
            features: view.features.data().to_vec(),
            grads: view.grads.data().to_vec(),
            losses: view.losses.to_vec(),
            labels: view.labels.to_vec(),
            preds: view.preds.to_vec(),
            row_ids: view.row_ids.iter().map(|&i| i as u64).collect(),
        }
    }
}

/// The rank decision on the wire (mirrors
/// [`RankDecision`](crate::graft::RankDecision)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireDecision {
    pub rank: u64,
    pub error: f64,
    pub satisfied: bool,
}

/// A batch selection reply.
#[derive(Debug, Clone, PartialEq)]
pub struct WireSelection {
    /// 0-based window ordinal in the tenant engine's lifetime.
    pub window: u64,
    /// The budget this selection was asked for.
    pub budget: u64,
    /// Batch-local winner indices, in selection order.
    pub indices: Vec<u64>,
    pub decision: Option<WireDecision>,
    /// Recorded degradation ladder steps, as display strings.
    pub degradations: Vec<String>,
}

/// A streaming snapshot reply.
#[derive(Debug, Clone, PartialEq)]
pub struct WireSnapshot {
    pub rows_seen: u64,
    pub reservoir_len: u64,
    pub budget: u64,
    /// Selected **global row ids**, in selection order.
    pub indices: Vec<u64>,
    pub decision: Option<WireDecision>,
    pub degradations: Vec<String>,
}

/// Drain telemetry: per-tenant progress plus the engine's fault counters
/// ([`PoolStats`](crate::coordinator::PoolStats) flattened).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WireDrain {
    /// Windows served (selects / snapshots answered).
    pub windows: u64,
    /// Rows ingested (batch rows submitted / stream rows pushed).
    pub rows: u64,
    pub respawns: u64,
    pub retries: u64,
    pub deadline_requeues: u64,
    pub join_timeouts: u64,
    pub quarantined_rows: u64,
    /// Live pool workers (0 for non-pooled tenants).
    pub live_workers: u64,
}

/// Why the server refused a request (the session stays open unless noted
/// in the [session docs](crate::serve)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCode {
    /// The `Hello` config failed `EngineBuilder` validation (detail names
    /// the offending field) or the tenant name is not `[A-Za-z0-9_.-]{1,64}`.
    BadHello = 1,
    /// Another live session already owns this tenant name.
    DuplicateTenant = 2,
    /// A second `Hello` on an established session.
    AlreadyHello = 3,
    /// A tenant request before `Hello`.
    NeedHello = 4,
    /// `SubmitBatch` while a window is already pending — the per-session
    /// admission bound; resolve it with `GetSelection` first.
    PendingSelection = 5,
    /// `GetSelection` with no pending window.
    NoPendingBatch = 6,
    /// A streaming request on a batch tenant.
    NotStreaming = 7,
    /// A batch request on a streaming tenant.
    NotBatch = 8,
    /// A streamed chunk whose feature/sketch widths differ from the
    /// stream's first chunk.
    ShapeMismatch = 9,
    /// A zero-row batch or chunk.
    EmptyBatch = 10,
}

impl RejectCode {
    fn from_u8(v: u8) -> Option<RejectCode> {
        Some(match v {
            1 => RejectCode::BadHello,
            2 => RejectCode::DuplicateTenant,
            3 => RejectCode::AlreadyHello,
            4 => RejectCode::NeedHello,
            5 => RejectCode::PendingSelection,
            6 => RejectCode::NoPendingBatch,
            7 => RejectCode::NotStreaming,
            8 => RejectCode::NotBatch,
            9 => RejectCode::ShapeMismatch,
            10 => RejectCode::EmptyBatch,
            _ => return None,
        })
    }
}

/// Which failure class a `Fault` reply carries: the wire form of
/// [`SelectError`](crate::coordinator::SelectError) plus a `Protocol`
/// class for codec/transport errors (after which the server closes the
/// connection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    PoisonedInput = 1,
    NumericalBreakdown = 2,
    ShardFailure = 3,
    PoolUnavailable = 4,
    Protocol = 5,
}

impl FaultKind {
    fn from_u8(v: u8) -> Option<FaultKind> {
        Some(match v {
            1 => FaultKind::PoisonedInput,
            2 => FaultKind::NumericalBreakdown,
            3 => FaultKind::ShardFailure,
            4 => FaultKind::PoolUnavailable,
            5 => FaultKind::Protocol,
            _ => return None,
        })
    }

    /// Classify a typed selection error for the wire.
    pub fn of(e: &crate::coordinator::SelectError) -> FaultKind {
        use crate::coordinator::SelectError::*;
        match e {
            PoisonedInput { .. } => FaultKind::PoisonedInput,
            NumericalBreakdown { .. } => FaultKind::NumericalBreakdown,
            ShardFailure { .. } => FaultKind::ShardFailure,
            PoolUnavailable => FaultKind::PoolUnavailable,
        }
    }
}

/// One protocol message, either direction.  See the
/// [module docs](self) for the frame table.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello { tenant: String, config: TenantConfig },
    SubmitBatch(WireBatch),
    PushChunk(WireBatch),
    GetSelection,
    Snapshot,
    Drain,
    Stats,
    Bye,
    HelloAck { session: u64, notes: Vec<String> },
    Ack { rows: u64 },
    Selection(WireSelection),
    SnapshotR(WireSnapshot),
    DrainAck(WireDrain),
    StatsR { json: String },
    Busy { active: u32, max: u32 },
    Rejected { code: RejectCode, detail: String },
    Fault { kind: FaultKind, detail: String },
    ByeAck,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

/// Frame writer: reserves the length prefix, appends scalars/arrays,
/// patches the prefix in [`Writer::finish`].
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(ty: u8) -> Writer {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&[0, 0, 0, 0]); // length prefix placeholder
        buf.push(PROTOCOL_VERSION);
        buf.push(ty);
        Writer { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f64s(&mut self, xs: &[f64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f64(x);
        }
    }

    fn i32s(&mut self, xs: &[i32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.i32(x);
        }
    }

    fn u64s(&mut self, xs: &[u64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u64(x);
        }
    }

    fn strs(&mut self, xs: &[String]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.str(x);
        }
    }

    fn decision(&mut self, d: &Option<WireDecision>) {
        match d {
            None => self.u8(0),
            Some(d) => {
                self.u8(1);
                self.u64(d.rank);
                self.f64(d.error);
                self.bool(d.satisfied);
            }
        }
    }

    fn batch(&mut self, b: &WireBatch) {
        self.u32(b.rows);
        self.u32(b.rcols);
        self.u32(b.ecols);
        self.u32(b.classes);
        self.f64s(&b.features);
        self.f64s(&b.grads);
        self.f64s(&b.losses);
        self.i32s(&b.labels);
        self.i32s(&b.preds);
        self.u64s(&b.row_ids);
    }

    fn finish(mut self) -> Vec<u8> {
        let len = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&len.to_le_bytes());
        self.buf
    }
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked cursor over one frame payload.  Every accessor returns
/// a typed error instead of panicking, and array accessors validate the
/// declared count against the bytes actually remaining before reserving
/// anything, so a hostile count cannot trigger an oversized allocation.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, field: &'static str) -> Result<&'a [u8], ProtoError> {
        if self.remaining() < n {
            return Err(ProtoError::Truncated { field });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, field: &'static str) -> Result<u8, ProtoError> {
        Ok(self.take(1, field)?[0])
    }

    fn bool(&mut self, field: &'static str) -> Result<bool, ProtoError> {
        match self.u8(field)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(ProtoError::Malformed { what: format!("{field}: bad bool byte {v}") }),
        }
    }

    fn u32(&mut self, field: &'static str) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4, field)?.try_into().unwrap()))
    }

    fn u64(&mut self, field: &'static str) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8, field)?.try_into().unwrap()))
    }

    fn f64(&mut self, field: &'static str) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(u64::from_le_bytes(self.take(8, field)?.try_into().unwrap())))
    }

    fn i32(&mut self, field: &'static str) -> Result<i32, ProtoError> {
        Ok(i32::from_le_bytes(self.take(4, field)?.try_into().unwrap()))
    }

    /// Validated element count for `size`-byte elements: the declared
    /// count must fit in the remaining bytes.
    fn count(&mut self, size: usize, field: &'static str) -> Result<usize, ProtoError> {
        let n = self.u32(field)? as usize;
        let need = n.checked_mul(size).ok_or(ProtoError::Truncated { field })?;
        if need > self.remaining() {
            return Err(ProtoError::Truncated { field });
        }
        Ok(n)
    }

    fn str(&mut self, field: &'static str) -> Result<String, ProtoError> {
        let n = self.count(1, field)?;
        let bytes = self.take(n, field)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtoError::Malformed { what: format!("{field}: invalid UTF-8") })
    }

    fn f64s(&mut self, field: &'static str) -> Result<Vec<f64>, ProtoError> {
        let n = self.count(8, field)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64(field)?);
        }
        Ok(out)
    }

    fn i32s(&mut self, field: &'static str) -> Result<Vec<i32>, ProtoError> {
        let n = self.count(4, field)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.i32(field)?);
        }
        Ok(out)
    }

    fn u64s(&mut self, field: &'static str) -> Result<Vec<u64>, ProtoError> {
        let n = self.count(8, field)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64(field)?);
        }
        Ok(out)
    }

    fn strs(&mut self, field: &'static str) -> Result<Vec<String>, ProtoError> {
        // Each entry carries at least its own u32 length.
        let n = self.count(4, field)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.str(field)?);
        }
        Ok(out)
    }

    fn decision(&mut self, field: &'static str) -> Result<Option<WireDecision>, ProtoError> {
        match self.u8(field)? {
            0 => Ok(None),
            1 => Ok(Some(WireDecision {
                rank: self.u64(field)?,
                error: self.f64(field)?,
                satisfied: self.bool(field)?,
            })),
            v => Err(ProtoError::Malformed { what: format!("{field}: bad option byte {v}") }),
        }
    }

    fn batch(&mut self) -> Result<WireBatch, ProtoError> {
        let rows = self.u32("batch.rows")?;
        let rcols = self.u32("batch.rcols")?;
        let ecols = self.u32("batch.ecols")?;
        let classes = self.u32("batch.classes")?;
        let b = WireBatch {
            rows,
            rcols,
            ecols,
            classes,
            features: self.f64s("batch.features")?,
            grads: self.f64s("batch.grads")?,
            losses: self.f64s("batch.losses")?,
            labels: self.i32s("batch.labels")?,
            preds: self.i32s("batch.preds")?,
            row_ids: self.u64s("batch.row_ids")?,
        };
        let (k, rc, ec) = (rows as usize, rcols as usize, ecols as usize);
        let khave = |name: &str, have: usize, want: usize| {
            if have == want {
                Ok(())
            } else {
                Err(ProtoError::Malformed {
                    what: format!(
                        "batch.{name}: {have} elements for {k} declared rows (want {want})"
                    ),
                })
            }
        };
        khave("features", b.features.len(), k.saturating_mul(rc))?;
        khave("grads", b.grads.len(), k.saturating_mul(ec))?;
        khave("losses", b.losses.len(), k)?;
        khave("labels", b.labels.len(), k)?;
        khave("preds", b.preds.len(), k)?;
        khave("row_ids", b.row_ids.len(), k)?;
        Ok(b)
    }

    fn finish(self) -> Result<(), ProtoError> {
        if self.remaining() > 0 {
            return Err(ProtoError::Malformed {
                what: format!("{} trailing byte(s) after message body", self.remaining()),
            });
        }
        Ok(())
    }
}

impl Msg {
    /// Encode into one complete frame (length prefix included), ready for
    /// a single `write_all`.
    pub fn encode(&self) -> Vec<u8> {
        let mut w;
        match self {
            Msg::Hello { tenant, config } => {
                w = Writer::new(1);
                w.str(tenant);
                w.str(&config.method);
                w.bool(config.streaming);
                w.u64(config.budget);
                w.f64(config.fraction);
                w.f64(config.epsilon);
                w.bool(config.adaptive);
                w.u64(config.seed);
                w.u32(config.shards);
                w.u32(config.workers);
                w.bool(config.overlap);
                match config.fault {
                    WireFaultPolicy::Fail => {
                        w.u8(0);
                        w.u32(0);
                        w.u32(0);
                    }
                    WireFaultPolicy::Retry { max, backoff_ms } => {
                        w.u8(1);
                        w.u32(max);
                        w.u32(backoff_ms);
                    }
                    WireFaultPolicy::Degrade => {
                        w.u8(2);
                        w.u32(0);
                        w.u32(0);
                    }
                }
                w.str(&config.extractor);
                w.str(&config.merge);
            }
            Msg::SubmitBatch(b) => {
                w = Writer::new(2);
                w.batch(b);
            }
            Msg::PushChunk(b) => {
                w = Writer::new(3);
                w.batch(b);
            }
            Msg::GetSelection => w = Writer::new(4),
            Msg::Snapshot => w = Writer::new(5),
            Msg::Drain => w = Writer::new(6),
            Msg::Stats => w = Writer::new(7),
            Msg::Bye => w = Writer::new(8),
            Msg::HelloAck { session, notes } => {
                w = Writer::new(64);
                w.u64(*session);
                w.strs(notes);
            }
            Msg::Ack { rows } => {
                w = Writer::new(65);
                w.u64(*rows);
            }
            Msg::Selection(s) => {
                w = Writer::new(66);
                w.u64(s.window);
                w.u64(s.budget);
                w.u64s(&s.indices);
                w.decision(&s.decision);
                w.strs(&s.degradations);
            }
            Msg::SnapshotR(s) => {
                w = Writer::new(67);
                w.u64(s.rows_seen);
                w.u64(s.reservoir_len);
                w.u64(s.budget);
                w.u64s(&s.indices);
                w.decision(&s.decision);
                w.strs(&s.degradations);
            }
            Msg::DrainAck(d) => {
                w = Writer::new(68);
                w.u64(d.windows);
                w.u64(d.rows);
                w.u64(d.respawns);
                w.u64(d.retries);
                w.u64(d.deadline_requeues);
                w.u64(d.join_timeouts);
                w.u64(d.quarantined_rows);
                w.u64(d.live_workers);
            }
            Msg::StatsR { json } => {
                w = Writer::new(69);
                w.str(json);
            }
            Msg::Busy { active, max } => {
                w = Writer::new(70);
                w.u32(*active);
                w.u32(*max);
            }
            Msg::Rejected { code, detail } => {
                w = Writer::new(71);
                w.u8(*code as u8);
                w.str(detail);
            }
            Msg::Fault { kind, detail } => {
                w = Writer::new(72);
                w.u8(*kind as u8);
                w.str(detail);
            }
            Msg::ByeAck => w = Writer::new(73),
        }
        w.finish()
    }

    /// Decode one frame payload (everything after the length prefix).
    pub fn decode(payload: &[u8]) -> Result<Msg, ProtoError> {
        if payload.is_empty() {
            return Err(ProtoError::EmptyFrame);
        }
        let version = payload[0];
        if version != PROTOCOL_VERSION {
            return Err(ProtoError::UnknownVersion { version });
        }
        if payload.len() < 2 {
            return Err(ProtoError::Truncated { field: "msg type" });
        }
        let ty = payload[1];
        let mut r = Reader::new(&payload[2..]);
        let msg = match ty {
            1 => {
                let tenant = r.str("hello.tenant")?;
                let method = r.str("hello.method")?;
                let streaming = r.bool("hello.streaming")?;
                let budget = r.u64("hello.budget")?;
                let fraction = r.f64("hello.fraction")?;
                let epsilon = r.f64("hello.epsilon")?;
                let adaptive = r.bool("hello.adaptive")?;
                let seed = r.u64("hello.seed")?;
                let shards = r.u32("hello.shards")?;
                let workers = r.u32("hello.workers")?;
                let overlap = r.bool("hello.overlap")?;
                let fkind = r.u8("hello.fault")?;
                let fmax = r.u32("hello.fault.max")?;
                let fbackoff = r.u32("hello.fault.backoff_ms")?;
                let fault = match fkind {
                    0 => WireFaultPolicy::Fail,
                    1 => WireFaultPolicy::Retry { max: fmax, backoff_ms: fbackoff },
                    2 => WireFaultPolicy::Degrade,
                    v => {
                        return Err(ProtoError::Malformed {
                            what: format!("hello.fault: bad policy byte {v}"),
                        })
                    }
                };
                let extractor = r.str("hello.extractor")?;
                let merge = r.str("hello.merge")?;
                Msg::Hello {
                    tenant,
                    config: TenantConfig {
                        method,
                        streaming,
                        budget,
                        fraction,
                        epsilon,
                        adaptive,
                        seed,
                        shards,
                        workers,
                        overlap,
                        fault,
                        extractor,
                        merge,
                    },
                }
            }
            2 => Msg::SubmitBatch(r.batch()?),
            3 => Msg::PushChunk(r.batch()?),
            4 => Msg::GetSelection,
            5 => Msg::Snapshot,
            6 => Msg::Drain,
            7 => Msg::Stats,
            8 => Msg::Bye,
            64 => Msg::HelloAck {
                session: r.u64("helloack.session")?,
                notes: r.strs("helloack.notes")?,
            },
            65 => Msg::Ack { rows: r.u64("ack.rows")? },
            66 => Msg::Selection(WireSelection {
                window: r.u64("selection.window")?,
                budget: r.u64("selection.budget")?,
                indices: r.u64s("selection.indices")?,
                decision: r.decision("selection.decision")?,
                degradations: r.strs("selection.degradations")?,
            }),
            67 => Msg::SnapshotR(WireSnapshot {
                rows_seen: r.u64("snapshot.rows_seen")?,
                reservoir_len: r.u64("snapshot.reservoir_len")?,
                budget: r.u64("snapshot.budget")?,
                indices: r.u64s("snapshot.indices")?,
                decision: r.decision("snapshot.decision")?,
                degradations: r.strs("snapshot.degradations")?,
            }),
            68 => Msg::DrainAck(WireDrain {
                windows: r.u64("drain.windows")?,
                rows: r.u64("drain.rows")?,
                respawns: r.u64("drain.respawns")?,
                retries: r.u64("drain.retries")?,
                deadline_requeues: r.u64("drain.deadline_requeues")?,
                join_timeouts: r.u64("drain.join_timeouts")?,
                quarantined_rows: r.u64("drain.quarantined_rows")?,
                live_workers: r.u64("drain.live_workers")?,
            }),
            69 => Msg::StatsR { json: r.str("stats.json")? },
            70 => Msg::Busy { active: r.u32("busy.active")?, max: r.u32("busy.max")? },
            71 => {
                let raw = r.u8("rejected.code")?;
                let code = RejectCode::from_u8(raw).ok_or_else(|| ProtoError::Malformed {
                    what: format!("rejected.code: unknown code {raw}"),
                })?;
                Msg::Rejected { code, detail: r.str("rejected.detail")? }
            }
            72 => {
                let raw = r.u8("fault.kind")?;
                let kind = FaultKind::from_u8(raw).ok_or_else(|| ProtoError::Malformed {
                    what: format!("fault.kind: unknown kind {raw}"),
                })?;
                Msg::Fault { kind, detail: r.str("fault.detail")? }
            }
            73 => Msg::ByeAck,
            ty => return Err(ProtoError::UnknownMsgType { ty }),
        };
        r.finish()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Outcome of one framed read attempt against a socket with a read
/// timeout installed (the session's poll tick).
pub enum FrameRead {
    /// One complete payload (version byte onward).
    Frame(Vec<u8>),
    /// Clean EOF at a frame boundary.
    Eof,
    /// Read timeout with no frame in progress — the caller decides
    /// whether to keep waiting (idle client) or shut down.
    Idle,
}

/// Read one length-prefixed frame.  `max` bounds the declared payload
/// length (checked before the body is read).  A read timeout at a frame
/// boundary returns [`FrameRead::Idle`]; once any byte of a frame has
/// arrived, up to `stall_ticks` consecutive timeouts are tolerated
/// (resetting on progress) before the peer is declared stalled — so a
/// slow-but-live client can trickle a large frame in, while a dead one
/// cannot wedge the session forever.
pub fn read_frame(
    r: &mut impl Read,
    max: usize,
    stall_ticks: u32,
) -> Result<FrameRead, ProtoError> {
    let mut hdr = [0u8; 4];
    let got = match read_exact_ticking(r, &mut hdr, 0, stall_ticks)? {
        ReadOutcome::Done => 4,
        ReadOutcome::Eof { got: 0 } => return Ok(FrameRead::Eof),
        ReadOutcome::Eof { got } => return Err(ProtoError::MidFrameEof { got, want: 4 }),
        ReadOutcome::Idle => return Ok(FrameRead::Idle),
        ReadOutcome::Stalled { got } => return Err(ProtoError::Stalled { got, want: 4 }),
    };
    debug_assert_eq!(got, 4);
    let len = u32::from_le_bytes(hdr) as usize;
    if len == 0 {
        return Err(ProtoError::EmptyFrame);
    }
    if len > max {
        return Err(ProtoError::FrameTooLarge { len, max });
    }
    let mut buf = vec![0u8; len];
    match read_exact_ticking(r, &mut buf, 4, stall_ticks)? {
        ReadOutcome::Done => Ok(FrameRead::Frame(buf)),
        ReadOutcome::Eof { got } => Err(ProtoError::MidFrameEof { got, want: len + 4 }),
        // The header already arrived, so a timeout here is always
        // mid-frame: both outcomes are a stall.
        ReadOutcome::Idle | ReadOutcome::Stalled { .. } => {
            Err(ProtoError::Stalled { got: 4, want: len + 4 })
        }
    }
}

/// Encode and send one message as a single frame (write + flush).
pub fn write_msg(w: &mut impl io::Write, msg: &Msg) -> io::Result<()> {
    w.write_all(&msg.encode())?;
    w.flush()
}

enum ReadOutcome {
    Done,
    /// Connection closed with `got` bytes of this read (plus `base`)
    /// already consumed.
    Eof { got: usize },
    /// Timed out before the first byte of this read.
    Idle,
    /// Timed out `stall_ticks` times in a row mid-read.
    Stalled { got: usize },
}

/// `read_exact` with timeout ticks: timeouts before the first byte are
/// `Idle`; after progress has been made, consecutive timeouts count
/// against `stall_ticks`.  `base` offsets the byte counts in outcomes so
/// errors report positions within the whole frame.
fn read_exact_ticking(
    r: &mut impl Read,
    buf: &mut [u8],
    base: usize,
    stall_ticks: u32,
) -> Result<ReadOutcome, ProtoError> {
    let mut got = 0usize;
    let mut idle_ticks = 0u32;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return Ok(ReadOutcome::Eof { got: base + got }),
            Ok(n) => {
                got += n;
                idle_ticks = 0;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if got == 0 && base == 0 {
                    return Ok(ReadOutcome::Idle);
                }
                idle_ticks += 1;
                if idle_ticks >= stall_ticks {
                    return Ok(ReadOutcome::Stalled { got: base + got });
                }
            }
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    Ok(ReadOutcome::Done)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let frame = msg.encode();
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4, "length prefix covers the payload");
        let back = Msg::decode(&frame[4..]).expect("decode");
        assert_eq!(back, msg);
    }

    fn sample_batch() -> WireBatch {
        WireBatch {
            rows: 2,
            rcols: 3,
            ecols: 2,
            classes: 4,
            features: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            grads: vec![0.1, 0.2, 0.3, 0.4],
            losses: vec![0.5, 0.25],
            labels: vec![1, -2],
            preds: vec![0, 3],
            row_ids: vec![10, 11],
        }
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Msg::Hello {
            tenant: "job-a".into(),
            config: TenantConfig {
                streaming: true,
                budget: 8,
                adaptive: true,
                fault: WireFaultPolicy::Retry { max: 3, backoff_ms: 5 },
                extractor: "svd".into(),
                merge: "grad".into(),
                ..TenantConfig::default()
            },
        });
        roundtrip(Msg::SubmitBatch(sample_batch()));
        roundtrip(Msg::PushChunk(sample_batch()));
        roundtrip(Msg::GetSelection);
        roundtrip(Msg::Snapshot);
        roundtrip(Msg::Drain);
        roundtrip(Msg::Stats);
        roundtrip(Msg::Bye);
        roundtrip(Msg::HelloAck { session: 7, notes: vec!["n1".into(), "n2".into()] });
        roundtrip(Msg::Ack { rows: 42 });
        roundtrip(Msg::Selection(WireSelection {
            window: 3,
            budget: 4,
            indices: vec![5, 1, 2, 9],
            decision: Some(WireDecision { rank: 4, error: 0.125, satisfied: true }),
            degradations: vec![],
        }));
        roundtrip(Msg::SnapshotR(WireSnapshot {
            rows_seen: 100,
            reservoir_len: 16,
            budget: 8,
            indices: vec![90, 3],
            decision: None,
            degradations: vec!["quarantined 1 poisoned row(s) [4]".into()],
        }));
        roundtrip(Msg::DrainAck(WireDrain {
            windows: 5,
            rows: 320,
            respawns: 1,
            retries: 2,
            ..WireDrain::default()
        }));
        roundtrip(Msg::StatsR { json: "{\"schema\":\"graft-bench-v1\",\"records\":[]}".into() });
        roundtrip(Msg::Busy { active: 64, max: 64 });
        roundtrip(Msg::Rejected { code: RejectCode::DuplicateTenant, detail: "tenant 'x'".into() });
        roundtrip(Msg::Fault { kind: FaultKind::NumericalBreakdown, detail: "pivot".into() });
        roundtrip(Msg::ByeAck);
    }

    #[test]
    fn unknown_version_and_type_are_typed() {
        let mut frame = Msg::GetSelection.encode();
        frame[4] = 9; // version byte
        assert!(matches!(
            Msg::decode(&frame[4..]),
            Err(ProtoError::UnknownVersion { version: 9 })
        ));
        let mut frame = Msg::GetSelection.encode();
        frame[5] = 200; // type byte
        assert!(matches!(
            Msg::decode(&frame[4..]),
            Err(ProtoError::UnknownMsgType { ty: 200 })
        ));
    }

    #[test]
    fn every_truncation_of_every_message_is_a_typed_error() {
        let msgs = [
            Msg::Hello { tenant: "t".into(), config: TenantConfig::default() },
            Msg::SubmitBatch(sample_batch()),
            Msg::Selection(WireSelection {
                window: 0,
                budget: 2,
                indices: vec![1, 0],
                decision: Some(WireDecision { rank: 2, error: 0.5, satisfied: false }),
                degradations: vec!["d".into()],
            }),
            Msg::HelloAck { session: 1, notes: vec!["abc".into()] },
            Msg::StatsR { json: "{}".into() },
        ];
        for msg in msgs {
            let frame = msg.encode();
            let payload = &frame[4..];
            // Full payload decodes; every proper prefix errors, never panics.
            assert!(Msg::decode(payload).is_ok());
            for cut in 0..payload.len() {
                assert!(Msg::decode(&payload[..cut]).is_err(), "prefix {cut} must error");
            }
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut frame = Msg::Ack { rows: 1 }.encode();
        frame.push(0xAB);
        assert!(matches!(Msg::decode(&frame[4..]), Err(ProtoError::Malformed { .. })));
    }

    #[test]
    fn hostile_counts_cannot_allocate() {
        // A Selection frame claiming u32::MAX indices in a tiny body must
        // fail the count-vs-remaining check, not reserve 32 GiB.
        let mut w = Writer::new(66);
        w.u64(0); // window
        w.u64(4); // budget
        w.u32(u32::MAX); // indices count — lies
        let frame = w.finish();
        assert!(matches!(Msg::decode(&frame[4..]), Err(ProtoError::Truncated { .. })));
    }

    #[test]
    fn batch_row_consistency_is_checked() {
        let mut b = sample_batch();
        b.losses.pop(); // 1 loss for 2 declared rows
        let frame = Msg::SubmitBatch(b).encode();
        assert!(matches!(Msg::decode(&frame[4..]), Err(ProtoError::Malformed { .. })));
    }

    #[test]
    fn fuzzed_payloads_never_panic() {
        let mut rng = crate::rng::Rng::new(0xF22);
        for _ in 0..2000 {
            let n = rng.below(96);
            let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let _ = Msg::decode(&payload); // any Result is fine; a panic fails the test
        }
        // Structured fuzz: valid header, random body.
        for _ in 0..2000 {
            let n = rng.below(64);
            let mut payload = vec![PROTOCOL_VERSION, (rng.next_u64() % 80) as u8];
            payload.extend((0..n).map(|_| rng.next_u64() as u8));
            let _ = Msg::decode(&payload);
        }
    }

    #[test]
    fn read_frame_reads_from_a_byte_stream() {
        let frame = Msg::Ack { rows: 9 }.encode();
        let mut stream: &[u8] = &frame;
        match read_frame(&mut stream, DEFAULT_MAX_FRAME, 4).unwrap() {
            FrameRead::Frame(p) => assert_eq!(Msg::decode(&p).unwrap(), Msg::Ack { rows: 9 }),
            _ => panic!("expected a frame"),
        }
        match read_frame(&mut stream, DEFAULT_MAX_FRAME, 4).unwrap() {
            FrameRead::Eof => {}
            _ => panic!("expected clean EOF"),
        }
    }

    #[test]
    fn oversized_prefix_rejected_before_body() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut stream: &[u8] = &bytes;
        assert!(matches!(
            read_frame(&mut stream, 1024, 4),
            Err(ProtoError::FrameTooLarge { len, max: 1024 }) if len == u32::MAX as usize
        ));
    }

    #[test]
    fn mid_frame_eof_is_typed() {
        let frame = Msg::Ack { rows: 9 }.encode();
        let mut stream: &[u8] = &frame[..frame.len() - 3];
        assert!(matches!(
            read_frame(&mut stream, DEFAULT_MAX_FRAME, 4),
            Err(ProtoError::MidFrameEof { .. })
        ));
        // EOF inside the header itself.
        let mut stream: &[u8] = &frame[..2];
        assert!(matches!(
            read_frame(&mut stream, DEFAULT_MAX_FRAME, 4),
            Err(ProtoError::MidFrameEof { got: 2, want: 4 })
        ));
    }
}

//! Selection as a service — the `graft serve` daemon.
//!
//! Hosts N tenant [`SelectionEngine`](crate::engine::SelectionEngine)s /
//! [`StreamingEngine`](crate::engine::StreamingEngine)s behind a
//! versioned, length-prefixed binary protocol (see [`protocol`] for the
//! frame table) over TCP or Unix sockets, so many concurrent training
//! jobs share one selection backend instead of each linking the crate.
//!
//! # Tenant lifecycle
//!
//! One connection is one tenant session:
//!
//! 1. `Hello { tenant, config }` — the name is claimed in the daemon-wide
//!    registry (`Rejected { DuplicateTenant }` while another session
//!    holds it) and the config is validated by the in-process
//!    [`EngineBuilder`](crate::engine::EngineBuilder) via
//!    [`engine_builder`] — bad budgets/fractions/shapes come back as
//!    `Rejected { BadHello }` naming the offending field.
//! 2. Batch tenants loop `SubmitBatch` → `GetSelection`; streaming
//!    tenants loop `PushChunk` and call `Snapshot` whenever a selection
//!    is wanted.  `Drain` drops any pending window and reports progress +
//!    fault counters; `Stats` (allowed on any connection, any time)
//!    returns daemon-wide telemetry as a graft-bench-v1 JSON document.
//! 3. `Bye` — or simply disconnecting — tears the tenant down: the
//!    engine is shut down with the pool's drop-senders-then-join drain
//!    idiom, the name is released, and accumulated telemetry stays in
//!    the stats registry.
//!
//! Served selections are **bit-identical** to an in-process engine built
//! from the same [`TenantConfig`](protocol::TenantConfig) — both sides
//! construct through [`engine_builder`], and the engines are fully
//! deterministic given (config, seed, data).  `rust/tests/serve_loopback.rs`
//! pins this for concurrent mixed batch/streaming tenants, across
//! disconnects and injected worker faults.
//!
//! # Backpressure & admission control
//!
//! The daemon never queues unboundedly; pressure surfaces as typed
//! replies instead:
//!
//! * **Admission:** at most `max_sessions` concurrent connections; the
//!   daemon answers `Busy { active, max }` and closes rather than
//!   accepting work it cannot host.
//! * **Per-session:** one window in flight — a second `SubmitBatch`
//!   before `GetSelection` is `Rejected { PendingSelection }`.  Inside a
//!   tenant the engine's own bounded pool channels hold (PR 3).
//! * **Frames:** payloads above the configured cap are refused *before*
//!   the body is read (`FrameTooLarge`), and a peer that stalls
//!   mid-frame past the stall budget is disconnected, so a dead client
//!   cannot pin a session slot forever.
//!
//! Selection faults ([`SelectError`](crate::engine::SelectError)) are
//! reported per-request as `Fault` replies and leave the session usable;
//! protocol errors (malformed/truncated/oversized frames, unknown
//! versions) get a best-effort `Fault { Protocol }` reply and close only
//! the offending connection — never anyone else's.
//!
//! # Loopback quickstart
//!
//! ```
//! use graft::serve::{Client, ServerBuilder};
//! use graft::serve::protocol::TenantConfig;
//! # use graft::linalg::Mat;
//! # use graft::selection::BatchView;
//! // A daemon on an OS-assigned loopback port.
//! let mut server = ServerBuilder::new().bind_tcp("127.0.0.1:0").expect("bind");
//! let addr = server.local_addr().expect("tcp addr").to_string();
//!
//! // A tenant: explicit 3-row budget, default GRAFT method and seed.
//! let mut client = Client::connect_tcp(&addr).expect("connect");
//! let config = TenantConfig { budget: 3, ..TenantConfig::default() };
//! client.hello("quickstart", &config).expect("admitted");
//!
//! # let k = 8;
//! # let mut rng = graft::rng::Rng::new(7);
//! # let features = Mat::from_fn(k, 3, |_, _| rng.normal());
//! # let grads = Mat::from_fn(k, 4, |_, _| rng.normal());
//! # let losses = vec![1.0; k];
//! # let labels = vec![0i32; k];
//! # let preds = vec![0i32; k];
//! # let row_ids: Vec<usize> = (0..k).collect();
//! # let batch = BatchView { features: &features, grads: &grads, losses: &losses,
//! #     labels: &labels, preds: &preds, classes: 2, row_ids: &row_ids };
//! // One window: submit + select.  Bit-identical to an in-process
//! // engine built via graft::serve::engine_builder(&config).
//! let sel = client.select(&batch).expect("selection");
//! assert_eq!(sel.indices.len(), 3);
//!
//! client.bye().expect("clean goodbye");
//! server.shutdown();
//! ```

pub mod client;
pub mod protocol;
pub mod tenant;

mod session;

pub use client::{Client, ClientError};
pub use tenant::{engine_builder, valid_tenant_name};

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::faults::FaultInjector;

use protocol::{write_msg, Msg, DEFAULT_MAX_FRAME};
use tenant::StatsRegistry;

/// Daemon tuning knobs (all bounded-by-construction; see the
/// [module docs](self) for the backpressure story).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Admission bound: connections above this get `Busy` + close.
    pub max_sessions: usize,
    /// Frame payload cap in bytes (checked against the length prefix
    /// before the body is read).
    pub max_frame: usize,
    /// Socket read-poll tick: how often an idle session checks for
    /// daemon shutdown.
    pub read_tick: Duration,
    /// Consecutive mid-frame timeout ticks before a peer is declared
    /// stalled and disconnected (`read_tick × stall_ticks` ≈ the stall
    /// budget).
    pub stall_ticks: u32,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            max_sessions: 64,
            max_frame: DEFAULT_MAX_FRAME,
            read_tick: Duration::from_millis(50),
            stall_ticks: 200, // × 50 ms tick = 10 s stall budget
        }
    }
}

/// Lock that survives a poisoned mutex: a panicking session must never
/// take the registry (and with it every other tenant) down with it.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One transport connection, TCP or Unix.  Cloned handles registered in
/// [`Sessions`] let the daemon unblock every session at shutdown.
pub(crate) enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn try_clone(&self) -> io::Result<Conn> {
        Ok(match self {
            Conn::Tcp(s) => Conn::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Conn::Unix(s) => Conn::Unix(s.try_clone()?),
        })
    }

    pub(crate) fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn set_write_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_write_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_write_timeout(d),
        }
    }

    /// Half-close both directions, unblocking any read the session is
    /// parked in.  Best-effort: the peer may already be gone.
    fn shutdown_both(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true); // request/response traffic
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Ok(Conn::Unix(s))
            }
        }
    }
}

/// Where the daemon listens — retained so shutdown can dial itself to
/// wake the blocking accept loop.
enum Endpoint {
    Tcp(SocketAddr),
    #[cfg(unix)]
    Unix(PathBuf),
}

impl Endpoint {
    fn wake(&self) {
        match self {
            Endpoint::Tcp(addr) => {
                let _ = TcpStream::connect(addr);
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let _ = UnixStream::connect(path);
            }
        }
    }
}

/// Live-session registry: claimed tenant names (name → session id) and
/// a cloned connection handle per session for shutdown fan-out.
#[derive(Default)]
pub(crate) struct Sessions {
    pub tenants: HashMap<String, u64>,
    pub conns: Vec<(u64, Conn)>,
}

/// State shared by the accept loop and every session thread.
pub(crate) struct Shared {
    pub opts: ServeOptions,
    /// Deterministic fault injection, threaded into every batch tenant's
    /// engine at `Hello` (tests/benches only; `None` in production).
    pub injector: Option<Arc<dyn FaultInjector>>,
    pub shutting_down: AtomicBool,
    pub sessions: Mutex<Sessions>,
    pub stats: Mutex<StatsRegistry>,
    next_session: AtomicU64,
}

/// Configure and bind a [`Server`].
pub struct ServerBuilder {
    opts: ServeOptions,
    injector: Option<Arc<dyn FaultInjector>>,
}

impl Default for ServerBuilder {
    fn default() -> ServerBuilder {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder { opts: ServeOptions::default(), injector: None }
    }

    /// Replace the default [`ServeOptions`].
    pub fn options(mut self, opts: ServeOptions) -> ServerBuilder {
        self.opts = opts;
        self
    }

    /// Install a deterministic fault injector on every batch tenant's
    /// engine (the loopback fault suites drive worker panics through the
    /// served path with this; see [`crate::faults::FaultPlan`]).
    pub fn fault_injector(mut self, injector: Arc<dyn FaultInjector>) -> ServerBuilder {
        self.injector = Some(injector);
        self
    }

    /// Bind a TCP endpoint (use port 0 for an OS-assigned loopback port,
    /// then read it back with [`Server::local_addr`]) and start serving.
    pub fn bind_tcp(self, addr: &str) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        Ok(self.start(Listener::Tcp(listener), Endpoint::Tcp(local), Some(local)))
    }

    /// Bind a Unix-domain socket (any stale file at `path` is replaced)
    /// and start serving.
    #[cfg(unix)]
    pub fn bind_unix(self, path: impl AsRef<Path>) -> io::Result<Server> {
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        Ok(self.start(Listener::Unix(listener), Endpoint::Unix(path), None))
    }

    fn start(self, listener: Listener, endpoint: Endpoint, local: Option<SocketAddr>) -> Server {
        let shared = Arc::new(Shared {
            opts: self.opts,
            injector: self.injector,
            shutting_down: AtomicBool::new(false),
            sessions: Mutex::new(Sessions::default()),
            stats: Mutex::new(StatsRegistry::default()),
            next_session: AtomicU64::new(1),
        });
        let workers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let workers = Arc::clone(&workers);
            std::thread::spawn(move || accept_loop(listener, shared, workers))
        };
        Server { local, endpoint, shared, workers, accept: Some(accept), done: false }
    }
}

fn accept_loop(
    listener: Listener,
    shared: Arc<Shared>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let conn = match listener.accept() {
            Ok(c) => c,
            Err(_) if shared.shutting_down.load(Ordering::SeqCst) => break,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.shutting_down.load(Ordering::SeqCst) {
            break; // the self-dial (or a late arrival) during shutdown
        }
        // Reap finished session threads so a long-lived daemon's handle
        // list stays proportional to live sessions.
        {
            let mut ws = lock(&workers);
            let mut live = Vec::with_capacity(ws.len());
            for h in ws.drain(..) {
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    live.push(h);
                }
            }
            *ws = live;
        }
        // Admission control: claim a slot or answer Busy and close.
        let admitted = {
            let mut s = lock(&shared.sessions);
            if s.conns.len() >= shared.opts.max_sessions {
                Err(s.conns.len())
            } else {
                let id = shared.next_session.fetch_add(1, Ordering::SeqCst);
                match conn.try_clone() {
                    Ok(clone) => {
                        s.conns.push((id, clone));
                        Ok(id)
                    }
                    Err(_) => Err(s.conns.len()),
                }
            }
        };
        match admitted {
            Ok(id) => {
                let shared = Arc::clone(&shared);
                let mut conn = conn;
                let h = std::thread::spawn(move || session::run(&mut conn, shared, id));
                lock(&workers).push(h);
            }
            Err(active) => {
                let mut conn = conn;
                let _ = conn.set_write_timeout(Some(Duration::from_secs(1)));
                let _ = write_msg(
                    &mut conn,
                    &Msg::Busy {
                        active: active as u32,
                        max: shared.opts.max_sessions as u32,
                    },
                );
                // Dropped: the peer sees Busy then EOF.
            }
        }
    }
}

/// A running daemon.  Dropping it shuts it down (idempotent; also
/// available explicitly as [`Server::shutdown`]).
pub struct Server {
    local: Option<SocketAddr>,
    endpoint: Endpoint,
    shared: Arc<Shared>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    accept: Option<JoinHandle<()>>,
    done: bool,
}

impl Server {
    /// The bound TCP address (`None` for Unix-socket servers) — how
    /// callers of `bind_tcp("127.0.0.1:0")` learn their port.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.local
    }

    /// Live session count (admission-relevant connections).
    pub fn active_sessions(&self) -> usize {
        lock(&self.shared.sessions).conns.len()
    }

    /// The daemon-wide telemetry document (graft-bench-v1 JSON) — the
    /// same bytes a `Stats` request returns over the wire.
    pub fn stats_json(&self) -> String {
        lock(&self.shared.stats).to_bench_json()
    }

    /// Stop accepting, unblock and drain every session (each tenant's
    /// engine shuts down through the pool's drop-senders-then-join
    /// idiom), and join all daemon threads.  Idempotent.
    pub fn shutdown(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        self.endpoint.wake();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Unblock sessions parked in reads; idle ones also notice the
        // flag at their next tick.
        for (_, conn) in lock(&self.shared.sessions).conns.iter() {
            conn.shutdown_both();
        }
        let handles: Vec<JoinHandle<()>> = lock(&self.workers).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        #[cfg(unix)]
        if let Endpoint::Unix(path) = &self.endpoint {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

//! One daemon session: the per-connection message loop and tenant state
//! machine.  See the [module docs](super) for lifecycle and backpressure
//! semantics.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::protocol::{
    read_frame, write_msg, FaultKind, FrameRead, Msg, RejectCode, WireDecision, WireDrain,
    WireSelection, WireSnapshot,
};
use super::tenant::{window_from_wire, EngineKind, Tenant};
use super::{lock, Conn, Shared};

/// What the loop does after sending a reply.
enum Action {
    Continue,
    Close,
}

fn rejected(code: RejectCode, detail: impl Into<String>) -> (Msg, Action) {
    (Msg::Rejected { code, detail: detail.into() }, Action::Continue)
}

pub(crate) fn run(conn: &mut Conn, shared: Arc<Shared>, session_id: u64) {
    let _ = conn.set_read_timeout(Some(shared.opts.read_tick));
    let mut tenant: Option<Tenant> = None;
    loop {
        let payload = match read_frame(conn, shared.opts.max_frame, shared.opts.stall_ticks) {
            Ok(FrameRead::Idle) => {
                if shared.shutting_down.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Ok(FrameRead::Eof) => break,
            Ok(FrameRead::Frame(p)) => p,
            Err(e) => {
                // Typed protocol failure: best-effort Fault reply, then
                // close this connection only.
                let _ = write_msg(
                    conn,
                    &Msg::Fault { kind: FaultKind::Protocol, detail: e.to_string() },
                );
                break;
            }
        };
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                let _ = write_msg(
                    conn,
                    &Msg::Fault { kind: FaultKind::Protocol, detail: e.to_string() },
                );
                break;
            }
        };
        let (reply, action) = handle(&mut tenant, msg, &shared, session_id);
        if write_msg(conn, &reply).is_err() {
            break;
        }
        if matches!(action, Action::Close) {
            break;
        }
    }
    // Drain-on-disconnect: shut the tenant engine down (pool
    // drop-senders-then-join), release the name, deregister the session.
    // Telemetry stays in the registry under the tenant's name.
    if let Some(mut t) = tenant.take() {
        t.shutdown();
        lock(&shared.sessions).tenants.remove(&t.name);
    }
    lock(&shared.sessions).conns.retain(|(id, _)| *id != session_id);
}

fn handle(
    tenant: &mut Option<Tenant>,
    msg: Msg,
    shared: &Shared,
    session_id: u64,
) -> (Msg, Action) {
    match msg {
        Msg::Hello { tenant: name, config } => {
            if tenant.is_some() {
                return rejected(RejectCode::AlreadyHello, "session already has a tenant");
            }
            if !super::valid_tenant_name(&name) {
                return rejected(
                    RejectCode::BadHello,
                    format!("tenant name {name:?} must match [A-Za-z0-9_.-]{{1,64}}"),
                );
            }
            // Claim the name first (short critical section), build the
            // engine outside the lock, release the claim on failure.
            {
                let mut s = lock(&shared.sessions);
                if s.tenants.contains_key(&name) {
                    return rejected(
                        RejectCode::DuplicateTenant,
                        format!("tenant '{name}' already has a live session"),
                    );
                }
                s.tenants.insert(name.clone(), session_id);
            }
            match Tenant::build(&name, &config, shared.injector.clone()) {
                Ok(t) => {
                    lock(&shared.stats).entry(&name, config.streaming);
                    let notes = t.notes();
                    *tenant = Some(t);
                    (Msg::HelloAck { session: session_id, notes }, Action::Continue)
                }
                Err(detail) => {
                    lock(&shared.sessions).tenants.remove(&name);
                    rejected(RejectCode::BadHello, detail)
                }
            }
        }

        Msg::SubmitBatch(batch) => {
            let Some(t) = tenant.as_mut() else {
                return rejected(RejectCode::NeedHello, "SubmitBatch before Hello");
            };
            let EngineKind::Batch { pending, .. } = &mut t.kind else {
                return rejected(RejectCode::NotBatch, "streaming tenants push chunks");
            };
            if pending.is_some() {
                return rejected(
                    RejectCode::PendingSelection,
                    "a window is already pending; GetSelection first",
                );
            }
            if batch.rows == 0 {
                return rejected(RejectCode::EmptyBatch, "zero-row batch");
            }
            let rows = batch.rows as u64;
            *pending = Some(window_from_wire(&batch));
            t.rows += rows;
            (Msg::Ack { rows }, Action::Continue)
        }

        Msg::GetSelection => {
            let Some(t) = tenant.as_mut() else {
                return rejected(RejectCode::NeedHello, "GetSelection before Hello");
            };
            let EngineKind::Batch { eng, pending } = &mut t.kind else {
                return rejected(RejectCode::NotBatch, "streaming tenants take snapshots");
            };
            let Some(win) = pending.take() else {
                return rejected(RejectCode::NoPendingBatch, "no window pending");
            };
            let t0 = Instant::now();
            let result = eng.select(&win.view());
            let ns = t0.elapsed().as_nanos() as f64;
            let reply = match result {
                Ok(sel) => {
                    t.windows += 1;
                    Msg::Selection(WireSelection {
                        window: sel.window,
                        budget: sel.budget as u64,
                        indices: sel.indices.iter().map(|&i| i as u64).collect(),
                        decision: sel.decision.map(|d| WireDecision {
                            rank: d.rank as u64,
                            error: d.error,
                            satisfied: d.satisfied,
                        }),
                        degradations: sel.degradations.iter().map(|d| d.to_string()).collect(),
                    })
                }
                Err(e) => Msg::Fault { kind: FaultKind::of(&e), detail: e.to_string() },
            };
            let faulted = matches!(reply, Msg::Fault { .. });
            {
                let mut reg = lock(&shared.stats);
                let e = reg.entry(&t.name, false);
                e.select.push(ns);
                e.windows = t.windows;
                e.rows = t.rows;
                if faulted {
                    e.faults += 1;
                }
            }
            (reply, Action::Continue)
        }

        Msg::PushChunk(batch) => {
            let Some(t) = tenant.as_mut() else {
                return rejected(RejectCode::NeedHello, "PushChunk before Hello");
            };
            let EngineKind::Stream { eng, dims } = &mut t.kind else {
                return rejected(RejectCode::NotStreaming, "batch tenants submit windows");
            };
            if batch.rows == 0 {
                return rejected(RejectCode::EmptyBatch, "zero-row chunk");
            }
            if let Some((rc, ec)) = *dims {
                if rc != batch.rcols || ec != batch.ecols {
                    return rejected(
                        RejectCode::ShapeMismatch,
                        format!(
                            "chunk widths {}/{} (features/sketch) differ from the stream's {}/{}",
                            batch.rcols, batch.ecols, rc, ec
                        ),
                    );
                }
            }
            let win = window_from_wire(&batch);
            let rows = batch.rows as u64;
            let t0 = Instant::now();
            let result = eng.push(&win.view());
            let ns = t0.elapsed().as_nanos() as f64;
            let reply = match result {
                Ok(()) => {
                    *dims = Some((batch.rcols, batch.ecols));
                    t.rows += rows;
                    Msg::Ack { rows }
                }
                Err(e) => Msg::Fault { kind: FaultKind::of(&e), detail: e.to_string() },
            };
            let faulted = matches!(reply, Msg::Fault { .. });
            {
                let mut reg = lock(&shared.stats);
                let e = reg.entry(&t.name, true);
                e.push.push(ns);
                e.rows = t.rows;
                if faulted {
                    e.faults += 1;
                }
            }
            (reply, Action::Continue)
        }

        Msg::Snapshot => {
            let Some(t) = tenant.as_mut() else {
                return rejected(RejectCode::NeedHello, "Snapshot before Hello");
            };
            let EngineKind::Stream { eng, .. } = &mut t.kind else {
                return rejected(RejectCode::NotStreaming, "batch tenants get selections");
            };
            let t0 = Instant::now();
            let result = eng.snapshot();
            let ns = t0.elapsed().as_nanos() as f64;
            let reply = match result {
                Ok(snap) => {
                    t.windows += 1;
                    Msg::SnapshotR(WireSnapshot {
                        rows_seen: snap.rows_seen,
                        reservoir_len: snap.reservoir_len as u64,
                        budget: snap.budget as u64,
                        indices: snap.indices.iter().map(|&i| i as u64).collect(),
                        decision: snap.decision.map(|d| WireDecision {
                            rank: d.rank as u64,
                            error: d.error,
                            satisfied: d.satisfied,
                        }),
                        degradations: snap.degradations.iter().map(|d| d.to_string()).collect(),
                    })
                }
                Err(e) => Msg::Fault { kind: FaultKind::of(&e), detail: e.to_string() },
            };
            let faulted = matches!(reply, Msg::Fault { .. });
            {
                let mut reg = lock(&shared.stats);
                let e = reg.entry(&t.name, true);
                e.snapshot.push(ns);
                e.windows = t.windows;
                if faulted {
                    e.faults += 1;
                }
            }
            (reply, Action::Continue)
        }

        Msg::Drain => {
            let Some(t) = tenant.as_mut() else {
                return rejected(RejectCode::NeedHello, "Drain before Hello");
            };
            let mut d = WireDrain { windows: t.windows, rows: t.rows, ..WireDrain::default() };
            match &mut t.kind {
                EngineKind::Batch { eng, pending } => {
                    // Quiesce: an un-selected window is dropped, reported
                    // implicitly by rows-vs-windows; the engine stays live.
                    *pending = None;
                    let s = eng.fault_stats();
                    d.respawns = s.respawns;
                    d.retries = s.retries;
                    d.deadline_requeues = s.deadline_requeues;
                    d.join_timeouts = s.join_timeouts;
                    d.quarantined_rows = s.quarantined_rows;
                    d.live_workers = eng.live_workers().unwrap_or(0) as u64;
                }
                EngineKind::Stream { eng, .. } => {
                    d.quarantined_rows = eng.quarantined_rows();
                }
            }
            (Msg::DrainAck(d), Action::Continue)
        }

        // Stats is deliberately tenant-free: monitoring connections may
        // ask without a Hello.
        Msg::Stats => {
            let json = lock(&shared.stats).to_bench_json();
            (Msg::StatsR { json }, Action::Continue)
        }

        Msg::Bye => (Msg::ByeAck, Action::Close),

        // Server→client message types arriving at the server are a
        // protocol violation, not tenant traffic: reply typed, close.
        Msg::HelloAck { .. }
        | Msg::Ack { .. }
        | Msg::Selection(_)
        | Msg::SnapshotR(_)
        | Msg::DrainAck(_)
        | Msg::StatsR { .. }
        | Msg::Busy { .. }
        | Msg::Rejected { .. }
        | Msg::Fault { .. }
        | Msg::ByeAck => (
            Msg::Fault {
                kind: FaultKind::Protocol,
                detail: "server-to-client message sent to the server".to_string(),
            },
            Action::Close,
        ),
    }
}

//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them on the CPU PJRT client.  Python is never on this
//! path — the engine is self-contained once `artifacts/` exists.
//!
//! Executable lifecycle: compiled lazily on first use, cached for the
//! engine's lifetime (compilation is the expensive part; execution is the
//! per-step hot path).

pub mod artifacts;
pub mod exec;
pub mod golden;

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

pub use artifacts::{default_dir, ConfigSpec, Manifest};
pub use exec::{EmbedOut, ModelParams, SelectOut, TrainState};
pub use golden::{Golden, GoldenTensor};

use crate::linalg::Mat;
use exec::{batch_literals, f32s, i32s, lit_scalar, lit_vec, param_literals};

/// Cumulative execution statistics (feeds the energy model + §Perf).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_secs: f64,
    pub exec_secs: f64,
    /// Executions per artifact name.
    pub per_artifact: HashMap<String, (usize, f64)>,
}

/// The PJRT engine: one CPU client + a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, String), xla::PjRtLoadedExecutable>,
    stats: EngineStats,
}

impl Engine {
    /// Create an engine over an artifacts directory (see [`default_dir`]).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: HashMap::new(), stats: EngineStats::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, config: &str) -> Result<&ConfigSpec> {
        self.manifest.config(config)
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Compile (or fetch from cache) one artifact executable.
    fn executable(&mut self, config: &str, artifact: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (config.to_string(), artifact.to_string());
        if !self.cache.contains_key(&key) {
            let path = self.manifest.hlo_path(config, artifact);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.stats.compiles += 1;
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Pre-compile every artifact a run will need (keeps compile time out
    /// of the measured training loop).
    pub fn warmup(&mut self, config: &str) -> Result<()> {
        let arts = self.spec(config)?.artifacts.clone();
        for a in arts {
            self.executable(config, &a)?;
        }
        Ok(())
    }

    /// Execute one artifact: inputs are literals, output is the untupled
    /// result literal list (our artifacts always return tuples).
    fn run(&mut self, config: &str, artifact: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        // Compile first (mutable borrow), then fetch for execution.
        self.executable(config, artifact)?;
        let key = (config.to_string(), artifact.to_string());
        let exe = &self.cache[&key];
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {config}/{artifact}"))?;
        let lit = result[0][0].to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.executions += 1;
        self.stats.exec_secs += dt;
        let entry = self.stats.per_artifact.entry(format!("{config}/{artifact}")).or_insert((0, 0.0));
        entry.0 += 1;
        entry.1 += dt;
        Ok(lit.to_tuple()?)
    }

    // -----------------------------------------------------------------
    // Typed artifact wrappers
    // -----------------------------------------------------------------

    /// `embed`: batch → (features K×Rmax, grad sketches K×E, losses, preds).
    pub fn embed(
        &mut self,
        config: &str,
        params: &ModelParams,
        x: &[f32],
        y1h: &[f32],
    ) -> Result<EmbedOut> {
        let spec = self.spec(config)?.clone();
        let (xl, yl) = batch_literals(x, y1h, spec.k, &spec)?;
        let mut inputs = param_literals(params, &spec)?;
        inputs.push(xl);
        inputs.push(yl);
        let out = self.run(config, "embed", &inputs)?;
        anyhow::ensure!(out.len() == 4, "embed returned {} outputs", out.len());
        let v = f32s(&out[0])?;
        let g = f32s(&out[1])?;
        let losses = f32s(&out[2])?;
        let preds = i32s(&out[3])?;
        Ok(EmbedOut {
            features: Mat::from_f32(spec.k, spec.rmax, &v),
            grads: Mat::from_f32(spec.k, spec.e, &g),
            losses: losses.into_iter().map(|x| x as f64).collect(),
            preds,
        })
    }

    /// `select`: batch → GRAFT Stage-1 outputs (Fast MaxVol indices +
    /// prefix projection errors) — the L1 Pallas kernels run inside this.
    pub fn select(
        &mut self,
        config: &str,
        params: &ModelParams,
        x: &[f32],
        y1h: &[f32],
    ) -> Result<SelectOut> {
        let spec = self.spec(config)?.clone();
        let (xl, yl) = batch_literals(x, y1h, spec.k, &spec)?;
        let mut inputs = param_literals(params, &spec)?;
        inputs.push(xl);
        inputs.push(yl);
        let out = self.run(config, "select", &inputs)?;
        anyhow::ensure!(out.len() == 4, "select returned {} outputs", out.len());
        let p = i32s(&out[0])?;
        let d = f32s(&out[1])?;
        let gnorm = f32s(&out[2])?[0] as f64;
        let align = f32s(&out[3])?[0] as f64;
        Ok(SelectOut {
            indices: p.into_iter().map(|i| i as usize).collect(),
            errors: d.into_iter().map(|x| x as f64).collect(),
            gnorm,
            align,
        })
    }

    /// `train_step_b{bucket}`: one SGD+momentum step on a padded subset.
    /// Returns the loss; the state is updated in place.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &mut self,
        config: &str,
        bucket: usize,
        state: &mut TrainState,
        x: &[f32],
        y1h: &[f32],
        weights: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<f64> {
        let spec = self.spec(config)?.clone();
        anyhow::ensure!(spec.buckets.contains(&bucket), "bucket {bucket} not in {:?}", spec.buckets);
        anyhow::ensure!(weights.len() == bucket, "weights len {} != bucket {bucket}", weights.len());
        let (xl, yl) = batch_literals(x, y1h, bucket, &spec)?;
        let mut inputs = param_literals(&state.params, &spec)?;
        inputs.extend(param_literals(&state.velocity, &spec)?);
        inputs.push(xl);
        inputs.push(yl);
        inputs.push(lit_vec(weights));
        inputs.push(lit_scalar(lr));
        inputs.push(lit_scalar(momentum));
        let artifact = format!("train_step_b{bucket}");
        let out = self.run(config, &artifact, &inputs)?;
        anyhow::ensure!(out.len() == 9, "train_step returned {} outputs", out.len());
        state.params.w1 = f32s(&out[0])?;
        state.params.b1 = f32s(&out[1])?;
        state.params.w2 = f32s(&out[2])?;
        state.params.b2 = f32s(&out[3])?;
        state.velocity.w1 = f32s(&out[4])?;
        state.velocity.b1 = f32s(&out[5])?;
        state.velocity.w2 = f32s(&out[6])?;
        state.velocity.b2 = f32s(&out[7])?;
        Ok(f32s(&out[8])?[0] as f64)
    }

    /// `eval_step`: one evaluation window → (mean loss, per-row correct).
    /// Correctness is per row so callers can mask wrap-padded tails.
    pub fn eval_step(
        &mut self,
        config: &str,
        params: &ModelParams,
        x: &[f32],
        y1h: &[f32],
    ) -> Result<(f64, Vec<i32>)> {
        let spec = self.spec(config)?.clone();
        let (xl, yl) = batch_literals(x, y1h, spec.k, &spec)?;
        let mut inputs = param_literals(params, &spec)?;
        inputs.push(xl);
        inputs.push(yl);
        let out = self.run(config, "eval_step", &inputs)?;
        anyhow::ensure!(out.len() == 2, "eval_step returned {} outputs", out.len());
        let loss = f32s(&out[0])?[0] as f64;
        let correct = i32s(&out[1])?;
        Ok((loss, correct))
    }

    /// Load the golden record for a config (integration tests).
    pub fn golden(&self, config: &str) -> Result<Golden> {
        Golden::load(self.manifest.golden_path(config))
    }
}

//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Flat key-value format (one `config` line per dataset
//! family) so no JSON dependency is needed on the Rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Static shape information for one dataset config's artifact family.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigSpec {
    pub name: String,
    /// Input feature dimension.
    pub d: usize,
    /// Number of classes.
    pub c: usize,
    /// Hidden width.
    pub h: usize,
    /// Batch size K.
    pub k: usize,
    /// Fast MaxVol depth / max candidate rank.
    pub rmax: usize,
    /// Gradient-sketch dimension E = H + C.
    pub e: usize,
    /// Padded train_step bucket sizes (ascending; last == k).
    pub buckets: Vec<usize>,
    /// Artifact names available for this config.
    pub artifacts: Vec<String>,
}

impl ConfigSpec {
    /// Smallest bucket that fits a subset of size `r`.
    pub fn bucket_for(&self, r: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= r)
    }
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        match lines.next() {
            Some("version 1") => {}
            other => bail!("unsupported manifest header: {other:?}"),
        }
        let mut configs = BTreeMap::new();
        for line in lines {
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.first() != Some(&"config") || fields.len() < 2 || fields.len() % 2 != 0 {
                bail!("malformed manifest line: {line:?}");
            }
            let name = fields[1].to_string();
            let mut kv = BTreeMap::new();
            for pair in fields[2..].chunks(2) {
                kv.insert(pair[0], pair[1]);
            }
            let get = |key: &str| -> Result<&str> {
                kv.get(key).copied().with_context(|| format!("manifest {name}: missing {key}"))
            };
            let num = |key: &str| -> Result<usize> {
                get(key)?.parse::<usize>().with_context(|| format!("manifest {name}: bad {key}"))
            };
            let buckets: Vec<usize> = get("buckets")?
                .split(',')
                .map(|s| s.parse::<usize>().map_err(Into::into))
                .collect::<Result<_>>()?;
            let spec = ConfigSpec {
                name: name.clone(),
                d: num("d")?,
                c: num("c")?,
                h: num("h")?,
                k: num("k")?,
                rmax: num("rmax")?,
                e: num("e")?,
                buckets,
                artifacts: get("artifacts")?.split(',').map(String::from).collect(),
            };
            if spec.buckets.last() != Some(&spec.k) {
                bail!("manifest {name}: largest bucket must equal k");
            }
            configs.insert(name, spec);
        }
        Ok(Manifest { dir, configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigSpec> {
        self.configs
            .get(name)
            .with_context(|| format!("config '{name}' not in manifest ({:?})", self.configs.keys().collect::<Vec<_>>()))
    }

    /// Path of one HLO artifact.
    pub fn hlo_path(&self, config: &str, artifact: &str) -> PathBuf {
        self.dir.join(config).join(format!("{artifact}.hlo.txt"))
    }

    pub fn golden_path(&self, config: &str) -> PathBuf {
        self.dir.join(config).join("golden.bin")
    }
}

/// Default artifacts directory: `$GRAFT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("GRAFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "version 1\n\
config iris d 4 c 3 h 16 k 120 rmax 4 e 19 buckets 2,4,8,120 artifacts embed,select,eval_step\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let c = m.config("iris").unwrap();
        assert_eq!(c.d, 4);
        assert_eq!(c.buckets, vec![2, 4, 8, 120]);
        assert_eq!(c.artifacts.len(), 3);
        assert_eq!(m.hlo_path("iris", "select"), PathBuf::from("/tmp/a/iris/select.hlo.txt"));
    }

    #[test]
    fn bucket_for_rounds_up() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let c = m.config("iris").unwrap();
        assert_eq!(c.bucket_for(1), Some(2));
        assert_eq!(c.bucket_for(5), Some(8));
        assert_eq!(c.bucket_for(120), Some(120));
        assert_eq!(c.bucket_for(121), None);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("version 9\n", PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_bucket_mismatch() {
        let bad = "version 1\nconfig x d 1 c 1 h 1 k 10 rmax 1 e 2 buckets 2,4 artifacts embed\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn unknown_config_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::new()).unwrap();
        assert!(m.config("nope").is_err());
    }
}

//! Reader for `artifacts/<config>/golden.bin` — deterministic inputs and
//! JAX-computed outputs used by the cross-language integration tests
//! (see python/compile/golden.py for the format).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One named tensor from a golden file.
#[derive(Debug, Clone)]
pub enum GoldenTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl GoldenTensor {
    pub fn f32(&self) -> Result<&[f32]> {
        match self {
            GoldenTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn i32(&self) -> Result<&[i32]> {
        match self {
            GoldenTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            GoldenTensor::F32 { shape, .. } | GoldenTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }
}

/// All records of one golden file, keyed by name (e.g. "select.p").
pub struct Golden(pub BTreeMap<String, GoldenTensor>);

impl Golden {
    pub fn load(path: impl AsRef<Path>) -> Result<Golden> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<Golden> {
        let mut cur = std::io::Cursor::new(bytes);
        let mut out = BTreeMap::new();
        loop {
            let mut head = [0u8; 4];
            match cur.read_exact(&mut head) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                Err(e) => return Err(e.into()),
            }
            let nlen = u32::from_le_bytes(head) as usize;
            let mut name = vec![0u8; nlen];
            cur.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut meta = [0u8; 5];
            cur.read_exact(&mut meta)?;
            let code = meta[0];
            let ndim = u32::from_le_bytes(meta[1..5].try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut d = [0u8; 4];
                cur.read_exact(&mut d)?;
                shape.push(u32::from_le_bytes(d) as usize);
            }
            let n: usize = shape.iter().product::<usize>().max(1);
            let mut raw = vec![0u8; n * 4];
            cur.read_exact(&mut raw)?;
            let tensor = match code {
                0 => GoldenTensor::F32 {
                    shape,
                    data: raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                },
                1 => GoldenTensor::I32 {
                    shape,
                    data: raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                },
                other => bail!("unknown dtype code {other} for {name}"),
            };
            out.insert(name, tensor);
        }
        Ok(Golden(out))
    }

    pub fn get(&self, name: &str) -> Result<&GoldenTensor> {
        self.0.get(name).with_context(|| format!("golden record '{name}' missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(name: &str, code: u8, shape: &[u32], payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(code);
        out.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn parse_roundtrip() {
        let mut buf = Vec::new();
        buf.extend(record("a", 0, &[2], &[1f32.to_le_bytes(), 2f32.to_le_bytes()].concat()));
        buf.extend(record("b", 1, &[], &7i32.to_le_bytes()));
        let g = Golden::parse(&buf).unwrap();
        assert_eq!(g.get("a").unwrap().f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(g.get("b").unwrap().i32().unwrap(), &[7]);
        assert_eq!(g.get("b").unwrap().shape(), &[] as &[usize]);
        assert!(g.get("c").is_err());
    }

    #[test]
    fn truncated_fails() {
        let mut buf = record("a", 0, &[4], &[0u8; 16]);
        buf.truncate(buf.len() - 4);
        assert!(Golden::parse(&buf).is_err());
    }
}

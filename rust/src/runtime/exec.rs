//! Typed execution layer over the PJRT client: model state, batch tensors,
//! and wrappers for the four artifact kinds (embed / select / train_step /
//! eval_step).  This is the ONLY place that touches `xla::Literal`s — the
//! rest of the crate works with plain slices and `linalg::Mat`.

use anyhow::{bail, Context, Result};

use super::artifacts::ConfigSpec;
use crate::linalg::Mat;

/// MLP parameters (host-side master copy, f32 row-major).
#[derive(Debug, Clone)]
pub struct ModelParams {
    pub w1: Vec<f32>, // d×h
    pub b1: Vec<f32>, // h
    pub w2: Vec<f32>, // h×c
    pub b2: Vec<f32>, // c
}

impl ModelParams {
    /// He-initialised parameters, matching `model.init_params` layout.
    pub fn init(spec: &ConfigSpec, seed: u64) -> ModelParams {
        use crate::rng::Rng;
        let mut rng = Rng::new(seed);
        let s1 = (2.0 / spec.d as f64).sqrt();
        let s2 = (2.0 / spec.h as f64).sqrt();
        ModelParams {
            w1: (0..spec.d * spec.h).map(|_| (rng.normal() * s1) as f32).collect(),
            b1: vec![0.0; spec.h],
            w2: (0..spec.h * spec.c).map(|_| (rng.normal() * s2) as f32).collect(),
            b2: vec![0.0; spec.c],
        }
    }

    pub fn num_params(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
    }

    fn validate(&self, spec: &ConfigSpec) -> Result<()> {
        if self.w1.len() != spec.d * spec.h
            || self.b1.len() != spec.h
            || self.w2.len() != spec.h * spec.c
            || self.b2.len() != spec.c
        {
            bail!("params do not match config '{}'", spec.name);
        }
        Ok(())
    }
}

/// Parameters + momentum buffers — the full optimiser state.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: ModelParams,
    pub velocity: ModelParams,
}

impl TrainState {
    pub fn init(spec: &ConfigSpec, seed: u64) -> TrainState {
        let params = ModelParams::init(spec, seed);
        let velocity = ModelParams {
            w1: vec![0.0; params.w1.len()],
            b1: vec![0.0; params.b1.len()],
            w2: vec![0.0; params.w2.len()],
            b2: vec![0.0; params.b2.len()],
        };
        TrainState { params, velocity }
    }
}

/// Output of the `embed` artifact for one batch.
pub struct EmbedOut {
    /// K×Rmax importance-ordered feature matrix.
    pub features: Mat,
    /// K×E per-sample gradient sketches.
    pub grads: Mat,
    /// Per-sample losses.
    pub losses: Vec<f64>,
    /// Predicted classes.
    pub preds: Vec<i32>,
}

/// Output of the `select` artifact for one batch.
#[derive(Debug, Clone)]
pub struct SelectOut {
    /// Prefix-nested Fast MaxVol indices (batch-local), length Rmax.
    pub indices: Vec<usize>,
    /// Normalised projection error per prefix rank, length Rmax.
    pub errors: Vec<f64>,
    /// ‖ḡ‖₂ of the batch-mean gradient sketch.
    pub gnorm: f64,
    /// cos(ḡ, mean selected sketch) — Fig 2 alignment signal.
    pub align: f64,
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

pub(super) fn lit_mat(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    if data.len() != rows * cols {
        bail!("literal shape mismatch: {} != {rows}x{cols}", data.len());
    }
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

pub(super) fn lit_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

pub(super) fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub(super) fn param_literals(p: &ModelParams, spec: &ConfigSpec) -> Result<Vec<xla::Literal>> {
    p.validate(spec)?;
    Ok(vec![
        lit_mat(&p.w1, spec.d, spec.h)?,
        lit_vec(&p.b1),
        lit_mat(&p.w2, spec.h, spec.c)?,
        lit_vec(&p.b2),
    ])
}

pub(super) fn f32s(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().context("literal -> f32 vec")
}

pub(super) fn i32s(l: &xla::Literal) -> Result<Vec<i32>> {
    l.to_vec::<i32>().context("literal -> i32 vec")
}

/// Turn a K×C one-hot + K×D batch into literals for an artifact call.
pub(super) fn batch_literals(
    x: &[f32],
    y1h: &[f32],
    rows: usize,
    spec: &ConfigSpec,
) -> Result<(xla::Literal, xla::Literal)> {
    Ok((lit_mat(x, rows, spec.d)?, lit_mat(y1h, rows, spec.c)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ConfigSpec {
        ConfigSpec {
            name: "t".into(),
            d: 4,
            c: 3,
            h: 2,
            k: 8,
            rmax: 4,
            e: 5,
            buckets: vec![2, 8],
            artifacts: vec![],
        }
    }

    #[test]
    fn init_shapes() {
        let s = spec();
        let st = TrainState::init(&s, 1);
        assert_eq!(st.params.w1.len(), 8);
        assert_eq!(st.params.num_params(), 8 + 2 + 6 + 3);
        assert!(st.velocity.w1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn validate_catches_mismatch() {
        let s = spec();
        let mut p = ModelParams::init(&s, 2);
        p.b2.push(0.0);
        assert!(p.validate(&s).is_err());
    }

    #[test]
    fn lit_mat_checks_shape() {
        assert!(lit_mat(&[0.0; 6], 2, 3).is_ok());
        assert!(lit_mat(&[0.0; 5], 2, 3).is_err());
    }
}

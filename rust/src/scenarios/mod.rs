//! Scenario-matrix evaluation harness: deterministic offline stress
//! testing of every selector the engine can build, under controlled data
//! pathologies.
//!
//! The matrix is the cross product of four things:
//!
//! * **scenario axes** ([`Axis`]) — class imbalance, label noise, a
//!   mid-stream distribution shift, curriculum ordering — each a single
//!   perturbed knob over the synthetic generator ([`gen`]);
//! * **the selector roster** ([`roster`]) — GRAFT (feature-volume and
//!   gradient-aware pivot ordering), the explore/exploit hybrid, and the
//!   eleven baseline selectors, all built through [`EngineBuilder`] so
//!   every cell inherits the engine's validation and fault policy;
//! * **execution shapes** — serial, sharded, and (for the reservoir
//!   methods) streaming ingestion through
//!   [`StreamingEngine`](crate::engine::StreamingEngine);
//! * **budget fractions** — the subset-size frontier.
//!
//! Every cell scores its subsets with the [`metrics`] module (gradient-
//! approximation error, class coverage, loss proxy, nearest-centroid
//! probe) averaged over the scenario's stream windows, and lands as one
//! [`ScenarioRecord`] row in a `graft-scenario-v1` document ([`sink`]).
//! The whole run is a pure function of [`MatrixConfig`]: same config,
//! same bytes — which is what `tests/scenarios.rs` and the CI
//! `scenario-smoke` job pin.
//!
//! ```no_run
//! use graft::scenarios::{run_matrix, MatrixConfig, ScenarioSink};
//!
//! let rows = run_matrix(&MatrixConfig::smoke()).expect("offline matrix");
//! let mut sink = ScenarioSink::new();
//! for row in rows {
//!     sink.record(row);
//! }
//! sink.write(std::path::Path::new("results/scenarios.json")).unwrap();
//! ```

pub mod gen;
pub mod metrics;
pub mod sink;

pub use gen::{scenario_windows, Axis, GenConfig};
pub use metrics::{subset_metrics, SubsetMetrics};
pub use sink::{ScenarioRecord, ScenarioSink};

use crate::coordinator::SelectWindow;
use crate::engine::{EngineBuilder, ExecShape, PivotMode};
use anyhow::Context;

/// One roster entry: the sink label, the engine method name, and the
/// pivot variant the cell is built with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MethodSpec {
    /// Row label, e.g. `graft+gradpivot`.
    pub label: &'static str,
    /// Engine method name passed to [`EngineBuilder::method`].
    pub method: &'static str,
    /// Pivot-ordering variant for the cell.
    pub pivot: PivotMode,
}

impl MethodSpec {
    /// Whether this entry also runs under the streaming shape (only the
    /// reservoir-capable methods do).
    pub fn streams(&self) -> bool {
        self.pivot == PivotMode::FeatureVol && matches!(self.method, "graft" | "maxvol")
    }
}

/// The full selector roster: GRAFT under both pivot orderings, the
/// explore/exploit hybrid, and the eleven baselines.
pub fn roster() -> Vec<MethodSpec> {
    let feature = |label: &'static str, method: &'static str| MethodSpec {
        label,
        method,
        pivot: PivotMode::FeatureVol,
    };
    vec![
        feature("graft", "graft"),
        MethodSpec {
            label: "graft+gradpivot",
            method: "graft",
            pivot: PivotMode::GradAware,
        },
        feature("maxvol", "maxvol"),
        feature("cross-maxvol", "cross-maxvol"),
        feature("random", "random"),
        feature("craig", "craig"),
        feature("gradmatch", "gradmatch"),
        feature("glister", "glister"),
        feature("drop", "drop"),
        feature("el2n", "el2n"),
        feature("badge", "badge"),
        feature("moderate", "moderate"),
        feature("forget", "forget"),
        feature("hybrid", "hybrid"),
    ]
}

/// Everything a matrix run depends on.  `run_matrix` is a pure function
/// of this value.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixConfig {
    /// Scenario stream generation (size, windows, seeds).
    pub gen: GenConfig,
    /// Scenario axes to sweep.
    pub axes: Vec<Axis>,
    /// Budget fractions to sweep.
    pub fractions: Vec<f64>,
    /// Shard count for the sharded execution shape.
    pub shards: usize,
    /// Engine seed (selector seeding, fallback draws).
    pub seed: u64,
}

impl MatrixConfig {
    /// The CI smoke matrix: 4 axes × full roster × 3 fractions on the
    /// tiny generator — small enough to run twice in the smoke job and
    /// diff for bit-identity.
    pub fn smoke() -> MatrixConfig {
        MatrixConfig {
            gen: GenConfig::smoke(),
            axes: vec![
                Axis::Imbalance(0.5),
                Axis::LabelNoise(0.2),
                Axis::Shift(0.5),
                Axis::Curriculum(1.0),
            ],
            fractions: vec![0.1, 0.25, 0.5],
            shards: 2,
            seed: 42,
        }
    }

    /// The full offline matrix: baseline plus two severities per axis,
    /// five budget fractions, the large generator.
    pub fn full() -> MatrixConfig {
        MatrixConfig {
            gen: GenConfig::full(),
            axes: vec![
                Axis::Baseline,
                Axis::Imbalance(0.3),
                Axis::Imbalance(0.7),
                Axis::LabelNoise(0.1),
                Axis::LabelNoise(0.3),
                Axis::Shift(0.5),
                Axis::Shift(1.0),
                Axis::Curriculum(0.5),
                Axis::Curriculum(1.0),
            ],
            fractions: vec![0.05, 0.1, 0.2, 0.35, 0.5],
            shards: 2,
            seed: 42,
        }
    }
}

/// Run the full matrix and return one row per (axis, roster entry,
/// shape, fraction) cell, in a fixed deterministic order.
pub fn run_matrix(cfg: &MatrixConfig) -> anyhow::Result<Vec<ScenarioRecord>> {
    let shard_label = format!("sharded{}", cfg.shards.max(1));
    let mut rows = Vec::new();
    for axis in &cfg.axes {
        let windows = scenario_windows(*axis, &cfg.gen);
        for m in roster() {
            for &fraction in &cfg.fractions {
                rows.push(run_batch_cell(
                    &windows,
                    *axis,
                    &m,
                    ExecShape::Serial,
                    "serial",
                    fraction,
                    cfg.seed,
                )?);
                rows.push(run_batch_cell(
                    &windows,
                    *axis,
                    &m,
                    ExecShape::Sharded {
                        shards: cfg.shards.max(1),
                    },
                    &shard_label,
                    fraction,
                    cfg.seed,
                )?);
                if m.streams() {
                    rows.push(run_stream_cell(&windows, *axis, &m, fraction, cfg.seed)?);
                }
            }
        }
    }
    Ok(rows)
}

/// Window-mean accumulator for one cell.
#[derive(Default)]
struct CellAcc {
    grad_error: f64,
    coverage: f64,
    mean_loss: f64,
    probe_acc: f64,
    budget: f64,
    degraded: u64,
    windows: usize,
}

impl CellAcc {
    fn add(&mut self, m: SubsetMetrics, selected: usize, degraded: usize) {
        self.grad_error += m.grad_error;
        self.coverage += m.coverage;
        self.mean_loss += m.mean_loss;
        self.probe_acc += m.probe_acc;
        self.budget += selected as f64;
        self.degraded += degraded as u64;
        self.windows += 1;
    }

    fn mean_budget(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.budget / self.windows as f64
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn finish(
        self,
        axis: Axis,
        m: &MethodSpec,
        shape: &str,
        fraction: f64,
        mean_rank: f64,
        seed: u64,
    ) -> ScenarioRecord {
        let inv = if self.windows == 0 {
            0.0
        } else {
            1.0 / self.windows as f64
        };
        ScenarioRecord {
            scenario: axis.label(),
            method: m.label.to_string(),
            shape: shape.to_string(),
            fraction,
            budget: self.mean_budget(),
            grad_error: self.grad_error * inv,
            coverage: self.coverage * inv,
            mean_loss: self.mean_loss * inv,
            probe_acc: self.probe_acc * inv,
            mean_rank,
            degraded: self.degraded,
            seed,
        }
    }
}

fn run_batch_cell(
    windows: &[SelectWindow],
    axis: Axis,
    m: &MethodSpec,
    shape: ExecShape,
    shape_label: &str,
    fraction: f64,
    seed: u64,
) -> anyhow::Result<ScenarioRecord> {
    let mut eng = EngineBuilder::new()
        .method(m.method)
        .fraction(fraction)
        .seed(seed)
        .exec(shape)
        .pivot(m.pivot)
        .build()
        .with_context(|| {
            format!(
                "building cell {} / {} / {} @ f={fraction}",
                axis.label(),
                m.label,
                shape_label
            )
        })?;
    let mut acc = CellAcc::default();
    for (w, win) in windows.iter().enumerate() {
        let view = win.view();
        let (indices, degraded) = {
            let sel = eng.select(&view).with_context(|| {
                format!(
                    "selecting window {w} of cell {} / {} / {}",
                    axis.label(),
                    m.label,
                    shape_label
                )
            })?;
            (sel.indices.to_vec(), sel.degradations.len())
        };
        acc.add(subset_metrics(win, &indices), indices.len(), degraded);
    }
    let mean_rank = eng
        .rank_stats()
        .map(|s| s.mean_rank)
        .unwrap_or_else(|| acc.mean_budget());
    Ok(acc.finish(axis, m, shape_label, fraction, mean_rank, seed))
}

fn run_stream_cell(
    windows: &[SelectWindow],
    axis: Axis,
    m: &MethodSpec,
    fraction: f64,
    seed: u64,
) -> anyhow::Result<ScenarioRecord> {
    let k = windows.first().map_or(0, |w| w.features.rows());
    anyhow::ensure!(k > 0, "stream cell needs non-empty windows");
    let budget = ((fraction * k as f64).round() as usize).clamp(1, k);
    let mut eng = EngineBuilder::new()
        .method(m.method)
        .seed(seed)
        .budget(budget)
        .build_streaming()
        .with_context(|| {
            format!("building stream cell {} / {} @ f={fraction}", axis.label(), m.label)
        })?;
    let mut acc = CellAcc::default();
    for (w, win) in windows.iter().enumerate() {
        let view = win.view();
        // Two chunks per window: exercises genuine incremental ingestion
        // rather than one batch-sized push.
        let half = k / 2;
        let ctx = |stage: &str| {
            format!(
                "{stage} window {w} of stream cell {} / {}",
                axis.label(),
                m.label
            )
        };
        eng.push_range(&view, 0..half).with_context(|| ctx("pushing first half of"))?;
        eng.push_range(&view, half..k).with_context(|| ctx("pushing second half of"))?;
        let snap = eng.snapshot().with_context(|| ctx("snapshotting"))?;
        let lo = win.row_ids[0];
        let local: Vec<usize> = snap.indices.iter().map(|&g| g - lo).collect();
        acc.add(subset_metrics(win, &local), local.len(), snap.degradations.len());
        eng.reset();
    }
    let mean_rank = eng
        .rank_stats()
        .map(|s| s.mean_rank)
        .unwrap_or_else(|| acc.mean_budget());
    Ok(acc.finish(axis, m, "stream", fraction, mean_rank, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_covers_graft_variants_hybrid_and_eleven_baselines() {
        let r = roster();
        assert_eq!(r.len(), 14, "2 graft variants + 11 baselines + hybrid");
        let labels: Vec<&str> = r.iter().map(|m| m.label).collect();
        for want in [
            "graft",
            "graft+gradpivot",
            "maxvol",
            "cross-maxvol",
            "random",
            "craig",
            "gradmatch",
            "glister",
            "drop",
            "el2n",
            "badge",
            "moderate",
            "forget",
            "hybrid",
        ] {
            assert!(labels.contains(&want), "roster is missing {want}");
        }
        let gradpivot = r.iter().find(|m| m.label == "graft+gradpivot").unwrap();
        assert_eq!(gradpivot.method, "graft");
        assert_eq!(gradpivot.pivot, PivotMode::GradAware);
    }

    #[test]
    fn only_reservoir_methods_stream() {
        let streaming: Vec<&str> = roster()
            .into_iter()
            .filter(MethodSpec::streams)
            .map(|m| m.label)
            .collect();
        assert_eq!(streaming, vec!["graft", "maxvol"]);
    }

    #[test]
    fn smoke_config_meets_the_issue_floor() {
        let cfg = MatrixConfig::smoke();
        assert!(cfg.axes.len() >= 3, "need ≥ 3 scenario axes");
        assert!(cfg.fractions.len() >= 3, "need ≥ 3 budget fractions");
        assert!(cfg.fractions.iter().all(|f| *f > 0.0 && *f <= 1.0));
    }
}

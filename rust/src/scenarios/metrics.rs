//! Subset-quality metrics for one selected window: the four axes the
//! scenario matrix reports per cell.
//!
//! All metrics are pure functions of `(window, selected indices)` —
//! no model training, no randomness — so rows are reproducible and the
//! CI smoke job can diff them byte-for-byte.

use crate::coordinator::SelectWindow;
use crate::graft::prefix_projection_errors;
use crate::linalg::Mat;

/// Quality of one selected subset within one window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubsetMetrics {
    /// Relative gradient-approximation error ‖ḡ − ĝ_S‖ / ‖ḡ‖: how much of
    /// the window-mean gradient the subset's gradient span fails to cover
    /// (0 = fully covered, 1 = orthogonal or empty subset).
    pub grad_error: f64,
    /// Distinct selected classes over distinct window classes.
    pub coverage: f64,
    /// Mean loss of the selected rows (0 for an empty subset).
    pub mean_loss: f64,
    /// Nearest-centroid probe: class centroids fit on the subset in
    /// feature space, accuracy measured over the whole window.
    pub probe_acc: f64,
}

/// Score `sel` (window-local row indices) against `win`.
pub fn subset_metrics(win: &SelectWindow, sel: &[usize]) -> SubsetMetrics {
    let k = win.features.rows();
    let e = win.grads.cols();
    debug_assert!(sel.iter().all(|&i| i < k), "selection indices must be window-local");

    // Gradient-approximation error: project the window-mean gradient onto
    // the span of the selected rows' gradient sketches.
    let grad_error = if sel.is_empty() || k == 0 {
        1.0
    } else {
        let mut gbar = vec![0.0; e];
        for i in 0..k {
            let row = win.grads.row(i);
            for (acc, &v) in gbar.iter_mut().zip(row) {
                *acc += v;
            }
        }
        let inv = 1.0 / k as f64;
        for v in &mut gbar {
            *v *= inv;
        }
        let gsel = Mat::from_fn(e, sel.len(), |dim, col| win.grads.row(sel[col])[dim]);
        prefix_projection_errors(&gsel, &gbar)
            .last()
            .copied()
            .unwrap_or(1.0)
    };

    // Class coverage.
    let distinct = |rows: &mut dyn Iterator<Item = usize>| -> usize {
        let mut seen = vec![false; win.classes.max(1)];
        let mut count = 0usize;
        for i in rows {
            let y = (win.labels[i].max(0) as usize).min(seen.len() - 1);
            if !seen[y] {
                seen[y] = true;
                count += 1;
            }
        }
        count
    };
    let window_classes = distinct(&mut (0..k));
    let subset_classes = distinct(&mut sel.iter().copied());
    let coverage = if window_classes == 0 {
        0.0
    } else {
        subset_classes as f64 / window_classes as f64
    };

    let mean_loss = if sel.is_empty() {
        0.0
    } else {
        sel.iter().map(|&i| win.losses[i]).sum::<f64>() / sel.len() as f64
    };

    SubsetMetrics {
        grad_error,
        coverage,
        mean_loss,
        probe_acc: probe_accuracy(win, sel),
    }
}

/// Nearest-centroid probe accuracy: centroids from the selected rows only,
/// evaluated over every window row.  Rows whose class has no selected
/// representative can never be scored correct, so sparse-coverage subsets
/// pay for it here.
fn probe_accuracy(win: &SelectWindow, sel: &[usize]) -> f64 {
    let k = win.features.rows();
    let r = win.features.cols();
    if sel.is_empty() || k == 0 {
        return 0.0;
    }
    let classes = win.classes.max(1);
    let mut centroid = vec![0.0; classes * r];
    let mut counts = vec![0usize; classes];
    for &i in sel {
        let y = (win.labels[i].max(0) as usize).min(classes - 1);
        counts[y] += 1;
        for (acc, &v) in centroid[y * r..(y + 1) * r].iter_mut().zip(win.features.row(i)) {
            *acc += v;
        }
    }
    for (c, &n) in counts.iter().enumerate() {
        if n > 0 {
            let inv = 1.0 / n as f64;
            for v in &mut centroid[c * r..(c + 1) * r] {
                *v *= inv;
            }
        }
    }
    let mut correct = 0usize;
    for i in 0..k {
        let row = win.features.row(i);
        let mut best_d = f64::INFINITY;
        let mut best_c = usize::MAX;
        for (c, &n) in counts.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let d2: f64 = centroid[c * r..(c + 1) * r]
                .iter()
                .zip(row)
                .map(|(&m, &v)| (v - m) * (v - m))
                .sum();
            // Strict `<` keeps the lowest class index on exact ties.
            if d2 < best_d {
                best_d = d2;
                best_c = c;
            }
        }
        if best_c == win.labels[i].max(0) as usize {
            correct += 1;
        }
    }
    correct as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two classes, features = one-hot-ish axes, grads = orthogonal basis
    /// columns per class.
    fn window() -> SelectWindow {
        let k = 6;
        let labels: Vec<i32> = vec![0, 0, 0, 1, 1, 1];
        let features = Mat::from_fn(k, 2, |i, j| {
            if (labels[i] as usize) == j {
                1.0
            } else {
                0.0
            }
        });
        let grads = Mat::from_fn(k, 2, |i, j| {
            if (labels[i] as usize) == j {
                2.0
            } else {
                0.0
            }
        });
        SelectWindow {
            features,
            grads,
            losses: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            labels,
            preds: vec![0; k],
            classes: 2,
            row_ids: (0..k).collect(),
        }
    }

    #[test]
    fn empty_subset_scores_worst_case() {
        let m = subset_metrics(&window(), &[]);
        assert_eq!(m.grad_error, 1.0);
        assert_eq!(m.coverage, 0.0);
        assert_eq!(m.mean_loss, 0.0);
        assert_eq!(m.probe_acc, 0.0);
    }

    #[test]
    fn one_class_subset_covers_half_and_misses_half_the_gradient() {
        let m = subset_metrics(&window(), &[0, 1]);
        assert_eq!(m.coverage, 0.5);
        assert_eq!(m.mean_loss, 1.5);
        // ḡ = (1, −1)-ish split over two orthogonal class directions; a
        // one-class subset spans exactly one of them: relative error
        // 1 − 1/2 = 0.5 of the squared mass.
        assert!((m.grad_error - 0.5).abs() < 1e-12, "{}", m.grad_error);
        // The probe only has a class-0 centroid, so class-1 rows are all
        // scored as class 0: accuracy 0.5.
        assert!((m.probe_acc - 0.5).abs() < 1e-12, "{}", m.probe_acc);
    }

    #[test]
    fn both_classes_selected_scores_perfectly() {
        let m = subset_metrics(&window(), &[0, 3]);
        assert_eq!(m.coverage, 1.0);
        assert!((m.mean_loss - 2.5).abs() < 1e-12);
        assert!(m.grad_error < 1e-9, "{}", m.grad_error);
        assert!((m.probe_acc - 1.0).abs() < 1e-12, "{}", m.probe_acc);
    }
}

//! Scenario generation: deterministic stress axes over the synthetic
//! generator, rendered as ready-to-select [`SelectWindow`]s.
//!
//! Each [`Axis`] perturbs one knob of [`SynthSpec`] — class imbalance,
//! label-noise rate, a mid-stream distribution shift, or a curriculum
//! (easy-to-hard) ordering — while everything else stays pinned, so a
//! metric delta between two axes is attributable to that knob alone.
//! The dataset's stream order is cut into equal windows, and every window
//! gets the three selector inputs the engine consumes:
//!
//! * **features** — an `svd` extraction of the raw window matrix, the
//!   same extractor family the trainer uses;
//! * **gradient sketches** — last-layer gradients of a fixed seeded
//!   linear probe, `(p − e_y) ⊗ (P x)` with a seeded projection `P`, so
//!   the sketch has the low-rank outer-product structure GRAFT exploits;
//! * **losses / labels / preds** — the probe's cross-entropy loss and
//!   argmax prediction per row.
//!
//! The probe and projection are seeded from [`GenConfig::seed`] and are
//! *independent of the axis*, so cross-axis comparisons hold the proxy
//! model fixed.  Everything is a pure function of `(axis, cfg)`: the same
//! inputs reproduce the same windows byte-for-byte.

use crate::coordinator::SelectWindow;
use crate::data::synth::{synth_dataset, SynthSpec};
use crate::features;
use crate::linalg::{dot, Mat};
use crate::rng::Rng;

/// One scenario stress axis: which [`SynthSpec`] knob to turn, and how far.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Axis {
    /// The unperturbed generator — the reference column of the matrix.
    Baseline,
    /// Geometric class imbalance severity in `[0, 1]`.
    Imbalance(f64),
    /// Fraction of labels resampled uniformly at random, in `[0, 1]`.
    LabelNoise(f64),
    /// Mid-stream distribution shift: rows after `n/2` are re-drawn with
    /// mode centres rotated by this strength in `[0, 1]`.
    Shift(f64),
    /// Curriculum ordering strength in `[0, 1]`: rows sorted easy-to-hard
    /// (by margin) with this much determinism.
    Curriculum(f64),
}

impl Axis {
    /// Stable row label for the sink, e.g. `label_noise-0.20`.
    pub fn label(&self) -> String {
        match self {
            Axis::Baseline => "baseline".to_string(),
            Axis::Imbalance(v) => format!("imbalance-{v:.2}"),
            Axis::LabelNoise(v) => format!("label_noise-{v:.2}"),
            Axis::Shift(v) => format!("shift-{v:.2}"),
            Axis::Curriculum(v) => format!("curriculum-{v:.2}"),
        }
    }

    fn apply(&self, spec: &mut SynthSpec) {
        match *self {
            Axis::Baseline => {}
            Axis::Imbalance(v) => spec.imbalance = v,
            Axis::LabelNoise(v) => spec.label_noise = v,
            Axis::Shift(v) => spec.shift_point = v,
            Axis::Curriculum(v) => spec.curriculum = v,
        }
    }
}

/// Size and seeding of the generated scenario stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GenConfig {
    /// Total rows in the scenario stream.
    pub n: usize,
    /// Raw input dimensionality.
    pub d: usize,
    /// Number of classes.
    pub classes: usize,
    /// Number of equal windows the stream is cut into.
    pub windows: usize,
    /// Extracted feature columns per window (the MaxVol rank ceiling).
    pub feat_r: usize,
    /// Projected input dimensions per class in the gradient sketch; the
    /// sketch width is `classes * proj_e`.
    pub proj_e: usize,
    /// Seed for the generator, the probe, and the sketch projection.
    pub seed: u64,
}

impl GenConfig {
    /// Tiny matrix for CI smoke runs and tests: 2 windows of 120 rows.
    pub fn smoke() -> GenConfig {
        GenConfig {
            n: 240,
            d: 24,
            classes: 3,
            windows: 2,
            feat_r: 8,
            proj_e: 3,
            seed: 0x5CE4_A210,
        }
    }

    /// Full offline matrix: 8 windows of 512 rows.
    pub fn full() -> GenConfig {
        GenConfig {
            n: 4096,
            d: 96,
            classes: 8,
            windows: 8,
            feat_r: 16,
            proj_e: 4,
            seed: 0x5CE4_A210,
        }
    }

    /// Rows per window.
    pub fn window_len(&self) -> usize {
        self.n / self.windows.max(1)
    }

    /// Gradient-sketch width `classes * proj_e`.
    pub fn sketch_dim(&self) -> usize {
        self.classes * self.proj_e
    }
}

/// Generate the scenario stream for `axis` and cut it into windows.
///
/// `row_ids` are global stream positions, so streaming snapshots (which
/// report global ids) map back to window-local rows by subtracting the
/// window offset.
pub fn scenario_windows(axis: Axis, cfg: &GenConfig) -> Vec<SelectWindow> {
    let mut spec = SynthSpec {
        name: "scenario",
        n: cfg.n,
        d: cfg.d,
        classes: cfg.classes,
        intra_rank: 4.min(cfg.d.max(1)),
        modes: 3,
        separation: 1.2,
        noise: 1.0,
        redundancy: 0.2,
        label_noise: 0.0,
        imbalance: 0.0,
        shift_point: 0.0,
        curriculum: 0.0,
        seed: cfg.seed,
    };
    axis.apply(&mut spec);
    let ds = synth_dataset(&spec);

    // Fixed probe weights and sketch projection: seeded off the config
    // only, never the axis, so every axis is scored by the same proxy.
    let mut prng = Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15);
    let scale = 1.0 / (cfg.d as f64).sqrt();
    let w0: Vec<f64> = (0..cfg.classes * cfg.d).map(|_| prng.normal() * scale).collect();
    let px: Vec<f64> = (0..cfg.proj_e * cfg.d).map(|_| prng.normal() * scale).collect();

    let extractor = features::by_name("svd").expect("svd extractor is always registered");
    let k = cfg.window_len();
    let e = cfg.sketch_dim();
    let mut out = Vec::with_capacity(cfg.windows);
    for w in 0..cfg.windows {
        let lo = w * k;
        let raw = Mat::from_fn(k, cfg.d, |i, j| f64::from(ds.x[(lo + i) * cfg.d + j]));
        let feats = extractor.extract(&raw, cfg.feat_r.min(cfg.d));

        let mut grads = Mat::zeros(k, e);
        let mut losses = vec![0.0; k];
        let mut labels = vec![0i32; k];
        let mut preds = vec![0i32; k];
        for i in 0..k {
            let row = raw.row(i);
            let y = ds.y[lo + i].max(0) as usize % cfg.classes.max(1);
            labels[i] = y as i32;

            let z: Vec<f64> = (0..cfg.classes)
                .map(|c| dot(&w0[c * cfg.d..(c + 1) * cfg.d], row))
                .collect();
            let zmax = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let expz: Vec<f64> = z.iter().map(|&v| (v - zmax).exp()).collect();
            let zsum: f64 = expz.iter().sum();
            losses[i] = zsum.ln() + zmax - z[y];
            let mut arg = 0usize;
            for (c, &v) in z.iter().enumerate() {
                if v > z[arg] {
                    arg = c;
                }
            }
            preds[i] = arg as i32;

            // Sketch: outer product of the softmax residual with the
            // projected input, flattened to `classes * proj_e` columns.
            let u: Vec<f64> = (0..cfg.proj_e)
                .map(|t| dot(&px[t * cfg.d..(t + 1) * cfg.d], row))
                .collect();
            for c in 0..cfg.classes {
                let coef = expz[c] / zsum - if c == y { 1.0 } else { 0.0 };
                for (t, &ut) in u.iter().enumerate() {
                    grads[(i, c * cfg.proj_e + t)] = coef * ut;
                }
            }
        }

        out.push(SelectWindow {
            features: feats,
            grads,
            losses,
            labels,
            preds,
            classes: cfg.classes,
            row_ids: (lo..lo + k).collect(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> GenConfig {
        GenConfig {
            n: 48,
            d: 10,
            classes: 3,
            windows: 2,
            feat_r: 4,
            proj_e: 2,
            seed: 11,
        }
    }

    fn flatten(wins: &[SelectWindow]) -> Vec<f64> {
        let mut v = Vec::new();
        for w in wins {
            v.extend_from_slice(w.features.data());
            v.extend_from_slice(w.grads.data());
            v.extend_from_slice(&w.losses);
            v.extend(w.labels.iter().map(|&x| f64::from(x)));
            v.extend(w.preds.iter().map(|&x| f64::from(x)));
        }
        v
    }

    #[test]
    fn windows_have_declared_shapes_and_global_row_ids() {
        let cfg = tiny();
        let wins = scenario_windows(Axis::LabelNoise(0.2), &cfg);
        assert_eq!(wins.len(), cfg.windows);
        let k = cfg.window_len();
        for (w, win) in wins.iter().enumerate() {
            assert_eq!(win.features.rows(), k);
            assert_eq!(win.features.cols(), cfg.feat_r);
            assert_eq!(win.grads.rows(), k);
            assert_eq!(win.grads.cols(), cfg.sketch_dim());
            assert_eq!(win.losses.len(), k);
            assert_eq!(win.classes, cfg.classes);
            assert_eq!(win.row_ids[0], w * k, "row ids are global stream positions");
            assert!(win.losses.iter().all(|l| l.is_finite() && *l >= 0.0));
        }
    }

    #[test]
    fn same_axis_and_seed_reproduce_bitwise() {
        let cfg = tiny();
        let a = flatten(&scenario_windows(Axis::Shift(0.5), &cfg));
        let b = flatten(&scenario_windows(Axis::Shift(0.5), &cfg));
        assert_eq!(a, b);
    }

    #[test]
    fn distinct_axes_produce_distinct_streams() {
        let cfg = tiny();
        let base = flatten(&scenario_windows(Axis::Baseline, &cfg));
        for axis in [
            Axis::Imbalance(0.6),
            Axis::LabelNoise(0.3),
            Axis::Shift(0.8),
            Axis::Curriculum(1.0),
        ] {
            let perturbed = flatten(&scenario_windows(axis, &cfg));
            assert_ne!(base, perturbed, "{} must differ from baseline", axis.label());
        }
    }

    #[test]
    fn axis_labels_are_stable() {
        assert_eq!(Axis::Baseline.label(), "baseline");
        assert_eq!(Axis::Imbalance(0.5).label(), "imbalance-0.50");
        assert_eq!(Axis::LabelNoise(0.2).label(), "label_noise-0.20");
        assert_eq!(Axis::Shift(0.75).label(), "shift-0.75");
        assert_eq!(Axis::Curriculum(1.0).label(), "curriculum-1.00");
    }
}

//! `graft-scenario-v1` JSON sink — the scenario matrix's machine-readable
//! output, in the same hand-rolled style as the bench harness's
//! `graft-bench-v1` sink (`benches/bench_util.rs`).  That sink is compiled
//! only into bench targets and is unreachable from `rust/src`, so the
//! scenario harness carries its own: fixed field order, fixed float
//! formatting, one record per line — the same seed always serialises to
//! the same bytes, which is what the CI smoke job diffs.
//!
//! Schema (validated by `scripts/validate_bench.py --schema scenario`):
//!
//! ```json
//! {"schema":"graft-scenario-v1","rows":[
//! {"scenario":"label_noise-0.20","method":"graft+gradpivot","shape":"serial",
//!  "fraction":0.2500,"budget":30.0,"grad_error":0.412345,"coverage":1.000000,
//!  "mean_loss":1.234567,"probe_acc":0.812345,"mean_rank":30.000,"degraded":0,
//!  "seed":42}
//! ]}
//! ```

use std::path::{Path, PathBuf};

/// One scenario-matrix cell: a (scenario axis, method, execution shape,
/// budget fraction) combination, with subset-quality metrics averaged over
/// the scenario's stream windows.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioRecord {
    /// Scenario axis label, e.g. `imbalance-0.50` or `label_noise-0.20`.
    pub scenario: String,
    /// Roster label (method plus variant), e.g. `graft`, `graft+gradpivot`,
    /// `hybrid`, `random`.
    pub method: String,
    /// Execution shape the cell ran under: `serial`, `sharded2`, `stream`.
    pub shape: String,
    /// Requested budget fraction f ∈ (0, 1].
    pub fraction: f64,
    /// Mean selected rows per window (the realised budget).
    pub budget: f64,
    /// Mean relative gradient-approximation error ‖ḡ − ĝ_S‖ / ‖ḡ‖ of the
    /// selected subset (0 = the subset spans the batch-mean gradient).
    pub grad_error: f64,
    /// Mean fraction of the window's classes present in the subset.
    pub coverage: f64,
    /// Mean loss of the selected rows (the loss-proxy axis).
    pub mean_loss: f64,
    /// Nearest-centroid probe accuracy: centroids fit on the subset,
    /// evaluated on the whole window (feature space).
    pub probe_acc: f64,
    /// Rank telemetry: the engine's mean decided rank where a rank stage
    /// exists, else the mean subset size.
    pub mean_rank: f64,
    /// Total degradation-ladder steps recorded across the cell's windows
    /// (0 on a healthy run).
    pub degraded: u64,
    /// Engine seed the cell ran with.
    pub seed: u64,
}

impl ScenarioRecord {
    /// Fixed-format serialisation: field order and float precision are
    /// part of the schema, so byte-identical rows ⇔ identical cells.
    fn to_json(&self) -> String {
        format!(
            "{{\"scenario\":\"{}\",\"method\":\"{}\",\"shape\":\"{}\",\
             \"fraction\":{:.4},\"budget\":{:.1},\"grad_error\":{:.6},\
             \"coverage\":{:.6},\"mean_loss\":{:.6},\"probe_acc\":{:.6},\
             \"mean_rank\":{:.3},\"degraded\":{},\"seed\":{}}}",
            self.scenario,
            self.method,
            self.shape,
            self.fraction,
            self.budget,
            self.grad_error,
            self.coverage,
            self.mean_loss,
            self.probe_acc,
            self.mean_rank,
            self.degraded,
            self.seed
        )
    }
}

/// Collects scenario rows and serialises the whole document.  Unlike the
/// bench sink there is no merge-with-existing-file step: a scenario run is
/// a complete matrix, so the document is always written whole.
#[derive(Debug, Default)]
pub struct ScenarioSink {
    rows: Vec<ScenarioRecord>,
}

impl ScenarioSink {
    pub fn new() -> ScenarioSink {
        ScenarioSink::default()
    }

    pub fn record(&mut self, row: ScenarioRecord) {
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The complete `graft-scenario-v1` document, one record per line.
    pub fn to_doc(&self) -> String {
        let mut body = String::from("{\"schema\":\"graft-scenario-v1\",\"rows\":[\n");
        let lines: Vec<String> = self.rows.iter().map(ScenarioRecord::to_json).collect();
        body.push_str(&lines.join(",\n"));
        body.push_str("\n]}\n");
        body
    }

    /// Write the document to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> std::io::Result<PathBuf> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_doc())?;
        Ok(path.to_path_buf())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> ScenarioRecord {
        ScenarioRecord {
            scenario: "label_noise-0.20".into(),
            method: "graft+gradpivot".into(),
            shape: "serial".into(),
            fraction: 0.25,
            budget: 30.0,
            grad_error: 0.4123456789,
            coverage: 1.0,
            mean_loss: 1.25,
            probe_acc: 0.8125,
            mean_rank: 30.0,
            degraded: 0,
            seed: 42,
        }
    }

    #[test]
    fn doc_is_deterministic_and_schema_tagged() {
        let mut a = ScenarioSink::new();
        let mut b = ScenarioSink::new();
        a.record(row());
        b.record(row());
        assert_eq!(a.to_doc(), b.to_doc(), "same rows must serialise to the same bytes");
        let doc = a.to_doc();
        assert!(doc.starts_with("{\"schema\":\"graft-scenario-v1\",\"rows\":["), "{doc}");
        assert!(doc.contains("\"grad_error\":0.412346"), "fixed precision: {doc}");
        assert!(doc.contains("\"fraction\":0.2500"), "{doc}");
        assert!(doc.trim_end().ends_with("]}"), "{doc}");
    }

    #[test]
    fn empty_sink_still_emits_a_valid_document() {
        let doc = ScenarioSink::new().to_doc();
        assert!(doc.contains("graft-scenario-v1"));
    }
}

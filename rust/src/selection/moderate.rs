//! Moderate-coreset baseline (Xia et al. 2023, paper §2): keep samples of
//! *intermediate* difficulty — those whose distance to their class centroid
//! (in gradient-sketch space) sits closest to the per-class median.
//! Rationale: extremes are either redundant (too easy) or noisy/outliers
//! (too hard); the middle band balances learnability and information.

use super::{BatchView, Selector};
use crate::linalg::Workspace;

pub struct Moderate;

impl Selector for Moderate {
    fn name(&self) -> &'static str {
        "moderate"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        let r = r.min(k);
        let g = view.grads;
        let e = g.cols();
        let c = view.classes;
        // Class centroids in sketch space.
        let mut centroids = vec![vec![0.0f64; e]; c];
        let mut counts = vec![0usize; c];
        for i in 0..k {
            let y = view.labels[i] as usize;
            counts[y] += 1;
            for (t, &v) in g.row(i).iter().enumerate() {
                centroids[y][t] += v;
            }
        }
        for (cls, cent) in centroids.iter_mut().enumerate() {
            let inv = 1.0 / counts[cls].max(1) as f64;
            for v in cent.iter_mut() {
                *v *= inv;
            }
        }
        // Distance to own centroid.
        let dist: Vec<f64> = (0..k)
            .map(|i| {
                let cent = &centroids[view.labels[i] as usize];
                g.row(i)
                    .iter()
                    .zip(cent)
                    .map(|(&a, &b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        // Per-class median distance.
        let mut med = vec![0.0f64; c];
        for cls in 0..c {
            let mut ds: Vec<f64> = (0..k)
                .filter(|&i| view.labels[i] as usize == cls)
                .map(|i| dist[i])
                .collect();
            if ds.is_empty() {
                continue;
            }
            ds.sort_unstable_by(f64::total_cmp);
            med[cls] = ds[ds.len() / 2];
        }
        // Rank by |dist − class median| ascending (most moderate first).
        out.clear();
        out.extend(0..k);
        out.sort_unstable_by(|&a, &b| {
            let da = (dist[a] - med[view.labels[a] as usize]).abs();
            let db = (dist[b] - med[view.labels[b] as usize]).abs();
            da.total_cmp(&db).then(a.cmp(&b))
        });
        out.truncate(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::testsupport::check_selector;
    use crate::selection::BatchView;

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(Moderate));
    }

    #[test]
    fn prefers_median_band() {
        // One class on a 1-D sketch: values 0..9; the median-distance
        // samples (neither centroid-huggers nor outliers) come first.
        let k = 10;
        let g = Mat::from_fn(k, 1, |i, _| i as f64);
        let feats = Mat::zeros(k, 2);
        let losses = vec![0.0; k];
        let labels = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &feats,
            grads: &g,
            losses: &losses,
            labels: &labels,
            preds: &labels,
            classes: 1,
            row_ids: &ids,
        };
        let sel = Moderate.select(&view, 2);
        // centroid = 4.5, distances |i-4.5| ∈ {4.5,3.5,…}; median dist = 2.5
        // → the most "moderate" rows are i=2 and i=7 (dist 2.5 exactly).
        let mut s = sel;
        s.sort_unstable();
        assert_eq!(s, vec![2, 7]);
    }
}

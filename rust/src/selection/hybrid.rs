//! Explore/exploit hybrid (GraND-style): a seeded random fraction mixed
//! into the Fast MaxVol subset.
//!
//! The exploit share `1 − φ` of the budget is the plain feature-volume
//! criterion ([`fast_maxvol_with`] + loss top-up, exactly the
//! [`FastMaxVol`](super::maxvol::FastMaxVol) path); the explore share `φ`
//! is drawn uniformly without replacement from the unselected complement
//! with a seeded partial Fisher–Yates.  The two endpoints are **bitwise**
//! pins, not approximations:
//!
//! * `φ = 0` runs the identical instruction stream as `FastMaxVol` and
//!   draws no RNG at all;
//! * `φ = 1` consumes the identical `Rng::below` sequence as
//!   [`RandomSelector`](super::random::RandomSelector) with the same seed,
//!   call after call.
//!
//! Stateful (the RNG advances per selection, like the random baseline), so
//! the method is not shardable: the engine falls back to a serial instance
//! with a recorded note, which also keeps selections identical across
//! requested execution shapes.

use super::maxvol::fast_maxvol_with;
use super::{BatchView, Selector};
use crate::linalg::Workspace;
use crate::rng::Rng;

/// Default explore fraction when the method is constructed by name
/// (`selection::by_name("hybrid")`) without an explicit knob.
pub const DEFAULT_EXPLORE: f64 = 0.25;

pub struct Hybrid {
    rng: Rng,
    explore: f64,
}

impl Hybrid {
    /// `explore` = φ ∈ [0, 1]: the fraction of the budget drawn at random.
    /// Callers validating user input should go through
    /// [`EngineBuilder::explore_fraction`](crate::engine::EngineBuilder::explore_fraction),
    /// which returns a typed error instead of panicking.
    pub fn new(seed: u64, explore: f64) -> Self {
        assert!(
            explore.is_finite() && (0.0..=1.0).contains(&explore),
            "explore fraction must be in [0, 1], got {explore}"
        );
        Hybrid { rng: Rng::new(seed), explore }
    }
}

impl Selector for Hybrid {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        let k = view.k();
        if k == 0 {
            return;
        }
        let want = r.min(k);
        let explore_n = ((self.explore * want as f64).round() as usize).min(want);
        let exploit_n = want - explore_n;
        if exploit_n > 0 {
            // The FastMaxVol path verbatim, at the exploit share of the
            // budget: φ = 0 makes this the whole selection, bit for bit.
            let width = view.features.cols().min(exploit_n);
            fast_maxvol_with(view.features, width, ws, out);
            super::top_up_by_loss(view, exploit_n, ws, out);
        }
        if explore_n > 0 {
            // Ascending complement table + partial Fisher–Yates: with an
            // empty exploit set (φ = 1) the table is 0..k in order, so the
            // `below()` sequence — and the subset — is exactly
            // `Rng::choose(k, want)`, matching the random baseline.
            let taken = &mut ws.sel_taken;
            taken.clear();
            taken.resize(k, false);
            for &i in out.iter() {
                taken[i] = true;
            }
            let cand = &mut ws.sel_rest;
            cand.clear();
            cand.extend((0..k).filter(|&i| !taken[i]));
            let m = cand.len();
            let need = explore_n.min(m);
            for i in 0..need {
                let j = i + self.rng.below(m - i);
                cand.swap(i, j);
            }
            out.extend(cand.iter().take(need).copied());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::maxvol::FastMaxVol;
    use crate::selection::random::RandomSelector;
    use crate::selection::testsupport::{check_selector, random_view};

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(Hybrid::new(11, 0.25)));
        check_selector(|| Box::new(Hybrid::new(11, 0.0)));
        check_selector(|| Box::new(Hybrid::new(11, 1.0)));
    }

    #[test]
    fn explore_zero_is_pure_maxvol_bitwise() {
        let owned = random_view(64, 8, 16, 4, 21);
        for r in [1usize, 4, 8, 24] {
            let h = Hybrid::new(999, 0.0).select(&owned.view(), r);
            let m = FastMaxVol.select(&owned.view(), r);
            assert_eq!(h, m, "r={r}");
        }
    }

    #[test]
    fn explore_one_is_seeded_random_bitwise() {
        let owned = random_view(64, 8, 16, 4, 22);
        let mut h = Hybrid::new(7, 1.0);
        let mut rnd = RandomSelector::new(7);
        // Successive draws must track the baseline's RNG state exactly.
        for r in [8usize, 8, 16, 3] {
            assert_eq!(h.select(&owned.view(), r), rnd.select(&owned.view(), r), "r={r}");
        }
    }

    #[test]
    fn intermediate_fraction_mixes_both_criteria() {
        let owned = random_view(64, 8, 16, 4, 23);
        let sel = Hybrid::new(5, 0.5).select(&owned.view(), 8);
        assert_eq!(sel.len(), 8);
        // Exploit half is the MaxVol prefix (prefix-nested greedy).
        let exploit = FastMaxVol.select(&owned.view(), 4);
        assert_eq!(&sel[..4], &exploit[..], "exploit share keeps the volume criterion");
        let mut u = sel.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 8, "explore share never duplicates the exploit rows");
    }

    #[test]
    fn deterministic_in_seed() {
        let owned = random_view(64, 8, 16, 4, 24);
        let a = Hybrid::new(3, 0.5).select(&owned.view(), 12);
        let b = Hybrid::new(3, 0.5).select(&owned.view(), 12);
        assert_eq!(a, b);
        let mut c = Hybrid::new(4, 0.5);
        let c1 = c.select(&owned.view(), 12);
        let c2 = c.select(&owned.view(), 12);
        assert_ne!(c1, c2, "RNG advances across selections");
    }

    #[test]
    #[should_panic(expected = "explore fraction")]
    fn constructor_rejects_out_of_range() {
        let _ = Hybrid::new(1, 1.5);
    }
}

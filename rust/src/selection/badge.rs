//! BADGE baseline (Ash et al. 2020): diverse + uncertain selection via
//! k-means++ seeding on per-sample gradient embeddings.  The gradient
//! norm encodes uncertainty and the k-means++ distance rule enforces
//! diversity — the exact construction of the paper's related work (§2).

use super::{BatchView, Selector};
use crate::linalg::Workspace;
use crate::rng::Rng;

pub struct Badge {
    rng: Rng,
}

impl Badge {
    pub fn new(seed: u64) -> Self {
        Badge { rng: Rng::new(seed) }
    }
}

impl Selector for Badge {
    fn name(&self) -> &'static str {
        "badge"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        let r = r.min(k);
        let g = view.grads;
        // First centre: largest gradient norm (most uncertain).
        let norm2 = |i: usize| crate::linalg::dot(g.row(i), g.row(i));
        let first = (0..k)
            .max_by(|&a, &b| norm2(a).total_cmp(&norm2(b)))
            .unwrap_or(0);
        out.clear();
        out.push(first);
        let mut taken = vec![false; k];
        taken[first] = true;
        // Squared distance to nearest selected centre.
        let dist = |i: usize, c: usize, g: &crate::linalg::Mat| {
            let (a, b) = (g.row(i), g.row(c));
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
        };
        let mut d2: Vec<f64> = (0..k).map(|i| dist(i, first, g)).collect();
        while out.len() < r {
            // k-means++ rule: sample ∝ D².  Deterministic given the seed.
            let total: f64 = (0..k).filter(|&i| !taken[i]).map(|i| d2[i]).sum();
            let pick = if total <= 1e-18 {
                // Degenerate (all identical): first untaken index.
                (0..k).find(|&i| !taken[i]).unwrap()
            } else {
                let mut u = self.rng.uniform() * total;
                let mut chosen = usize::MAX;
                for i in 0..k {
                    if taken[i] {
                        continue;
                    }
                    if u < d2[i] {
                        chosen = i;
                        break;
                    }
                    u -= d2[i];
                }
                if chosen == usize::MAX {
                    (0..k).rev().find(|&i| !taken[i]).unwrap()
                } else {
                    chosen
                }
            };
            taken[pick] = true;
            out.push(pick);
            for i in 0..k {
                if !taken[i] {
                    d2[i] = d2[i].min(dist(i, pick, g));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::BatchView;

    fn view_over<'a>(
        g: &'a Mat,
        feats: &'a Mat,
        losses: &'a [f64],
        labels: &'a [i32],
        ids: &'a [usize],
    ) -> BatchView<'a> {
        BatchView {
            features: feats,
            grads: g,
            losses,
            labels,
            preds: labels,
            classes: 2,
            row_ids: ids,
        }
    }

    #[test]
    fn contract_basics() {
        let mut rng = crate::rng::Rng::new(1);
        let g = Mat::from_fn(40, 8, |_, _| rng.normal());
        let feats = Mat::zeros(40, 2);
        let losses = vec![0.0; 40];
        let labels = vec![0i32; 40];
        let ids: Vec<usize> = (0..40).collect();
        let view = view_over(&g, &feats, &losses, &labels, &ids);
        for r in [1usize, 5, 20] {
            let sel = Badge::new(7).select(&view, r);
            assert_eq!(sel.len(), r);
            let mut s = sel;
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), r);
        }
    }

    #[test]
    fn first_pick_is_max_norm() {
        let mut g = Mat::zeros(10, 3);
        for i in 0..10 {
            g[(i, 0)] = i as f64;
        }
        let feats = Mat::zeros(10, 2);
        let losses = vec![0.0; 10];
        let labels = vec![0i32; 10];
        let ids: Vec<usize> = (0..10).collect();
        let view = view_over(&g, &feats, &losses, &labels, &ids);
        let sel = Badge::new(2).select(&view, 3);
        assert_eq!(sel[0], 9);
    }

    #[test]
    fn spans_clusters() {
        // Two far-apart gradient clusters: both must be represented.
        let mut g = Mat::zeros(20, 2);
        for i in 0..20 {
            if i < 10 {
                g[(i, 0)] = 100.0 + i as f64 * 0.01;
            } else {
                g[(i, 1)] = 100.0 + i as f64 * 0.01;
            }
        }
        let feats = Mat::zeros(20, 2);
        let losses = vec![0.0; 20];
        let labels = vec![0i32; 20];
        let ids: Vec<usize> = (0..20).collect();
        let view = view_over(&g, &feats, &losses, &labels, &ids);
        let sel = Badge::new(3).select(&view, 2);
        let c0 = sel.iter().filter(|&&i| i < 10).count();
        assert_eq!(c0, 1, "one pick per cluster: {sel:?}");
    }

    #[test]
    fn degenerate_identical_gradients() {
        let g = Mat::from_fn(12, 4, |_, _| 1.0);
        let feats = Mat::zeros(12, 2);
        let losses = vec![0.0; 12];
        let labels = vec![0i32; 12];
        let ids: Vec<usize> = (0..12).collect();
        let view = view_over(&g, &feats, &losses, &labels, &ids);
        let sel = Badge::new(4).select(&view, 5);
        assert_eq!(sel.len(), 5);
        let mut s = sel;
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
    }
}

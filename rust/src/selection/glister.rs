//! GLISTER baseline (Killamsetty et al. 2021): bi-level "generalisation
//! based" selection — greedily pick samples whose gradient most increases
//! held-out performance, using the standard first-order (Taylor)
//! approximation: gain(i) ≈ ⟨g_i, g_val⟩ where g_val is the validation
//! gradient after the tentative update.
//!
//! We use the batch-mean gradient of *correctly-labelled-hard* rows as the
//! validation surrogate (the coordinator passes a held-out split when
//! available; inside a batch the surrogate is the mean gradient, which is
//! what CORDS' online variant reduces to at batch scope).

use super::{BatchView, Selector};
use crate::linalg::{dot, Workspace};

pub struct Glister {
    /// Learning-rate used in the one-step Taylor update.
    pub eta: f64,
}

impl Default for Glister {
    fn default() -> Self {
        Glister { eta: 0.1 }
    }
}

impl Selector for Glister {
    fn name(&self) -> &'static str {
        "glister"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        let r = r.min(k);
        let g = view.grads;
        let e = g.cols();
        // Validation surrogate gradient = batch mean.
        let mut gval = vec![0.0f64; e];
        for i in 0..k {
            for (t, &v) in g.row(i).iter().enumerate() {
                gval[t] += v;
            }
        }
        for t in gval.iter_mut() {
            *t /= k as f64;
        }
        // Greedy with Taylor re-estimation: after adding i, the validation
        // gradient moves by −η H g_i ≈ −η g_i (identity-Hessian approx, as
        // in GLISTER-ONLINE's last-layer variant).
        let mut taken = vec![false; k];
        out.clear();
        let mut cur = gval;
        for _ in 0..r {
            let (mut best, mut bestval) = (usize::MAX, f64::MIN);
            for i in 0..k {
                if taken[i] {
                    continue;
                }
                let gain = dot(g.row(i), &cur);
                if gain > bestval {
                    best = i;
                    bestval = gain;
                }
            }
            taken[best] = true;
            out.push(best);
            for (c, &gi) in cur.iter_mut().zip(g.row(best)) {
                *c -= self.eta * gi;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::testsupport::check_selector;
    use crate::selection::BatchView;

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(Glister::default()));
    }

    #[test]
    fn prefers_aligned_gradients() {
        // Rows aligned with the mean direction must be picked first.
        let k = 20;
        let mut g = Mat::zeros(k, 4);
        for i in 0..k {
            g[(i, 0)] = 1.0; // common direction
            g[(i, 1)] = if i < 3 { 3.0 } else { 0.0 }; // rows 0-2: extra aligned mass
        }
        // Mean has a positive component on axis 1 → rows 0..3 score highest.
        let feats = Mat::zeros(k, 2);
        let losses = vec![1.0; k];
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &feats,
            grads: &g,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        // Tiny eta so Taylor deflation doesn't reorder the aligned rows.
        let sel = Glister { eta: 0.001 }.select(&view, 3);
        let mut s = sel;
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2]);
    }

    #[test]
    fn taylor_deflation_diversifies() {
        // With a huge eta, repeatedly picking the same direction is
        // penalised — selections should span both clusters.
        let k = 16;
        let mut g = Mat::zeros(k, 2);
        for i in 0..k {
            if i < 8 {
                g[(i, 0)] = 2.0;
            } else {
                g[(i, 1)] = 1.9;
            }
        }
        let feats = Mat::zeros(k, 2);
        let losses = vec![1.0; k];
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &feats,
            grads: &g,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        let sel = Glister { eta: 1.0 }.select(&view, 4);
        let c0 = sel.iter().filter(|&&i| i < 8).count();
        assert!(c0 >= 1 && c0 <= 3, "should mix clusters: {sel:?}");
    }
}

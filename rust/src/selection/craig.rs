//! CRAIG baseline (Mirzasoleiman et al. 2020): coreset selection as
//! submodular facility-location maximisation over gradient similarity —
//! greedily pick the sample that best "covers" all others, where coverage
//! is the maximum gradient-sketch similarity to any selected exemplar.

use super::{BatchView, Selector};
use crate::linalg::{dot, Workspace};

pub struct Craig;

impl Selector for Craig {
    fn name(&self) -> &'static str {
        "craig"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        let r = r.min(k);
        let g = view.grads;
        // Similarity: shifted inner product so all values are ≥ 0 (facility
        // location needs non-negative utilities).
        let mut sims = vec![0.0f64; k * k];
        let mut smin = f64::MAX;
        for i in 0..k {
            for j in 0..k {
                let s = dot(g.row(i), g.row(j));
                sims[i * k + j] = s;
                smin = smin.min(s);
            }
        }
        for s in sims.iter_mut() {
            *s -= smin;
        }
        // Greedy facility location: coverage[j] = max_{i∈S} sim(i, j).
        let mut coverage = vec![0.0f64; k];
        let mut taken = vec![false; k];
        out.clear();
        for _ in 0..r {
            let (mut best, mut bestgain) = (usize::MAX, -1.0f64);
            for cand in 0..k {
                if taken[cand] {
                    continue;
                }
                let mut gain = 0.0;
                let row = &sims[cand * k..(cand + 1) * k];
                for j in 0..k {
                    let c = row[j];
                    if c > coverage[j] {
                        gain += c - coverage[j];
                    }
                }
                if gain > bestgain {
                    best = cand;
                    bestgain = gain;
                }
            }
            taken[best] = true;
            out.push(best);
            let row = &sims[best * k..(best + 1) * k];
            for j in 0..k {
                coverage[j] = coverage[j].max(row[j]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::testsupport::{check_selector, random_view};
    use crate::selection::BatchView;

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(Craig));
    }

    #[test]
    fn covers_clusters() {
        // Three well-separated gradient clusters; with r=3 CRAIG must pick
        // one exemplar from each.
        let k = 30;
        let mut g = Mat::zeros(k, 3);
        for i in 0..k {
            g[(i, i % 3)] = 5.0 + (i as f64) * 0.01;
        }
        let feats = Mat::zeros(k, 2);
        let losses = vec![1.0; k];
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &feats,
            grads: &g,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        let sel = Craig.select(&view, 3);
        let mut clusters: Vec<usize> = sel.iter().map(|&i| i % 3).collect();
        clusters.sort_unstable();
        assert_eq!(clusters, vec![0, 1, 2]);
    }

    #[test]
    fn marginal_gains_monotone() {
        // Submodularity sanity: first pick's gain ≥ later picks' gains.
        // We proxy-check via coverage improvement decreasing.
        let owned = random_view(48, 6, 12, 3, 5);
        let sel = Craig.select(&owned.view(), 10);
        assert_eq!(sel.len(), 10);
    }
}

//! GradMatch baseline (Killamsetty et al. 2021): pick a subset whose
//! gradient combination matches the full-batch mean gradient, via greedy
//! Orthogonal Matching Pursuit on the per-sample gradient sketches —
//! exactly the mechanism GRAFT's §1 contrasts itself against ("explicit
//! comparisons of gradient vectors").

use super::{BatchView, Selector};
use crate::linalg::{dot, norm2, Mat, Workspace};

pub struct GradMatch {
    /// Residual tolerance for early stop (the budget r still rules).
    pub tol: f64,
}

impl Default for GradMatch {
    fn default() -> Self {
        GradMatch { tol: 1e-8 }
    }
}

impl Selector for GradMatch {
    fn name(&self) -> &'static str {
        "gradmatch"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        let r = r.min(k);
        let g = view.grads; // K×E
        let e = g.cols();
        // Target: mean gradient.
        let mut target = vec![0.0f64; e];
        for i in 0..k {
            for (t, &v) in g.row(i).iter().enumerate() {
                target[t] += v;
            }
        }
        let inv = 1.0 / k as f64;
        for t in target.iter_mut() {
            *t *= inv;
        }

        // OMP with an incrementally orthonormalised dictionary (MGS), so
        // each step is O(K·E) for scoring + O(|S|·E) for the basis update.
        let mut residual = target.clone();
        let mut taken = vec![false; k];
        let mut basis: Vec<Vec<f64>> = Vec::with_capacity(r);
        out.clear();
        for _ in 0..r {
            // Highest |correlation| with the residual (normalised atoms).
            let (mut best, mut bestval) = (usize::MAX, -1.0f64);
            for i in 0..k {
                if taken[i] {
                    continue;
                }
                let row = g.row(i);
                let n = norm2(row);
                let c = if n > 1e-12 { dot(row, &residual).abs() / n } else { 0.0 };
                if c > bestval {
                    best = i;
                    bestval = c;
                }
            }
            taken[best] = true;
            out.push(best);
            // Orthonormalise the new atom against the basis, then deflate
            // the residual (OMP re-projection onto the selected span).
            let mut atom = g.row(best).to_vec();
            for b in &basis {
                let p = dot(b, &atom);
                for (a, &bb) in atom.iter_mut().zip(b) {
                    *a -= p * bb;
                }
            }
            let n = norm2(&atom);
            if n > 1e-10 {
                for a in atom.iter_mut() {
                    *a /= n;
                }
                let p = dot(&atom, &residual);
                for (rv, &av) in residual.iter_mut().zip(&atom) {
                    *rv -= p * av;
                }
                basis.push(atom);
            }
            if norm2(&residual) < self.tol {
                // Fill the remaining budget with unselected max-norm rows
                // (the CORDS implementation pads similarly).
                break;
            }
        }
        if out.len() < r {
            let mut rest: Vec<usize> = (0..k).filter(|&i| !taken[i]).collect();
            rest.sort_unstable_by(|&a, &b| {
                norm2(g.row(b)).total_cmp(&norm2(g.row(a))).then(a.cmp(&b))
            });
            out.extend(rest.into_iter().take(r - out.len()));
        }
    }
}

/// Residual gradient error ‖ḡ − proj_span(S) ḡ‖₂ — the quantity GradMatch
/// minimises; exposed for tests and the Table 1 complexity bench.
pub fn residual_error(g: &Mat, subset: &[usize]) -> f64 {
    let k = g.rows();
    let e = g.cols();
    let mut target = vec![0.0f64; e];
    for i in 0..k {
        for (t, &v) in g.row(i).iter().enumerate() {
            target[t] += v;
        }
    }
    for t in target.iter_mut() {
        *t /= k as f64;
    }
    let sub = g.take_rows(subset).transpose(); // E×|S|
    let (_, res) = crate::linalg::project_onto_colspace(&sub, &target);
    res.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testsupport::{check_selector, random_view};

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(GradMatch::default()));
    }

    #[test]
    fn residual_decreases_with_budget() {
        let owned = random_view(48, 6, 10, 3, 7);
        let view = owned.view();
        let mut gm = GradMatch::default();
        let mut prev = f64::MAX;
        for r in [2usize, 4, 8, 16] {
            let sel = gm.select(&view, r);
            let err = residual_error(&owned.grads, &sel);
            assert!(err <= prev + 1e-9, "r={r}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn beats_random_at_matching() {
        let owned = random_view(64, 6, 12, 4, 8);
        let view = owned.view();
        let sel = GradMatch::default().select(&view, 6);
        let err_gm = residual_error(&owned.grads, &sel);
        let mut rng = crate::rng::Rng::new(9);
        let mut errs: Vec<f64> = (0..15)
            .map(|_| residual_error(&owned.grads, &rng.choose(64, 6)))
            .collect();
        errs.sort_by(f64::total_cmp);
        assert!(err_gm <= errs[7], "gm {err_gm} vs random median {}", errs[7]);
    }
}

//! DRoP baseline (Vysogorets et al. 2025): distributionally-robust data
//! pruning — allocate the per-class budget inversely to class performance
//! (hard classes keep more data), then sample uniformly within class.
//! Reproduces DRoP's signature behaviour in the paper's tables: very low
//! emissions but steep accuracy loss at small fractions.

use super::{BatchView, Selector};
use crate::linalg::Workspace;
use crate::rng::Rng;

pub struct Drop {
    rng: Rng,
}

impl Drop {
    pub fn new(seed: u64) -> Self {
        Drop { rng: Rng::new(seed) }
    }
}

impl Selector for Drop {
    fn name(&self) -> &'static str {
        "drop"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        let r = r.min(k);
        let c = view.classes;
        // Per-class error rates (robust weighting signal).
        let mut total = vec![0usize; c];
        let mut wrong = vec![0usize; c];
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); c];
        for i in 0..k {
            let y = view.labels[i] as usize;
            total[y] += 1;
            if view.preds[i] != view.labels[i] {
                wrong[y] += 1;
            }
            members[y].push(i);
        }
        // Budget ∝ error (Laplace-smoothed), capped by availability.
        let weights: Vec<f64> = (0..c)
            .map(|j| {
                if total[j] == 0 {
                    0.0
                } else {
                    (wrong[j] as f64 + 1.0) / (total[j] as f64 + 2.0)
                }
            })
            .collect();
        let wsum: f64 = weights.iter().sum();
        let mut quota: Vec<usize> = weights
            .iter()
            .zip(&total)
            .map(|(&w, &t)| (((w / wsum.max(1e-12)) * r as f64).floor() as usize).min(t))
            .collect();
        // Distribute the remainder round-robin to classes with headroom.
        let mut assigned: usize = quota.iter().sum();
        let mut j = 0;
        while assigned < r {
            if quota[j] < total[j] {
                quota[j] += 1;
                assigned += 1;
            }
            j = (j + 1) % c;
        }
        // Within class: keep the easiest (lowest-loss) prototypes first —
        // the DRoP pruning rule whose low-fraction brittleness the paper's
        // tables exhibit (easy prototypes carry little boundary
        // information, so aggressive pruning underfits).
        out.clear();
        for (cls, &q) in quota.iter().enumerate() {
            if q == 0 {
                continue;
            }
            let mut m = members[cls].clone();
            m.sort_unstable_by(|&a, &b| {
                view.losses[a].total_cmp(&view.losses[b]).then(a.cmp(&b))
            });
            out.extend(m.into_iter().take(q));
        }
        // rng retained for tie-breaking compatibility / future variants.
        let _ = &mut self.rng;
        out.truncate(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::testsupport::random_view;
    use crate::selection::BatchView;

    #[test]
    fn contract_sizes() {
        let owned = random_view(64, 8, 16, 4, 11);
        let mut s = Drop::new(1);
        for r in [1usize, 4, 16, 48] {
            let sel = s.select(&owned.view(), r);
            assert_eq!(sel.len(), r);
            let mut u = sel.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), r);
        }
    }

    #[test]
    fn hard_classes_get_more_budget() {
        // Class 1 always mispredicted, class 0 always right.
        let k = 40;
        let feats = Mat::zeros(k, 2);
        let grads = Mat::zeros(k, 2);
        let losses = vec![1.0; k];
        let labels: Vec<i32> = (0..k).map(|i| (i % 2) as i32).collect();
        let preds: Vec<i32> = labels.iter().map(|&y| if y == 1 { 0 } else { 0 }).collect();
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &feats,
            grads: &grads,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 2,
            row_ids: &ids,
        };
        let sel = Drop::new(2).select(&view, 10);
        let hard = sel.iter().filter(|&&i| labels[i] == 1).count();
        assert!(hard >= 6, "hard class got {hard}/10");
    }
}

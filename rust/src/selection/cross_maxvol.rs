//! CrossMaxVol baseline — the Cross-2D skeleton method (Tyrtyshnikov 2000)
//! the paper compares against in Table 4 / Fig 4 (right): alternate MaxVol
//! sweeps over rows given the current columns and over columns given the
//! current rows, until the selections stabilise.
//!
//! As the paper notes (§3), the concurrent row/column search is (a) more
//! expensive per iteration and (b) sensitive to initialisation — both
//! properties our benchmark reproduces.

use super::{BatchView, Selector};
use crate::linalg::{transpose_into, Mat, Workspace};
use crate::selection::maxvol::fast_maxvol;

pub struct CrossMaxVol {
    pub max_sweeps: usize,
}

impl Default for CrossMaxVol {
    fn default() -> Self {
        CrossMaxVol { max_sweeps: 20 }
    }
}

impl CrossMaxVol {
    /// Select `r` rows (and internally r columns) of `a` by alternating
    /// row/column MaxVol. Returns (rows, sweeps executed).
    pub fn select_rows(&self, a: &Mat, r: usize) -> (Vec<usize>, usize) {
        let (k, m) = (a.rows(), a.cols());
        let r = r.min(k).min(m);
        // Init: first r columns (the paper notes initialisation sensitivity;
        // this deterministic choice mirrors teneva's default).
        let mut cols: Vec<usize> = (0..r).collect();
        let mut rows: Vec<usize> = Vec::new();
        let mut sweeps = 0;
        // Sweep scratch, held across iterations: the row gather (r×m) and
        // its transpose (m×r) would otherwise be two fresh `Mat`s per
        // sweep.
        let mut gather = vec![0.0f64; r * m];
        let mut subr = Mat::zeros(m, r);
        for _ in 0..self.max_sweeps {
            sweeps += 1;
            // Rows maximising volume within the selected columns.
            let sub = a.take_cols(&cols);
            let new_rows = fast_maxvol(&sub, r);
            // Columns maximising volume within the selected rows.
            for (t, &ri) in new_rows.iter().enumerate() {
                gather[t * m..(t + 1) * m].copy_from_slice(a.row(ri));
            }
            transpose_into(r, m, &gather, subr.data_mut()); // m×r
            let new_cols = fast_maxvol(&subr, r);
            let converged = new_rows == rows && new_cols == cols;
            rows = new_rows;
            cols = new_cols;
            if converged {
                break;
            }
        }
        (rows, sweeps)
    }
}

impl Selector for CrossMaxVol {
    fn name(&self) -> &'static str {
        "cross-maxvol"
    }

    /// Stateless, volume-based: compatible with the sharded coordinator's
    /// second-stage MaxVol merge.
    fn shardable(&self) -> bool {
        true
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let width = view.features.cols().min(r);
        let (rows, _) = self.select_rows(view.features, width);
        out.clear();
        out.extend_from_slice(&rows);
        super::top_up_by_loss(view, r, ws, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::selection::testsupport::check_selector;

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(CrossMaxVol::default()));
    }

    #[test]
    fn converges_on_random_input() {
        let mut rng = Rng::new(31);
        let a = Mat::from_fn(60, 20, |_, _| rng.normal());
        let cm = CrossMaxVol::default();
        let (rows, sweeps) = cm.select_rows(&a, 6);
        assert_eq!(rows.len(), 6);
        assert!(sweeps <= 20);
        let mut s = rows.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 6);
    }

    #[test]
    fn selects_informative_rows_on_structured_input() {
        // Rows 0..4 carry all the energy; CrossMaxVol must find them.
        let mut rng = Rng::new(32);
        let mut a = Mat::zeros(40, 10);
        for i in 0..4 {
            for j in 0..10 {
                a[(i, j)] = 10.0 * rng.normal();
            }
        }
        for i in 4..40 {
            for j in 0..10 {
                a[(i, j)] = 0.01 * rng.normal();
            }
        }
        let (rows, _) = CrossMaxVol::default().select_rows(&a, 4);
        let mut r = rows;
        r.sort_unstable();
        assert_eq!(r, vec![0, 1, 2, 3]);
    }
}

//! Fast MaxVol row selection (paper §3.1) — the Rust twin of the L1 Pallas
//! kernel, used (a) for selection on non-AOT data paths, (b) for the
//! Table 4 speed benchmark, and (c) for channel pruning.
//!
//! Also contains the *conventional* MaxVol (Goreinov et al. 2010) swap
//! iteration, which the CrossMaxVol baseline builds on.  Per-swap cost is
//! O(K·r) via a Sherman–Morrison rank-1 update of the interpolation
//! matrix; the original full re-inversion is kept as
//! [`conventional_maxvol_reference`] for tests.

use super::{BatchView, Selector};
use crate::linalg::{axpy_lanes, lu_solve, Mat, Workspace};

/// Greedy Fast MaxVol: selects `r` rows of the K×R matrix `v` (r ≤ R ≤ K)
/// with one rank-1 elimination per step — O(K·R·r) total, O(KR²) at r = R.
/// The returned sequence is prefix-nested.
///
/// Allocating wrapper over [`fast_maxvol_with`].
pub fn fast_maxvol(v: &Mat, r: usize) -> Vec<usize> {
    let mut ws = Workspace::default();
    let mut out = Vec::with_capacity(r);
    fast_maxvol_with(v, r, &mut ws, &mut out);
    out
}

/// [`fast_maxvol`] drawing every scratch buffer (working copy, pivot row,
/// selection mask) from a caller-owned [`Workspace`]: zero heap
/// allocations once the workspace and `out` have warmed up.  Pivot choice
/// and elimination arithmetic are performed in the same order as the
/// scalar reference, so the result is bit-identical to
/// [`fast_maxvol_reference`].
pub fn fast_maxvol_with(v: &Mat, r: usize, ws: &mut Workspace, out: &mut Vec<usize>) {
    fast_maxvol_core(v.data(), v.rows(), v.cols(), r, ws, out);
}

/// [`fast_maxvol_with`] on a raw row-major K×R slice instead of a [`Mat`]
/// — the same kernel, byte for byte, for callers that keep their candidate
/// rows in a flat buffer (the streaming reservoir).  Extracted rather than
/// duplicated so the two paths cannot drift.
pub(crate) fn fast_maxvol_core(
    data: &[f64],
    k: usize,
    rcols: usize,
    r: usize,
    ws: &mut Workspace,
    out: &mut Vec<usize>,
) {
    assert_eq!(data.len(), k * rcols, "flat candidate buffer must be K×R");
    assert!(r <= rcols && r <= k, "need r <= min(K={k}, R={rcols}), got {r}");
    out.clear();
    // Working copy, row-major K×R; selected mask keeps selections unique
    // even on rank-deficient inputs (matches the Pallas kernel).
    let w = &mut ws.mv_w;
    w.clear();
    w.extend_from_slice(data);
    let taken = &mut ws.mv_taken;
    taken.clear();
    taken.resize(k, false);
    ws.mv_prow.clear();
    ws.mv_prow.resize(rcols, 0.0);
    for j in 0..r {
        // argmax |w[:, j]| over untaken rows.
        let (mut best, mut bestval) = (usize::MAX, -1.0f64);
        for i in 0..k {
            if taken[i] {
                continue;
            }
            let a = w[i * rcols + j].abs();
            if a > bestval {
                best = i;
                bestval = a;
            }
        }
        let piv = w[best * rcols + j];
        let safe = if piv.abs() < 1e-300 {
            // Degenerate pivot: selection proceeds (clamped, matching the
            // Pallas kernel) but the workspace counts it so the engine's
            // fault path can surface the breakdown instead of silently
            // returning a subset the volume criterion no longer justifies.
            ws.mv_degenerate += 1;
            if piv >= 0.0 { 1e-300 } else { -1e-300 }
        } else {
            piv
        };
        taken[best] = true;
        out.push(best);
        if j + 1 == r {
            break;
        }
        // Rank-1 elimination on the remaining columns:
        //   w[:, l] -= col_j * w[best, l] / piv   for l > j
        let width = rcols - j - 1;
        {
            let base = best * rcols;
            for t in 0..width {
                ws.mv_prow[t] = w[base + j + 1 + t] / safe;
            }
        }
        let prow = &ws.mv_prow[..width];
        for i in 0..k {
            let base = i * rcols;
            let ci = w[base + j];
            if ci == 0.0 {
                continue;
            }
            // row -= ci·prow as a lane axpy with negated coefficient:
            // bit-identical to the scalar subtraction (IEEE negation is
            // exact), so the reference/cached-replay pins are untouched.
            let row = &mut w[base + j + 1..base + rcols];
            axpy_lanes(row, -ci, prow);
        }
    }
}

/// The pre-PR scalar implementation (clones its input, allocates per
/// pivot).  Ground truth for the bit-identical property test and the
/// "before" rows of the hot-path regression bench.
pub fn fast_maxvol_reference(v: &Mat, r: usize) -> Vec<usize> {
    let (k, rcols) = (v.rows(), v.cols());
    assert!(r <= rcols && r <= k, "need r <= min(K={k}, R={rcols}), got {r}");
    let mut w = v.clone();
    let mut taken = vec![false; k];
    let mut p = Vec::with_capacity(r);
    for j in 0..r {
        let (mut best, mut bestval) = (usize::MAX, -1.0f64);
        for i in 0..k {
            if taken[i] {
                continue;
            }
            let a = w[(i, j)].abs();
            if a > bestval {
                best = i;
                bestval = a;
            }
        }
        let piv = w[(best, j)];
        let safe = if piv.abs() < 1e-300 {
            if piv >= 0.0 { 1e-300 } else { -1e-300 }
        } else {
            piv
        };
        taken[best] = true;
        p.push(best);
        if j + 1 == r {
            break;
        }
        let prow: Vec<f64> = (j + 1..rcols).map(|l| w[(best, l)] / safe).collect();
        for i in 0..k {
            let ci = w[(i, j)];
            if ci == 0.0 {
                continue;
            }
            let row = w.row_mut(i);
            for (t, l) in (j + 1..rcols).enumerate() {
                row[l] -= ci * prow[t];
            }
        }
    }
    p
}

/// Conventional MaxVol (Goreinov et al.): start from some r rows, swap a
/// row in whenever an interpolation-matrix entry exceeds `tau`, until
/// convergence.  Returns (rows, swap count).
///
/// The interpolation matrix B = Vr·A⁻¹ (A the selected r×r block) is
/// built once, then maintained across swaps with the Sherman–Morrison
/// rank-1 update
///
/// ```text
/// B ← B − B[:, j] ⊗ (B[i*, :] − eⱼ) / B[i*, j]
/// ```
///
/// so each swap costs O(K·r) instead of the O(r·r³ + K·r²) full
/// re-inversion of [`conventional_maxvol_reference`].
pub fn conventional_maxvol(v: &Mat, r: usize, tau: f64, max_iters: usize) -> (Vec<usize>, usize) {
    let k = v.rows();
    assert!(r <= v.cols() && r <= k);
    let cols: Vec<usize> = (0..r).collect();
    let vr = v.take_cols(&cols); // K×r
    // Initialise with the greedy selection (any non-singular start works).
    let mut rows = fast_maxvol(&vr, r);
    let mut swaps = 0;
    // One-time inverse of the starting block: row c of A⁻¹ solves Aᵀx = e_c.
    let sub = vr.take_rows(&rows); // r×r
    let subt = sub.transpose();
    let mut inv = Mat::zeros(r, r);
    for c in 0..r {
        let mut e = vec![0.0; r];
        e[c] = 1.0;
        match lu_solve(&subt, &e) {
            Some(x) => {
                for i in 0..r {
                    inv[(c, i)] = x[i];
                }
            }
            None => return (rows, swaps), // singular start: keep greedy rows
        }
    }
    let mut b = vr.matmul(&inv); // K×r with B[rows, :] = I
    let mut urow = vec![0.0f64; r];
    for _ in 0..max_iters {
        // Find max |B[i][j]|.
        let (mut bi, mut bj, mut bv) = (0usize, 0usize, 0.0f64);
        for i in 0..k {
            for (j, &x) in b.row(i).iter().enumerate() {
                let a = x.abs();
                if a > bv {
                    bi = i;
                    bj = j;
                    bv = a;
                }
            }
        }
        if bv <= tau {
            break;
        }
        let pivot = b[(bi, bj)];
        if pivot.abs() < 1e-300 {
            break; // numerically singular swap — matches reference bail-out
        }
        // urow = (B[i*, :] − e_bj) / pivot
        urow.copy_from_slice(b.row(bi));
        urow[bj] -= 1.0;
        for t in urow.iter_mut() {
            *t /= pivot;
        }
        for i in 0..k {
            let ci = b[(i, bj)];
            if ci == 0.0 {
                continue;
            }
            axpy_lanes(b.row_mut(i), -ci, &urow);
        }
        // Pin the new basis row to the exact identity it converges to,
        // stopping float drift from accumulating over long swap chains.
        for x in b.row_mut(bi).iter_mut() {
            *x = 0.0;
        }
        b[(bi, bj)] = 1.0;
        rows[bj] = bi;
        swaps += 1;
    }
    (rows, swaps)
}

/// Pre-PR conventional MaxVol: full inverse + K×r interpolation rebuild on
/// every swap.  Kept as the convergence ground truth for
/// `tests/linalg_kernels.rs`.
pub fn conventional_maxvol_reference(
    v: &Mat,
    r: usize,
    tau: f64,
    max_iters: usize,
) -> (Vec<usize>, usize) {
    let k = v.rows();
    assert!(r <= v.cols() && r <= k);
    let cols: Vec<usize> = (0..r).collect();
    let vr = v.take_cols(&cols);
    let mut rows = fast_maxvol(&vr, r);
    let mut swaps = 0;
    for _ in 0..max_iters {
        let sub = vr.take_rows(&rows);
        let mut inv = Mat::zeros(r, r);
        let subt = sub.transpose();
        let mut singular = false;
        for c in 0..r {
            let mut e = vec![0.0; r];
            e[c] = 1.0;
            match lu_solve(&subt, &e) {
                Some(x) => {
                    for i in 0..r {
                        inv[(c, i)] = x[i];
                    }
                }
                None => {
                    singular = true;
                    break;
                }
            }
        }
        if singular {
            break;
        }
        let b = vr.matmul(&inv);
        let (mut bi, mut bj, mut bv) = (0usize, 0usize, 0.0f64);
        for i in 0..k {
            for j in 0..r {
                let a = b[(i, j)].abs();
                if a > bv {
                    bi = i;
                    bj = j;
                    bv = a;
                }
            }
        }
        if bv <= tau {
            break;
        }
        rows[bj] = bi;
        swaps += 1;
    }
    (rows, swaps)
}

/// [`Selector`] wrapper over [`fast_maxvol`] on the batch feature matrix.
/// For r beyond the feature width the remainder is filled with the
/// highest-residual-loss rows (keeps the contract |S| = r for any budget).
pub struct FastMaxVol;

impl Selector for FastMaxVol {
    fn name(&self) -> &'static str {
        "maxvol"
    }

    /// Stateless, volume-based: the sharded coordinator's second-stage
    /// MaxVol merge applies exactly this criterion to the union.
    fn shardable(&self) -> bool {
        true
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let width = view.features.cols().min(r);
        fast_maxvol_with(view.features, width, ws, out);
        // Budget beyond feature rank: top-up with highest-loss rows.
        super::top_up_by_loss(view, r, ws, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::det;
    use crate::rng::Rng;
    use crate::selection::testsupport::{check_selector, random_view};

    fn randmat(k: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(k, r, |_, _| rng.normal())
    }

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(FastMaxVol));
    }

    #[test]
    fn prefix_nested() {
        let v = randmat(64, 12, 1);
        let full = fast_maxvol(&v, 12);
        for r in [1, 4, 8] {
            assert_eq!(full[..r], fast_maxvol(&v, r)[..]);
        }
    }

    #[test]
    fn first_pick_max_abs() {
        let v = randmat(40, 5, 2);
        let p = fast_maxvol(&v, 5);
        let col = v.col(0);
        let want = (0..40).max_by(|&a, &b| col[a].abs().total_cmp(&col[b].abs())).unwrap();
        assert_eq!(p[0], want);
    }

    #[test]
    fn workspace_reuse_is_stable() {
        // The same workspace must produce identical selections across
        // differently-shaped inputs (buffers are re-sized, not assumed).
        let mut ws = Workspace::default();
        let mut out = Vec::new();
        for (k, r, seed) in [(32usize, 8usize, 3u64), (16, 4, 4), (64, 12, 5)] {
            let v = randmat(k, r, seed);
            fast_maxvol_with(&v, r, &mut ws, &mut out);
            assert_eq!(out, fast_maxvol_reference(&v, r), "K={k} R={r}");
        }
    }

    #[test]
    fn volume_beats_random_median() {
        let v = randmat(64, 8, 3);
        let p = fast_maxvol(&v, 8);
        let vol = det(&v.take_rows(&p)).abs();
        let mut rng = Rng::new(4);
        let mut rand_vols: Vec<f64> = (0..21)
            .map(|_| det(&v.take_rows(&rng.choose(64, 8))).abs())
            .collect();
        rand_vols.sort_by(f64::total_cmp);
        assert!(vol >= rand_vols[10], "maxvol {vol} vs median {}", rand_vols[10]);
    }

    #[test]
    fn unique_on_duplicate_rows() {
        let mut rng = Rng::new(5);
        let base = Mat::from_fn(4, 6, |_, _| rng.normal());
        let v = Mat::from_fn(32, 6, |i, j| base[(i % 4, j)]);
        let p = fast_maxvol(&v, 6);
        let mut s = p.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 6);
    }

    #[test]
    fn matches_pallas_reference_semantics() {
        // Same algorithm as python/compile/kernels/fast_maxvol.py: verify
        // on a fixed case against the residual-solve formulation.
        let v = randmat(32, 8, 6);
        let p = fast_maxvol(&v, 8);
        // Step-by-step residual recomputation (independent path).
        let mut sel: Vec<usize> = Vec::new();
        for j in 0..8 {
            let col = v.col(j);
            let resid: Vec<f64> = if sel.is_empty() {
                col.clone()
            } else {
                let sub = v.take_rows(&sel).take_cols(&(0..j).collect::<Vec<_>>());
                let rhs: Vec<f64> = sel.iter().map(|&i| v[(i, j)]).collect();
                let coef = crate::linalg::lstsq(&sub, &rhs);
                let vj = v.take_cols(&(0..j).collect::<Vec<_>>());
                let pred = vj.matvec(&coef);
                col.iter().zip(&pred).map(|(c, p)| c - p).collect()
            };
            let mut best = (0usize, -1.0f64);
            for (i, &x) in resid.iter().enumerate() {
                if !sel.contains(&i) && x.abs() > best.1 {
                    best = (i, x.abs());
                }
            }
            sel.push(best.0);
        }
        assert_eq!(p, sel);
    }

    #[test]
    fn conventional_maxvol_dominance() {
        // After convergence every interpolation entry ≤ tau.
        let v = randmat(48, 6, 7);
        let (rows, _swaps) = conventional_maxvol(&v, 6, 1.01, 100);
        let cols: Vec<usize> = (0..6).collect();
        let vr = v.take_cols(&cols);
        let sub = vr.take_rows(&rows);
        let b = vr.matmul(&crate::linalg::pinv(&sub));
        assert!(b.max_abs() <= 1.02, "max |B| = {}", b.max_abs());
    }

    #[test]
    fn conventional_improves_or_equals_greedy_volume() {
        let v = randmat(48, 6, 8);
        let cols: Vec<usize> = (0..6).collect();
        let vr = v.take_cols(&cols);
        let greedy = fast_maxvol(&vr, 6);
        let (conv, _) = conventional_maxvol(&v, 6, 1.0, 200);
        let vol_g = det(&vr.take_rows(&greedy)).abs();
        let vol_c = det(&vr.take_rows(&conv)).abs();
        assert!(vol_c >= vol_g * 0.999, "conv {vol_c} < greedy {vol_g}");
    }

    #[test]
    fn degenerate_pivots_are_counted() {
        let mut ws = Workspace::default();
        let mut out = Vec::new();
        let v = randmat(16, 4, 11);
        fast_maxvol_with(&v, 4, &mut ws, &mut out);
        assert_eq!(ws.mv_degenerate, 0, "full-rank gaussian features are clean");
        let dup = Mat::from_fn(16, 4, |_, j| (j + 1) as f64); // identical rows
        fast_maxvol_with(&dup, 4, &mut ws, &mut out);
        assert!(ws.mv_degenerate > 0, "identical rows must trip the pivot clamp");
        assert_eq!(out.len(), 4, "clamped selection still returns unique rows");
    }

    #[test]
    fn budget_beyond_feature_rank_tops_up() {
        let owned = random_view(32, 4, 8, 2, 9);
        let sel = FastMaxVol.select(&owned.view(), 12);
        assert_eq!(sel.len(), 12);
        let mut s = sel;
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 12);
    }
}

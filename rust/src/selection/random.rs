//! Random subset baseline (Table 14): uniform sampling without replacement,
//! re-drawn at every selection refresh.

use super::{BatchView, Selector};
use crate::linalg::Workspace;
use crate::rng::Rng;

pub struct RandomSelector {
    rng: Rng,
}

impl RandomSelector {
    pub fn new(seed: u64) -> Self {
        RandomSelector { rng: Rng::new(seed) }
    }
}

impl Selector for RandomSelector {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        out.clear();
        out.extend(self.rng.choose(view.k(), r.min(view.k())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testsupport::random_view;

    #[test]
    fn contract_except_determinism() {
        // Random is stateful by design; check size/uniqueness/range only.
        let owned = random_view(64, 8, 16, 4, 1);
        let mut s = RandomSelector::new(7);
        for r in [1usize, 8, 32] {
            let sel = s.select(&owned.view(), r);
            assert_eq!(sel.len(), r);
            let mut u = sel.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), r);
        }
    }

    #[test]
    fn seeded_reproducible() {
        let owned = random_view(64, 8, 16, 4, 2);
        let a = RandomSelector::new(3).select(&owned.view(), 8);
        let b = RandomSelector::new(3).select(&owned.view(), 8);
        assert_eq!(a, b);
    }

    #[test]
    fn successive_draws_differ() {
        let owned = random_view(64, 8, 16, 4, 3);
        let mut s = RandomSelector::new(4);
        let a = s.select(&owned.view(), 8);
        let b = s.select(&owned.view(), 8);
        assert_ne!(a, b);
    }
}

//! EL2N pre-selection score (Paul et al. 2021): rank samples by per-sample
//! gradient norm (here the exact last-layers sketch norm, which for
//! cross-entropy equals the error-L2-norm ‖p − y‖ plus the hidden term)
//! and keep the top-r.

use super::{BatchView, Selector};
use crate::linalg::{norm2, Workspace};

pub struct El2n;

impl Selector for El2n {
    fn name(&self) -> &'static str {
        "el2n"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        out.clear();
        out.extend(0..k);
        out.sort_unstable_by(|&a, &b| {
            let na = norm2(view.grads.row(a));
            let nb = norm2(view.grads.row(b));
            nb.total_cmp(&na).then(a.cmp(&b))
        });
        out.truncate(r.min(k));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::testsupport::check_selector;
    use crate::selection::BatchView;

    #[test]
    fn selector_contract() {
        check_selector(|| Box::new(El2n));
    }

    #[test]
    fn picks_largest_gradients() {
        let k = 10;
        let g = Mat::from_fn(k, 2, |i, _| (k - i) as f64);
        let feats = Mat::zeros(k, 2);
        let losses = vec![0.0; k];
        let labels = vec![0i32; k];
        let preds = vec![0i32; k];
        let ids: Vec<usize> = (0..k).collect();
        let view = BatchView {
            features: &feats,
            grads: &g,
            losses: &losses,
            labels: &labels,
            preds: &preds,
            classes: 1,
            row_ids: &ids,
        };
        assert_eq!(El2n.select(&view, 3), vec![0, 1, 2]);
    }
}

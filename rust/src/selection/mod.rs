//! Subset-selection methods: GRAFT's Fast MaxVol plus every baseline the
//! paper compares against (Table 1 / §4): Random, CRAIG, GradMatch,
//! GLISTER, DRoP, CrossMaxVol, and the pre-selection scores EL2N / Forget.
//!
//! All methods consume the same [`BatchView`] — per-batch feature matrix,
//! gradient sketches, losses, labels — which the coordinator obtains from
//! the AOT `embed` artifact (or Rust-side extractors for non-AOT data).

pub mod badge;
pub mod craig;
pub mod cross_maxvol;
pub mod drop_;
pub mod el2n;
pub mod forget;
pub mod glister;
pub mod gradmatch;
pub mod hybrid;
pub mod maxvol;
pub mod moderate;
pub mod random;

use crate::graft::rank::{RankDecision, RankStats};
use crate::linalg::{Mat, Workspace};

/// Everything a selector may look at for one mini-batch.
pub struct BatchView<'a> {
    /// K×R importance-ordered feature matrix (V = f(X)).
    pub features: &'a Mat,
    /// K×E per-sample gradient sketches.
    pub grads: &'a Mat,
    /// Per-sample losses.
    pub losses: &'a [f64],
    /// Ground-truth labels.
    pub labels: &'a [i32],
    /// Current model predictions.
    pub preds: &'a [i32],
    /// Number of classes.
    pub classes: usize,
    /// Global dataset row ids of the batch rows (for stateful methods).
    pub row_ids: &'a [usize],
}

impl<'a> BatchView<'a> {
    pub fn k(&self) -> usize {
        self.features.rows()
    }
}

/// A batch-subset selector. `r` is the requested subset size; the produced
/// indices are batch-local (0..K), unique, and |result| == r.
///
/// [`Selector::select_into`] is the hot-path entry point: scratch comes
/// from a caller-owned [`Workspace`] and the selection lands in a reused
/// output buffer, so steady-state selection performs no heap allocations
/// (exactly zero for the MaxVol/GRAFT paths; baselines may still allocate
/// internally).  [`Selector::select`] is the allocating convenience
/// wrapper used by tests and one-shot callers.
pub trait Selector: Send {
    fn name(&self) -> &'static str;

    /// Write the selection for one batch into `out` (cleared first),
    /// drawing all scratch from `ws`.
    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    );

    /// Convenience wrapper over [`Selector::select_into`] for one-shot
    /// callers (tests, examples, REPL-style use).
    ///
    /// Allocation behaviour, precisely: the returned `Vec` is the only
    /// per-call heap allocation on the warm path.  Scratch comes from a
    /// **per-thread cached [`Workspace`]** — a thread's first `select`
    /// allocates the arena buffers, every later `select` on that thread
    /// reuses their capacity (buffers are cleared, never shrunk, by their
    /// consumers, so results are identical to a fresh workspace — pinned
    /// by `workspace_reuse_across_batches`).  A re-entrant call (a
    /// selector calling `select` from inside its own `select_into` on the
    /// same thread) cannot reuse the busy cache and falls back to a fresh
    /// `Workspace` for that call, paying its allocations.  Hot loops
    /// should keep calling [`Selector::select_into`] with run-owned
    /// scratch and a reused output buffer — or better, drive selection
    /// through [`crate::engine::SelectionEngine`], which owns both.
    fn select(&mut self, view: &BatchView<'_>, r: usize) -> Vec<usize> {
        thread_local! {
            static ONE_SHOT_WS: std::cell::RefCell<Workspace> =
                std::cell::RefCell::new(Workspace::new());
        }
        let mut out = Vec::new();
        ONE_SHOT_WS.with(|cell| match cell.try_borrow_mut() {
            Ok(mut ws) => self.select_into(view, r, &mut ws, &mut out),
            Err(_) => {
                let mut ws = Workspace::default();
                self.select_into(view, r, &mut ws, &mut out);
            }
        });
        out
    }

    /// Whether this selector may be wrapped by the sharded coordinator
    /// (`coordinator::shard`), which runs one instance per shard and
    /// folds the per-shard winners with a second-stage **feature-space
    /// MaxVol** (`coordinator::merge`).  That reduction preserves the
    /// criterion of subspace/volume-based selectors, so only those opt
    /// in (MaxVol, CrossMaxVol, GRAFT).  Defaults to false: for score-
    /// or RNG-based methods the MaxVol merge would silently rewrite the
    /// selection criterion, and per-shard instances fragment any
    /// cross-batch state (e.g. `forget`'s per-row history).
    fn shardable(&self) -> bool {
        false
    }

    /// Post-merge dynamic-rank hook for the coordinator's gradient-aware
    /// merge (`coordinator::merge`, `MergePolicy::Grad`).  After the
    /// second-stage MaxVol tournament fixes the merged pivot order, the
    /// coordinator computes the prefix projection errors of the *global*
    /// batch-mean gradient ĝ over that order and asks its single
    /// **rank-authority** instance for R*; the merged selection is then
    /// truncated to the returned rank.  Exactly one authority exists per
    /// coordinator, so the ε/budget accounting is shard- and worker-count
    /// independent.
    ///
    /// The default `None` keeps the full merged budget — correct for pure
    /// volume criteria (MaxVol, CrossMaxVol) whose selection has no
    /// dynamic-rank stage.  GRAFT overrides this with its
    /// `BudgetedRankPolicy` decision, restoring the paper's criterion on
    /// the sharded path.
    fn post_merge_rank(
        &mut self,
        errors: &[f64],
        r_budget: usize,
        rmax: usize,
    ) -> Option<RankDecision> {
        let _ = (errors, r_budget, rmax);
        None
    }

    /// Snapshot of this selector's dynamic-rank accounting (`None` for
    /// methods without one).  For sharded/pooled execution the coordinator
    /// forwards its rank authority's stats, which is how the trainer reads
    /// `mean_rank` from one accumulator at any shard/worker count.
    fn rank_stats(&self) -> Option<RankStats> {
        None
    }
}

/// Pad `out` up to `r.min(k)` indices with the highest-loss unselected
/// rows — the shared budget top-up rule (NaN-safe via `total_cmp`, index
/// tie-break for determinism).  Allocation-free: masks and candidate lists
/// come from `ws`.
pub(crate) fn top_up_by_loss(
    view: &BatchView<'_>,
    r: usize,
    ws: &mut Workspace,
    out: &mut Vec<usize>,
) {
    let k = view.k();
    let want = r.min(k);
    if out.len() >= want {
        return;
    }
    let taken = &mut ws.sel_taken;
    taken.clear();
    taken.resize(k, false);
    for &i in out.iter() {
        taken[i] = true;
    }
    let rest = &mut ws.sel_rest;
    rest.clear();
    rest.extend((0..k).filter(|&i| !taken[i]));
    rest.sort_unstable_by(|&a, &b| view.losses[b].total_cmp(&view.losses[a]).then(a.cmp(&b)));
    let need = want - out.len();
    out.extend(rest.iter().copied().take(need));
}

/// Construct a selector by name (CLI / config entry point).
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn Selector>> {
    Some(match name {
        "maxvol" | "fast-maxvol" => Box::new(maxvol::FastMaxVol),
        "cross-maxvol" => Box::new(cross_maxvol::CrossMaxVol::default()),
        "random" => Box::new(random::RandomSelector::new(seed)),
        "craig" => Box::new(craig::Craig),
        "gradmatch" => Box::new(gradmatch::GradMatch::default()),
        "glister" => Box::new(glister::Glister::default()),
        "drop" => Box::new(drop_::Drop::new(seed)),
        "el2n" => Box::new(el2n::El2n),
        "hybrid" => Box::new(hybrid::Hybrid::new(seed, hybrid::DEFAULT_EXPLORE)),
        "badge" => Box::new(badge::Badge::new(seed)),
        "moderate" => Box::new(moderate::Moderate),
        "forget" => Box::new(forget::Forget::default()),
        _ => return None,
    })
}

#[cfg(test)]
pub(crate) mod testsupport {
    use super::*;
    use crate::rng::Rng;

    pub struct Owned {
        pub features: Mat,
        pub grads: Mat,
        pub losses: Vec<f64>,
        pub labels: Vec<i32>,
        pub preds: Vec<i32>,
        pub classes: usize,
        pub row_ids: Vec<usize>,
    }

    impl Owned {
        pub fn view(&self) -> BatchView<'_> {
            BatchView {
                features: &self.features,
                grads: &self.grads,
                losses: &self.losses,
                labels: &self.labels,
                preds: &self.preds,
                classes: self.classes,
                row_ids: &self.row_ids,
            }
        }
    }

    /// Random batch view with class structure.
    pub fn random_view(k: usize, r: usize, e: usize, classes: usize, seed: u64) -> Owned {
        let mut rng = Rng::new(seed);
        let features = Mat::from_fn(k, r, |_, _| rng.normal());
        let grads = Mat::from_fn(k, e, |_, _| rng.normal());
        let losses: Vec<f64> = (0..k).map(|_| rng.uniform() * 2.0).collect();
        let labels: Vec<i32> = (0..k).map(|i| (i % classes) as i32).collect();
        let preds: Vec<i32> = labels
            .iter()
            .map(|&y| if rng.uniform() < 0.7 { y } else { rng.below(classes) as i32 })
            .collect();
        Owned {
            features,
            grads,
            losses,
            labels,
            preds,
            classes,
            row_ids: (0..k).collect(),
        }
    }

    /// Contract every selector must satisfy: right size, unique, in range,
    /// deterministic given identical state.
    pub fn check_selector(mk: impl Fn() -> Box<dyn Selector>) {
        let owned = random_view(64, 8, 16, 4, 42);
        for r in [1usize, 4, 8, 32] {
            let sel = mk().select(&owned.view(), r);
            assert_eq!(sel.len(), r, "size for r={r}");
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), r, "uniqueness for r={r}");
            assert!(s.iter().all(|&i| i < 64), "range for r={r}");
        }
        let a = mk().select(&owned.view(), 8);
        let b = mk().select(&owned.view(), 8);
        assert_eq!(a, b, "determinism");
    }
}

//! Forget-score pre-selection (Toneva et al. 2018): count per-sample
//! "forgetting events" (correct → incorrect transitions) across selection
//! rounds; prefer the most-forgotten (hardest) samples.  Stateful: the
//! coordinator feeds every batch through `observe` implicitly via
//! `select`, keyed by global row ids.

use std::collections::HashMap;

use super::{BatchView, Selector};
use crate::linalg::Workspace;

#[derive(Default)]
pub struct Forget {
    /// row id → (was_correct_last_time, forget_count, seen_count)
    history: HashMap<usize, (bool, u32, u32)>,
}

impl Forget {
    pub fn forget_count(&self, row: usize) -> u32 {
        self.history.get(&row).map(|&(_, f, _)| f).unwrap_or(0)
    }
}

impl Selector for Forget {
    fn name(&self) -> &'static str {
        "forget"
    }

    fn select_into(
        &mut self,
        view: &BatchView<'_>,
        r: usize,
        ws: &mut Workspace,
        out: &mut Vec<usize>,
    ) {
        let _ = ws;
        let k = view.k();
        // Update forgetting statistics.
        for i in 0..k {
            let id = view.row_ids[i];
            let correct = view.preds[i] == view.labels[i];
            let entry = self.history.entry(id).or_insert((correct, 0, 0));
            if entry.0 && !correct {
                entry.1 += 1; // forgetting event
            }
            entry.0 = correct;
            entry.2 += 1;
        }
        // Rank: most forgotten first; tie-break on loss (harder first),
        // then index for determinism.
        out.clear();
        out.extend(0..k);
        out.sort_unstable_by(|&a, &b| {
            let fa = self.forget_count(view.row_ids[a]);
            let fb = self.forget_count(view.row_ids[b]);
            fb.cmp(&fa)
                .then(view.losses[b].total_cmp(&view.losses[a]))
                .then(a.cmp(&b))
        });
        out.truncate(r.min(k));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::selection::BatchView;

    fn view_with_preds<'a>(
        feats: &'a Mat,
        grads: &'a Mat,
        losses: &'a [f64],
        labels: &'a [i32],
        preds: &'a [i32],
        ids: &'a [usize],
    ) -> BatchView<'a> {
        BatchView { features: feats, grads, losses, labels, preds, classes: 2, row_ids: ids }
    }

    #[test]
    fn counts_forgetting_events() {
        let k = 4;
        let feats = Mat::zeros(k, 2);
        let grads = Mat::zeros(k, 2);
        let losses = vec![0.1, 0.2, 0.3, 0.4];
        let labels = vec![1, 1, 1, 1];
        let ids: Vec<usize> = vec![10, 11, 12, 13];
        let mut f = Forget::default();

        // Round 1: all correct.
        let preds = vec![1, 1, 1, 1];
        f.select(&view_with_preds(&feats, &grads, &losses, &labels, &preds, &ids), 2);
        // Round 2: row 11 forgotten.
        let preds = vec![1, 0, 1, 1];
        let sel = f.select(&view_with_preds(&feats, &grads, &losses, &labels, &preds, &ids), 1);
        assert_eq!(f.forget_count(11), 1);
        assert_eq!(sel, vec![1]); // most-forgotten row selected first
    }

    #[test]
    fn tie_breaks_on_loss() {
        let k = 3;
        let feats = Mat::zeros(k, 2);
        let grads = Mat::zeros(k, 2);
        let losses = vec![0.1, 0.9, 0.5];
        let labels = vec![0, 0, 0];
        let preds = vec![0, 0, 0];
        let ids: Vec<usize> = vec![0, 1, 2];
        let mut f = Forget::default();
        let sel = f.select(&view_with_preds(&feats, &grads, &losses, &labels, &preds, &ids), 2);
        assert_eq!(sel, vec![1, 2]); // no forgetting yet → by loss desc
    }
}

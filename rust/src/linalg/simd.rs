//! Portable 4-lane f64 microkernels for the hot-path inner loops.
//!
//! The blocked kernels in [`super::mat`], the fused MGS step in
//! [`super::qr`], and the MaxVol elimination loops
//! ([`crate::selection::maxvol`], [`super::incremental`]) all bottom out
//! in two memory-bound primitives: an elementwise `y += α·x` update and a
//! reduction `Σ xᵢ·yᵢ`.  Written as plain `zip` loops the compiler often
//! keeps them scalar (the loop-carried dependence of the dot, the
//! aliasing analysis of the axpy); unrolled into four explicit lanes they
//! vectorise on every target without `std::arch` or nightly SIMD.
//!
//! Exactness contract, relied on by the bit-identity pins across
//! execution shapes:
//!
//! * [`axpy_lanes`] / [`axpy2_lanes`] are **bit-exact** vs. the scalar
//!   loop: each output element still receives exactly one
//!   `yᵢ += α·xᵢ` — the unroll changes no per-element operation order.
//! * [`dot_lanes`] **reassociates** the reduction (four independent
//!   accumulators, pairwise-combined, plus a scalar tail), so results
//!   differ from a sequential sum by the usual O(n·ε) noise.  Every
//!   cross-shape bit-identity pin in the crate compares paths that share
//!   this same kernel, so the reassociation is invisible to them; the
//!   cross-kernel property tests (`tests/linalg_kernels.rs`) are
//!   tolerance-based.

/// Lane width of the portable kernels (4 × f64 = one AVX2 register, two
/// NEON registers).
pub const LANES: usize = 4;

/// Four-accumulator dot product over `min(|a|, |b|)` elements.
///
/// Combination order is fixed — `(acc0 + acc1) + (acc2 + acc3) + tail` —
/// so the result is deterministic for given inputs (just not
/// sequentially associated).
#[inline]
pub fn dot_lanes(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut acc = [0.0f64; LANES];
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `y[i] += alpha * x[i]` over `min(|y|, |x|)` elements, four lanes per
/// iteration.  Bit-exact vs. the scalar loop (elementwise, no
/// reassociation).
#[inline]
pub fn axpy_lanes(y: &mut [f64], alpha: f64, x: &[f64]) {
    let n = y.len().min(x.len());
    let (y, x) = (&mut y[..n], &x[..n]);
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (cy, cx) in (&mut yc).zip(&mut xc) {
        cy[0] += alpha * cx[0];
        cy[1] += alpha * cx[1];
        cy[2] += alpha * cx[2];
        cy[3] += alpha * cx[3];
    }
    for (py, &px) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *py += alpha * px;
    }
}

/// Paired-row axpy for the register-tiled GEMM panel:
/// `r0[i] += x0 * b[i]; r1[i] += x1 * b[i]` — each streamed `b` element
/// is used twice per load.  Bit-exact vs. the scalar pair loop.
#[inline]
pub fn axpy2_lanes(r0: &mut [f64], r1: &mut [f64], x0: f64, x1: f64, b: &[f64]) {
    let n = r0.len().min(r1.len()).min(b.len());
    let (r0, r1, b) = (&mut r0[..n], &mut r1[..n], &b[..n]);
    let mut c0 = r0.chunks_exact_mut(LANES);
    let mut c1 = r1.chunks_exact_mut(LANES);
    let mut cb = b.chunks_exact(LANES);
    for ((o0, o1), v) in (&mut c0).zip(&mut c1).zip(&mut cb) {
        o0[0] += x0 * v[0];
        o1[0] += x1 * v[0];
        o0[1] += x0 * v[1];
        o1[1] += x1 * v[1];
        o0[2] += x0 * v[2];
        o1[2] += x1 * v[2];
        o0[3] += x0 * v[3];
        o1[3] += x1 * v[3];
    }
    let (t0, t1, tb) = (c0.into_remainder(), c1.into_remainder(), cb.remainder());
    for ((o0, o1), &bv) in t0.iter_mut().zip(t1.iter_mut()).zip(tb) {
        *o0 += x0 * bv;
        *o1 += x1 * bv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Awkward lengths around the 4-lane boundary, shared with the
    /// integration parity tests in `tests/linalg_kernels.rs`.
    const SIZES: [usize; 6] = [1, 3, 5, 7, 63, 65];

    fn randv(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn axpy_lanes_is_bit_exact_at_lane_remainders() {
        for (si, &n) in SIZES.iter().enumerate() {
            let x = randv(n, si as u64 + 1);
            let mut got = randv(n, si as u64 + 50);
            let mut want = got.clone();
            let alpha = -0.37;
            axpy_lanes(&mut got, alpha, &x);
            for (w, &xv) in want.iter_mut().zip(&x) {
                *w += alpha * xv;
            }
            assert_eq!(got, want, "axpy_lanes differs from scalar at n={n}");
        }
    }

    #[test]
    fn axpy2_lanes_is_bit_exact_at_lane_remainders() {
        for (si, &n) in SIZES.iter().enumerate() {
            let b = randv(n, si as u64 + 101);
            let mut g0 = randv(n, si as u64 + 150);
            let mut g1 = randv(n, si as u64 + 200);
            let (mut w0, mut w1) = (g0.clone(), g1.clone());
            let (x0, x1) = (1.25, -0.5);
            axpy2_lanes(&mut g0, &mut g1, x0, x1, &b);
            for ((o0, o1), &bv) in w0.iter_mut().zip(w1.iter_mut()).zip(&b) {
                *o0 += x0 * bv;
                *o1 += x1 * bv;
            }
            assert_eq!(g0, w0, "axpy2_lanes row 0 differs at n={n}");
            assert_eq!(g1, w1, "axpy2_lanes row 1 differs at n={n}");
        }
    }

    #[test]
    fn dot_lanes_matches_sequential_within_tolerance() {
        for (si, &n) in SIZES.iter().enumerate() {
            let a = randv(n, si as u64 + 301);
            let b = randv(n, si as u64 + 400);
            let got = dot_lanes(&a, &b);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                "dot_lanes {got} vs sequential {want} at n={n}"
            );
        }
    }

    #[test]
    fn dot_lanes_negation_symmetry_supports_exact_elimination() {
        // The elimination loops rewrite `y -= c·p` as
        // `axpy_lanes(y, -c, p)`; per element `y + (-c)·p == y - c·p`
        // bitwise (IEEE negation is exact), which is what keeps the
        // cached-vs-fresh tournament pins bit-identical.
        let p = randv(65, 7);
        let c = 0.8391;
        let mut a = randv(65, 9);
        let mut b = a.clone();
        axpy_lanes(&mut a, -c, &p);
        for (y, &pv) in b.iter_mut().zip(&p) {
            *y -= c * pv;
        }
        assert_eq!(a, b);
    }
}

//! Principal angles between subspaces — Table 4's "subspace similarity"
//! metric: sim(V₁, V₂) = Σᵢ cos²(θᵢ), computed from the singular values of
//! Q₁ᵀ Q₂ (Björck-Golub).

use super::mat::Mat;
use super::qr::orth;
use super::svd::svd;

/// Cosines of the principal angles between col(A) and col(B), descending.
pub fn principal_angle_cosines(a: &Mat, b: &Mat) -> Vec<f64> {
    let qa = orth(a);
    let qb = orth(b);
    if qa.cols() == 0 || qb.cols() == 0 {
        return Vec::new();
    }
    let m = qa.transpose().matmul(&qb);
    svd(&m).s.into_iter().map(|s| s.clamp(0.0, 1.0)).collect()
}

/// Table 4 similarity: Σᵢ cos²(θᵢ), normalised by k = min(dim A, dim B)
/// when `normalise` (the paper reports the raw sum on equal-rank bases).
pub fn subspace_similarity(a: &Mat, b: &Mat) -> f64 {
    principal_angle_cosines(a, b).iter().map(|c| c * c).sum()
}

/// Normalised variant in [0, 1]: sum of cos² over min rank.
pub fn subspace_similarity_normalised(a: &Mat, b: &Mat) -> f64 {
    let cs = principal_angle_cosines(a, b);
    if cs.is_empty() {
        return 0.0;
    }
    cs.iter().map(|c| c * c).sum::<f64>() / cs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn identical_subspaces() {
        let a = randmat(20, 4, 1);
        // Same span, different basis.
        let mix = randmat(4, 4, 2);
        let b = a.matmul(&mix);
        let sim = subspace_similarity(&a, &b);
        assert!((sim - 4.0).abs() < 1e-8, "{sim}");
    }

    #[test]
    fn orthogonal_subspaces() {
        let mut a = Mat::zeros(6, 2);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        let mut b = Mat::zeros(6, 2);
        b[(2, 0)] = 1.0;
        b[(3, 1)] = 1.0;
        assert!(subspace_similarity(&a, &b) < 1e-12);
    }

    #[test]
    fn partial_overlap() {
        let mut a = Mat::zeros(6, 2);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        let mut b = Mat::zeros(6, 2);
        b[(0, 0)] = 1.0; // shares e₀
        b[(3, 1)] = 1.0;
        let sim = subspace_similarity(&a, &b);
        assert!((sim - 1.0).abs() < 1e-10, "{sim}");
    }

    #[test]
    fn normalised_bounds() {
        let a = randmat(30, 5, 3);
        let b = randmat(30, 5, 4);
        let s = subspace_similarity_normalised(&a, &b);
        assert!((0.0..=1.0 + 1e-12).contains(&s));
    }

    #[test]
    fn symmetry() {
        let a = randmat(25, 3, 5);
        let b = randmat(25, 4, 6);
        let s1 = subspace_similarity(&a, &b);
        let s2 = subspace_similarity(&b, &a);
        assert!((s1 - s2).abs() < 1e-9);
    }
}

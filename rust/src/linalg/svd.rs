//! Thin SVD via one-sided Jacobi rotations (Hestenes method).
//!
//! No LAPACK anywhere in the stack (DESIGN.md §1), so the Rust-side feature
//! extractors and principal-angle computations use this implementation.
//! One-sided Jacobi is simple, numerically robust, and plenty fast at the
//! K×R scales GRAFT touches (≤ a few hundred columns).

use super::mat::{dot, Mat};

pub struct Svd {
    /// Left singular vectors, m×k (k = min(m, n)), importance-ordered.
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors, n×k (columns).
    pub v: Mat,
}

/// Thin SVD of `a` (m×n). Works for any aspect ratio (transposes internally
/// so the Jacobi sweep runs on the short side).
pub fn svd(a: &Mat) -> Svd {
    if a.rows() < a.cols() {
        // A = U S Vᵀ  ⇔  Aᵀ = V S Uᵀ
        let t = svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    one_sided_jacobi(a)
}

fn one_sided_jacobi(a: &Mat) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    // Work on columns of W = A; rotate pairs until all are orthogonal.
    let mut w = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let wp = w.col(p);
                let wq = w.col(q);
                let alpha = dot(&wp, &wp);
                let beta = dot(&wq, &wq);
                let gamma = dot(&wp, &wq);
                if alpha * beta <= 0.0 {
                    continue;
                }
                let denom = (alpha * beta).sqrt();
                if denom <= 0.0 {
                    continue;
                }
                off = off.max((gamma / denom).abs());
                if gamma.abs() <= eps * denom {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) off-diagonal of WᵀW.
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wip = w[(i, p)];
                    let wiq = w[(i, q)];
                    w[(i, p)] = c * wip - s * wiq;
                    w[(i, q)] = s * wip + c * wiq;
                }
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
        if off < 1e-13 {
            break;
        }
    }
    // Extract singular values = column norms; U = W / s.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n).map(|j| dot(&w.col(j), &w.col(j)).sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].total_cmp(&norms[i]));
    let mut u = Mat::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut vv = Mat::zeros(n, n);
    for (jj, &j) in order.iter().enumerate() {
        let nrm = norms[j];
        s.push(nrm);
        if nrm > 1e-300 {
            let col: Vec<f64> = w.col(j).iter().map(|x| x / nrm).collect();
            u.set_col(jj, &col);
        }
        vv.set_col(jj, &v.col(j));
    }
    Svd { u, s, v: vv }
}

/// Truncated SVD features: top-r left singular vectors scaled or not.
pub fn truncated_u(a: &Mat, r: usize) -> Mat {
    let d = svd(a);
    let idx: Vec<usize> = (0..r.min(d.u.cols())).collect();
    d.u.take_cols(&idx)
}

/// Spectral norm (largest singular value) via a few power iterations —
/// cheaper than a full SVD when only σ₁ is needed.
pub fn spectral_norm(a: &Mat, iters: usize, seed: u64) -> f64 {
    use crate::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut x: Vec<f64> = (0..a.cols()).map(|_| rng.normal()).collect();
    let mut sigma = 0.0;
    for _ in 0..iters.max(1) {
        let y = a.matvec(&x);
        let mut z = a.tmatvec(&y);
        let n = super::mat::normalize(&mut z);
        sigma = n.sqrt();
        x = z;
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    fn reconstruct(d: &Svd) -> Mat {
        let k = d.s.len();
        let mut us = d.u.clone();
        for j in 0..k {
            let col: Vec<f64> = us.col(j).iter().map(|x| x * d.s[j]).collect();
            us.set_col(j, &col);
        }
        us.matmul(&d.v.transpose())
    }

    #[test]
    fn svd_reconstructs_tall() {
        let a = randmat(12, 5, 1);
        let d = svd(&a);
        assert!(reconstruct(&d).sub(&a).max_abs() < 1e-9);
    }

    #[test]
    fn svd_reconstructs_wide() {
        let a = randmat(4, 9, 2);
        let d = svd(&a);
        assert!(reconstruct(&d).sub(&a).max_abs() < 1e-9);
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let a = randmat(10, 6, 3);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn u_v_orthonormal() {
        let a = randmat(11, 5, 4);
        let d = svd(&a);
        assert!(d.u.gram().sub(&Mat::eye(5)).max_abs() < 1e-10);
        assert!(d.v.gram().sub(&Mat::eye(5)).max_abs() < 1e-10);
    }

    #[test]
    fn known_diagonal() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (4 - i) as f64 } else { 0.0 });
        let d = svd(&a);
        for (i, &s) in d.s.iter().enumerate() {
            assert!((s - (4 - i) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn frobenius_identity() {
        let a = randmat(8, 8, 5);
        let d = svd(&a);
        let f2: f64 = d.s.iter().map(|s| s * s).sum();
        assert!((f2.sqrt() - a.frob_norm()).abs() < 1e-9);
    }

    #[test]
    fn spectral_norm_close_to_s1() {
        let a = randmat(20, 10, 6);
        let d = svd(&a);
        let sn = spectral_norm(&a, 50, 7);
        assert!((sn - d.s[0]).abs() / d.s[0] < 1e-6);
    }

    #[test]
    fn low_rank_matrix() {
        let u = randmat(16, 2, 8);
        let v = randmat(2, 10, 9);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[2] < 1e-9 * d.s[0]);
    }
}

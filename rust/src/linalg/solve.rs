//! Direct solvers: Cholesky (SPD), LU with partial pivoting, least squares
//! and pseudo-inverse — everything the baselines (GradMatch OMP, GLISTER
//! taylor steps, curve fitting) need, LAPACK-free.

use super::mat::Mat;
use super::svd::svd;

/// Cholesky factor L (lower) of an SPD matrix; returns None if not PD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward) then Lᵀ x = y (backward).
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve A x = b via LU with partial pivoting; None if singular.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    assert_eq!(n, b.len());
    let mut m = a.clone();
    let mut x: Vec<f64> = b.to_vec();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        let (mut pmax, mut vmax) = (k, m[(piv[k], k)].abs());
        for i in (k + 1)..n {
            let v = m[(piv[i], k)].abs();
            if v > vmax {
                pmax = i;
                vmax = v;
            }
        }
        if vmax < 1e-300 {
            return None;
        }
        piv.swap(k, pmax);
        let pk = piv[k];
        for i in (k + 1)..n {
            let pi = piv[i];
            let f = m[(pi, k)] / m[(pk, k)];
            m[(pi, k)] = f;
            for j in (k + 1)..n {
                let v = m[(pk, j)];
                m[(pi, j)] -= f * v;
            }
            x[pi] -= f * x[pk];
        }
    }
    let mut out = vec![0.0; n];
    for k in (0..n).rev() {
        let pk = piv[k];
        let mut s = x[pk];
        for j in (k + 1)..n {
            s -= m[(pk, j)] * out[j];
        }
        out[k] = s / m[(pk, k)];
    }
    Some(out)
}

/// Minimum-norm least squares via SVD: x = V Σ⁺ Uᵀ b.
pub fn lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    let d = svd(a);
    let cutoff = d.s.first().copied().unwrap_or(0.0) * 1e-12;
    let utb = d.u.tmatvec(b);
    let coef: Vec<f64> = utb
        .iter()
        .zip(&d.s)
        .map(|(&c, &s)| if s > cutoff { c / s } else { 0.0 })
        .collect();
    d.v.matvec(&coef)
}

/// Moore-Penrose pseudo-inverse via SVD.
pub fn pinv(a: &Mat) -> Mat {
    let d = svd(a);
    let cutoff = d.s.first().copied().unwrap_or(0.0) * 1e-12;
    let k = d.s.len();
    let mut vs = d.v.clone();
    for j in 0..k {
        let inv = if d.s[j] > cutoff { 1.0 / d.s[j] } else { 0.0 };
        let col: Vec<f64> = vs.col(j).iter().map(|x| x * inv).collect();
        vs.set_col(j, &col);
    }
    vs.matmul(&d.u.transpose())
}

/// Determinant via LU (for small matrices — MaxVol volume checks).
pub fn det(a: &Mat) -> f64 {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut m = a.clone();
    let mut sign = 1.0;
    let mut d = 1.0;
    for k in 0..n {
        let mut pmax = k;
        for i in (k + 1)..n {
            if m[(i, k)].abs() > m[(pmax, k)].abs() {
                pmax = i;
            }
        }
        if m[(pmax, k)].abs() < 1e-300 {
            return 0.0;
        }
        if pmax != k {
            for j in 0..n {
                let t = m[(k, j)];
                m[(k, j)] = m[(pmax, j)];
                m[(pmax, j)] = t;
            }
            sign = -sign;
        }
        d *= m[(k, k)];
        for i in (k + 1)..n {
            let f = m[(i, k)] / m[(k, k)];
            for j in k..n {
                let v = m[(k, j)];
                m[(i, j)] -= f * v;
            }
        }
    }
    sign * d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn cholesky_solve_spd() {
        let a = randmat(8, 5, 1);
        let spd = a.gram(); // 5x5 SPD (w.h.p.)
        let l = cholesky(&spd).expect("PD");
        let xtrue: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let b = spd.matvec(&xtrue);
        let x = cholesky_solve(&l, &b);
        for (a, b) in x.iter().zip(&xtrue) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn lu_solve_random() {
        let a = randmat(7, 7, 2);
        let xtrue: Vec<f64> = (0..7).map(|i| (i as f64).sin()).collect();
        let b = a.matvec(&xtrue);
        let x = lu_solve(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&xtrue) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn lu_singular_none() {
        let a = Mat::from_fn(3, 3, |i, _| i as f64); // rank 1
        assert!(lu_solve(&a, &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn lstsq_overdetermined() {
        let a = randmat(20, 4, 3);
        let xtrue = vec![1.0, -0.5, 2.0, 0.25];
        let b = a.matvec(&xtrue);
        let x = lstsq(&a, &b);
        for (g, w) in x.iter().zip(&xtrue) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn pinv_properties() {
        let a = randmat(6, 4, 4);
        let p = pinv(&a);
        // A A⁺ A = A
        let apa = a.matmul(&p).matmul(&a);
        assert!(apa.sub(&a).max_abs() < 1e-9);
        // A⁺ A A⁺ = A⁺
        let pap = p.matmul(&a).matmul(&p);
        assert!(pap.sub(&p).max_abs() < 1e-9);
    }

    #[test]
    fn det_known() {
        let a = Mat::from_vec(2, 2, vec![3.0, 1.0, 4.0, 2.0]);
        assert!((det(&a) - 2.0).abs() < 1e-12);
        assert!((det(&Mat::eye(5)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn det_product_rule() {
        let a = randmat(4, 4, 5);
        let b = randmat(4, 4, 6);
        let lhs = det(&a.matmul(&b));
        let rhs = det(&a) * det(&b);
        assert!((lhs - rhs).abs() < 1e-8 * rhs.abs().max(1.0));
    }
}

//! Dense linear-algebra substrate (LAPACK-free; see DESIGN.md §1).

pub mod angles;
pub mod mat;
pub mod qr;
pub mod solve;
pub mod svd;

pub use angles::{principal_angle_cosines, subspace_similarity, subspace_similarity_normalised};
pub use mat::{axpy, dot, norm2, normalize, Mat};
pub use qr::{orth, project_onto_colspace, qr, Qr};
pub use solve::{cholesky, cholesky_solve, det, lstsq, lu_solve, pinv};
pub use svd::{spectral_norm, svd, truncated_u, Svd};

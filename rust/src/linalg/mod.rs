//! Dense linear-algebra substrate (LAPACK-free; see DESIGN.md §1).
//!
//! # Hot-path design (PR 1)
//!
//! The per-step GRAFT selection path — `fast_maxvol` → prefix projection
//! errors → budget top-up — runs once per mini-batch, so it is engineered
//! around two rules:
//!
//! 1. **Zero steady-state allocations.** Every scratch buffer lives in a
//!    reusable [`Workspace`] arena ([`workspace`]): consumers `clear()` and
//!    re-fill, so capacity is retained across batches.  The `_with`/`_into`
//!    variants (`fast_maxvol_with`, `qr_with`, `Selector::select_into` —
//!    whose GRAFT implementation fuses the prefix-projection-error MGS
//!    in-place) are the allocation-free entry points; the original
//!    signatures remain as convenience wrappers.  `tests/alloc_free.rs`
//!    pins this property with a counting global allocator.
//!
//! 2. **Blocked, register-tiled, optionally threaded kernels.**
//!    `Mat::matmul` streams B in `BLOCK_KC × BLOCK_NC` panels
//!    (L2-resident) against register-tiled pairs of output rows;
//!    `Mat::gram` accumulates the upper triangle over contiguous row
//!    suffixes; `Mat::transpose` moves `BLOCK_TILE`² tiles.  Above
//!    `PAR_MIN_FLOPS` fused ops, `matmul`/`gram` fan row panels out over
//!    `std::thread::scope` workers (no thread-pool dependency; scoped
//!    threads may borrow the operands directly).  Thresholds live in
//!    [`mat`] as `pub const`s so benches and future tuning PRs can see
//!    them:
//!
//!    | constant         | value   | meaning                              |
//!    |------------------|---------|--------------------------------------|
//!    | `BLOCK_NC`       | 512     | B-columns per streamed panel (L1)    |
//!    | `BLOCK_KC`       | 256     | inner-dim block (B panel in L2)      |
//!    | `BLOCK_TILE`     | 32      | transpose tile edge                  |
//!    | `PAR_MIN_FLOPS`  | 2²²     | m·k·n above which panels go parallel |
//!
//!    The effective threshold is [`mat::par_min_flops`], overridable via
//!    the `GRAFT_PAR_MIN_FLOPS` env var for bench sweeps (unparseable
//!    values fall back to the constant).  `gram` prices its symmetric
//!    half-work (`m·n·(n+1)/2`) against the same threshold.
//!
//! 3. **Explicit 4-lane inner kernels** ([`simd`]): the innermost loops of
//!    `matmul`/`gram`, the fused MGS step, and the MaxVol elimination
//!    replays all bottom out in `dot_lanes`/`axpy_lanes` — portable
//!    unrolled f64 lanes, axpy bit-exact vs. scalar, dot deterministic
//!    but reassociated (see the module docs for the exactness contract).
//!
//! The scalar reference kernels (`matmul_naive`, `gram_naive`,
//! `fast_maxvol_reference`) are kept as ground truth for the property
//! tests in `tests/linalg_kernels.rs` and the before/after rows in
//! `BENCH_pr1.json` (see `scripts/bench.sh`).

pub mod angles;
pub(crate) mod incremental;
pub mod mat;
pub mod qr;
pub mod simd;
pub mod solve;
pub mod svd;
pub mod workspace;

pub use angles::{principal_angle_cosines, subspace_similarity, subspace_similarity_normalised};
pub use mat::{
    axpy, dot, norm2, normalize, par_min_flops, transpose_into, Mat, BLOCK_KC, BLOCK_NC,
    BLOCK_TILE, PAR_MIN_FLOPS,
};
pub use simd::{axpy2_lanes, axpy_lanes, dot_lanes, LANES};
pub use qr::{orth, project_onto_colspace, qr, qr_with, Qr};
pub use solve::{cholesky, cholesky_solve, det, lstsq, lu_solve, pinv};
pub use svd::{spectral_norm, svd, truncated_u, Svd};
pub use workspace::Workspace;

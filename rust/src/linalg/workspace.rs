//! Reusable scratch arena for the per-batch selection hot path.
//!
//! Every buffer is `clear()`ed and re-`extend`ed / `resize`d by its
//! consumer, so capacity is retained across calls: after a one-batch
//! warm-up, `fast_maxvol_with`, the fused prefix-error kernel inside
//! `GraftSelector::select_into`, `qr_with`, and the `Selector::select_into`
//! implementations perform **zero heap allocations** (asserted by
//! `tests/alloc_free.rs` with a counting global allocator).
//!
//! Fields are grouped by consumer and crate-private: callers outside the
//! crate only ever construct a [`Workspace`] and pass it by `&mut`.

/// Scratch arena threaded through the selection hot path.
///
/// One `Workspace` per worker thread / coordinator loop; it is `Send` so
/// the trainer can move it into the producer thread if selection ever
/// migrates there.
#[derive(Default)]
pub struct Workspace {
    // -- fast_maxvol ------------------------------------------------------
    /// Working copy of the K×R candidate matrix (row-major).
    pub(crate) mv_w: Vec<f64>,
    /// Scaled pivot-row scratch (≤ R).
    pub(crate) mv_prow: Vec<f64>,
    /// Selected-row mask (K).
    pub(crate) mv_taken: Vec<bool>,

    // -- qr_with ----------------------------------------------------------
    /// Column-major copy of the input (n columns of length m), MGS'd in
    /// place.
    pub(crate) qr_cols: Vec<f64>,

    // -- prefix projection errors -----------------------------------------
    /// Column-major E×R selected-gradient matrix, orthonormalised in place.
    pub(crate) pe_g: Vec<f64>,
    /// Normalised batch-mean gradient ĝ (E).
    pub(crate) pe_ghat: Vec<f64>,
    /// Batch-mean gradient ḡ (E).
    pub(crate) pe_gbar: Vec<f64>,
    /// Prefix errors d_r (R).
    pub(crate) pe_err: Vec<f64>,

    // -- numerics health ---------------------------------------------------
    /// Degenerate (|pivot| < 1e-300, clamped) MaxVol pivots seen by this
    /// workspace, monotone over its lifetime.  The engine reads the delta
    /// across a select to detect numerical breakdown (rank-deficient /
    /// duplicated rows) and route it through the typed fault path.
    pub(crate) mv_degenerate: u64,

    // -- selector plumbing -------------------------------------------------
    /// MaxVol pivot order (taken out via `mem::take` around nested calls).
    pub(crate) sel_order: Vec<usize>,
    /// Already-selected mask for budget top-up (K).
    pub(crate) sel_taken: Vec<bool>,
    /// Unselected candidates for budget top-up (≤ K).
    pub(crate) sel_rest: Vec<usize>,

    // -- incremental (streaming) MaxVol ------------------------------------
    /// Eliminated copy of one incoming feature row (R), consumed by
    /// `linalg::incremental::eliminate_row` on the streaming push path.
    pub(crate) st_x: Vec<f64>,
    /// Pivot order scratch for the streaming reservoir tournaments (≤ R),
    /// kept separate from `sel_order` so a snapshot can replay a
    /// tournament without disturbing selector state.
    pub(crate) st_order: Vec<usize>,
}

impl Workspace {
    /// Fresh workspace; buffers grow lazily on first use — warm up by
    /// running one batch through the selection path before a measured
    /// region (what `tests/alloc_free.rs` and the trainer's first refresh
    /// window do).
    pub fn new() -> Self {
        Workspace::default()
    }
}

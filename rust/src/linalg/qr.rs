//! Thin QR via two-pass modified Gram-Schmidt — mirrors the L2/L1 MGS so
//! Rust-side baselines and the AOT kernels share semantics (including the
//! relative dependence threshold for rank-deficient inputs).
//!
//! The factorisation runs on a column-major scratch copy drawn from a
//! [`Workspace`] ([`qr_with`]): every projection is a contiguous dot /
//! axpy instead of a strided `Mat::col` gather, and the per-column Vec
//! allocations of the original implementation are gone.

use super::mat::{dot, transpose_into, Mat};
use super::simd::axpy_lanes;
use super::workspace::Workspace;

/// Result of a rank-revealing thin QR: `a ≈ q · r`, `q` has orthonormal
/// (or zero, where dependent) columns, `rank` counts the nonzero ones.
pub struct Qr {
    pub q: Mat,
    pub r: Mat,
    pub rank: usize,
}

/// Columns whose post-orthogonalisation norm falls below `REL_TOL` times
/// their original norm are treated as dependent (zeroed), matching the L1
/// projection kernel.
pub(crate) const REL_TOL: f64 = 1e-10;

/// One column step of two-pass MGS, shared by [`qr_with`] and the fused
/// prefix-error kernel in `graft`: orthonormalise `v` (length `m`) in
/// place against the `j` already-final columns stored contiguously in
/// `done`, reporting every projection coefficient through `proj` (for R
/// accumulation).  Applies the `REL_TOL` dependence rule: returns
/// `Some(post_norm)` and leaves `v` unit-norm when independent, returns
/// `None` and zero-fills `v` when dependent.  Keeping this in one place
/// guarantees the two consumers can never drift apart numerically.
pub(crate) fn mgs_column_step(
    done: &[f64],
    m: usize,
    j: usize,
    v: &mut [f64],
    mut proj: impl FnMut(usize, f64),
) -> Option<f64> {
    debug_assert_eq!(v.len(), m);
    debug_assert!(done.len() >= j * m);
    let nrm0 = dot(v, v).sqrt();
    for _pass in 0..2 {
        for i in 0..j {
            let qi = &done[i * m..(i + 1) * m];
            let p = dot(qi, v);
            proj(i, p);
            // v -= p·qᵢ as a lane axpy with negated coefficient — IEEE
            // negation is exact, so this is bit-identical to the
            // subtraction loop it replaces.
            axpy_lanes(v, -p, qi);
        }
    }
    let nrm = dot(v, v).sqrt();
    if nrm <= REL_TOL * nrm0.max(1e-300) || nrm0 == 0.0 {
        v.fill(0.0);
        None
    } else {
        let inv = 1.0 / nrm;
        for vt in v.iter_mut() {
            *vt *= inv;
        }
        Some(nrm)
    }
}

/// Two-pass MGS QR. Dependent columns become zero columns of Q (and zero
/// rows of R beyond the diagonal), matching the L1 projection kernel.
pub fn qr(a: &Mat) -> Qr {
    qr_with(a, &mut Workspace::default())
}

/// [`qr`] drawing its column-major scratch from a caller-owned
/// [`Workspace`] — steady-state the only allocations are the returned
/// `q`/`r` matrices themselves.
pub fn qr_with(a: &Mat, ws: &mut Workspace) -> Qr {
    let (m, n) = (a.rows(), a.cols());
    // Column-major working copy: column j occupies cols[j*m..(j+1)*m].
    let cols = &mut ws.qr_cols;
    cols.clear();
    cols.resize(m * n, 0.0);
    transpose_into(m, n, a.data(), cols);
    let mut r = Mat::zeros(n, n);
    let mut rank = 0;
    for j in 0..n {
        // Orthogonalise column j against the already-final columns i < j.
        // Projection coefficients accumulate into R on both passes; the
        // second-pass re-orthogonalisation correction still belongs to
        // r[i][j].
        let (done, rest) = cols.split_at_mut(j * m);
        let v = &mut rest[..m];
        match mgs_column_step(done, m, j, v, |i, p| r[(i, j)] += p) {
            Some(nrm) => {
                r[(j, j)] = nrm;
                rank += 1;
            }
            None => r[(j, j)] = 0.0,
        }
    }
    // cols now holds Qᵀ (n×m row-major) — transpose back into Q.
    let mut q = Mat::zeros(m, n);
    transpose_into(n, m, &ws.qr_cols, q.data_mut());
    Qr { q, r, rank }
}

/// Orthonormal basis of col(A) with exactly `rank` columns (zeros dropped).
pub fn orth(a: &Mat) -> Mat {
    let d = qr(a);
    let keep: Vec<usize> = (0..d.q.cols()).filter(|&j| d.r[(j, j)] != 0.0).collect();
    d.q.take_cols(&keep)
}

/// Projection of vector `g` onto col(A): returns (projection, residual norm²).
pub fn project_onto_colspace(a: &Mat, g: &[f64]) -> (Vec<f64>, f64) {
    let q = orth(a);
    let coeffs = q.tmatvec(g);
    let proj = q.matvec(&coeffs);
    let res: f64 = g.iter().zip(&proj).map(|(x, p)| (x - p) * (x - p)).sum();
    (proj, res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn qr_reconstructs() {
        let a = randmat(20, 6, 1);
        let d = qr(&a);
        let rec = d.q.matmul(&d.r);
        assert!(rec.sub(&a).max_abs() < 1e-10);
        assert_eq!(d.rank, 6);
    }

    #[test]
    fn q_orthonormal() {
        let a = randmat(15, 5, 2);
        let d = qr(&a);
        let gram = d.q.gram();
        assert!(gram.sub(&Mat::eye(5)).max_abs() < 1e-10);
    }

    #[test]
    fn qr_with_reuses_workspace() {
        let mut ws = Workspace::default();
        for seed in 0..3 {
            let a = randmat(12, 4, 100 + seed);
            let d = qr_with(&a, &mut ws);
            assert!(d.q.matmul(&d.r).sub(&a).max_abs() < 1e-10);
        }
    }

    #[test]
    fn rank_deficient_detected() {
        let mut rng = Rng::new(3);
        let col: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let a = Mat::from_fn(12, 4, |i, j| col[i] * (j as f64 + 1.0));
        let d = qr(&a);
        assert_eq!(d.rank, 1);
        let o = orth(&a);
        assert_eq!(o.cols(), 1);
    }

    #[test]
    fn projection_residual() {
        let a = randmat(30, 4, 4);
        // g in the column space => zero residual.
        let coef = vec![1.0, -2.0, 0.5, 3.0];
        let g = a.matvec(&coef);
        let (_, res) = project_onto_colspace(&a, &g);
        assert!(res < 1e-18 * g.iter().map(|x| x * x).sum::<f64>().max(1.0));
        // random g => residual <= |g|^2 and > 0 (30 > 4 dims).
        let mut rng = Rng::new(5);
        let g2: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let (_, res2) = project_onto_colspace(&a, &g2);
        let n2: f64 = g2.iter().map(|x| x * x).sum();
        assert!(res2 > 0.0 && res2 < n2);
    }
}

//! Thin QR via two-pass modified Gram-Schmidt — mirrors the L2/L1 MGS so
//! Rust-side baselines and the AOT kernels share semantics (including the
//! relative dependence threshold for rank-deficient inputs).

use super::mat::{dot, Mat};

/// Result of a rank-revealing thin QR: `a ≈ q · r`, `q` has orthonormal
/// (or zero, where dependent) columns, `rank` counts the nonzero ones.
pub struct Qr {
    pub q: Mat,
    pub r: Mat,
    pub rank: usize,
}

const REL_TOL: f64 = 1e-10;

/// Two-pass MGS QR. Dependent columns become zero columns of Q (and zero
/// rows of R beyond the diagonal), matching the L1 projection kernel.
pub fn qr(a: &Mat) -> Qr {
    let (m, n) = (a.rows(), a.cols());
    let mut q = a.clone();
    let mut r = Mat::zeros(n, n);
    let mut rank = 0;
    for j in 0..n {
        let mut v = q.col(j);
        let nrm0 = dot(&v, &v).sqrt();
        for _pass in 0..2 {
            for i in 0..j {
                let qi = q.col(i);
                let proj = dot(&qi, &v);
                // Accumulate into R only on the first pass target; the
                // re-orthogonalisation correction still belongs to r[i][j].
                r[(i, j)] += proj;
                for t in 0..m {
                    v[t] -= proj * qi[t];
                }
            }
        }
        let nrm = dot(&v, &v).sqrt();
        if nrm <= REL_TOL * nrm0.max(1e-300) || nrm0 == 0.0 {
            r[(j, j)] = 0.0;
            q.set_col(j, &vec![0.0; m]);
        } else {
            r[(j, j)] = nrm;
            let inv = 1.0 / nrm;
            let vn: Vec<f64> = v.iter().map(|x| x * inv).collect();
            q.set_col(j, &vn);
            rank += 1;
        }
    }
    Qr { q, r, rank }
}

/// Orthonormal basis of col(A) with exactly `rank` columns (zeros dropped).
pub fn orth(a: &Mat) -> Mat {
    let d = qr(a);
    let keep: Vec<usize> = (0..d.q.cols()).filter(|&j| d.r[(j, j)] != 0.0).collect();
    d.q.take_cols(&keep)
}

/// Projection of vector `g` onto col(A): returns (projection, residual norm²).
pub fn project_onto_colspace(a: &Mat, g: &[f64]) -> (Vec<f64>, f64) {
    let q = orth(a);
    let coeffs = q.tmatvec(g);
    let proj = q.matvec(&coeffs);
    let res: f64 = g.iter().zip(&proj).map(|(x, p)| (x - p) * (x - p)).sum();
    (proj, res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn qr_reconstructs() {
        let a = randmat(20, 6, 1);
        let d = qr(&a);
        let rec = d.q.matmul(&d.r);
        assert!(rec.sub(&a).max_abs() < 1e-10);
        assert_eq!(d.rank, 6);
    }

    #[test]
    fn q_orthonormal() {
        let a = randmat(15, 5, 2);
        let d = qr(&a);
        let gram = d.q.gram();
        assert!(gram.sub(&Mat::eye(5)).max_abs() < 1e-10);
    }

    #[test]
    fn rank_deficient_detected() {
        let mut rng = Rng::new(3);
        let col: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let a = Mat::from_fn(12, 4, |i, j| col[i] * (j as f64 + 1.0));
        let d = qr(&a);
        assert_eq!(d.rank, 1);
        let o = orth(&a);
        assert_eq!(o.cols(), 1);
    }

    #[test]
    fn projection_residual() {
        let a = randmat(30, 4, 4);
        // g in the column space => zero residual.
        let coef = vec![1.0, -2.0, 0.5, 3.0];
        let g = a.matvec(&coef);
        let (_, res) = project_onto_colspace(&a, &g);
        assert!(res < 1e-18 * g.iter().map(|x| x * x).sum::<f64>().max(1.0));
        // random g => residual <= |g|^2 and > 0 (30 > 4 dims).
        let mut rng = Rng::new(5);
        let g2: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let (_, res2) = project_onto_colspace(&a, &g2);
        let n2: f64 = g2.iter().map(|x| x * x).sum();
        assert!(res2 > 0.0 && res2 < n2);
    }
}

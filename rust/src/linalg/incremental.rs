//! Incremental MaxVol machinery for the streaming reservoir
//! (`coordinator::stream`): a replayable cache of the pivot-elimination
//! trajectory of `fast_maxvol_core`, plus the O(R²) per-row admission test
//! built on it.
//!
//! `fast_maxvol_core` is a greedy elimination: at step `j` it picks the
//! untaken row with the largest |column-`j`| value, then applies the
//! rank-1 update `w[i, j+1..] -= w[i, j] · prow_j` to every row.  The key
//! structural fact this module exploits is that each row's working-value
//! trajectory depends only on *its own* starting values and the shared
//! pivot rows — never on the other competitors.  So once a tournament has
//! fixed the pivots, their per-step values (`pvals`) and scaled
//! elimination rows (`prows`) are a complete, bit-exact description of
//! what any future candidate row would experience in a re-run tournament:
//!
//! * [`replay_pivot_cache`] rebuilds `pvals`/`prows` from the pivot rows
//!   alone (O(R²·R) once per reservoir change), reproducing the exact
//!   clamp-and-divide arithmetic of the full kernel.
//! * [`eliminate_row`] pushes one candidate through the cached trajectory:
//!   if its working value ever *strictly* exceeds the pivot's, the
//!   candidate would win that argmax step and the caller must re-run the
//!   full tournament; otherwise the reservoir's pivot set is provably
//!   unchanged and the candidate can be triaged by loss alone.
//!
//! Ties favour the resident pivot, matching the strict `>` argmax of
//! `fast_maxvol_core` (residents precede an appended candidate in scan
//! order), so "admit ⟺ the full tournament would change" holds exactly —
//! pinned by the property tests below.

/// Degenerate-pivot clamp shared with `fast_maxvol_core`: division uses
/// the clamped value, comparisons use the raw one.
#[inline]
fn clamp_pivot(piv: f64) -> f64 {
    if piv.abs() < 1e-300 {
        if piv >= 0.0 {
            1e-300
        } else {
            -1e-300
        }
    } else {
        piv
    }
}

/// Rebuild the elimination cache from the pivot rows of a finished
/// tournament.
///
/// `pivots` holds the `width` pivot rows (each `rcols` wide, row-major,
/// in pivot order) *as they appear in the original matrix* — i.e. before
/// any elimination.  The replay applies the same rank-1 updates the full
/// kernel would, recording for each step `j`:
///
/// * `pvals[j]` — the pivot's working value at column `j` (pre-clamp;
///   this is what argmax compares against), and
/// * `prows[j]` — the scaled elimination row for columns `j+1..rcols`
///   (post-clamp divide), flattened ragged into `prows` (step `j`
///   contributes `rcols - j - 1` entries).
///
/// Degenerate pivots are clamped locally and **not** counted anywhere:
/// the tournament that produced these pivots already bumped
/// `Workspace::mv_degenerate`, and a replay must not double-count.
/// `work` is caller-owned scratch (capacity retained across calls).
pub(crate) fn replay_pivot_cache(
    pivots: &[f64],
    rcols: usize,
    work: &mut Vec<f64>,
    prows: &mut Vec<f64>,
    pvals: &mut Vec<f64>,
) {
    let width = if rcols == 0 { 0 } else { pivots.len() / rcols };
    debug_assert_eq!(pivots.len(), width * rcols, "pivot buffer must be width×rcols");
    work.clear();
    work.extend_from_slice(pivots);
    prows.clear();
    pvals.clear();
    for j in 0..width {
        let piv = work[j * rcols + j];
        pvals.push(piv);
        let safe = clamp_pivot(piv);
        let tail = rcols - j - 1;
        let base = j * rcols;
        let start = prows.len();
        for t in 0..tail {
            prows.push(work[base + j + 1 + t] / safe);
        }
        // Eliminate the *later* pivot rows exactly as the kernel would;
        // earlier pivots and row j itself are never read again.
        for i in j + 1..width {
            let ib = i * rcols;
            let ci = work[ib + j];
            if ci == 0.0 {
                continue;
            }
            // Same negated-coefficient lane axpy as `fast_maxvol_core`
            // uses — the replay must mirror its arithmetic bit for bit.
            let prow = &prows[start..start + tail];
            super::simd::axpy_lanes(&mut work[ib + j + 1..ib + rcols], -ci, prow);
        }
    }
}

/// Push one candidate row through the cached pivot trajectory, in place.
///
/// `x` is the candidate's raw feature row (`rcols` long); on return it
/// holds the partially-eliminated values.  Returns `Some(j)` at the first
/// step where the candidate's working value **strictly** exceeds the
/// resident pivot's (`|x[j]| > |pvals[j]|`) — the candidate would win
/// that argmax, so the caller must re-run the full tournament with it
/// included.  Returns `None` when every step is survived: the reservoir's
/// pivot set is unchanged by this candidate, bit-for-bit.
///
/// The arithmetic (`x[j+1..] -= x[j] · prow_j`, skipped when
/// `x[j] == 0.0`) mirrors `fast_maxvol_core` exactly, so the values seen
/// here are the values a full re-tournament would compare.
pub(crate) fn eliminate_row(x: &mut [f64], prows: &[f64], pvals: &[f64], rcols: usize) -> Option<usize> {
    debug_assert_eq!(x.len(), rcols, "candidate row must be rcols wide");
    let width = pvals.len();
    let mut off = 0usize;
    for j in 0..width {
        if x[j].abs() > pvals[j].abs() {
            return Some(j);
        }
        let tail = rcols - j - 1;
        let ci = x[j];
        if ci != 0.0 {
            let prow = &prows[off..off + tail];
            super::simd::axpy_lanes(&mut x[j + 1..rcols], -ci, prow);
        }
        off += tail;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Workspace;
    use crate::rng::Rng;
    use crate::selection::maxvol::fast_maxvol_core;

    fn random_flat(k: usize, rcols: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..k * rcols).map(|_| rng.normal()).collect()
    }

    /// Run the full tournament and build the cache from its pivot rows.
    fn cache_for(
        data: &[f64],
        k: usize,
        rcols: usize,
        ws: &mut Workspace,
    ) -> (Vec<usize>, Vec<f64>, Vec<f64>) {
        let mut order = Vec::new();
        fast_maxvol_core(data, k, rcols, rcols.min(k), ws, &mut order);
        let mut flat = Vec::new();
        for &i in &order {
            flat.extend_from_slice(&data[i * rcols..(i + 1) * rcols]);
        }
        let (mut work, mut prows, mut pvals) = (Vec::new(), Vec::new(), Vec::new());
        replay_pivot_cache(&flat, rcols, &mut work, &mut prows, &mut pvals);
        (order, prows, pvals)
    }

    #[test]
    fn resident_non_pivots_never_admit() {
        // Every row that lost the tournament must survive the cached
        // trajectory without ever beating a pivot — otherwise skipping
        // the re-tournament for such rows would be unsound.
        for seed in 0..6u64 {
            let (k, rcols) = (24usize, 6usize);
            let data = random_flat(k, rcols, 100 + seed);
            let mut ws = Workspace::default();
            let (order, prows, pvals) = cache_for(&data, k, rcols, &mut ws);
            for i in 0..k {
                if order.contains(&i) {
                    continue;
                }
                let mut x = data[i * rcols..(i + 1) * rcols].to_vec();
                assert_eq!(
                    eliminate_row(&mut x, &prows, &pvals, rcols),
                    None,
                    "seed {seed}: losing row {i} claimed an admit"
                );
            }
        }
    }

    #[test]
    fn admit_iff_full_tournament_includes_candidate() {
        // The whole point of the cache: eliminate_row says Some ⟺ a full
        // re-tournament over reservoir+candidate picks the candidate.
        for seed in 0..10u64 {
            let (k, rcols) = (20usize, 5usize);
            let data = random_flat(k, rcols, 200 + seed);
            let mut ws = Workspace::default();
            let (_, prows, pvals) = cache_for(&data, k, rcols, &mut ws);
            let mut rng = Rng::new(900 + seed);
            for trial in 0..8 {
                // Mix of fresh rows and amplified copies of resident rows
                // (the latter usually trip an admit, exercising both arms).
                let cand: Vec<f64> = if trial % 2 == 0 {
                    (0..rcols).map(|_| rng.normal()).collect()
                } else {
                    let src = rng.below(k);
                    data[src * rcols..(src + 1) * rcols].iter().map(|v| v * 3.0).collect()
                };
                let mut x = cand.clone();
                let admit = eliminate_row(&mut x, &prows, &pvals, rcols).is_some();
                let mut ext = data.clone();
                ext.extend_from_slice(&cand);
                let mut order = Vec::new();
                fast_maxvol_core(&ext, k + 1, rcols, rcols, &mut ws, &mut order);
                let in_tournament = order.contains(&k);
                assert_eq!(
                    admit, in_tournament,
                    "seed {seed} trial {trial}: admit={admit} but tournament={in_tournament}"
                );
            }
        }
    }

    #[test]
    fn exact_tie_favours_resident_pivot() {
        // A candidate identical to a pivot row ties every argmax; the
        // strict > comparison must keep the resident (no admit), matching
        // the kernel's earliest-index tie-break for an appended candidate.
        let (k, rcols) = (16usize, 4usize);
        let data = random_flat(k, rcols, 77);
        let mut ws = Workspace::default();
        let (order, prows, pvals) = cache_for(&data, k, rcols, &mut ws);
        let p0 = order[0];
        let mut x = data[p0 * rcols..(p0 + 1) * rcols].to_vec();
        assert_eq!(eliminate_row(&mut x, &prows, &pvals, rcols), None);
    }

    #[test]
    fn degenerate_pivots_clamp_without_counting() {
        // Rank-deficient pivot set: the replay must clamp like the kernel
        // but leave the workspace's degeneracy counter untouched.
        let rcols = 3usize;
        // Two identical rows: the second pivot's working value collapses.
        let pivots = vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0, 0.5, 0.1, 0.2];
        let (mut work, mut prows, mut pvals) = (Vec::new(), Vec::new(), Vec::new());
        replay_pivot_cache(&pivots, rcols, &mut work, &mut prows, &mut pvals);
        assert_eq!(pvals.len(), 3);
        assert_eq!(pvals[1], 0.0, "collapsed pivot recorded pre-clamp");
        assert!(prows.iter().all(|v| v.is_finite()), "clamped divide stays finite");
    }
}
